"""Model zoo: one unified API over the 10 assigned architectures.

Model protocol
  init(key) -> params
  param_specs() -> pytree of logical-axis tuples (mirrors params)
  loss_fn(params, batch, rules) -> (loss, metrics)          [train_4k]
  prefill(params, batch, rules) -> (last_logits, caches)    [prefill_32k]
  decode_step(params, caches, tokens, pos, rules)
      -> (logits, caches)                                   [decode_* cells]
  init_cache(batch, seq_len) / cache_specs() for serving state.

Embedding tables are vocab-sharded ("vocab" -> model axis); tied models reuse
the table for logits (local matmul on the vocab shard). Loss keeps logits
vocab-sharded and masks padded vocab rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.common import (
    make_embedding, embed_tokens, make_norm_params, apply_norm, dense_init,
    sinusoidal_positions, dtype_of,
)
from repro.models.mamba2 import (
    init_mamba, MAMBA_SPECS, apply_mamba, decode_mamba, init_mamba_cache,
    mamba_dims,
)
from repro.models.xlstm import (
    init_mlstm, init_slstm, MLSTM_SPECS, SLSTM_SPECS, apply_mlstm,
    apply_slstm, decode_mlstm, decode_slstm, mlstm_state0, slstm_state0,
)

EMB_SPECS = {"tok": ("vocab", "w_embed")}
WHISPER_ENC_LEN = 1500      # standard whisper frame count (30 s @ 50 Hz)


def softmax_xent(cfg, logits, targets, rules):
    """logits: (B,S,Vp) f32 (kept vocab-sharded); targets: (B,S), -1 = masked."""
    logits = rules.constrain(logits, "batch", "seq", "act_vocab")
    if cfg.padded_vocab != cfg.vocab_size:
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(vocab_ok, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(
        logits, jnp.maximum(targets, 0)[..., None], axis=-1)[..., 0]
    valid = (targets >= 0).astype(jnp.float32)
    loss = jnp.sum((lse - tgt) * valid) / jnp.maximum(valid.sum(), 1.0)
    return loss


def _logits(cfg, params, x, rules):
    table = params["unemb"] if "unemb" in params else params["emb"]["tok"]
    logits = jnp.einsum("bse,ve->bsv", x, table).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return rules.constrain(logits, "batch", "seq", "act_vocab")


class BaseModel:
    def __init__(self, cfg):
        self.cfg = cfg

    def _final(self, params, x):
        return apply_norm(self.cfg, params["ln_f"], x)

    def metrics_from_loss(self, loss):
        return {"loss": loss}


# ---------------------------------------------------------------- decoder LMs
class DecoderLM(BaseModel):
    """Dense / MoE / VLM decoder-only LM (llama, nemotron, gemma, minitron,
    paligemma, arctic, granite)."""

    def init(self, key):
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"emb": make_embedding(cfg, k1),
             "layers": T.stack_init(
                 lambda k: T.init_dense_layer(cfg, k), k2, cfg.num_layers),
             "ln_f": make_norm_params(cfg, k3, cfg.d_model)}
        if not cfg.tie_embeddings:
            p["unemb"] = dense_init(k4, cfg.d_model,
                                    (cfg.padded_vocab, cfg.d_model),
                                    dtype_of(cfg))
        return p

    def param_specs(self):
        cfg = self.cfg
        p = {"emb": EMB_SPECS,
             "layers": T.stacked_specs(T.dense_layer_specs(cfg)),
             "ln_f": T.norm_specs(cfg)}
        if not cfg.tie_embeddings:
            p["unemb"] = ("vocab", "w_embed")
        return p

    def _inputs(self, params, batch, rules):
        cfg = self.cfg
        x = embed_tokens(cfg, params["emb"], batch["tokens"], rules)
        prefix_len = 0
        if cfg.num_prefix_tokens and "prefix" in batch:
            prefix = batch["prefix"].astype(x.dtype)
            x = jnp.concatenate([prefix, x], axis=1)
            prefix_len = prefix.shape[1]
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return x, positions, prefix_len

    def loss_fn(self, params, batch, rules):
        cfg = self.cfg
        x, positions, prefix_len = self._inputs(params, batch, rules)
        x, aux = T.run_stack(cfg, params["layers"], x, positions, rules,
                             causal=True, prefix_len=prefix_len)
        x = self._final(params, x)
        if prefix_len:
            x = x[:, prefix_len:]
        logits = _logits(cfg, params, x, rules)
        loss = softmax_xent(cfg, logits, batch["targets"], rules)
        metrics = {"xent": loss}
        if aux is not None:
            loss = loss + 0.01 * aux["lb_loss"] + 1e-3 * aux["router_z"]
            metrics.update(lb_loss=aux["lb_loss"],
                           dropped_frac=aux["dropped_frac"],
                           expert_load_max=aux["expert_load"].max())
        metrics["loss"] = loss
        return loss, metrics

    def prefill(self, params, batch, rules):
        cfg = self.cfg
        x, positions, prefix_len = self._inputs(params, batch, rules)
        x, caches = T.run_stack_prefill(cfg, params["layers"], x, positions,
                                        rules, causal=True,
                                        prefix_len=prefix_len)
        x = self._final(params, x[:, -1:])
        logits = _logits(cfg, params, x, rules)[:, 0]
        return logits, caches

    def init_cache(self, batch, seq_len, dtype=jnp.bfloat16):
        cfg = self.cfg
        shape = (cfg.num_layers, batch, seq_len, cfg.num_kv_heads,
                 cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    def cache_specs(self):
        kv = (None, "batch", "kv_seq", "kv_heads", None)
        return {"k": kv, "v": kv}

    def decode_step(self, params, caches, tokens, pos, rules):
        cfg = self.cfg
        x = embed_tokens(cfg, params["emb"], tokens[:, None], rules)
        # caches' layer-stacked scan; pos offset by prefix for VLM is folded
        # into pos by the caller (prefix lives at cache[:prefix_len]).
        x, caches = T.run_stack_decode(cfg, params["layers"], x, caches, pos,
                                       rules)
        x = self._final(params, x)
        logits = _logits(cfg, params, x, rules)[:, 0]
        return logits, caches


# ----------------------------------------------------------------- enc-dec LM
class EncDecLM(BaseModel):
    """Whisper-family: encoder over (stubbed) audio frames, causal decoder
    with cross-attention."""

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        return {
            "emb": make_embedding(cfg, ks[0]),
            "enc": T.stack_init(lambda k: T.init_dense_layer(cfg, k),
                                ks[1], cfg.encoder_layers),
            "ln_enc": make_norm_params(cfg, ks[2], cfg.d_model),
            "dec": T.stack_init(lambda k: T.init_dense_layer(cfg, k,
                                                             cross=True),
                                ks[3], cfg.num_layers),
            "ln_f": make_norm_params(cfg, ks[4], cfg.d_model),
        }

    def param_specs(self):
        cfg = self.cfg
        ns = T.norm_specs(cfg)
        return {"emb": EMB_SPECS,
                "enc": T.stacked_specs(T.dense_layer_specs(cfg)),
                "ln_enc": ns,
                "dec": T.stacked_specs(T.dense_layer_specs(cfg, cross=True)),
                "ln_f": ns}

    def encode(self, params, frames, rules):
        cfg = self.cfg
        B, Se, E = frames.shape
        x = frames.astype(dtype_of(cfg)) + sinusoidal_positions(
            Se, E).astype(dtype_of(cfg))
        x = rules.constrain(x, "batch", "seq", "embed")
        positions = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
        x, _ = T.run_stack(cfg, params["enc"], x, positions, rules,
                           causal=False)
        return apply_norm(cfg, params["ln_enc"], x), positions

    def _dec_inputs(self, params, tokens, rules, offset=0):
        cfg = self.cfg
        B, S = tokens.shape
        x = embed_tokens(cfg, params["emb"], tokens, rules)
        x = x + sinusoidal_positions(S, cfg.d_model,
                                     offset=offset).astype(x.dtype)
        positions = offset + jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (B, S))
        return x, positions

    def loss_fn(self, params, batch, rules):
        cfg = self.cfg
        enc_out, enc_pos = self.encode(params, batch["enc_frames"], rules)
        x, positions = self._dec_inputs(params, batch["tokens"], rules)
        x, _ = T.run_stack(cfg, params["dec"], x, positions, rules,
                           causal=True, enc_out=enc_out,
                           enc_positions=enc_pos)
        x = self._final(params, x)
        logits = _logits(cfg, params, x, rules)
        loss = softmax_xent(cfg, logits, batch["targets"], rules)
        return loss, {"loss": loss, "xent": loss}

    def prefill(self, params, batch, rules):
        cfg = self.cfg
        enc_out, enc_pos = self.encode(params, batch["enc_frames"], rules)
        x, positions = self._dec_inputs(params, batch["tokens"], rules)
        x, caches = T.run_stack_prefill(cfg, params["dec"], x, positions,
                                        rules, causal=True, enc_out=enc_out,
                                        enc_positions=enc_pos)
        x = self._final(params, x[:, -1:])
        logits = _logits(cfg, params, x, rules)[:, 0]
        return logits, caches

    def init_cache(self, batch, seq_len, dtype=jnp.bfloat16,
                   enc_len=WHISPER_ENC_LEN):
        cfg = self.cfg
        kv = (cfg.num_layers, batch, seq_len, cfg.num_kv_heads, cfg.head_dim)
        xkv = (cfg.num_layers, batch, enc_len, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
                "xk": jnp.zeros(xkv, dtype), "xv": jnp.zeros(xkv, dtype)}

    def cache_specs(self):
        kv = (None, "batch", "kv_seq", "kv_heads", None)
        xkv = (None, "batch", None, "kv_heads", None)
        return {"k": kv, "v": kv, "xk": xkv, "xv": xkv}

    def decode_step(self, params, caches, tokens, pos, rules):
        cfg = self.cfg
        S = caches["k"].shape[2]
        x = embed_tokens(cfg, params["emb"], tokens[:, None], rules)
        postab = sinusoidal_positions(S, cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(postab, pos, 1).astype(x.dtype)
        x, caches = T.run_stack_decode(cfg, params["dec"], x, caches, pos,
                                       rules)
        x = self._final(params, x)
        logits = _logits(cfg, params, x, rules)[:, 0]
        return logits, caches


# ----------------------------------------------------------------- hybrid LM
class HybridLM(BaseModel):
    """Zamba2-style: Mamba2 backbone + one shared attention/MLP block applied
    every `attn_period` layers (shared weights, per-application KV cache)."""

    def group_sizes(self):
        cfg = self.cfg
        period = cfg.attn_period
        sizes = []
        left = cfg.num_layers
        while left > 0:
            sizes.append(min(period, left))
            left -= period
        return sizes

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 5)

        def init_block(k):
            k1, k2 = jax.random.split(k)
            return {"ln": make_norm_params(cfg, k1, cfg.d_model),
                    "mamba": init_mamba(cfg, k2)}

        return {"emb": make_embedding(cfg, ks[0]),
                "layers": T.stack_init(init_block, ks[1], cfg.num_layers),
                "shared": T.init_dense_layer(cfg, ks[2]),
                "ln_f": make_norm_params(cfg, ks[3], cfg.d_model)}

    def param_specs(self):
        cfg = self.cfg
        block = {"ln": T.norm_specs(cfg), "mamba": dict(MAMBA_SPECS)}
        return {"emb": EMB_SPECS,
                "layers": T.stacked_specs(block),
                "shared": T.dense_layer_specs(cfg),
                "ln_f": T.norm_specs(cfg)}

    def _backbone(self, params, x, positions, rules, collect=False):
        cfg = self.cfg
        caches = {"k": [], "v": [], "mamba": []}
        idx = 0
        for size in self.group_sizes():
            if collect:
                h = apply_norm(cfg, params["shared"]["ln1"], x)
                o, kv = T.attn_sublayer(cfg, params["shared"]["attn"], h,
                                        positions, rules, causal=True,
                                        return_kv=True)
                caches["k"].append(kv[0])
                caches["v"].append(kv[1])
                x = x + o
                h = apply_norm(cfg, params["shared"]["ln2"], x)
                from repro.models.mlp import apply_mlp
                x = x + apply_mlp(cfg, params["shared"]["mlp"], h, rules)
            else:
                x, _, _ = T.apply_dense_layer(cfg, params["shared"], x,
                                              positions, rules, causal=True)
            sl = jax.tree.map(lambda a: a[idx:idx + size], params["layers"])

            def body(h, p):
                if collect:
                    o, cache = apply_mamba(cfg, p["mamba"],
                                           apply_norm(cfg, p["ln"], h), rules,
                                           return_cache=True)
                    return h + o, cache
                o = apply_mamba(cfg, p["mamba"], apply_norm(cfg, p["ln"], h),
                                rules)
                return h + o, None

            x, mc = jax.lax.scan(jax.checkpoint(body), x, sl)
            if collect:
                caches["mamba"].append(mc)
            idx += size
        if collect:
            caches["k"] = jnp.stack(caches["k"])      # (n_apps,B,S,Hkv,D)
            caches["v"] = jnp.stack(caches["v"])
            caches["mamba"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs), *caches["mamba"])
            return x, caches
        return x, None

    def loss_fn(self, params, batch, rules):
        cfg = self.cfg
        x = embed_tokens(cfg, params["emb"], batch["tokens"], rules)
        B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, _ = self._backbone(params, x, positions, rules)
        x = self._final(params, x)
        logits = _logits(cfg, params, x, rules)
        loss = softmax_xent(cfg, logits, batch["targets"], rules)
        return loss, {"loss": loss, "xent": loss}

    def prefill(self, params, batch, rules):
        cfg = self.cfg
        x = embed_tokens(cfg, params["emb"], batch["tokens"], rules)
        B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, caches = self._backbone(params, x, positions, rules, collect=True)
        x = self._final(params, x[:, -1:])
        logits = _logits(cfg, params, x, rules)[:, 0]
        return logits, caches

    def init_cache(self, batch, seq_len, dtype=jnp.bfloat16):
        cfg = self.cfg
        n_apps = len(self.group_sizes())
        kv = (n_apps, batch, seq_len, cfg.num_kv_heads, cfg.head_dim)
        mamba = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[init_mamba_cache(cfg, batch, dtype)
              for _ in range(cfg.num_layers)])
        return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
                "mamba": mamba}

    def cache_specs(self):
        kv = (None, "batch", "kv_seq", "kv_heads", None)
        mamba = {"state": (None, "batch", "heads", None, None),
                 "conv_x": (None, "batch", None, "ff"),
                 "conv_B": (None, "batch", None, None),
                 "conv_C": (None, "batch", None, None)}
        return {"k": kv, "v": kv, "mamba": mamba}

    def decode_step(self, params, caches, tokens, pos, rules):
        cfg = self.cfg
        x = embed_tokens(cfg, params["emb"], tokens[:, None], rules)
        idx = 0
        new_k, new_v, new_mamba = [], [], []
        for g, size in enumerate(self.group_sizes()):
            h = apply_norm(cfg, params["shared"]["ln1"], x)
            o, kc, vc = T.attn_decode_sublayer(
                cfg, params["shared"]["attn"], h, caches["k"][g],
                caches["v"][g], pos, rules)
            new_k.append(kc)
            new_v.append(vc)
            x = x + o
            h = apply_norm(cfg, params["shared"]["ln2"], x)
            from repro.models.mlp import apply_mlp
            x = x + apply_mlp(cfg, params["shared"]["mlp"], h, rules)
            sl = jax.tree.map(lambda a: a[idx:idx + size], params["layers"])
            mc = jax.tree.map(lambda a: a[idx:idx + size], caches["mamba"])

            def body(h, inp):
                p, cache = inp
                o, cache = decode_mamba(cfg, p["mamba"],
                                        apply_norm(cfg, p["ln"], h[:, 0]),
                                        cache, rules)
                return h + o[:, None], cache

            x, mc_new = jax.lax.scan(body, x, (sl, mc))
            new_mamba.append(mc_new)
            idx += size
        x = self._final(params, x)
        logits = _logits(cfg, params, x, rules)[:, 0]
        caches = {"k": jnp.stack(new_k), "v": jnp.stack(new_v),
                  "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                        *new_mamba)}
        return logits, caches


# ------------------------------------------------------------------ xLSTM LM
class XLSTMLM(BaseModel):
    """Alternating mLSTM / sLSTM blocks (xLSTM), pre-norm residual."""

    def block_kinds(self):
        cfg = self.cfg
        kinds = [cfg.block_types[i % len(cfg.block_types)]
                 for i in range(cfg.num_layers)]
        return kinds

    def init(self, key):
        cfg = self.cfg
        kinds = self.block_kinds()
        n_m = kinds.count("mlstm")
        n_s = kinds.count("slstm")
        ks = jax.random.split(key, 5)

        def wrap(init_fn):
            def f(k):
                k1, k2 = jax.random.split(k)
                return {"ln": make_norm_params(cfg, k1, cfg.d_model),
                        "cell": init_fn(cfg, k2)}
            return f

        return {"emb": make_embedding(cfg, ks[0]),
                "mlstm": T.stack_init(wrap(init_mlstm), ks[1], n_m),
                "slstm": T.stack_init(wrap(init_slstm), ks[2], n_s),
                "ln_f": make_norm_params(cfg, ks[3], cfg.d_model)}

    def param_specs(self):
        cfg = self.cfg
        ns = T.norm_specs(cfg)
        return {"emb": EMB_SPECS,
                "mlstm": T.stacked_specs({"ln": ns, "cell": dict(MLSTM_SPECS)}),
                "slstm": T.stacked_specs({"ln": ns, "cell": dict(SLSTM_SPECS)}),
                "ln_f": ns}

    def _forward(self, params, x, rules, states=None, collect=False):
        cfg = self.cfg
        kinds = self.block_kinds()
        counters = {"mlstm": 0, "slstm": 0}
        new_states = {"mlstm": [], "slstm": []}
        for kind in kinds:
            i = counters[kind]
            counters[kind] += 1
            p = jax.tree.map(lambda a: a[i], params[kind])
            h = apply_norm(cfg, p["ln"], x)
            fn = apply_mlstm if kind == "mlstm" else apply_slstm
            s0 = None if states is None else jax.tree.map(
                lambda a: a[i], states[kind], is_leaf=None)
            if collect:
                o, st = fn(cfg, p["cell"], h, rules, state0=s0,
                           return_state=True)
                new_states[kind].append(st)
            else:
                o = fn(cfg, p["cell"], h, rules, state0=s0)
            x = x + o
        if collect:
            stacked = {k: jax.tree.map(lambda *xs: jnp.stack(xs), *v)
                       for k, v in new_states.items() if v}
            return x, stacked
        return x, None

    def loss_fn(self, params, batch, rules):
        cfg = self.cfg
        x = embed_tokens(cfg, params["emb"], batch["tokens"], rules)
        x, _ = self._forward(params, x, rules)
        x = self._final(params, x)
        logits = _logits(cfg, params, x, rules)
        loss = softmax_xent(cfg, logits, batch["targets"], rules)
        return loss, {"loss": loss, "xent": loss}

    def prefill(self, params, batch, rules):
        cfg = self.cfg
        x = embed_tokens(cfg, params["emb"], batch["tokens"], rules)
        x, states = self._forward(params, x, rules, collect=True)
        x = self._final(params, x[:, -1:])
        logits = _logits(cfg, params, x, rules)[:, 0]
        return logits, states

    def init_cache(self, batch, seq_len=None, dtype=jnp.float32):
        cfg = self.cfg
        kinds = self.block_kinds()
        n_m, n_s = kinds.count("mlstm"), kinds.count("slstm")
        out = {}
        if n_m:
            out["mlstm"] = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[mlstm_state0(cfg, batch) for _ in range(n_m)])
        if n_s:
            out["slstm"] = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[slstm_state0(cfg, batch) for _ in range(n_s)])
        return out

    def cache_specs(self):
        m = ((None, "batch", None, None, None), (None, "batch", None, None),
             (None, "batch", None))
        sv = (None, "batch", None, None)
        return {"mlstm": m, "slstm": (sv, sv, sv, sv)}

    def decode_step(self, params, caches, tokens, pos, rules):
        cfg = self.cfg
        x = embed_tokens(cfg, params["emb"], tokens[:, None], rules)[:, 0]
        kinds = self.block_kinds()
        counters = {"mlstm": 0, "slstm": 0}
        new_states = {"mlstm": [], "slstm": []}
        for kind in kinds:
            i = counters[kind]
            counters[kind] += 1
            p = jax.tree.map(lambda a: a[i], params[kind])
            st = jax.tree.map(lambda a: a[i], caches[kind])
            h = apply_norm(cfg, p["ln"], x)
            fn = decode_mlstm if kind == "mlstm" else decode_slstm
            o, st = fn(cfg, p["cell"], h, st, rules)
            new_states[kind].append(st)
            x = x + o
        x = self._final(params, x[:, None])
        logits = _logits(cfg, params, x, rules)[:, 0]
        caches = {k: jax.tree.map(lambda *xs: jnp.stack(xs), *v)
                  for k, v in new_states.items() if v}
        return logits, caches


def build_model(cfg):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "ssm":
        return XLSTMLM(cfg)
    raise KeyError(cfg.family)
