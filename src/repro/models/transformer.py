"""Transformer layer assembly: attention sublayer (train/prefill + decode),
dense/MoE layers, stacked-scan runner. Used by dense, MoE, VLM, enc-dec and
the hybrid's shared attention block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models.common import apply_norm, make_norm_params, apply_rope
from repro.models.mlp import init_mlp, apply_mlp, mlp_specs
from repro.models.moe import init_moe, apply_moe, moe_specs

NORM_SPECS_RMS = {"scale": (None,)}
NORM_SPECS_LN = {"scale": (None,), "bias": (None,)}


def norm_specs(cfg):
    return NORM_SPECS_RMS if cfg.norm == "rmsnorm" else NORM_SPECS_LN


# ------------------------------------------------------------ attention sublayer
def attn_sublayer(cfg, p, x, positions, rules, *, causal=True, prefix_len=0,
                  kv_x=None, kv_positions=None, q_block=1024, kv_block=512,
                  return_kv=False):
    """Full-sequence attention. x: (B,S,E) -> (B,S,E) [, (k, v) for caching]."""
    kv_in = x if kv_x is None else kv_x
    q = rules.constrain(x @ p["wq"], "batch", "seq", "act_q")
    k = rules.constrain(kv_in @ p["wk"], "batch", "seq", "act_kv")
    v = rules.constrain(kv_in @ p["wv"], "batch", "seq", "act_kv")
    q, k, v = A.split_heads(cfg, q, k, v)
    if cfg.use_rope:
        kv_pos = positions if kv_positions is None else kv_positions
        B, S, Hkv, G, D = q.shape
        q = apply_rope(q.reshape(B, S, Hkv * G, D), positions,
                       cfg.rope_theta).reshape(B, S, Hkv, G, D)
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    use_cp = getattr(rules, "mode", "") == "sp_ep" and \
        kv_x is None and q.shape[1] <= 8192
    if use_cp:
        o = A.cp_attention(q, k, v, causal=causal, prefix_len=prefix_len,
                           rules=rules)
    else:
        o = A.blockwise_attention(q, k, v, causal=causal,
                                  prefix_len=prefix_len,
                                  q_block=q_block, kv_block=kv_block)
    o = A.merge_heads(cfg, o)
    o = rules.constrain(o, "batch", "seq", "act_q")
    out = o @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def attn_decode_sublayer(cfg, p, x, k_cache, v_cache, pos, rules, *,
                         cross=False, update_cache=True):
    """Single-token attention vs cache.

    x: (B,1,E); k_cache/v_cache: (B,S,Hkv,D); pos: scalar int32.
    Returns (out (B,1,E), k_cache, v_cache)."""
    B = x.shape[0]
    q = x @ p["wq"]                                           # (B,1,q_dim)
    G = cfg.num_heads // cfg.num_kv_heads
    qh = q.reshape(B, 1, cfg.num_kv_heads * G, cfg.head_dim)
    if cfg.use_rope:
        pos_arr = jnp.full((B, 1), pos, jnp.int32)
        qh = apply_rope(qh, pos_arr, cfg.rope_theta)
    qh = qh.reshape(B, cfg.num_kv_heads, G, cfg.head_dim)
    if not cross and update_cache:
        k_new = (x @ p["wk"]).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
        v_new = (x @ p["wv"]).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
        if cfg.use_rope:
            k_new = apply_rope(k_new, jnp.full((B, 1), pos, jnp.int32),
                               cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
    att_pos = k_cache.shape[1] if cross else pos
    o = A.decode_attention(qh, k_cache.astype(x.dtype), v_cache.astype(x.dtype),
                           att_pos)
    o = o.reshape(B, 1, cfg.q_dim)
    return o @ p["wo"], k_cache, v_cache


# ------------------------------------------------------------ layer definitions
def init_dense_layer(cfg, key, cross=False):
    ks = jax.random.split(key, 5)
    p = {"ln1": make_norm_params(cfg, ks[0], cfg.d_model),
         "attn": A.init_attn(cfg, ks[1]),
         "ln2": make_norm_params(cfg, ks[2], cfg.d_model)}
    if cfg.family == "moe":
        p["moe"] = init_moe(cfg, ks[3])
        if cfg.dense_ff:
            p["mlp"] = init_mlp(cfg, ks[4], d_ff=cfg.dense_ff)
    else:
        p["mlp"] = init_mlp(cfg, ks[3])
    if cross:
        p["ln_x"] = make_norm_params(cfg, ks[0], cfg.d_model)
        p["xattn"] = A.init_attn(cfg, ks[4])
    return p


def dense_layer_specs(cfg, cross=False):
    ns = norm_specs(cfg)
    p = {"ln1": ns, "attn": dict(A.ATTN_SPECS), "ln2": ns}
    if cfg.family == "moe":
        p["moe"] = moe_specs(cfg)
        if cfg.dense_ff:
            p["mlp"] = mlp_specs(cfg.mlp)
    else:
        p["mlp"] = mlp_specs(cfg.mlp)
    if cross:
        p["ln_x"] = ns
        p["xattn"] = dict(A.ATTN_SPECS)
    return p


def apply_dense_layer(cfg, p, x, positions, rules, *, causal=True,
                      prefix_len=0, enc_out=None, enc_positions=None,
                      return_kv=False):
    """Pre-norm residual layer; optional cross-attention (enc-dec decoder).

    Returns (x, moe_aux, kv) — kv is (k, v) [+ cross (xk, xv)] if return_kv."""
    h = apply_norm(cfg, p["ln1"], x)
    kv = None
    if return_kv:
        o, kv = attn_sublayer(cfg, p["attn"], h, positions, rules,
                              causal=causal, prefix_len=prefix_len,
                              return_kv=True)
        x = x + o
    else:
        x = x + attn_sublayer(cfg, p["attn"], h, positions, rules,
                              causal=causal, prefix_len=prefix_len)
    if enc_out is not None:
        h = apply_norm(cfg, p["ln_x"], x)
        if return_kv:
            o, xkv = attn_sublayer(cfg, p["xattn"], h, positions, rules,
                                   causal=False, kv_x=enc_out,
                                   kv_positions=enc_positions, return_kv=True)
            kv = kv + xkv
            x = x + o
        else:
            x = x + attn_sublayer(cfg, p["xattn"], h, positions, rules,
                                  causal=False, kv_x=enc_out,
                                  kv_positions=enc_positions)
    h = apply_norm(cfg, p["ln2"], x)
    aux = None
    if cfg.family == "moe":
        moe_out, aux = apply_moe(cfg, p["moe"], h, rules)
        out = moe_out
        if cfg.dense_ff:
            out = out + apply_mlp(cfg, p["mlp"], h, rules)
        x = x + out
    else:
        x = x + apply_mlp(cfg, p["mlp"], h, rules)
    x = rules.constrain(x, "batch", "seq", "embed")
    return x.astype(h.dtype), aux, kv


def decode_dense_layer(cfg, p, x, k_cache, v_cache, pos, rules,
                       xk_cache=None, xv_cache=None):
    h = apply_norm(cfg, p["ln1"], x)
    o, k_cache, v_cache = attn_decode_sublayer(cfg, p["attn"], h, k_cache,
                                               v_cache, pos, rules)
    x = x + o
    if xk_cache is not None:
        h = apply_norm(cfg, p["ln_x"], x)
        o, _, _ = attn_decode_sublayer(cfg, p["xattn"], h, xk_cache, xv_cache,
                                       pos, rules, cross=True)
        x = x + o
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.family == "moe":
        out, _ = apply_moe(cfg, p["moe"], h, rules)
        if cfg.dense_ff:
            out = out + apply_mlp(cfg, p["mlp"], h, rules)
        x = x + out
    else:
        x = x + apply_mlp(cfg, p["mlp"], h, rules)
    return x.astype(h.dtype), k_cache, v_cache


# ------------------------------------------------------------ stacked runners
def stack_init(init_fn, key, n):
    """Initialize n layers and stack leaves on a leading axis (for lax.scan)."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def stacked_specs(layer_specs):
    """Prepend the layer axis (replicated) to every leaf spec tuple."""
    return jax.tree.map(lambda t: (None,) + t, layer_specs,
                        is_leaf=lambda v: isinstance(v, tuple))


def run_stack(cfg, stacked, x, positions, rules, *, causal=True,
              prefix_len=0, enc_out=None, enc_positions=None, remat=True):
    """lax.scan over stacked layer params. Returns (x, summed moe aux)."""

    def body(carry, layer_p):
        h, aux_acc = carry
        h, aux, _ = apply_dense_layer(cfg, layer_p, h, positions, rules,
                                      causal=causal, prefix_len=prefix_len,
                                      enc_out=enc_out,
                                      enc_positions=enc_positions)
        if aux is not None:
            aux_acc = {"lb_loss": aux_acc["lb_loss"] + aux["lb_loss"],
                       "router_z": aux_acc["router_z"] + aux["router_z"],
                       "expert_load": aux_acc["expert_load"]
                       + aux["expert_load"],
                       "dropped_frac": aux_acc["dropped_frac"]
                       + aux["dropped_frac"]}
        return (h, aux_acc), None

    aux0 = {"lb_loss": jnp.zeros(()), "router_z": jnp.zeros(()),
            "expert_load": jnp.zeros((cfg.num_experts,)),
            "dropped_frac": jnp.zeros(())} if cfg.family == "moe" else None
    fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(fn, (x, aux0), stacked)
    if aux is not None:
        n = cfg.num_layers
        aux = jax.tree.map(lambda v: v / n, aux)
    return x, aux


def run_stack_prefill(cfg, stacked, x, positions, rules, *, causal=True,
                      prefix_len=0, enc_out=None, enc_positions=None):
    """Scan over layers, emitting per-layer KV caches: (x, caches)."""

    def body(h, layer_p):
        h, _, kv = apply_dense_layer(cfg, layer_p, h, positions, rules,
                                     causal=causal, prefix_len=prefix_len,
                                     enc_out=enc_out,
                                     enc_positions=enc_positions,
                                     return_kv=True)
        return h, kv

    x, kvs = jax.lax.scan(body, x, stacked)
    caches = {"k": kvs[0], "v": kvs[1]}                  # (L,B,S,Hkv,D)
    if enc_out is not None:
        caches["xk"], caches["xv"] = kvs[2], kvs[3]
    return x, caches


def run_stack_decode(cfg, stacked, x, caches, pos, rules):
    """Scan over layers for decode; caches: dict of (L, ...) arrays."""

    def body(h, inp):
        layer_p, kc, vc, xkc, xvc = inp
        h, kc, vc = decode_dense_layer(cfg, layer_p, h, kc, vc, pos, rules,
                                       xk_cache=xkc, xv_cache=xvc)
        return h, (kc, vc)

    has_cross = "xk" in caches
    xs = (stacked, caches["k"], caches["v"],
          caches["xk"] if has_cross else jnp.zeros((cfg.num_layers,)),
          caches["xv"] if has_cross else jnp.zeros((cfg.num_layers,)))
    if not has_cross:
        def body(h, inp):  # noqa: F811 — simpler body without cross caches
            layer_p, kc, vc = inp
            h, kc, vc = decode_dense_layer(cfg, layer_p, h, kc, vc, pos, rules)
            return h, (kc, vc)
        xs = (stacked, caches["k"], caches["v"])
    x, (k_new, v_new) = jax.lax.scan(body, x, xs)
    new_caches = dict(caches)
    new_caches["k"], new_caches["v"] = k_new, v_new
    return x, new_caches
