"""Grouped-query attention: blockwise (flash-style, online softmax) for
train/prefill, single-step for decode.

Blockwise form: outer lax.scan over query blocks, inner lax.scan over KV
blocks, carries (m, l, acc) — O(Sq·D) live memory instead of O(Sq·Skv).
Bodies are jax.checkpoint'd so the backward pass recomputes scores
(flash-attention recompute strategy, structurally — the Pallas-kernel budget is
reserved for the paper's audio hot-spots per DESIGN.md §6).

Causal blocks below the diagonal are skipped at runtime via lax.cond.
GQA is computed grouped (B,S,Hkv,G,D): repeated KV heads are never
materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, dtype_of

_NEG = -1e30


def init_attn(cfg, key, d_model=None):
    E = d_model or cfg.d_model
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "wq": dense_init(kq, E, (E, cfg.q_dim), dt),
        "wk": dense_init(kk, E, (E, cfg.kv_dim), dt),
        "wv": dense_init(kv, E, (E, cfg.kv_dim), dt),
        "wo": dense_init(ko, cfg.q_dim, (cfg.q_dim, E), dt),
    }


ATTN_SPECS = {
    "wq": ("w_embed", "q_dim"), "wk": ("w_embed", "kv_dim"),
    "wv": ("w_embed", "kv_dim"), "wo": ("q_dim", "w_embed"),
}


def _pick_block(size, target):
    b = min(target, size)
    while size % b:
        b -= 1
    return b


def blockwise_attention(q, k, v, *, causal, prefix_len=0, q_offset=0,
                        kv_offset=0, q_block=1024, kv_block=512,
                        softmax_scale=None):
    """q: (B,Sq,Hkv,G,D); k,v: (B,Skv,Hkv,D) -> (B,Sq,Hkv,G,D)."""
    B, Sq, Hkv, G, D = q.shape
    Skv = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)
    qb = _pick_block(Sq, q_block)
    kb = _pick_block(Skv, kv_block)
    nq, nk = Sq // qb, Skv // kb
    qs = jnp.moveaxis(q.reshape(B, nq, qb, Hkv, G, D), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nk, kb, Hkv, D), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, kb, Hkv, D), 1, 0)
    q_pos_base = jnp.arange(qb)
    k_pos_base = jnp.arange(kb)

    @jax.checkpoint
    def kv_body(carry, inputs, qi, iq):
        m, l, acc = carry
        kj, vj, jk = inputs
        q_pos = q_offset + iq * qb + q_pos_base          # (qb,)
        k_pos = kv_offset + jk * kb + k_pos_base          # (kb,)

        def compute(m, l, acc):
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                ok = k_pos[None, :] <= q_pos[:, None]
                if prefix_len:
                    ok = ok | (k_pos[None, :] < prefix_len)
                s = jnp.where(ok[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return m_new, l_new, acc_new

        if causal and not prefix_len:
            # runtime skip of fully-masked blocks (above the causal diagonal)
            needed = (kv_offset + jk * kb) <= (q_offset + iq * qb + qb - 1)
            carry = jax.lax.cond(needed, compute, lambda m, l, a: (m, l, a),
                                 m, l, acc)
        else:
            carry = compute(m, l, acc)
        return carry, None

    def q_body(_, inputs):
        qi, iq = inputs
        m0 = jnp.full((B, Hkv, G, qb), _NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            functools.partial(kv_body, qi=qi, iq=iq), (m0, l0, a0),
            (ks, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, jnp.moveaxis(out, -2, 1).astype(q.dtype)  # (B,qb,Hkv,G,D)

    _, outs = jax.lax.scan(q_body, None, (qs, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hkv, G, D)


def cp_attention(q, k, v, *, causal, prefix_len=0, softmax_scale=None,
                 rules=None):
    """Context-parallel full-matrix attention (train-length sequences).

    q sharded over seq on the model axis; k/v replicated — every score/PV
    contraction is LOCAL, eliminating the per-block all-reduces GSPMD emits
    when kv_heads doesn't divide TP (EXPERIMENTS.md §Perf, arctic iter 2).
    Memory: (B_loc, H, S/TP, S) scores — fine at 4k, use blockwise for 32k.
    """
    B, Sq, Hkv, G, D = q.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)
    if rules is not None:
        q = rules.constrain(q, "batch", "seq_cp", None, None, None)
        k = rules.constrain(k, "batch", None, None, None)
        v = rules.constrain(v, "batch", None, None, None)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None]
        kpos = jnp.arange(k.shape[1])[None, :]
        ok = kpos <= qpos
        if prefix_len:
            ok = ok | (kpos < prefix_len)
        s = jnp.where(ok[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def decode_attention(q, k, v, pos, *, softmax_scale=None):
    """One-token attention against a cache.

    q: (B,Hkv,G,D); k,v: (B,S,Hkv,D); pos: scalar current position.
    Works unchanged when k/v are sequence-sharded (GSPMD inserts the psum over
    the contraction — flash-decode)."""
    D = q.shape[-1]
    S = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)
    s = jnp.einsum("bhgd,bkhd->bhgk", q, k,
                   preferred_element_type=jnp.float32) * scale
    ok = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(ok, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def split_heads(cfg, q, k, v):
    """(B,Sq,q_dim)/(B,Skv,kv_dim) -> grouped (B,Sq,Hkv,G,D), (B,Skv,Hkv,D).

    k/v may have a different sequence length than q (cross-attention)."""
    B, Sq, _ = q.shape
    Skv = k.shape[1]
    G = cfg.num_heads // cfg.num_kv_heads
    q = q.reshape(B, Sq, cfg.num_kv_heads, G, cfg.head_dim)
    k = k.reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def merge_heads(cfg, o):
    B, S = o.shape[:2]
    return o.reshape(B, S, cfg.q_dim)
