"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory, recurrent).

Faithful to the xLSTM cell equations (Beck et al. 2024) with stabilized
exponential gating (running max-state m). Both cells run as lax.scan over
time — sLSTM is inherently sequential (its recurrence reads h_{t-1}); the
recurrent mLSTM baseline is the hillclimb target for a chunkwise-parallel
variant (see EXPERIMENTS.md §Perf).

Simplification vs the reference implementation (documented per DESIGN.md):
both block types use a pre-norm residual block with 2x up-projection and a
SiLU-gated output branch; per-head causal conv frontends are omitted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, dtype_of


def xlstm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.num_heads
    dh = d_inner // H
    return d_inner, H, dh


# ------------------------------------------------------------------- mLSTM
def init_mlstm(cfg, key):
    dt = dtype_of(cfg)
    E = cfg.d_model
    d_inner, H, dh = xlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], E, (E, 2 * d_inner), dt),
        "w_q": dense_init(ks[1], d_inner, (d_inner, d_inner), dt),
        "w_k": dense_init(ks[2], d_inner, (d_inner, d_inner), dt),
        "w_v": dense_init(ks[3], d_inner, (d_inner, d_inner), dt),
        "w_if": dense_init(ks[4], d_inner, (d_inner, 2 * H), jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "norm": jnp.zeros((d_inner,), dt),
        "w_down": dense_init(ks[5], d_inner, (d_inner, E), dt),
    }


MLSTM_SPECS = {
    "w_up": ("w_embed", "ff"), "w_q": (None, "ff"), "w_k": (None, "ff"),
    "w_v": (None, "ff"), "w_if": ("ff", None), "b_if": (None,),
    "norm": ("ff",), "w_down": ("ff", "w_embed"),
}


def _mlstm_scan(q, k, v, li, lf, state0):
    """q,k,v: (B,S,H,dh); li,lf: (B,S,H) log gates; returns h (B,S,H,dh)."""
    B, S, H, dh = q.shape

    def body(carry, inp):
        C, n, m = carry                    # (B,H,dh,dh),(B,H,dh),(B,H)
        qt, kt, vt, lit, lft = inp
        m_new = jnp.maximum(lft + m, lit)
        ig = jnp.exp(lit - m_new)[..., None]
        fg = jnp.exp(lft + m - m_new)[..., None]
        C = fg[..., None] * C + ig[..., None] * jnp.einsum(
            "bhv,bhk->bhvk", vt, kt)
        n = fg * n + ig * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (q, k, v, li, lf))
    (C, n, m), hs = jax.lax.scan(jax.checkpoint(body), state0, xs)
    return jnp.moveaxis(hs, 0, 1), (C, n, m)


def mlstm_state0(cfg, batch):
    _, H, dh = xlstm_dims(cfg)
    return (jnp.zeros((batch, H, dh, dh), jnp.float32),
            jnp.zeros((batch, H, dh), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


def apply_mlstm(cfg, p, x, rules, state0=None, return_state=False):
    B, S, E = x.shape
    d_inner, H, dh = xlstm_dims(cfg)
    up = x @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    xm = rules.constrain(xm, "batch", "seq", "act_ff")
    q = (xm @ p["w_q"]).reshape(B, S, H, dh)
    k = (xm @ p["w_k"]).reshape(B, S, H, dh) / jnp.sqrt(float(dh))
    v = (xm @ p["w_v"]).reshape(B, S, H, dh)
    gates = xm.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    li, lf = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])
    if state0 is None:
        state0 = mlstm_state0(cfg, B)
    h, state = _mlstm_scan(q, k, v, li, lf, state0)
    h = h.reshape(B, S, d_inner)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + 1e-5) * (1.0 + p["norm"].astype(jnp.float32))
    h = h * jax.nn.silu(z.astype(jnp.float32))
    out = h.astype(x.dtype) @ p["w_down"]
    if return_state:
        return out, state
    return out


def decode_mlstm(cfg, p, x, state, rules):
    """x: (B,E); single-step mLSTM."""
    out, new_state = apply_mlstm(cfg, p, x[:, None, :], rules,
                                 state0=state, return_state=True)
    return out[:, 0], new_state


# ------------------------------------------------------------------- sLSTM
def init_slstm(cfg, key):
    dt = dtype_of(cfg)
    E = cfg.d_model
    d_inner, H, dh = xlstm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w_up": dense_init(ks[0], E, (E, 2 * d_inner), dt),
        "w_g": dense_init(ks[1], d_inner, (d_inner, 4 * d_inner), jnp.float32),
        "r_g": dense_init(ks[2], dh, (H, dh, 4 * dh), jnp.float32),
        "b_g": jnp.zeros((4 * d_inner,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dt),
        "w_down": dense_init(ks[3], d_inner, (d_inner, E), dt),
    }


SLSTM_SPECS = {
    "w_up": ("w_embed", "ff"), "w_g": ("ff", None), "r_g": (None, None, None),
    "b_g": (None,), "norm": ("ff",), "w_down": ("ff", "w_embed"),
}


def slstm_state0(cfg, batch):
    d_inner, H, dh = xlstm_dims(cfg)
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return (z, z, jnp.full((batch, H, dh), -1e30, jnp.float32), z)  # c,n,m,h


def _slstm_scan(wx, r_g, state0):
    """wx: (B,S,4*d_inner) input-side gate preactivations."""
    B, S, _ = wx.shape
    H, dh, _ = r_g.shape

    def body(carry, xt):
        c, n, m, h = carry                         # (B,H,dh) each
        rec = jnp.einsum("bhd,hdg->bhg", h, r_g)   # (B,H,4*dh)
        g = xt.reshape(B, 4, H, dh).transpose(0, 2, 1, 3)  # (B,H,4,dh)
        rec = rec.reshape(B, H, 4, dh)
        pre = g + rec
        li, lf = pre[..., 0, :], jax.nn.log_sigmoid(pre[..., 1, :])
        zt, ot = jnp.tanh(pre[..., 2, :]), jax.nn.sigmoid(pre[..., 3, :])
        m_new = jnp.maximum(lf + m, li)
        ig = jnp.exp(li - m_new)
        fg = jnp.exp(lf + m - m_new)
        c = fg * c + ig * zt
        n = jnp.maximum(fg * n + ig, 1e-6)
        h = ot * (c / n)
        return (c, n, m_new, h), h

    xs = jnp.moveaxis(wx.astype(jnp.float32), 1, 0)
    state, hs = jax.lax.scan(jax.checkpoint(body), state0, xs)
    return jnp.moveaxis(hs, 0, 1), state           # (B,S,H,dh)


def apply_slstm(cfg, p, x, rules, state0=None, return_state=False):
    B, S, E = x.shape
    d_inner, H, dh = xlstm_dims(cfg)
    up = x @ p["w_up"]
    xs_, z = jnp.split(up, 2, axis=-1)
    xs_ = rules.constrain(xs_, "batch", "seq", "act_ff")
    wx = xs_.astype(jnp.float32) @ p["w_g"] + p["b_g"]
    if state0 is None:
        state0 = slstm_state0(cfg, B)
    h, state = _slstm_scan(wx, p["r_g"], state0)
    h = h.reshape(B, S, d_inner)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + 1e-5) * (1.0 + p["norm"].astype(jnp.float32))
    h = h * jax.nn.silu(z.astype(jnp.float32))
    out = h.astype(x.dtype) @ p["w_down"]
    if return_state:
        return out, state
    return out


def decode_slstm(cfg, p, x, state, rules):
    out, new_state = apply_slstm(cfg, p, x[:, None, :], rules,
                                 state0=state, return_state=True)
    return out[:, 0], new_state
