"""Feed-forward blocks: SwiGLU / GeGLU / squared-ReLU / GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, dtype_of

GLU = ("swiglu", "geglu")


def init_mlp(cfg, key, d_model=None, d_ff=None, mlp=None):
    E = d_model or cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    mlp = mlp or cfg.mlp
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_down": dense_init(k3, F, (F, E), dt)}
    if mlp in GLU:
        p["w_gate"] = dense_init(k1, E, (E, F), dt)
        p["w_up"] = dense_init(k2, E, (E, F), dt)
    else:
        p["w_up"] = dense_init(k2, E, (E, F), dt)
    return p


def mlp_specs(mlp):
    p = {"w_down": ("ff", "w_embed"), "w_up": ("w_embed", "ff")}
    if mlp in GLU:
        p["w_gate"] = ("w_embed", "ff")
    return p


def _act(mlp, h):
    if mlp == "swiglu":
        return jax.nn.silu(h)
    if mlp == "geglu":
        return jax.nn.gelu(h)
    if mlp == "squared_relu":
        r = jax.nn.relu(h)
        return r * r
    if mlp == "gelu":
        return jax.nn.gelu(h)
    raise KeyError(mlp)


def apply_mlp(cfg, p, x, rules, mlp=None):
    mlp = mlp or cfg.mlp
    if mlp in GLU:
        h = _act(mlp, x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = _act(mlp, x @ p["w_up"])
    h = rules.constrain(h, "batch", "seq", "act_ff")
    return (h @ p["w_down"]).astype(x.dtype)
