"""Mixture-of-Experts block: top-k routing, capacity-bounded scatter dispatch,
expert-parallel batched matmuls (experts sharded over the "model" axis).

Dispatch is gather/scatter-based (GShard-style capacity without materializing
the (tokens, experts, capacity) one-hot): per batch row, tokens are assigned a
position-in-expert by a cumsum over the (S*K, E) one-hot (small), then
scattered into a dense (E, C, d) buffer. Tokens past capacity are dropped
(their contribution is the residual stream only) — standard TPU practice.

Router statistics (per-expert load fractions) are returned: they are the MoE
analogue of the paper's load-balance analysis (Figs 14-18), and are consumed by
the same balance reporting the audio scheduler uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, dtype_of
from repro.models.mlp import init_mlp, apply_mlp, GLU, _act


def moe_capacity(seq_len, num_experts, top_k, capacity_factor=1.25):
    c = int(np.ceil(seq_len * top_k / num_experts * capacity_factor))
    return max(8, ((c + 7) // 8) * 8)          # pad to 8 for tiling


def init_moe(cfg, key):
    dt = dtype_of(cfg)
    kr, ke = jax.random.split(key)
    E, F, X = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {"router": dense_init(kr, E, (E, X), jnp.float32)}
    ks = jax.random.split(ke, 3)
    p["w_gate"] = dense_init(ks[0], E, (X, E, F), dt) if cfg.mlp in GLU else None
    p["w_up"] = dense_init(ks[1], E, (X, E, F), dt)
    p["w_down"] = dense_init(ks[2], F, (X, F, E), dt)
    p = {k: v for k, v in p.items() if v is not None}
    return p


def moe_specs(cfg):
    if cfg.expert_shard == "tp":      # experts replicated, ff dim sharded
        p = {"router": ("w_embed", None),
             "w_up": (None, "w_embed", "ff"),
             "w_down": (None, "ff", "w_embed")}
        if cfg.mlp in GLU:
            p["w_gate"] = (None, "w_embed", "ff")
        return p
    p = {"router": ("w_embed", None),
         "w_up": ("experts", "w_embed", "expert_ff"),
         "w_down": ("experts", "expert_ff", "w_embed")}
    if cfg.mlp in GLU:
        p["w_gate"] = ("experts", "w_embed", "expert_ff")
    return p


def apply_moe(cfg, p, x, rules, capacity_factor=None):
    """x: (B,S,E_model) -> (out, aux) with aux = load-balance metrics/loss."""
    B, S, E = x.shape
    X, K = cfg.num_experts, cfg.top_k
    cf = cfg.moe_capacity_factor if capacity_factor is None else capacity_factor
    C = moe_capacity(S, X, K, cf)

    logits = jnp.einsum("bse,ex->bsx", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,S,X)
    gate_w, gate_i = jax.lax.top_k(probs, K)                      # (B,S,K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # position-in-expert via cumsum of one-hot over flattened (S*K)
    flat_i = gate_i.reshape(B, S * K)                             # (B,T)
    onehot = jax.nn.one_hot(flat_i, X, dtype=jnp.int32)           # (B,T,X)
    pos_all = jnp.cumsum(onehot, axis=1) - 1                      # (B,T,X)
    pos = jnp.take_along_axis(
        pos_all, flat_i[..., None], axis=-1)[..., 0]              # (B,T)
    keep = pos < C

    # dispatch: scatter tokens into (B, X, C, E).
    # The scatter/gather pair MUST run on batch-only sharding: with the
    # buffer sharded on the expert dim, the flat (X*C) token gather crosses
    # expert shards and SPMD falls back to replicating the whole (B,X*C,E)
    # buffer per layer (arctic: 4.6 TB/dev of all-gathers — EXPERIMENTS.md
    # §Perf arctic iter 1). Constraining batch-only here and expert-sharded
    # around the expert FFN yields the canonical MoE all-to-all pair.
    tok = jnp.repeat(x, K, axis=1)                                # (B,T,E) bf16
    slot = jnp.where(keep, flat_i * C + pos, X * C)               # overflow slot
    dispatch = jnp.zeros((B, X * C + 1, E), x.dtype)
    dispatch = dispatch.at[
        jnp.arange(B)[:, None], slot].add(tok)                    # (B,XC+1,E)
    dispatch = rules.constrain(dispatch, "batch", None, None)
    xe = dispatch[:, :-1].reshape(B, X, C, E)
    exp_ax = "act_experts" if cfg.expert_shard == "ep" else None
    ff_ax = "act_expert_ff" if cfg.expert_shard == "ep" else "act_ff"
    xe = rules.constrain(xe, "batch", exp_ax, None, None)   # a2a: to experts

    # expert FFN (batched over experts; experts or their ff dim sharded on
    # "model" per cfg.expert_shard)
    if cfg.mlp in GLU:
        h = _act(cfg.mlp, jnp.einsum("bxce,xef->bxcf", xe, p["w_gate"]))
        h = h * jnp.einsum("bxce,xef->bxcf", xe, p["w_up"])
    else:
        h = _act(cfg.mlp, jnp.einsum("bxce,xef->bxcf", xe, p["w_up"]))
    h = rules.constrain(h, "batch", exp_ax, None, ff_ax)
    ye = jnp.einsum("bxcf,xfe->bxce", h, p["w_down"])              # (B,X,C,E)
    ye = rules.constrain(ye, "batch", None, None, None)      # a2a: back

    # combine: gather each token's expert output, weight, sum over K
    # (local: buffer and indices are both batch-sharded here)
    flat_slot = jnp.minimum(flat_i * C + pos, X * C - 1)
    yt = jnp.take_along_axis(
        ye.reshape(B, X * C, E), flat_slot[..., None], axis=1)     # (B,T,E)
    yt = yt * (gate_w.reshape(B, S * K, 1) * keep[..., None]).astype(yt.dtype)
    out = yt.reshape(B, S, K, E).sum(axis=2).astype(x.dtype)

    # load-balance aux (Switch-style) + stats for the balance report
    me = probs.mean(axis=(0, 1))                                   # (X,)
    ce = (onehot.sum(axis=(0, 1)) / (B * S * K)).astype(jnp.float32)
    aux = {
        "lb_loss": X * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2),
        "expert_load": ce,
        "dropped_frac": 1.0 - keep.mean(),
    }
    return out, aux
