"""Shared layer primitives: norms, embeddings, RoPE, positional encodings, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- init helpers
def dense_init(key, fan_in, shape, dtype):
    scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------------- norms
def rms_norm(x, scale, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def make_norm_params(cfg, key, d):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype_of(cfg))}
    return {"scale": jnp.ones((d,), dtype_of(cfg)),
            "bias": jnp.zeros((d,), dtype_of(cfg))}


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["scale"], cfg.norm_eps)
    return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)


# ------------------------------------------------------------------------ RoPE
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta))           # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., :, None, :]                           # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len, d_model, offset=0):
    pos = np.arange(offset, offset + seq_len, dtype=np.float32)[:, None]
    dim = np.arange(0, d_model, 2, dtype=np.float32)[None, :]
    angle = pos / np.power(10_000.0, dim / d_model)
    enc = np.zeros((seq_len, d_model), np.float32)
    enc[:, 0::2] = np.sin(angle)
    enc[:, 1::2] = np.cos(angle)
    return jnp.asarray(enc)


# ----------------------------------------------------------------- embeddings
def make_embedding(cfg, key):
    return {"tok": embed_init(key, (cfg.padded_vocab, cfg.d_model), dtype_of(cfg))}


def embed_tokens(cfg, params, tokens, rules):
    x = params["tok"][tokens]
    if cfg.name.startswith("gemma") or cfg.family == "vlm":   # gemma-family scaling
        x = (x.astype(jnp.float32) * np.sqrt(cfg.d_model)).astype(x.dtype)
    return rules.constrain(x, "batch", "seq", "embed")


def logits_from_hidden(cfg, params, x, unembed=None):
    """x: (B,S,E) -> (B,S,padded_vocab) float32."""
    w = params["tok"] if unembed is None else unembed
    logits = jnp.einsum("bse,ve->bsv", x, w).astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
