"""Mamba2 (SSD) block: chunked-scan training form + single-token decode form.

Chunked state-space dual form (Dao & Gu 2024): sequence is processed in chunks
of `ssm_chunk`; within a chunk the quadratic masked-decay form runs on the MXU,
between chunks a lax.scan carries the (B,H,P,N) state. All decays are computed
in log space (f32) for stability.

Sharding: d_inner (x/z projections, conv channels, heads) maps to the "model"
axis; the SSM state dims (P,N) stay local to a head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, dtype_of


def mamba_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    P = min(64, d_inner)                     # head dim
    H = d_inner // P
    return d_inner, H, P, cfg.ssm_state


def init_mamba(cfg, key):
    dt = dtype_of(cfg)
    E = cfg.d_model
    d_inner, H, P, N = mamba_dims(cfg)
    ks = jax.random.split(key, 8)
    dt_init = np.log(np.expm1(np.exp(np.random.RandomState(0).uniform(
        np.log(1e-3), np.log(1e-1), size=(H,)))))
    return {
        "w_z": dense_init(ks[0], E, (E, d_inner), dt),
        "w_x": dense_init(ks[1], E, (E, d_inner), dt),
        "w_B": dense_init(ks[2], E, (E, N), dt),
        "w_C": dense_init(ks[3], E, (E, N), dt),
        "w_dt": dense_init(ks[4], E, (E, H), dt),
        "dt_bias": jnp.asarray(dt_init, jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "conv_x": dense_init(ks[5], cfg.ssm_conv, (cfg.ssm_conv, d_inner), dt),
        "conv_B": dense_init(ks[6], cfg.ssm_conv, (cfg.ssm_conv, N), dt),
        "conv_C": dense_init(ks[7], cfg.ssm_conv, (cfg.ssm_conv, N), dt),
        "norm": jnp.zeros((d_inner,), dt),
        "w_out": dense_init(ks[4], d_inner, (d_inner, E), dt),
    }


MAMBA_SPECS = {
    "w_z": ("w_embed", "ff"), "w_x": ("w_embed", "ff"),
    "w_B": ("w_embed", None), "w_C": ("w_embed", None),
    "w_dt": ("w_embed", None), "dt_bias": (None,), "A_log": (None,),
    "D": (None,), "conv_x": (None, "ff"), "conv_B": (None, None),
    "conv_C": (None, None), "norm": ("ff",), "w_out": ("ff", "w_embed"),
}


def _causal_conv(x, w):
    """x: (B,S,C), w: (k,C) depthwise causal conv as k shifted adds."""
    k = w.shape[0]
    out = x * w[-1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[k - 1 - i]
    return out


def _gated_rmsnorm(y, z, scale, eps=1e-5):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))


def _ssd_chunked(xdt, a, Bm, Cm, chunk, state0=None):
    """Chunked SSD scan.

    xdt: (B,S,H,P) inputs pre-multiplied by dt; a: (B,S,H) log-decay dt*A;
    Bm/Cm: (B,S,N). Returns y: (B,S,H,P) (f32) and final state (B,H,P,N)."""
    B_, S, H, P = xdt.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q
    xs = jnp.moveaxis(xdt.reshape(B_, nc, Q, H, P), 1, 0)
    as_ = jnp.moveaxis(a.reshape(B_, nc, Q, H), 1, 0)
    Bs = jnp.moveaxis(Bm.reshape(B_, nc, Q, N), 1, 0)
    Cs = jnp.moveaxis(Cm.reshape(B_, nc, Q, N), 1, 0)
    tril = jnp.tril(jnp.ones((Q, Q), bool))

    @jax.checkpoint
    def body(state, inp):
        x_c, a_c, B_c, C_c = inp                     # (B,Q,H,P),(B,Q,H),(B,Q,N)
        a_t = jnp.moveaxis(a_c, -1, 1).astype(jnp.float32)   # (B,H,Q)
        a_cs = jnp.cumsum(a_t, axis=-1)                       # (B,H,Q)
        # intra-chunk: masked decay matrix
        L = jnp.where(tril, jnp.exp(a_cs[..., :, None] - a_cs[..., None, :]),
                      0.0)                                    # (B,H,Q,Q)
        scores = jnp.einsum("bqn,bkn->bqk", C_c, B_c,
                            preferred_element_type=jnp.float32)
        Y_diag = jnp.einsum("bqk,bhqk,bkhp->bqhp", scores, L,
                            xs_f32 := x_c.astype(jnp.float32))
        # contribution of the carried-in state
        decay_out = jnp.exp(a_cs)                             # (B,H,Q)
        Y_off = jnp.einsum("bqn,bhpn,bhq->bqhp", C_c.astype(jnp.float32),
                           state, decay_out)
        # new state
        decay_in = jnp.exp(a_cs[..., -1:] - a_cs)             # (B,H,Q)
        chunk_state = jnp.einsum("bkn,bhk,bkhp->bhpn",
                                 B_c.astype(jnp.float32), decay_in, xs_f32)
        state = state * jnp.exp(a_cs[..., -1])[..., None, None] + chunk_state
        return state, Y_diag + Y_off

    if state0 is None:
        state0 = jnp.zeros((B_, H, P, N), jnp.float32)
    state, ys = jax.lax.scan(body, state0, (xs, as_, Bs, Cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, S, H, P)
    return y, state


def apply_mamba(cfg, p, x, rules, state0=None, return_state=False,
                return_cache=False):
    """Training/prefill form. x: (B,S,E) -> (B,S,E).

    return_cache: also return a decode-compatible cache (final SSM state +
    conv input tails), for prefill-then-serve."""
    d_inner, H, P, N = mamba_dims(cfg)
    z = x @ p["w_z"]
    xc_in = x @ p["w_x"]
    bc_in = x @ p["w_B"]
    cc_in = x @ p["w_C"]
    xi = _causal_conv(xc_in, p["conv_x"])
    xi = jax.nn.silu(xi)
    xi = rules.constrain(xi, "batch", "seq", "act_ff")
    Bm = jax.nn.silu(_causal_conv(bc_in, p["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(cc_in, p["conv_C"]))
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                  # (H,) negative
    B_, S, _ = x.shape
    xh = xi.reshape(B_, S, H, P)
    xdt = xh * dt[..., None].astype(xh.dtype)
    a = dt * A                                                # (B,S,H) log decay
    y, state = _ssd_chunked(xdt, a, Bm, Cm, cfg.ssm_chunk, state0)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = _gated_rmsnorm(y.reshape(B_, S, d_inner), z, p["norm"])
    out = (y.astype(x.dtype) @ p["w_out"])
    if return_cache:
        t = cfg.ssm_conv - 1
        cache = {"state": state, "conv_x": xc_in[:, -t:],
                 "conv_B": bc_in[:, -t:], "conv_C": cc_in[:, -t:]}
        return out, cache
    if return_state:
        return out, state
    return out


def init_mamba_cache(cfg, batch, dtype=jnp.float32):
    d_inner, H, P, N = mamba_dims(cfg)
    k = cfg.ssm_conv
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, k - 1, d_inner), dtype),
        "conv_B": jnp.zeros((batch, k - 1, N), dtype),
        "conv_C": jnp.zeros((batch, k - 1, N), dtype),
    }


def decode_mamba(cfg, p, x, cache, rules):
    """Single-token step. x: (B,E); cache from init_mamba_cache."""
    d_inner, H, P, N = mamba_dims(cfg)

    def conv_step(hist, xt, w):
        buf = jnp.concatenate([hist, xt[:, None]], axis=1)    # (B,k,C)
        out = jnp.einsum("bkc,kc->bc", buf, w)
        return out, buf[:, 1:]

    z = x @ p["w_z"]
    xc, conv_x = conv_step(cache["conv_x"], x @ p["w_x"], p["conv_x"])
    xi = jax.nn.silu(xc)
    Bc, conv_B = conv_step(cache["conv_B"], x @ p["w_B"], p["conv_B"])
    Cc, conv_C = conv_step(cache["conv_C"], x @ p["w_C"], p["conv_C"])
    Bm, Cm = jax.nn.silu(Bc), jax.nn.silu(Cc)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    B_ = x.shape[0]
    xh = xi.reshape(B_, H, P).astype(jnp.float32)
    da = jnp.exp(dt * A)                                       # (B,H)
    state = cache["state"] * da[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32), xh)
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = _gated_rmsnorm(y.reshape(B_, d_inner), z, p["norm"])
    out = y.astype(x.dtype) @ p["w_out"]
    new_cache = {"state": state, "conv_x": conv_x, "conv_B": conv_B,
                 "conv_C": conv_C}
    return out, new_cache
