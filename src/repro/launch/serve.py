"""Serving driver: batched decode against a (reduced, CPU-runnable) model.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --reduced \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models.zoo import build_model
from repro.distributed.sharding import NULL_RULES
from repro.serve.engine import ServeEngine, RequestQueue


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params,
                         max_seq=args.prompt_len + args.gen + 8)
    q = RequestQueue(engine, args.batch, args.prompt_len, args.gen)

    rng = np.random.RandomState(args.seed)
    rids = [q.submit(rng.randint(0, cfg.vocab_size, size=args.prompt_len))
            for _ in range(args.requests)]
    t0 = time.time()
    done = []
    while len(done) < len(rids):
        done.extend(q.pump())
    dt = time.time() - t0
    n_tok = len(rids) * args.gen
    print(f"served {len(rids)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")
    sample = q.result(rids[0])
    print("sample output tokens:", sample[:16].tolist())
    return done


if __name__ == "__main__":
    main()
