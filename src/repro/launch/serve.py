"""Serving driver, two modes:

LM decode (the model-zoo twin):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --reduced \
      --batch 4 --prompt-len 16 --gen 32

Audio preprocessing behind the serving subsystem — a persistent worker
pool plus the continuous batcher, fed by synthetic concurrent clients:
  PYTHONPATH=src python -m repro.launch.serve --audio \
      --pool-workers 2 --pool-transport proc --clients 4 --requests 12 \
      --max-batch 4 --linger-ms 20

The audio mode is the operational entry point for the serving tier: it
stands up a `WorkerPool` (long-lived `repro.dist` workers, warm jits
across waves), fronts it with a `ContinuousBatcher` (pow2 zero-padded
batch assembly, admission control, per-request deadlines), drives it
with concurrent client threads, and reports p50/p99 latency, batch
occupancy, and the per-worker ledger. `benchmarks/bench_serving.py` is
the calibrated load-test version of the same loop.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def _lm_main(args):
    import jax

    from repro.configs import get_config, reduced as reduce_cfg
    from repro.models.zoo import build_model
    from repro.serve.engine import ServeEngine, RequestQueue

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params,
                         max_seq=args.prompt_len + args.gen + 8)
    q = RequestQueue(engine, args.batch, args.prompt_len, args.gen)

    rng = np.random.RandomState(args.seed)
    rids = [q.submit(rng.randint(0, cfg.vocab_size, size=args.prompt_len))
            for _ in range(args.requests)]
    t0 = time.time()
    done = []
    while len(done) < len(rids):
        done.extend(q.pump())
    dt = time.time() - t0
    n_tok = len(rids) * args.gen
    print(f"served {len(rids)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")
    sample = q.result(rids[0])
    print("sample output tokens:", sample[:16].tolist())
    return done


def _audio_main(args):
    from repro.configs import SERF_AUDIO as cfg
    from repro.data.loader import audio_batch_maker
    from repro.obs import metrics as obs_metrics
    from repro.obs import telemetry as obs_telemetry
    from repro.obs import tracing as obs_tracing
    from repro.serve import ContinuousBatcher, WorkerPool

    telem = (obs_telemetry.TelemetryWriter(args.telemetry)
             if args.telemetry else None)
    tracer = None
    if args.trace:
        tracer = obs_tracing.Tracer()
        obs_tracing.set_tracer(tracer)
        tracer.start_run("serve_run")
    make = audio_batch_maker(seed=args.seed, batch_long_chunks=1)
    pool = WorkerPool(cfg, workers=args.pool_workers,
                      transport=args.pool_transport,
                      poll_s=args.poll_ms / 1e3,
                      min_workers=args.pool_min_workers,
                      max_workers=args.pool_max_workers,
                      speculate=args.pool_speculate,
                      store=args.pool_store,
                      telemetry=telem).start()
    batcher = ContinuousBatcher(pool=pool, max_batch=args.max_batch,
                                max_queue=args.max_queue,
                                linger_s=args.linger_ms / 1e3)
    lat, lock = [], threading.Lock()

    def client(cid):
        rng = np.random.RandomState(args.seed * 1000 + cid)
        for i in range(args.requests):
            chunk = make(cid * args.requests + i)[0][0]
            t0 = time.monotonic()
            rid = batcher.submit(chunk, timeout_s=args.timeout_s)
            rec = batcher.wait(rid, timeout_s=600.0)
            with lock:
                lat.append((time.monotonic() - t0, rec["ok"]))
            time.sleep(float(rng.exponential(1.0 / args.rate_hz)))

    t0 = time.time()
    with batcher:
        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    wall = time.time() - t0
    pool.shutdown(drain=True)

    if tracer is not None:
        tracer.finish_run()
        tracer.save(args.trace)
        print(f"trace: {len(tracer.events)} events -> {args.trace}")
    if telem is not None:
        telem.close()
        print(f"telemetry: {telem.records_written} records -> "
              f"{args.telemetry}")
    ok = [l for l, good in lat if good]
    print(f"served {len(ok)}/{len(lat)} requests in {wall:.1f}s "
          f"({len(ok) / wall:.2f} req/s)")
    if ok:
        print(f"latency p50 {np.percentile(ok, 50) * 1e3:.0f} ms, "
              f"p99 {np.percentile(ok, 99) * 1e3:.0f} ms")
    print(f"batcher: {batcher.stats()}")
    print("workers:", [(s.worker, s.pid, s.state, s.chunks_done)
                       for s in pool.worker_stats])
    if args.pool_max_workers is not None:
        print(f"autoscale: {pool.scale_ups} scale-ups, "
              f"{pool.scale_downs} scale-downs, membership epoch "
              f"{pool.service.epoch}")
    if args.trace or args.telemetry:
        for line in obs_metrics.summary_lines():
            print("metrics:", line)
    return lat


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--audio", action="store_true",
                    help="serve audio preprocessing via the worker pool "
                         "+ continuous batcher (default: LM decode)")
    ap.add_argument("--seed", type=int, default=0)
    # LM mode
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8,
                    help="requests total (LM) / per client (audio)")
    # audio serving mode
    ap.add_argument("--pool-workers", type=int, default=2)
    ap.add_argument("--pool-min-workers", type=int, default=None,
                    help="autoscale floor (default: --pool-workers, i.e. "
                         "a fixed fleet)")
    ap.add_argument("--pool-max-workers", type=int, default=None,
                    help="autoscale ceiling: arms queue-depth-driven "
                         "scale-up on sustained backlog and scale-down "
                         "by draining idle workers (default: off)")
    ap.add_argument("--pool-speculate", action="store_true",
                    help="speculatively duplicate the slowest in-flight "
                         "request onto an idle worker (first completion "
                         "wins)")
    ap.add_argument("--pool-transport", default="proc",
                    choices=("proc", "inproc", "tcp"))
    ap.add_argument("--pool-store", default=None, metavar="DIR",
                    help="audio mode: store data plane — workers fetch "
                         "chunks from / push results into a shared "
                         "ChunkStore at DIR; the pool socket carries only "
                         "leases and key refs")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rate-hz", type=float, default=1.0,
                    help="per-client mean arrival rate")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--linger-ms", type=float, default=20.0)
    ap.add_argument("--poll-ms", type=float, default=5.0)
    ap.add_argument("--timeout-s", type=float, default=None,
                    help="per-request deadline (default: none)")
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="audio mode: durable per-chunk JSONL telemetry, "
                         "written master-side at acceptance")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="audio mode: Chrome trace-event JSON of the "
                         "serving run (requests appear as async spans)")
    args = ap.parse_args(argv)
    if (args.telemetry or args.trace) and not args.audio:
        ap.error("--telemetry/--trace instrument the audio serving tier")
    return _audio_main(args) if args.audio else _lm_main(args)


if __name__ == "__main__":
    main()
