"""The paper's end-to-end driver: preprocess a stream of bird-acoustic long
chunks through the stage-graph pipeline under a chosen execution plan.

  PYTHONPATH=src python -m repro.launch.preprocess --minutes 8 --plan streaming
  PYTHONPATH=src python -m repro.launch.preprocess --plan sharded --shards 4

Reports per-stage removal fractions and throughput (the paper's headline
metric: MB/s of source audio preprocessed; their 4-VM x 4-core figure was
16.4-16.5 MB/s). Per-batch stats are aggregated weighted by chunk count, so
uneven batches don't skew the fractions. The sharded plan additionally
reports queue redeliveries and the last round's survivor re-shard loads.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import SERF_AUDIO
from repro.core.plans import PLANS, Preprocessor
from repro.core.scheduler import balance_stats
from repro.data.loader import AudioChunkLoader, audio_shard_pool
from repro.distributed.sharding import ShardingRules, pool_rules
from repro.launch.mesh import make_local_mesh

_FRAC_KEYS = ("frac_rain", "frac_silence", "frac_kept", "frac_cicada15")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=4.0)
    ap.add_argument("--batch-long-chunks", type=int, default=4)
    ap.add_argument("--plan", "--mode", dest="plan", default="two_phase",
                    choices=sorted(PLANS))
    ap.add_argument("--shards", type=int, default=2,
                    help="simulated shard count for --plan sharded")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = SERF_AUDIO
    n_batches = max(1, int(round(args.minutes / args.batch_long_chunks)))
    mesh = make_local_mesh()
    pad = max(1, len(jax.devices()))
    if args.plan == "sharded":
        # per-shard loaders over ONE shared leased queue; shards share this
        # process's mesh, so their compiles dedup in the CompileCache
        loader = audio_shard_pool(
            seed=args.seed, n_batches=n_batches, n_shards=args.shards,
            batch_long_chunks=args.batch_long_chunks)
        pre = Preprocessor(cfg, pool_rules(args.shards, mesh),
                           plan="sharded", pad_multiple=pad,
                           shards=args.shards)
    else:
        loader = AudioChunkLoader(seed=args.seed, n_batches=n_batches,
                                  batch_long_chunks=args.batch_long_chunks)
        pre = Preprocessor(cfg, ShardingRules(mesh), plan=args.plan,
                           pad_multiple=pad)

    tot_bytes = tot_kept = tot_chunks = 0
    agg = {k: 0.0 for k in _FRAC_KEYS}
    last_keep = None
    t0 = time.time()
    for res in pre.run(loader):
        w = float(res.det.stats["n_chunks5"])    # weight: chunks in batch
        for k in _FRAC_KEYS:
            agg[k] += float(res.det.stats[k]) * w
        tot_bytes += res.src_bytes
        tot_kept += res.n_kept
        tot_chunks += int(w)
        last_keep = res.det.keep
    dt = time.time() - t0
    if tot_chunks == 0:
        print("empty stream: the loader yielded no batches — nothing to do")
        return 0
    frac = {k: agg[k] / tot_chunks for k in _FRAC_KEYS}
    print(f"plan={args.plan}  {tot_bytes / 2**20:.0f} MB source audio "
          f"in {dt:.1f}s  ->  {tot_bytes / 2**20 / dt:.2f} MB/s")
    print(f"chunks kept {tot_kept}/{tot_chunks} "
          f"(rain {frac['frac_rain']:.1%}, "
          f"silence {frac['frac_silence']:.1%}, "
          f"cicada-filtered {frac['frac_cicada15']:.1%})")
    bs = jax.jit(lambda k: balance_stats(k, len(jax.devices())))(last_keep)
    print(f"survivor load imbalance (max/mean): "
          f"{float(bs['imbalance']):.3f} -> "
          f"{float(bs['imbalance_after_compact']):.3f} after compaction")
    if args.plan == "sharded":
        asg = pre.plan.last_assignment
        print(f"shards={args.shards} redeliveries={pre.plan.redeliveries}")
        if asg is not None:
            st = asg.stats()
            print(f"last-round survivor re-shard: "
                  f"{st['loads_before'].tolist()} -> "
                  f"{st['loads_after'].tolist()} "
                  f"(max/min {st['max_min_before']:.2f} -> "
                  f"{st['max_min_after']:.2f}, moved {st['moved']})")
    return tot_kept


if __name__ == "__main__":
    main()
