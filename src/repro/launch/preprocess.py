"""The paper's end-to-end driver: preprocess a stream of bird-acoustic long
chunks through the stage-graph pipeline under a chosen execution plan.

  PYTHONPATH=src python -m repro.launch.preprocess --minutes 8 --plan async --depth 4
  PYTHONPATH=src python -m repro.launch.preprocess --plan sharded --shards 4
  PYTHONPATH=src python -m repro.launch.preprocess --plan sharded --transport proc --shards 2 --lease-items 4
  PYTHONPATH=src python -m repro.launch.preprocess --plan sharded --store /data/store
  PYTHONPATH=src python -m repro.launch.preprocess --store /data/store --resume

Reports per-stage removal fractions and throughput (the paper's headline
metric: MB/s of source audio preprocessed; their 4-VM x 4-core figure was
16.4-16.5 MB/s). Per-batch stats are aggregated weighted by chunk count, so
uneven batches don't skew the fractions. The sharded plan additionally
reports queue redeliveries, the last round's survivor re-shard loads, and a
per-worker progress summary (leases held, chunks done, redeliveries charged,
heartbeat age, idle/busy split) — under BOTH transports: `--transport
inproc` is the simulated single-process mode, `--transport proc` spawns
real worker processes (`python -m repro.dist.worker`) that pull leases over
the master's socket in batches of `--lease-items` (the paper's Table 7
`max_queue_size` knob).

`--plan` choices come straight from the `PLANS` registry, so new plans
appear here without touching this driver. `--plan async` is the deep
pipeline (`--depth` detect batches in flight, device-resident survivor
compaction, bucketed tail shapes via `--bucket`); plans that record
per-batch timings get a per-stage pipeline report (dispatch / mask
readback / compact / tail / emit, overlap count, host-boundary bytes).
`--store DIR` wraps the chosen plan in `CachedPlan` over a
content-addressed `repro.store.ChunkStore` (re-runs over overlapping data
become lookups) plus a `RunJournal`; `--resume` relaunches a killed
`--store` run mid-stream with each chunk emitted exactly once;
`--store-max-bytes` runs the store's least-recently-hit retention sweep
after the run so a rolling archive's cache stays bounded.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import SERF_AUDIO
from repro.core.plans import PLANS, Preprocessor
from repro.core.scheduler import balance_stats
from repro.data.loader import AudioChunkLoader, audio_shard_pool
from repro.distributed.sharding import ShardingRules, pool_rules
from repro.launch.mesh import make_local_mesh
from repro.obs import metrics as obs_metrics
from repro.obs import telemetry as obs_telemetry
from repro.obs import tracing as obs_tracing

_FRAC_KEYS = ("frac_rain", "frac_silence", "frac_kept", "frac_cicada15")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=4.0)
    ap.add_argument("--batch-long-chunks", type=int, default=4)
    # the registry IS the choice list: a newly registered plan (e.g.
    # 'cached') shows up here with zero driver edits
    ap.add_argument("--plan", "--mode", dest="plan", default="two_phase",
                    choices=sorted(PLANS))
    ap.add_argument("--shards", type=int, default=2,
                    help="shard / worker count for --plan sharded")
    ap.add_argument("--transport", choices=("inproc", "proc", "tcp"),
                    default="inproc",
                    help="sharded worker runtime: 'inproc' simulates "
                         "every shard in this process (deterministic, "
                         "zero spawn cost); 'proc' runs real worker "
                         "processes over the repro.dist socket transport; "
                         "'tcp' binds non-loopback so workers can join "
                         "from other hosts (pair with --data-plane-store)")
    ap.add_argument("--data-plane-store", default=None, metavar="DIR",
                    help="move the sharded data plane off the master's "
                         "socket: chunk bytes and result payloads flow "
                         "through a shared ChunkStore at DIR, the socket "
                         "carries only content keys (proc/tcp transports)")
    ap.add_argument("--no-speculate", action="store_true",
                    help="disable speculative re-lease of end-of-stream "
                         "stragglers (sharded plan; on by default under "
                         "the proc transport)")
    ap.add_argument("--lease-items", type=int, default=1,
                    help="work ids per queue round-trip (the paper's "
                         "Table 7 max_queue_size knob) for --plan sharded")
    ap.add_argument("--depth", type=int, default=None,
                    help="detect dispatch-ahead window for --plan async "
                         "(default 4)")
    ap.add_argument("--bucket", choices=("pow2", "linear"), default=None,
                    help="survivor-count quantization for the tail jit "
                         "(default: the plan's own — pow2 for async, "
                         "linear elsewhere)")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="content-addressed result store: wraps the chosen "
                         "plan in CachedPlan + a resume journal")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed --store run from its journal "
                         "(exactly-once emission across the restart)")
    ap.add_argument("--store-max-bytes", type=int, default=None,
                    help="after the run, evict least-recently-hit store "
                         "entries until the payload fits this budget")
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="write one durable JSONL telemetry record per "
                         "chunk (master-side, at acceptance — survives "
                         "killed workers) into DIR")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="write a Chrome trace-event JSON of the run "
                         "(load in chrome://tracing or Perfetto); sharded "
                         "proc workers ship their spans back at sign-off")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.resume and not args.store:
        ap.error("--resume requires --store")
    if args.store_max_bytes is not None and not args.store:
        ap.error("--store-max-bytes requires --store")

    cfg = SERF_AUDIO
    n_batches = max(1, int(round(args.minutes / args.batch_long_chunks)))
    mesh = make_local_mesh()
    pad = max(1, len(jax.devices()))
    sharded = args.plan == "sharded"
    if not sharded:
        if args.transport != "inproc":
            ap.error("--transport picks the sharded plan's worker "
                     f"runtime; plan '{args.plan}' has no workers")
        if args.lease_items != 1:
            ap.error("--lease-items batches the sharded plan's queue "
                     f"pulls; plan '{args.plan}' has no lease loop")
        if args.no_speculate:
            ap.error("--no-speculate disables the sharded plan's "
                     f"speculative re-lease; plan '{args.plan}' has none")
        if args.data_plane_store:
            ap.error("--data-plane-store moves the sharded plan's worker "
                     f"data plane; plan '{args.plan}' has no workers")
    if args.data_plane_store and args.transport == "inproc":
        ap.error("--data-plane-store rides the proc/tcp worker runtime "
                 "(the in-proc simulated loop never serializes chunks)")
    rules = pool_rules(args.shards, mesh) if sharded else ShardingRules(mesh)
    plan_kwargs = {"shards": args.shards, "transport": args.transport,
                   "lease_items": args.lease_items,
                   "data_plane": args.data_plane_store,
                   # None = the plan's default (on for proc workers)
                   "speculate": False if args.no_speculate else None} \
        if sharded else {}
    if args.plan == "async":
        plan_kwargs["depth"] = 4 if args.depth is None else args.depth
    elif args.depth is not None:
        ap.error(f"--depth is the async plan's dispatch-ahead window; "
                 f"plan '{args.plan}' has no use for it")
    if args.bucket is not None:
        if args.plan not in ("two_phase", "streaming", "async", "cached"):
            # sharded pads through its Rebalancer (cross-shard re-slicing
            # has its own shape economy), fused has no tail at all
            ap.error(f"--bucket selects the tail-shape quantization of "
                     f"the single-stream two-phase-family plans; plan "
                     f"'{args.plan}' does not take it")
        plan_kwargs["bucket"] = args.bucket
    if args.store:
        # CachedPlan must see chunk content before dispatch, so even a
        # sharded inner is fed the plain stream (it builds its leased pool
        # internally); hits never reach the inner plan at all
        inner = "two_phase" if args.plan == "cached" else args.plan
        plan, plan_kwargs = "cached", {
            "inner": inner, "store": args.store, "journal": True,
            "resume": args.resume, **plan_kwargs}
        loader = AudioChunkLoader(seed=args.seed, n_batches=n_batches,
                                  batch_long_chunks=args.batch_long_chunks)
    elif sharded:
        # per-shard loaders over ONE shared leased queue; in-proc shards
        # share this process's mesh so their compiles dedup in the
        # CompileCache, proc workers compile in their own processes
        plan = "sharded"
        # proc workers heartbeat per item, but the FIRST item of a batch
        # carries the jit compile (~minute on CPU) — give real processes
        # a lease long enough that a healthy compiling worker is never
        # mistaken for a dead one
        loader = audio_shard_pool(
            seed=args.seed, n_batches=n_batches, n_shards=args.shards,
            batch_long_chunks=args.batch_long_chunks,
            lease_items=args.lease_items,
            lease_timeout_s=300.0 if args.transport in ("proc", "tcp")
            else 60.0)
    else:
        plan = args.plan
        loader = AudioChunkLoader(seed=args.seed, n_batches=n_batches,
                                  batch_long_chunks=args.batch_long_chunks)
    telem = (obs_telemetry.TelemetryWriter(args.telemetry)
             if args.telemetry else None)
    tracer = None
    if args.trace:
        tracer = obs_tracing.Tracer()
        obs_tracing.set_tracer(tracer)
        tracer.start_run("preprocess_run")
    if telem is not None and plan == "sharded":
        # the sharded plan's QueueService writes the records itself, at
        # master-side acceptance — a SIGKILLed worker cannot lose them
        plan_kwargs["telemetry"] = telem
    pre = Preprocessor(cfg, rules, plan=plan, pad_multiple=pad,
                       **plan_kwargs)

    tot_bytes = tot_kept = tot_chunks = 0
    agg = {k: 0.0 for k in _FRAC_KEYS}
    last_keep = None
    timings = []
    t0 = time.time()
    for i, res in enumerate(pre.run(loader)):
        w = float(res.det.stats["n_chunks5"])    # weight: chunks in batch
        for k in _FRAC_KEYS:
            agg[k] += float(res.det.stats[k]) * w
        tot_bytes += res.src_bytes
        tot_kept += res.n_kept
        tot_chunks += int(w)
        last_keep = res.det.keep
        if res.timings is not None:
            timings.append(res.timings)
        if telem is not None and plan != "sharded":
            # single-process plans have no acceptance point but this loop
            wid = res.wid if res.wid is not None else i
            obs_telemetry.record_result(telem, wid, res)
    dt = time.time() - t0
    if tracer is not None:
        tracer.finish_run()
        tracer.save(args.trace)
        print(f"trace: {len(tracer.events)} events -> {args.trace}")
    if telem is not None:
        telem.close()
        print(f"telemetry: {telem.records_written} records -> "
              f"{args.telemetry}")
    if args.trace or args.telemetry:
        for line in obs_metrics.summary_lines():
            print("metrics:", line)
    cached = pre.plan if plan == "cached" else None
    exec_plan = cached.inner if cached is not None else pre.plan
    if tot_chunks == 0:
        if cached is not None and args.resume:
            print("nothing left to emit: the journal shows every chunk of "
                  "this stream was already emitted before the kill")
        else:
            print("empty stream: the loader yielded no batches — "
                  "nothing to do")
        return 0
    frac = {k: agg[k] / tot_chunks for k in _FRAC_KEYS}
    print(f"plan={args.plan}  {tot_bytes / 2**20:.0f} MB source audio "
          f"in {dt:.1f}s  ->  {tot_bytes / 2**20 / dt:.2f} MB/s")
    print(f"chunks kept {tot_kept}/{tot_chunks} "
          f"(rain {frac['frac_rain']:.1%}, "
          f"silence {frac['frac_silence']:.1%}, "
          f"cicada-filtered {frac['frac_cicada15']:.1%})")
    bs = jax.jit(lambda k: balance_stats(k, len(jax.devices())))(last_keep)
    print(f"survivor load imbalance (max/mean): "
          f"{float(bs['imbalance']):.3f} -> "
          f"{float(bs['imbalance_after_compact']):.3f} after compaction")
    if exec_plan.name == "sharded":
        asg = exec_plan.last_assignment
        dp = " data_plane=store" if args.data_plane_store else ""
        print(f"shards={args.shards} transport={args.transport}{dp} "
              f"lease_items={args.lease_items} "
              f"redeliveries={exec_plan.redeliveries} "
              f"speculations={exec_plan.speculations} "
              f"(lost races {exec_plan.speculations_lost})")
        if asg is not None:
            st = asg.stats()
            print(f"last-round survivor re-shard: "
                  f"{st['loads_before'].tolist()} -> "
                  f"{st['loads_after'].tolist()} "
                  f"(max/min {st['max_min_before']:.2f} -> "
                  f"{st['max_min_after']:.2f}, moved {st['moved']})")
        for line in worker_summary(exec_plan.worker_stats):
            print(line)
    if timings:
        report = pipeline_report(timings)
        stages = "  ".join(f"{k} {report[k + '_ms']:.2f}ms"
                           for k in ("dispatch", "readback", "compact",
                                     "tail", "emit"))
        print(f"pipeline: {stages}")
        print(f"pipeline: {report['overlapped']}/{report['batches']} "
              f"overlapped dispatches (max in-flight "
              f"{report['max_in_flight']}), host boundary "
              f"{report['d2h_bytes_per_batch'] / 2**20:.2f} MB down + "
              f"{report['h2d_bytes_per_batch'] / 2**10:.1f} KB up per "
              f"batch (the old host-compaction round-trip moved "
              f"{report['old_boundary_bytes_per_batch'] / 2**20:.2f} MB "
              f"on this stream)")
    if cached is not None and cached.stats is not None:
        print(f"store: {cached.stats}")
    if args.store_max_bytes is not None and cached is not None \
            and cached.store is not None:
        rep = cached.store.gc(args.store_max_bytes)
        print(f"store gc: {rep['evicted']} entries / "
              f"{rep['bytes_freed'] / 2**20:.1f} MB evicted -> "
              f"{rep['entries_after']} entries / "
              f"{rep['bytes_after'] / 2**20:.1f} MB retained")
    return tot_kept


def worker_summary(worker_stats):
    """Per-worker progress lines for the end-of-run summary (sharded plan,
    both transports): queue round-trips vs work ids granted (the lease-
    batching economy), chunks finished, leases still held, redeliveries
    charged to the worker (its lost leases), final membership state
    (late joiners appear here; drained workers read "departed"),
    heartbeat age, and — proc transport only — the worker-reported
    idle/busy split."""
    lines = []
    for st in worker_stats or ():
        pid = f" pid={st.pid}" if st.pid else ""
        beat = ("never" if st.last_beat_age_s is None
                else f"{st.last_beat_age_s:.1f}s ago")
        split = (f"  idle {st.idle_s:.1f}s / busy {st.busy_s:.1f}s"
                 if (st.idle_s or st.busy_s) else "")
        lines.append(
            f"worker {st.worker}{pid} [{st.state}]: "
            f"{st.chunks_done} chunks done, "
            f"{st.leased_total} leased over {st.lease_calls} round-trips "
            f"({st.leases_held} still held), "
            f"{st.redeliveries} redelivered, last beat {beat}{split}")
    return lines


def pipeline_report(timings):
    """Aggregate per-batch plan timing records into per-stage means, the
    overlap count, and host-boundary traffic (shared by this driver and
    benchmarks/bench_dispatch_depth.py)."""
    n = len(timings)
    rep = {"batches": n}
    for k in ("dispatch", "readback", "compact", "tail", "emit"):
        rep[k + "_ms"] = 1e3 * sum(t.get(k + "_s", 0.0)
                                   for t in timings) / n
    rep["overlapped"] = sum(1 for t in timings
                            if t.get("in_flight", 1) >= 2)
    rep["max_in_flight"] = max(t.get("in_flight", 1) for t in timings)
    rep["d2h_bytes_per_batch"] = sum(t.get("d2h_bytes", 0)
                                     for t in timings) / n
    rep["h2d_bytes_per_batch"] = sum(t.get("h2d_bytes", 0)
                                     for t in timings) / n
    # the counterfactual: what the old host-compaction bookkeeping moved
    # per batch on THIS stream (full wave5 + mask down, survivor batch
    # up, cleaned down — measured per batch, not a 2x-full-batch model)
    rep["old_boundary_bytes_per_batch"] = sum(
        t.get("old_boundary_bytes", 0) for t in timings) / n
    rep["full_batch_bytes"] = sum(t.get("wave5_bytes", 0)
                                  for t in timings) / n
    return rep


if __name__ == "__main__":
    main()
