"""The paper's end-to-end driver: preprocess a stream of bird-acoustic long
chunks through the stage-graph pipeline under a chosen execution plan.

  PYTHONPATH=src python -m repro.launch.preprocess --minutes 8 --plan streaming
  PYTHONPATH=src python -m repro.launch.preprocess --plan sharded --shards 4
  PYTHONPATH=src python -m repro.launch.preprocess --plan sharded --store /data/store
  PYTHONPATH=src python -m repro.launch.preprocess --store /data/store --resume

Reports per-stage removal fractions and throughput (the paper's headline
metric: MB/s of source audio preprocessed; their 4-VM x 4-core figure was
16.4-16.5 MB/s). Per-batch stats are aggregated weighted by chunk count, so
uneven batches don't skew the fractions. The sharded plan additionally
reports queue redeliveries and the last round's survivor re-shard loads.

`--plan` choices come straight from the `PLANS` registry, so new plans
appear here without touching this driver. `--store DIR` wraps the chosen
plan in `CachedPlan` over a content-addressed `repro.store.ChunkStore`
(re-runs over overlapping data become lookups) plus a `RunJournal`;
`--resume` relaunches a killed `--store` run mid-stream with each chunk
emitted exactly once.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import SERF_AUDIO
from repro.core.plans import PLANS, Preprocessor
from repro.core.scheduler import balance_stats
from repro.data.loader import AudioChunkLoader, audio_shard_pool
from repro.distributed.sharding import ShardingRules, pool_rules
from repro.launch.mesh import make_local_mesh

_FRAC_KEYS = ("frac_rain", "frac_silence", "frac_kept", "frac_cicada15")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=4.0)
    ap.add_argument("--batch-long-chunks", type=int, default=4)
    # the registry IS the choice list: a newly registered plan (e.g.
    # 'cached') shows up here with zero driver edits
    ap.add_argument("--plan", "--mode", dest="plan", default="two_phase",
                    choices=sorted(PLANS))
    ap.add_argument("--shards", type=int, default=2,
                    help="simulated shard count for --plan sharded")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="content-addressed result store: wraps the chosen "
                         "plan in CachedPlan + a resume journal")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed --store run from its journal "
                         "(exactly-once emission across the restart)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.resume and not args.store:
        ap.error("--resume requires --store")

    cfg = SERF_AUDIO
    n_batches = max(1, int(round(args.minutes / args.batch_long_chunks)))
    mesh = make_local_mesh()
    pad = max(1, len(jax.devices()))
    sharded = args.plan == "sharded"
    rules = pool_rules(args.shards, mesh) if sharded else ShardingRules(mesh)
    plan_kwargs = {"shards": args.shards} if sharded else {}
    if args.store:
        # CachedPlan must see chunk content before dispatch, so even a
        # sharded inner is fed the plain stream (it builds its leased pool
        # internally); hits never reach the inner plan at all
        inner = "two_phase" if args.plan == "cached" else args.plan
        plan, plan_kwargs = "cached", {
            "inner": inner, "store": args.store, "journal": True,
            "resume": args.resume, **plan_kwargs}
        loader = AudioChunkLoader(seed=args.seed, n_batches=n_batches,
                                  batch_long_chunks=args.batch_long_chunks)
    elif sharded:
        # per-shard loaders over ONE shared leased queue; shards share this
        # process's mesh, so their compiles dedup in the CompileCache
        plan = "sharded"
        loader = audio_shard_pool(
            seed=args.seed, n_batches=n_batches, n_shards=args.shards,
            batch_long_chunks=args.batch_long_chunks)
    else:
        plan = args.plan
        loader = AudioChunkLoader(seed=args.seed, n_batches=n_batches,
                                  batch_long_chunks=args.batch_long_chunks)
    pre = Preprocessor(cfg, rules, plan=plan, pad_multiple=pad,
                       **plan_kwargs)

    tot_bytes = tot_kept = tot_chunks = 0
    agg = {k: 0.0 for k in _FRAC_KEYS}
    last_keep = None
    t0 = time.time()
    for res in pre.run(loader):
        w = float(res.det.stats["n_chunks5"])    # weight: chunks in batch
        for k in _FRAC_KEYS:
            agg[k] += float(res.det.stats[k]) * w
        tot_bytes += res.src_bytes
        tot_kept += res.n_kept
        tot_chunks += int(w)
        last_keep = res.det.keep
    dt = time.time() - t0
    cached = pre.plan if plan == "cached" else None
    exec_plan = cached.inner if cached is not None else pre.plan
    if tot_chunks == 0:
        if cached is not None and args.resume:
            print("nothing left to emit: the journal shows every chunk of "
                  "this stream was already emitted before the kill")
        else:
            print("empty stream: the loader yielded no batches — "
                  "nothing to do")
        return 0
    frac = {k: agg[k] / tot_chunks for k in _FRAC_KEYS}
    print(f"plan={args.plan}  {tot_bytes / 2**20:.0f} MB source audio "
          f"in {dt:.1f}s  ->  {tot_bytes / 2**20 / dt:.2f} MB/s")
    print(f"chunks kept {tot_kept}/{tot_chunks} "
          f"(rain {frac['frac_rain']:.1%}, "
          f"silence {frac['frac_silence']:.1%}, "
          f"cicada-filtered {frac['frac_cicada15']:.1%})")
    bs = jax.jit(lambda k: balance_stats(k, len(jax.devices())))(last_keep)
    print(f"survivor load imbalance (max/mean): "
          f"{float(bs['imbalance']):.3f} -> "
          f"{float(bs['imbalance_after_compact']):.3f} after compaction")
    if exec_plan.name == "sharded":
        asg = exec_plan.last_assignment
        print(f"shards={args.shards} redeliveries={exec_plan.redeliveries}")
        if asg is not None:
            st = asg.stats()
            print(f"last-round survivor re-shard: "
                  f"{st['loads_before'].tolist()} -> "
                  f"{st['loads_after'].tolist()} "
                  f"(max/min {st['max_min_before']:.2f} -> "
                  f"{st['max_min_after']:.2f}, moved {st['moved']})")
    if cached is not None and cached.stats is not None:
        print(f"store: {cached.stats}")
    return tot_kept


if __name__ == "__main__":
    main()
