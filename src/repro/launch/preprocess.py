"""The paper's end-to-end driver: preprocess a stream of bird-acoustic long
chunks through the stage-graph pipeline under a chosen execution plan.

  PYTHONPATH=src python -m repro.launch.preprocess --minutes 8 --plan streaming

Reports per-stage removal fractions and throughput (the paper's headline
metric: MB/s of source audio preprocessed; their 4-VM x 4-core figure was
16.4-16.5 MB/s). Per-batch stats are aggregated weighted by chunk count, so
uneven batches don't skew the fractions.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import SERF_AUDIO
from repro.core.plans import PLANS, Preprocessor
from repro.core.scheduler import balance_stats
from repro.data.loader import AudioChunkLoader
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_local_mesh

_FRAC_KEYS = ("frac_rain", "frac_silence", "frac_kept", "frac_cicada15")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=4.0)
    ap.add_argument("--batch-long-chunks", type=int, default=4)
    ap.add_argument("--plan", "--mode", dest="plan", default="two_phase",
                    choices=sorted(PLANS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = SERF_AUDIO
    n_batches = max(1, int(round(args.minutes / args.batch_long_chunks)))
    loader = AudioChunkLoader(seed=args.seed, n_batches=n_batches,
                              batch_long_chunks=args.batch_long_chunks)
    mesh = make_local_mesh()
    rules = ShardingRules(mesh)
    pre = Preprocessor(cfg, rules, plan=args.plan,
                       pad_multiple=max(1, len(jax.devices())))

    tot_bytes = tot_kept = tot_chunks = 0
    agg = {k: 0.0 for k in _FRAC_KEYS}
    last_keep = None
    t0 = time.time()
    for res in pre.run(loader):
        w = float(res.det.stats["n_chunks5"])    # weight: chunks in batch
        for k in _FRAC_KEYS:
            agg[k] += float(res.det.stats[k]) * w
        tot_bytes += res.src_bytes
        tot_kept += res.n_kept
        tot_chunks += int(w)
        last_keep = res.det.keep
    dt = time.time() - t0
    if tot_chunks == 0:
        print("empty stream: the loader yielded no batches — nothing to do")
        return 0
    frac = {k: agg[k] / tot_chunks for k in _FRAC_KEYS}
    print(f"plan={args.plan}  {tot_bytes / 2**20:.0f} MB source audio "
          f"in {dt:.1f}s  ->  {tot_bytes / 2**20 / dt:.2f} MB/s")
    print(f"chunks kept {tot_kept}/{tot_chunks} "
          f"(rain {frac['frac_rain']:.1%}, "
          f"silence {frac['frac_silence']:.1%}, "
          f"cicada-filtered {frac['frac_cicada15']:.1%})")
    bs = jax.jit(lambda k: balance_stats(k, len(jax.devices())))(last_keep)
    print(f"survivor load imbalance (max/mean): "
          f"{float(bs['imbalance']):.3f} -> "
          f"{float(bs['imbalance_after_compact']):.3f} after compaction")
    return tot_kept


if __name__ == "__main__":
    main()
