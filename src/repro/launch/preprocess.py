"""The paper's end-to-end driver: preprocess a stream of bird-acoustic long
chunks through the unified early-exit pipeline.

  PYTHONPATH=src python -m repro.launch.preprocess --minutes 8 --mode two_phase

Reports per-stage removal fractions and throughput (the paper's headline
metric: MB/s of source audio preprocessed; their 4-VM x 4-core figure was
16.4-16.5 MB/s).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SERF_AUDIO
from repro.core.pipeline import (detection_phase, preprocess_two_phase,
                                 preprocess_fused)
from repro.core.scheduler import balance_stats
from repro.data.loader import AudioChunkLoader
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_local_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=4.0)
    ap.add_argument("--batch-long-chunks", type=int, default=4)
    ap.add_argument("--mode", default="two_phase",
                    choices=["two_phase", "fused"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = SERF_AUDIO
    n_batches = max(1, int(round(args.minutes / args.batch_long_chunks)))
    loader = AudioChunkLoader(seed=args.seed, n_batches=n_batches,
                              batch_long_chunks=args.batch_long_chunks)
    mesh = make_local_mesh()
    rules = ShardingRules(mesh)

    tot_bytes = 0
    tot_kept = tot_chunks = 0
    t0 = time.time()
    agg = None
    for wid, (chunks, labels) in loader:
        tot_bytes += chunks.nbytes
        x = jnp.asarray(chunks)
        if args.mode == "two_phase":
            cleaned, det, n_real = preprocess_two_phase(
                cfg, x, rules, pad_multiple=max(1, len(jax.devices())))
            kept = n_real
        else:
            out = jax.jit(lambda a: preprocess_fused(cfg, a, rules))(x)
            kept = int(np.asarray(out.keep).sum())
            det = out
        stats = {k: float(v) for k, v in det.stats.items()}
        agg = stats if agg is None else {
            k: agg[k] + stats[k] for k in stats}
        tot_kept += kept
        tot_chunks += int(stats["n_chunks5"])
    dt = time.time() - t0
    n = n_batches
    print(f"mode={args.mode}  {tot_bytes / 2**20:.0f} MB source audio "
          f"in {dt:.1f}s  ->  {tot_bytes / 2**20 / dt:.2f} MB/s")
    print(f"chunks kept {tot_kept}/{tot_chunks} "
          f"(rain {agg['frac_rain']/n:.1%}, silence {agg['frac_silence']/n:.1%}, "
          f"cicada-filtered {agg['frac_cicada15']/n:.1%})")
    bs = jax.jit(lambda k: balance_stats(k, len(jax.devices())))(det.keep)
    print(f"survivor load imbalance (max/mean): "
          f"{float(bs['imbalance']):.3f} -> "
          f"{float(bs['imbalance_after_compact']):.3f} after compaction")
    return tot_kept


if __name__ == "__main__":
    main()
