"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so tests/benches see 1 CPU device while the
dry-run sees its 512 placeholder devices)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(model_parallel=1):
    """Whatever this host offers (tests / CPU examples)."""
    n = len(jax.devices())
    mp = model_parallel
    while n % mp:
        mp //= 2
    return jax.make_mesh((n // mp, mp), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def mesh_from_plan(plan):
    """Build a mesh from an ft.failure.MeshPlan (elastic restart path)."""
    return jax.make_mesh(plan.shape, plan.axes,
                         axis_types=(AxisType.Auto,) * len(plan.axes))
