"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so tests/benches see 1 CPU device while the
dry-run sees its 512 placeholder devices)."""
from __future__ import annotations

import jax

try:                                    # jax >= 0.4.35
    from jax.sharding import AxisType
except ImportError:                     # older jax: make_mesh has no
    AxisType = None                     # axis_types parameter


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(model_parallel=1):
    """Whatever this host offers (tests / CPU examples)."""
    n = len(jax.devices())
    mp = model_parallel
    while n % mp:
        mp //= 2
    return _make_mesh((n // mp, mp), ("data", "model"))


def mesh_from_plan(plan):
    """Build a mesh from an ft.failure.MeshPlan (elastic restart path)."""
    return _make_mesh(plan.shape, plan.axes)
