"""Trip-count-aware HLO cost analysis.

XLA's `compiled.cost_analysis()` counts each computation ONCE — a lax.scan
(while loop) body executed L times is undercounted by L (verified in
tests/test_hlo_analysis.py). Since every layer stack, attention block loop,
SSD chunk loop and microbatch loop in this framework is a scan, we walk the
post-SPMD scheduled HLO text ourselves:

  * dot ops        -> FLOPs (2 * prod(out dims) * contracted sizes) and
                      stream bytes (lhs + rhs + out), operand shapes resolved
                      through a per-computation symbol table (scheduled HLO
                      does not print operand shapes inline)
  * collectives    -> ring-model wire bytes (group size from replica_groups)
  * while loops    -> body/cond costs multiplied by the trip count recovered
                      from the largest integer constant reachable from the
                      loop condition
  * call/fusion/conditional -> recursed at multiplier 1

Shapes in post-SPMD HLO are per-device, so all outputs are per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
                "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(
    r"true_computation=%?([\w.\-]+), false_computation=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# operand shapes are printed inline by some XLA versions
# ("dot(f32[4,128]{1,0} %a, ...)") and omitted by others ("dot(%a, ...)")
_OPT_SHAPE = r"(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?\s+)?"
_DOT_RE = re.compile(
    r"\bdot\(\s*" + _OPT_SHAPE + r"%([\w.\-]+),\s*"
    + _OPT_SHAPE + r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _dims(s):
    return [int(d) for d in s.split(",") if d]


def _prod(dims):
    n = 1
    for d in dims:
        n *= d
    return n


def _nbytes(dtype, dims):
    return _prod(dims) * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class Computation:
    name: str
    lines: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)     # var -> (dtype, dims)
    is_entry: bool = False


def split_computations(text):
    comps = {}
    cur = None
    for line in text.splitlines():
        s = line.strip()
        if cur is None:
            if s.endswith("{") and "->" in s and ("%" in s or
                                                  s.startswith("ENTRY")):
                name_part = s.split("(", 1)[0].strip()
                is_entry = name_part.startswith("ENTRY")
                name = name_part.replace("ENTRY", "").strip().lstrip("%")
                cur = Computation(name=name, is_entry=is_entry)
                comps[name] = cur
            continue
        if s == "}":
            cur = None
            continue
        cur.lines.append(line)
        m = _INSTR_RE.match(line)
        if m:
            var, rhs = m.groups()
            sm = _SHAPE_RE.search(rhs)
            if sm:
                cur.shapes[var] = (sm.group(1), _dims(sm.group(2)))
    return comps


@dataclass
class CompCost:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = field(default_factory=dict)
    children: list = field(default_factory=list)   # (kind, name, cond)


def _analyze_comp(comp: Computation):
    cost = CompCost()
    for line in comp.lines:
        mw = _WHILE_RE.search(line)
        if mw:
            cost.children.append(("while", mw.group(2), mw.group(1)))
            continue
        mb = _BRANCHES_RE.search(line)
        if mb:
            for n in mb.group(1).split(","):
                n = n.strip().lstrip("%")
                if n:
                    cost.children.append(("call", n, None))
            continue
        mtf = _TF_RE.search(line)
        if mtf:
            cost.children.append(("call", mtf.group(1), None))
            cost.children.append(("call", mtf.group(2), None))
            continue
        mc = _CALLS_RE.search(line)
        if mc:
            cost.children.append(("call", mc.group(1), None))
            # fusions can contain dots on some backends — recursing covers it
        md = _DOT_RE.search(line)
        if md:
            m_out = _INSTR_RE.match(line)
            if not m_out:
                continue
            out_dtype, out_dims = comp.shapes.get(m_out.group(1),
                                                  ("f32", []))
            lhs = comp.shapes.get(md.group(1))
            csize = 1
            mct = _CONTRACT_RE.search(line)
            if lhs and mct:
                for ci in _dims(mct.group(1)):
                    if ci < len(lhs[1]):
                        csize *= lhs[1][ci]
            cost.dot_flops += 2.0 * _prod(out_dims) * csize
            stream = _nbytes(out_dtype, out_dims)
            for opname in (md.group(1), md.group(2)):
                sh = comp.shapes.get(opname)
                if sh:
                    stream += _nbytes(*sh)
            cost.dot_bytes += stream
            continue
        mcol = _COLL_RE.search(line)
        if mcol:
            op = mcol.group(1)
            m_out = _INSTR_RE.match(line)
            if not m_out:
                continue
            var = m_out.group(1)
            sh = comp.shapes.get(var)
            if not sh:
                continue
            nbytes = _nbytes(*sh)
            n = 1
            g = _GROUPS_RE.search(line)
            if g:
                n = len(g.group(1).split(","))
            else:
                g2 = _GROUPS_IOTA_RE.search(line)
                if g2:
                    n = int(g2.group(2))
            if n <= 1:
                continue
            if op == "all-gather":
                b = nbytes * (n - 1) / n
            elif op == "all-reduce":
                b = 2.0 * nbytes * (n - 1) / n
            elif op == "reduce-scatter":
                b = nbytes * (n - 1)
            elif op == "all-to-all":
                b = nbytes * (n - 1) / n
            else:
                b = float(nbytes)
            cost.coll_bytes += b
            cost.coll_by_op[op] = cost.coll_by_op.get(op, 0.0) + b
    return cost


def _trip_count(comps, costs, cond_name, depth=0):
    """Largest integer constant reachable from the loop condition."""
    if cond_name not in comps or depth > 3:
        return 1
    best = 1
    comp = comps[cond_name]
    for line in comp.lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    for kind, child, _ in costs[cond_name].children:
        best = max(best, _trip_count(comps, costs, child, depth + 1))
    return best


def analyze_hlo(text):
    comps = split_computations(text)
    costs = {name: _analyze_comp(c) for name, c in comps.items()}
    entry = None
    for name, c in comps.items():
        if c.is_entry:
            entry = name
    if entry is None and comps:
        entry = list(comps)[-1]

    agg = {"dot_flops": 0.0, "dot_bytes": 0.0, "coll_bytes": 0.0,
           "coll_by_op": {}}
    stack = set()

    def visit(name, mult):
        if name not in costs or name in stack:
            return
        stack.add(name)
        c = costs[name]
        agg["dot_flops"] += mult * c.dot_flops
        agg["dot_bytes"] += mult * c.dot_bytes
        agg["coll_bytes"] += mult * c.coll_bytes
        for op, b in c.coll_by_op.items():
            agg["coll_by_op"][op] = agg["coll_by_op"].get(op, 0.0) + mult * b
        for kind, child, cond in c.children:
            if kind == "while":
                t = _trip_count(comps, costs, cond)
                visit(child, mult * t)
                if cond != child:
                    visit(cond, mult * t)
            else:
                visit(child, mult)
        stack.discard(name)

    if entry:
        visit(entry, 1.0)
    return agg
