import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct stand-ins (no allocation), record memory_analysis,
cost_analysis, and the parsed collective schedule for the roofline.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init) — do not move it.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse     # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp                                   # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import (get_config, list_archs, SHAPES,                # noqa: E402
                           cell_is_runnable)
from repro.models.zoo import build_model, WHISPER_ENC_LEN  # noqa: E402
from repro.distributed.sharding import ShardingRules, tree_shardings  # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.train.train_step import (make_train_step, train_state_specs)  # noqa: E402
from repro.train.optimizer import OptConfig, init_opt_state  # noqa: E402

# ----------------------------------------------------------- input specs
def input_specs(cfg, shape):
    """ShapeDtypeStruct stand-ins for every model input of the cell —
    weak-type-correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "train":
            batch["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.num_prefix_tokens:
            batch["prefix"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.is_enc_dec:
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (B, WHISPER_ENC_LEN, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def batch_spec_tree(cfg, shape, rules):
    if shape.kind in ("train", "prefill"):
        spec = {"tokens": rules.sharding("batch", None)}
        if shape.kind == "train":
            spec["targets"] = rules.sharding("batch", None)
        if cfg.num_prefix_tokens:
            spec["prefix"] = rules.sharding("batch", None, None)
        if cfg.is_enc_dec:
            spec["enc_frames"] = rules.sharding("batch", None, None)
        return spec
    return {"tokens": rules.sharding("batch"), "pos": rules.sharding()}


def decode_overrides(cfg, shape):
    """Sharding-rule overrides for decode cells (DESIGN.md §5): KV caches are
    sequence-sharded so every arch shards evenly regardless of kv_heads;
    batch=1 long-context replicates batch and spreads seq over both axes."""
    if shape.name == "long_500k":
        return {"batch": (), "kv_seq": ("data", "model"),
                "heads": ("model",)}
    return {"kv_seq": ("model",), "heads": ()}


# ------------------------------------------------- collective-bytes parsing
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}
_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype, dims):
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text):
    """Sum per-device collective wire bytes from post-SPMD HLO.

    Ring-model byte multipliers per op result size R with group size n:
      all-gather:        R * (n-1)/n      (R = gathered result)
      all-reduce:        2R * (n-1)/n
      reduce-scatter:    R * (n-1)         (R = scattered result, in = R*n)
      all-to-all:        R * (n-1)/n
      collective-permute R
    """
    per_op = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, dtype, dims, op = m.groups()
        nbytes = _shape_bytes(dtype, dims)
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_IOTA_RE.search(line)
            if g2:
                n = int(g2.group(2))
        if n <= 1:
            continue
        if op == "all-gather":
            b = nbytes * (n - 1) / n
        elif op == "all-reduce":
            b = 2.0 * nbytes * (n - 1) / n
        elif op == "reduce-scatter":
            b = nbytes * (n - 1)
        elif op == "all-to-all":
            b = nbytes * (n - 1) / n
        else:
            b = float(nbytes)
        per_op[op] = per_op.get(op, 0.0) + b
        total += b
    return total, per_op


# ----------------------------------------------- the paper's own workload
def lower_audio_cell(mesh, mesh_name, variant="fused", n_chunks=512):
    """Lower the SERF preprocessing pipeline itself as a dry-run cell.

    variant:
      fused     — detection + masked MMSE on ALL chunks (no early exit —
                  the paper's baseline)
      detect    — detection phase only (phase A of the paper's early exit)
      mmse45    — MMSE phase on a 45% survivor batch (phase B; 0.45 is the
                  measured mean survivor fraction)
    """
    from repro.configs import SERF_AUDIO
    from repro.core.plans import Preprocessor
    from repro.kernels import backend
    cfg = SERF_AUDIO
    rules = ShardingRules(mesh)
    pre = Preprocessor(cfg, rules)
    t0 = time.time()
    S60 = int(12 * 5.0 * cfg.source_rate_hz)
    # matmul-DFT path: the TPU-target computation shape (MXU DFT), and the
    # only SPMD-partitionable one (XLA's FFT op forces all-gathers)
    with backend.use("matmul"):
        if variant in ("fused", "detect"):
            x = jax.ShapeDtypeStruct((n_chunks, 2, S60), jnp.float32)
            fn = pre.phase_fn("fused" if variant == "fused" else "detect")
            sh = rules.sharding("chunks", None, None)
            lowered = jax.jit(fn, in_shardings=(sh,)).lower(x)
        else:
            n5 = int(round(n_chunks * 12 * 0.45))
            n5 -= n5 % mesh.devices.size
            w = jax.ShapeDtypeStruct((n5, cfg.final_split_samples),
                                     jnp.float32)
            lowered = jax.jit(pre.phase_fn("mmse"),
                              in_shardings=(rules.sharding("chunks", None),)
                              ).lower(w)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    from repro.launch.hlo_analysis import analyze_hlo
    walk = analyze_hlo(compiled.as_text())
    audio_s = n_chunks * 60.0
    return {
        "arch": "serf-audio", "shape": f"pipeline_{variant}",
        "mesh": mesh_name, "kind": "pipeline", "mode": "dp",
        "microbatches": None, "n_devices": int(mesh.devices.size),
        "audio_hours": audio_s / 3600.0,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": walk["dot_flops"],
        "bytes_per_device": walk["dot_bytes"],
        "collective_bytes_per_device": walk["coll_bytes"],
        "collectives_by_op": walk["coll_by_op"],
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_gb": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
                3),
        },
        # "useful" work = the paper's two-phase cost: detection on all
        # chunks + MMSE on the measured survivor fraction (0.45)
        "model_flops": None,
    }


# ------------------------------------------------------------- cell lowering
def lower_cell(arch, shape_name, mesh, mesh_name, opt_cfg=None,
               num_microbatches=1, mode=None, q_block=None, kv_block=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    runnable, why = cell_is_runnable(cfg, shape)
    if not runnable:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": why}
    if mode is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, sharding_mode=mode,
                                  train_sharding_mode=mode)
    used_mode = cfg.sharding_mode
    model = build_model(cfg)
    t0 = time.time()

    if shape.kind == "train":
        train_mode = cfg.train_sharding_mode or cfg.sharding_mode
        if cfg.train_microbatches:
            num_microbatches = cfg.train_microbatches
        rules = ShardingRules(mesh, train_mode)
        # zero3-style modes shard batch over every axis; fall back when the
        # global batch doesn't divide (e.g. 256 over a 512-chip multi-pod)
        bt_axes = [a for a in rules._table["batch"] if a in mesh.shape]
        bt = 1
        for a in bt_axes:
            bt *= mesh.shape[a]
        if shape.global_batch % max(bt, 1):
            train_mode = cfg.sharding_mode
            rules = ShardingRules(mesh, train_mode)
        used_mode = train_mode
        opt_cfg = opt_cfg or OptConfig(quantize_state=cfg.quantize_opt_state)
        p_struct = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        o_struct = jax.eval_shape(
            lambda p: init_opt_state(opt_cfg, p), p_struct)
        pspecs, ospecs = train_state_specs(model, opt_cfg)
        p_sh = tree_shardings(rules, pspecs)
        o_sh = tree_shardings(rules, ospecs)
        b_struct = input_specs(cfg, shape)
        b_sh = batch_spec_tree(cfg, shape, rules)
        step = make_train_step(model, rules, opt_cfg,
                               num_microbatches=num_microbatches)
        lowered = jax.jit(
            step, in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        ).lower(p_struct, o_struct, b_struct)
    elif shape.kind == "prefill":
        rules = ShardingRules(mesh, cfg.sharding_mode)
        p_struct = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        p_sh = tree_shardings(rules, model.param_specs())
        b_struct = input_specs(cfg, shape)
        b_sh = batch_spec_tree(cfg, shape, rules)
        fn = lambda p, b: model.prefill(p, b, rules)   # noqa: E731
        lowered = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(
            p_struct, b_struct)
    else:  # decode
        rules = ShardingRules(mesh, cfg.sharding_mode,
                              overrides=decode_overrides(cfg, shape))
        p_struct = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        p_sh = tree_shardings(rules, model.param_specs())
        kwargs = {"enc_len": WHISPER_ENC_LEN} if cfg.is_enc_dec else {}
        c_struct = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     **kwargs))
        c_sh = tree_shardings(rules, model.cache_specs())
        ins = input_specs(cfg, shape)
        t_sh = batch_spec_tree(cfg, shape, rules)

        def serve_step(params, caches, tokens, pos):
            return model.decode_step(params, caches, tokens, pos, rules)

        lowered = jax.jit(
            serve_step,
            in_shardings=(p_sh, c_sh, t_sh["tokens"], t_sh["pos"]),
            donate_argnums=(1,),
        ).lower(p_struct, c_struct, ins["tokens"], ins["pos"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll_bytes, coll_by_op = parse_collectives(hlo)
    # trip-count-aware walk (cost_analysis counts scan bodies once — see
    # hlo_analysis.py); these are the roofline inputs
    from repro.launch.hlo_analysis import analyze_hlo
    walk = analyze_hlo(hlo)
    n_dev = mesh.devices.size
    pc = cfg.param_counts()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = {"train": 6, "prefill": 2, "decode": 2}[shape.kind]
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind,
        "mode": (used_mode if shape.kind == "train" else cfg.sharding_mode),
        "microbatches": num_microbatches if shape.kind == "train" else None,
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": walk["dot_flops"],
        "bytes_per_device": walk["dot_bytes"],
        "collective_bytes_per_device": walk["coll_bytes"],
        "collectives_by_op": walk["coll_by_op"],
        # raw XLA numbers (scan bodies counted once) kept for reference
        "xla_flops_per_device": ca.get("flops", 0.0),
        "xla_bytes_per_device": ca.get("bytes accessed", 0.0),
        "flat_collective_bytes": coll_bytes,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_gb": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
                3),
        },
        "model_params_total": pc["total"],
        "model_params_active": pc["active"],
        "model_flops": mult * pc["active"] * tokens,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4,
                    help="grad-accum microbatches for train cells (mb=1 overflows HBM for the larger archs — see EXPERIMENTS.md)")
    ap.add_argument("--mode", default=None,
                    help="override sharding mode (tp|fsdp_tp|zero3)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    results = []
    for mesh_name, mesh in meshes:
        if args.all:
            # the paper's own workload, as dry-run cells
            for variant in ("fused", "detect", "mmse45"):
                try:
                    with mesh:
                        rec = lower_audio_cell(mesh, mesh_name, variant)
                    print(f"OK   serf-audio x {variant} x {mesh_name}: "
                          f"flops/dev {rec['flops_per_device']:.3e} "
                          f"coll/dev "
                          f"{rec['collective_bytes_per_device']:.3e}",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": "serf-audio", "shape": variant,
                           "mesh": mesh_name,
                           "error": f"{type(e).__name__}: {e}"}
                    print(f"FAIL serf-audio x {variant}: {rec['error']}",
                          flush=True)
                results.append(rec)
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch} x {shape_name} x {mesh_name}"
                try:
                    with mesh:
                        rec = lower_cell(arch, shape_name, mesh, mesh_name,
                                         num_microbatches=args.microbatches,
                                         mode=args.mode)
                    if "skipped" in rec:
                        print(f"SKIP {tag}: {rec['skipped']}", flush=True)
                    else:
                        print(f"OK   {tag}: compile {rec['compile_s']}s "
                              f"flops/dev {rec['flops_per_device']:.3e} "
                              f"coll/dev {rec['collective_bytes_per_device']:.3e} "
                              f"peak {rec['memory']['peak_estimate_gb']} GB",
                              flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"FAIL {tag}: {rec['error']}", flush=True)
                results.append(rec)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out if args.out.endswith(".json")
                  else args.out + ".json", "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {len(results)} records")
    n_fail = sum(1 for r in results if "error" in r)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
