"""Training driver (deliverable (b): end-to-end example).

CPU-runnable with reduced configs; the same driver lowers to the production
mesh unchanged (launch/dryrun.py proves every full cell compiles there).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
Resume after interruption (fault tolerance path):
  ... --resume
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models.zoo import build_model
from repro.distributed.sharding import ShardingRules, tree_shardings
from repro.launch.mesh import make_local_mesh
from repro.train.optimizer import OptConfig
from repro.train.train_step import (make_train_step, init_train_state,
                                    train_state_specs)
from repro.data.loader import TokenLoader
from repro.ckpt import checkpoint as ckpt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    mesh = make_local_mesh(args.model_parallel)
    rules = ShardingRules(mesh, cfg.sharding_mode)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                        decay_steps=args.steps,
                        quantize_state=cfg.quantize_opt_state)

    params, opt_state = init_train_state(model, opt_cfg,
                                         jax.random.key(args.seed),
                                         compress_grads=args.compress_grads)
    pspecs, ospecs = train_state_specs(model, opt_cfg, args.compress_grads)
    p_sh, o_sh = tree_shardings(rules, pspecs), tree_shardings(rules, ospecs)

    start_step = 0
    loader_start = 0
    if args.resume and args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), meta = ckpt.restore(
                args.ckpt_dir, last, like=(params, opt_state),
                shardings=(p_sh, o_sh) if p_sh else None)
            start_step = meta["step"]
            loader_start = meta["cursor_done"]
            print(f"resumed from step {start_step}")

    step_fn = jax.jit(
        make_train_step(model, rules, opt_cfg, args.microbatches,
                        args.compress_grads),
        in_shardings=(p_sh, o_sh, None) if p_sh else None,
        out_shardings=(p_sh, o_sh, None) if p_sh else None,
        donate_argnums=(0, 1))

    loader = TokenLoader(cfg.vocab_size, args.batch, args.seq,
                         n_batches=args.steps, seed=args.seed,
                         start_at=loader_start)
    t0 = time.time()
    handle = None
    step = start_step
    for wid, batch in loader:
        batch = jax.tree.map(jnp.asarray, batch)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        step += 1
        if step % args.log_every == 0 or step == start_step + 1:
            m = jax.tree.map(lambda x: float(np.asarray(x)), metrics)
            toks = args.batch * args.seq * (step - start_step)
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                  f"tok/s {toks / (time.time() - t0):,.0f}", flush=True)
        if args.ckpt_dir and step % args.ckpt_every == 0:
            if handle:
                handle.wait()
            handle = ckpt.save(args.ckpt_dir, step, (params, opt_state),
                               meta={"step": step,
                                     "cursor_done": len(loader.cursor()["done"])},
                               async_save=True)
        if step >= args.steps:
            break
    if handle:
        handle.wait()
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, step, (params, opt_state),
                  meta={"step": step,
                        "cursor_done": len(loader.cursor()["done"])})
        ckpt.prune_old(args.ckpt_dir, keep=3)
    print("done")
    return step


if __name__ == "__main__":
    main()
