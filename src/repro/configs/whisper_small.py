"""Assigned architecture config (see archs.py for the dataclass)."""
from repro.configs.archs import WHISPER_SMALL as CONFIG
