from repro.configs.base import (
    ModelConfig, ShapeConfig, SHAPES, cell_is_runnable, reduced,
)
from repro.configs.archs import ALL as ARCHS
from repro.configs.serf_audio import SERF_AUDIO, AudioPipelineConfig


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)
