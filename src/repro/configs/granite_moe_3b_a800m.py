"""Assigned architecture config (see archs.py for the dataclass)."""
from repro.configs.archs import GRANITE_MOE_3B as CONFIG
