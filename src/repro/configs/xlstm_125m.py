"""Assigned architecture config (see archs.py for the dataclass)."""
from repro.configs.archs import XLSTM_125M as CONFIG
