"""Assigned architecture config (see archs.py for the dataclass)."""
from repro.configs.archs import PALIGEMMA_3B as CONFIG
