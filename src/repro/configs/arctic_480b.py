"""Assigned architecture config (see archs.py for the dataclass)."""
from repro.configs.archs import ARCTIC_480B as CONFIG
