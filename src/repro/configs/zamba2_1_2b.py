"""Assigned architecture config (see archs.py for the dataclass)."""
from repro.configs.archs import ZAMBA2_1_2B as CONFIG
