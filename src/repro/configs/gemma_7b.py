"""Assigned architecture config (see archs.py for the dataclass)."""
from repro.configs.archs import GEMMA_7B as CONFIG
