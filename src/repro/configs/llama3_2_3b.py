"""Assigned architecture config (see archs.py for the dataclass)."""
from repro.configs.archs import LLAMA3_2_3B as CONFIG
