"""Assigned architecture config (see archs.py for the dataclass)."""
from repro.configs.archs import MINITRON_8B as CONFIG
