"""The 10 assigned architecture configs (exact figures from the brief).

Head dims not stated in the brief use the published values for each model family.
"""
from repro.configs.base import ModelConfig

LLAMA3_2_3B = ModelConfig(
    name="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128_256, mlp="swiglu", rope_theta=500_000.0,
    tie_embeddings=True,
    # hillclimbed (EXPERIMENTS §Perf): 3B params over 256 chips is
    # activation-AR-bound under TP; ZeRO-3 pure-DP is compute-bound at 65%
    train_sharding_mode="zero3", train_microbatches=1,
)

NEMOTRON_4_15B = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=24_576, vocab_size=256_000, mlp="squared_relu", rope_theta=10_000.0,
    tie_embeddings=False,
)

GEMMA_7B = ModelConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16, head_dim=256,
    d_ff=24_576, vocab_size=256_000, mlp="geglu", rope_theta=10_000.0,
    tie_embeddings=True, norm_eps=1e-6,
)

MINITRON_8B = ModelConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=16_384, vocab_size=256_000, mlp="squared_relu", rope_theta=10_000.0,
    tie_embeddings=False,
)

ZAMBA2_1_2B = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32_000, mlp="geglu",
    ssm_state=64, ssm_expand=2, ssm_conv=4,
    attn_period=6,                      # shared attention block every 6 mamba blocks
    subquadratic=True,                  # mamba2 backbone -> long_500k eligible
    # hillclimb breadth (EXPERIMENTS §Perf appendix): zero3 34 -> 74% roofline
    train_sharding_mode="zero3", train_microbatches=1,
)

XLSTM_125M = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4, head_dim=192,
    d_ff=0,                             # per brief: projections live inside blocks
    vocab_size=50_304, block_types=("mlstm", "slstm"),
    ssm_expand=2, subquadratic=True, norm="layernorm", use_rope=False,
)

PALIGEMMA_3B = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16_384, vocab_size=257_216, mlp="geglu", rope_theta=10_000.0,
    frontend="siglip_stub", num_prefix_tokens=256, tie_embeddings=True,
    # zero3: 57 -> 69% roofline; peak 16.5 GB is marginal on v5e (§Perf appendix)
    train_sharding_mode="zero3", train_microbatches=1,
)

ARCTIC_480B = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32_000, mlp="swiglu",
    num_experts=128, top_k=2,
    dense_ff=7168,                      # dense residual MLP in parallel with MoE
    tie_embeddings=False,
    # 480B params: optimizer state must shard over (pod,data) x model and use
    # 8-bit moments to approach HBM (DESIGN.md §5, EXPERIMENTS.md §Dry-run);
    # train cells use sequence-parallel + EP (EXPERIMENTS §Perf arctic iters)
    sharding_mode="fsdp_tp", quantize_opt_state=True,
    train_sharding_mode="sp_ep", train_microbatches=4,
)

GRANITE_MOE_3B = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49_155, mlp="swiglu",
    num_experts=40, top_k=8, tie_embeddings=True,
    # 40 experts don't divide the 16-way model axis -> shard each expert's
    # ff dim instead (expert-TP); see DESIGN.md §5
    expert_shard="tp",
)

WHISPER_SMALL = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51_865, mlp="gelu", norm="layernorm",
    encoder_layers=12, frontend="audio_stub", use_rope=False,
    tie_embeddings=True,
)

ALL = {
    c.name: c for c in [
        LLAMA3_2_3B, NEMOTRON_4_15B, GEMMA_7B, MINITRON_8B, ZAMBA2_1_2B,
        XLSTM_125M, PALIGEMMA_3B, ARCTIC_480B, GRANITE_MOE_3B, WHISPER_SMALL,
    ]
}
