"""Assigned architecture config (see archs.py for the dataclass)."""
from repro.configs.archs import NEMOTRON_4_15B as CONFIG
