"""The paper's own workload config: the SERF bird-acoustic preprocessing pipeline.

All constants trace to the paper:
  - downsample to 22.05 kHz (Nyquist 11.025 kHz covers bird sound)
  - mono mix
  - 1 kHz high-pass (birds rarely vocalise below 1 kHz)
  - STFT: 256-sample windows, Hamming, 50% overlap
  - rain / cicada detection via rules over acoustic indices (C4.5-derived)
  - re-split to 5 s chunks; silence detection via SNR threshold (paper: the
    "lower threshold" 0.2 at 5 s splits was chosen; 0.25 is the aggressive one)
  - MMSE-STSA last (dominant cost; skipped for removed audio)
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class AudioPipelineConfig:
    name: str = "serf_audio"
    source_rate_hz: int = 44_100
    target_rate_hz: int = 22_050
    # chunking (paper: long split for HPF stage, short split for detection,
    # 5 s splits for silence + MMSE)
    long_split_s: float = 60.0        # Fig 2: 1-minute chunks for HPF
    detect_split_s: float = 15.0      # Table 4/5: 15 s most accurate for rain/cicada
    final_split_s: float = 5.0        # silence detection resolution
    # high-pass filter
    hpf_cutoff_hz: float = 1_000.0
    hpf_taps: int = 129
    # STFT
    stft_window: int = 256
    stft_hop: int = 128               # 50% overlap
    # MMSE-STSA (Ephraim-Malah)
    mmse_alpha: float = 0.98          # decision-directed smoothing
    mmse_gain_floor: float = 0.1      # min gain (noise floor retention)
    noise_est_frames: int = 16        # initial frames used for noise PSD estimate
    # silence detection (paper: estimated-SNR threshold; the paper picked the
    # LOWER of two thresholds at 5 s splits — same structure here, constants
    # calibrated on the synthetic labelled set (see EXPERIMENTS.md):
    # silence snr ~0.32 [0.30,0.36], bird ~0.92 [0.89,0.95]
    silence_snr_threshold: float = 0.45
    silence_snr_threshold_hi: float = 0.60
    # spectral-flux energy detection (Stowell-style onset strength), the
    # drop-in alternative to SNR silence detection ('detect_flux' stage):
    # calibrated on the synthetic labelled set — active chunks (bird,
    # cicada) p5 >= 2.1, inactive (silence, steady rain) p95 <= 0.98
    flux_threshold: float = 1.5
    # rain detection rule constants (C4.5-derived structure; constants fit on
    # the synthetic labelled set since SERF audio is not redistributable):
    # rain psd ~1.87 / flatness ~0.33 / snr ~0.35 vs bird 1.1 / 0.19 / 0.92
    rain_psd_min: float = 1.5         # broadband power spectral density floor
    rain_snr_max: float = 0.6         # rain envelope is flat (low est. SNR)
    rain_flatness_min: float = 0.25   # spectral flatness (rain ~ white-ish)
    rain_low_band_hz: tuple = (1_000.0, 6_000.0)
    # cicada detection: strong sustained narrowband chorus energy
    # (peakiness ~1783 vs bird p95 ~700; persistence ~1.0 vs bird p95 ~0.89)
    cicada_band_hz: tuple = (2_500.0, 8_000.0)
    cicada_band_ratio_min: float = 0.9    # band energy / total energy
    cicada_peakiness_min: float = 1000.0  # peak-bin to median-bin PSD ratio
    cicada_persistence_min: float = 0.95  # fraction of frames band-dominated
    cicada_stop_width_hz: float = 800.0   # band-stop width around detected peak
    # distribution parameters (paper Table 7)
    slave_queue_size: int = 5
    send_interval_s: float = 2.0
    # the pipeline stage order AS DATA (names from repro.core.graph.STAGES).
    # This default is the paper's profiled order; ablations (reorder, drop a
    # detector, move the removal point) are dataclasses.replace edits, not
    # driver forks. "removal_point" marks where host compaction may occur
    # (the early-exit boundary two-phase/streaming plans cut at).
    stages: tuple = (
        "to_mono",
        "compress",
        "split_detect",
        "stft",
        "detect_rain",
        "cicada_bandstop",
        "istft",
        "split_final",
        "detect_silence",
        "removal_point",
        "mmse",
    )

    @property
    def long_split_samples(self) -> int:
        return int(self.long_split_s * self.source_rate_hz)

    @property
    def detect_split_samples(self) -> int:
        return int(self.detect_split_s * self.target_rate_hz)

    @property
    def final_split_samples(self) -> int:
        return int(self.final_split_s * self.target_rate_hz)

    @property
    def n_bins(self) -> int:
        return self.stft_window // 2 + 1


SERF_AUDIO = AudioPipelineConfig()
