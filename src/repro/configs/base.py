"""Config system: architecture + shape cells.

Every assigned architecture is a `ModelConfig`; the paper's own workload is an
`AudioPipelineConfig` (see serf_audio.py). Shapes are the four assigned cells.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # mlp
    mlp: str = "swiglu"           # swiglu | geglu | squared_relu | gelu
    # moe
    num_experts: int = 0
    top_k: int = 0
    dense_ff: int = 0             # parallel dense residual MLP (arctic-style)
    moe_capacity_factor: float = 1.25   # >= top_k*experts/tokens => dropless
    expert_shard: str = "ep"      # ep: experts over "model" (needs E%16==0);
    #                               tp: shard each expert's ff dim instead
    # ssm / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256          # mamba2 chunked-scan chunk length
    attn_period: int = 0          # hybrid: shared attn block applied every N blocks
    block_types: tuple = ()       # xlstm: cycle of ("mlstm","slstm")
    # enc-dec
    encoder_layers: int = 0
    # modality frontend (stubbed per brief: precomputed embeddings)
    frontend: str = "none"        # none | siglip_stub | audio_stub
    num_prefix_tokens: int = 0
    # attention / norm details
    rope_theta: float = 10_000.0
    use_rope: bool = True
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-5
    logit_softcap: float = 0.0
    tie_embeddings: bool = True
    # capability flags
    subquadratic: bool = False    # eligible for long_500k
    # distribution profile (dry-run defaults; see DESIGN.md §5)
    sharding_mode: str = "tp"     # tp | fsdp_tp | zero3 | sp_ep
    train_sharding_mode: str = ""   # override for train cells ("" = same)
    train_microbatches: int = 0     # override for train cells (0 = CLI)
    quantize_opt_state: bool = False
    # numerics
    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP=16 shards evenly.

        Padded logit rows are masked out of the loss (see train/loss.py)."""
        return _round_up(self.vocab_size, 256)

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    # ---- parameter counting (for MODEL_FLOPS = 6*N*D roofline term) ----
    def param_counts(self) -> dict:
        """Returns dict with total and active parameter counts (embedding incl.)."""
        E, L = self.d_model, self.num_layers
        attn = E * self.q_dim + E * 2 * self.kv_dim + self.q_dim * E

        def mlp_params(ff):
            if ff == 0:
                return 0
            n_in = 2 if self.mlp in ("swiglu", "geglu") else 1
            return n_in * E * ff + ff * E

        per_layer_total = 0
        per_layer_active = 0
        if self.family in ("dense", "vlm", "audio"):
            per_layer_total = attn + mlp_params(self.d_ff)
            per_layer_active = per_layer_total
        elif self.family == "moe":
            router = E * self.num_experts
            experts = self.num_experts * mlp_params(self.d_ff)
            act_experts = self.top_k * mlp_params(self.d_ff)
            dense = mlp_params(self.dense_ff)
            per_layer_total = attn + router + experts + dense
            per_layer_active = attn + router + act_experts + dense
        elif self.family == "ssm":
            # xlstm-style block: in/out proj with expansion + gates (approximate
            # but exact enough for the roofline's useful-FLOPs ratio)
            d_in = self.ssm_expand * E
            per_layer_total = 2 * E * d_in + 4 * d_in * self.head_dim
            per_layer_active = per_layer_total
        elif self.family == "hybrid":
            d_in = self.ssm_expand * E
            mamba = (E * (2 * d_in + 2 * self.ssm_state)  # in-proj (x,z) + B,C
                     + d_in * E                            # out proj
                     + 3 * d_in)                           # dt/A/D params
            per_layer_total = mamba
            per_layer_active = mamba
        total = L * per_layer_total
        active = L * per_layer_active
        if self.family == "hybrid" and self.attn_period:
            shared = attn + mlp_params(self.d_ff)
            n_apps = max(1, self.num_layers // self.attn_period)
            total += shared                      # shared weights stored once
            active += shared * n_apps            # ... applied n_apps times
        if self.is_enc_dec:
            # encoder layers + cross-attention in decoder
            enc = self.encoder_layers * (attn + mlp_params(self.d_ff))
            cross = L * (E * self.q_dim + E * 2 * self.kv_dim + self.q_dim * E)
            total += enc + cross
            active += enc + cross
        emb = self.padded_vocab * E * (1 if self.tie_embeddings else 2)
        total += emb
        active += emb
        return {"total": total, "active": active}


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch x shape) is a live cell, else (False, reason).

    Per the brief: long_500k needs sub-quadratic attention — skipped for pure
    full-attention archs; encoder-only archs would skip decode (none assigned).
    """
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: O(S^2) at 524k tokens excluded by brief"
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduced config for CPU smoke tests."""
    kw = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(4, max(1, cfg.num_kv_heads * 4 // max(cfg.num_heads, 1))),
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        rope_theta=cfg.rope_theta,
    )
    if cfg.family == "moe":
        kw.update(num_experts=8, top_k=min(cfg.top_k, 2),
                  dense_ff=128 if cfg.dense_ff else 0)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_chunk=16)
    if cfg.attn_period:
        kw.update(attn_period=2, num_layers=4)
    if cfg.block_types:
        kw.update(num_layers=2)
    if cfg.is_enc_dec:
        kw.update(encoder_layers=2)
    if cfg.num_prefix_tokens:
        kw.update(num_prefix_tokens=8)
    return replace(cfg, name=cfg.name + "-reduced", **kw)
