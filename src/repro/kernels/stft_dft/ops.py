"""Public wrapper for the STFT kernel.

Backend dispatch (repro.kernels.backend): compiled Pallas on TPU, jnp-FFT ref
on CPU, interpret-mode Pallas for kernel correctness tests. Functions are
plain (not jit'd) — they compose inside the pipeline's jit regions.
"""
import jax
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.stft_dft import kernel as K
from repro.kernels.stft_dft import ref as R


def pad_for_stft(x, window=256, hop=128):
    """Right-pad (B,S) so the kernel's frame count is tile-aligned."""
    B, S = x.shape
    tile_span = K.FRAME_TILE * hop
    tail = window - hop
    n_tiles = max(1, -(-(S - tail) // tile_span))
    target = n_tiles * tile_span + tail
    if target > S:
        x = jnp.pad(x, ((0, 0), (0, target - S)))
    return x


def stft(x, window=256, hop=128):
    """x: (B,S) -> complex (B,F,bins). S must satisfy the kernel tiling
    (use pad_for_stft)."""
    use_pallas, interp = backend.resolve()
    if backend.matmul_dft():
        return R.stft_matmul(x, window, hop)
    if not use_pallas:
        return R.stft_ref(x, window, hop)
    bins = window // 2 + 1
    packed = K.stft_pallas(x, window, hop, interpret=interp)
    return jax.lax.complex(packed[..., :bins], packed[..., bins:2 * bins])


def stft_power(x, window=256, hop=128):
    """x: (B,S) -> power spectrum (B,F,bins) f32."""
    use_pallas, interp = backend.resolve()
    if backend.matmul_dft():
        z = R.stft_matmul(x, window, hop)
        return jnp.real(z) ** 2 + jnp.imag(z) ** 2
    if not use_pallas:
        return R.power_spectrum(x, window, hop)
    bins = window // 2 + 1
    packed = K.stft_pallas(x, window, hop, interpret=interp)
    re, im = packed[..., :bins], packed[..., bins:2 * bins]
    return re * re + im * im


def istft(z, n_samples, window=256, hop=128):
    """Inverse STFT (overlap-add; matmul inverse-DFT under mode "matmul")."""
    if backend.matmul_dft():
        return R.istft_matmul(z, n_samples, window, hop)
    return R.istft_ref(z, n_samples, window, hop)
