"""Pallas TPU kernel: fused framing + Hamming window + real-DFT-as-matmul.

TPU adaptation of the paper's FFT stage (Apache Commons radix FFT on CPU):
a 256-point real DFT is a (frames x 256) @ (256 x 2*bins) matmul — MXU-native,
no butterfly/bit-reversal (which would serialize on a systolic array).

Framing exploits the 50% overlap: within a tile's contiguous sample span, the
even frames are one contiguous reshape and the odd frames a hop-shifted
reshape — no gathers inside the kernel. Because Pallas blocked indexing cannot
express *overlapping* blocks, each grid step receives its (FRAME_TILE*hop)
main span plus a (window-hop) boundary tail (precomputed view, ops.py).

Grid: (batch, frame_tiles). VMEM per step:
  main span (1,1,32768) f32  = 128 KiB      (FRAME_TILE=256, hop=128)
  tail      (1,1,128)        = 0.5 KiB
  dft basis (256,384)        = 384 KiB      (grid-invariant, stays resident)
  out       (1,256,384)      = 384 KiB
MXU alignment: contraction dim 256 and padded output dim 384 are multiples of
the 128-lane tiling.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.stft_dft.ref import hamming

FRAME_TILE = 128   # block-shape hillclimb: 256 -> 128 cuts pad waste ~4.5%
                   # and halves the per-step VMEM footprint (EXPERIMENTS §Perf)
PAD_OUT = 384          # 2*(128+1) = 258 -> padded to 3*128


def dft_basis(window=256, dtype=jnp.float32, windowed=True):
    """Packed real-DFT basis (window, PAD_OUT): [cos | -sin | zero-pad].

    With windowed=True the Hamming window is folded into the basis rows
    (diag(w) @ basis), fusing the windowing into the DFT matmul."""
    bins = window // 2 + 1
    n = np.arange(window)[:, None]
    k = np.arange(bins)[None, :]
    ang = 2.0 * np.pi * n * k / window
    basis = np.zeros((window, PAD_OUT), np.float32)
    basis[:, :bins] = np.cos(ang)
    basis[:, bins:2 * bins] = -np.sin(ang)
    if windowed:
        basis *= hamming(window)[:, None]
    return jnp.asarray(basis, dtype)


def _stft_kernel(x_ref, tail_ref, basis_ref, o_ref, *, window, hop,
                 frame_tile):
    span = jnp.concatenate([x_ref[0, 0], tail_ref[0, 0]])   # (T*hop + w-hop,)
    half = frame_tile // 2
    even = span[:half * window].reshape(half, window)
    odd = span[hop:hop + half * window].reshape(half, window)
    frames = jnp.stack([even, odd], axis=1).reshape(frame_tile, window)
    o_ref[0] = jnp.dot(frames, basis_ref[...],
                       preferred_element_type=jnp.float32)


def stft_pallas(x, window=256, hop=128, interpret=False):
    """x: (B, S) f32, S = n_tiles*FRAME_TILE*hop + (window-hop)
    -> (B, F, PAD_OUT) packed [re | im | pad], F = n_tiles*FRAME_TILE."""
    assert hop * 2 == window, "kernel exploits 50% overlap"
    B, S = x.shape
    tile_span = FRAME_TILE * hop
    tail_len = window - hop
    assert (S - tail_len) % tile_span == 0, (
        f"S={S} must be n*{tile_span}+{tail_len} (ops.py pads)")
    n_tiles = (S - tail_len) // tile_span
    F = n_tiles * FRAME_TILE
    main = x[:, :n_tiles * tile_span].reshape(B, n_tiles, tile_span)
    tail_idx = (np.arange(n_tiles)[:, None] * tile_span + tile_span
                + np.arange(tail_len)[None, :])
    tails = x[:, tail_idx.reshape(-1)].reshape(B, n_tiles, tail_len)
    basis = dft_basis(window, jnp.float32)

    kernel = functools.partial(_stft_kernel, window=window, hop=hop,
                               frame_tile=FRAME_TILE)
    out = pl.pallas_call(
        kernel,
        grid=(B, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, tile_span), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, 1, tail_len), lambda b, t: (b, t, 0)),
            pl.BlockSpec((window, PAD_OUT), lambda b, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, FRAME_TILE, PAD_OUT),
                               lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, F, PAD_OUT), jnp.float32),
        interpret=interpret,
    )(main.astype(jnp.float32), tails.astype(jnp.float32), basis)
    return out
