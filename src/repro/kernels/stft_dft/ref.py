"""Pure-jnp oracle for the STFT kernel (framing + Hamming + real DFT).

Uses jnp.fft.rfft — deliberately a different computational path than the
kernel's matmul-DFT, so the allclose sweep is a real cross-check.
"""
import jax
import jax.numpy as jnp
import numpy as np


def hamming(n):
    return 0.54 - 0.46 * np.cos(2.0 * np.pi * np.arange(n) / (n - 1))


def num_frames(n_samples, window, hop):
    return (n_samples - window) // hop + 1


def frame(x, window, hop):
    """x: (..., S) -> (..., F, window).

    For the 50%-overlap case the even/odd frames are two CONTIGUOUS
    reshapes interleaved — no gather. This matters under GSPMD: a gather
    over the sharded chunk-batch dim made XLA all-gather entire
    spectrogram-sized tensors (EXPERIMENTS.md §Perf, pipeline iter 1);
    reshapes/slices stay local."""
    F = num_frames(x.shape[-1], window, hop)
    if 2 * hop == window:
        lead = x.shape[:-1]
        n_even = (F + 1) // 2
        n_odd = F - n_even
        even = x[..., :n_even * window].reshape(*lead, n_even, window)
        odd = x[..., hop:hop + n_odd * window].reshape(*lead, n_odd, window)
        if n_odd < n_even:
            odd = jnp.concatenate(
                [odd, jnp.zeros((*lead, 1, window), x.dtype)], axis=-2)
        out = jnp.stack([even, odd], axis=-2).reshape(*lead, -1, window)
        return out[..., :F, :]
    idx = np.arange(F)[:, None] * hop + np.arange(window)[None, :]
    return x[..., idx]


def stft_ref(x, window=256, hop=128):
    """x: (B, S) f32 -> (B, F, window//2+1) complex64."""
    frames = frame(x, window, hop) * jnp.asarray(hamming(window), x.dtype)
    return jnp.fft.rfft(frames, axis=-1)


def stft_ref_packed(x, window=256, hop=128):
    """Packed real output (B, F, 2*(window//2+1)): [re | im]."""
    z = stft_ref(x, window, hop)
    return jnp.concatenate([jnp.real(z), jnp.imag(z)], axis=-1)


def power_spectrum(x, window=256, hop=128):
    z = stft_ref(x, window, hop)
    return jnp.abs(z) ** 2


# --------------------------------------------------------- matmul-DFT path
# The TPU target computes the DFT as a matmul on the MXU (kernel.py). These
# pure-jnp equivalents run the SAME computation shape without pallas — used
# by the dry-run (backend mode "matmul") both because they mirror the TPU
# cost profile and because XLA's FFT op is NOT SPMD-partitionable (GSPMD
# all-gathers its operands; EXPERIMENTS.md §Perf pipeline iter 1).
def _fwd_basis(window):
    from repro.kernels.stft_dft.kernel import dft_basis, PAD_OUT
    return dft_basis(window), PAD_OUT


def _inv_basis(window):
    bins = window // 2 + 1
    m_re = np.fft.irfft(np.eye(bins), n=window)
    m_im = np.fft.irfft(1j * np.eye(bins), n=window)
    return jnp.asarray(np.concatenate([m_re, m_im], 0).astype(np.float32))


MATMUL_DTYPE = jnp.bfloat16   # halves the dominant DFT stream bytes
#                               (pipeline §Perf iter 3); detector indices are
#                               ratio-based and tolerate it (test_pipeline).


def stft_matmul(x, window=256, hop=128):
    """frame + windowed-DFT-as-matmul; matches stft_ref to ~1e-6 (f32)."""
    bins = window // 2 + 1
    basis, _ = _fwd_basis(window)
    frames = frame(x, window, hop)
    packed = jnp.einsum("bfw,wk->bfk", frames.astype(MATMUL_DTYPE),
                        basis.astype(MATMUL_DTYPE),
                        preferred_element_type=jnp.float32)
    return jax.lax.complex(packed[..., :bins], packed[..., bins:2 * bins])


def istft_matmul(z, n_samples, window=256, hop=128):
    """OLA inverse with the inverse DFT as a matmul (irfft-free)."""
    assert 2 * hop == window
    w = jnp.asarray(hamming(window), jnp.float32)
    ib = _inv_basis(window)                       # (2*bins, window)
    packed = jnp.concatenate([jnp.real(z), jnp.imag(z)], axis=-1)
    frames = jnp.einsum("bfk,kw->bfw", packed.astype(MATMUL_DTYPE),
                        ib.astype(MATMUL_DTYPE),
                        preferred_element_type=jnp.float32) * w
    B, F, _ = frames.shape
    n_even = (F + 1) // 2
    n_odd = F - n_even
    even = frames[:, 0::2].reshape(B, -1)
    odd = frames[:, 1::2].reshape(B, -1)
    L = n_even * window + hop
    out = jnp.zeros((B, L), jnp.float32)
    out = out.at[:, :n_even * window].set(even)
    out = out.at[:, hop:hop + n_odd * window].add(odd)
    wn = (hamming(window) ** 2).astype(np.float32)
    norm = np.zeros(L, np.float32)
    norm[:n_even * window] += np.tile(wn, n_even)
    norm[hop:hop + n_odd * window] += np.tile(wn, n_odd)
    out = out[:, :n_samples]
    if L < n_samples:
        out = jnp.pad(out, ((0, 0), (0, n_samples - L)))
        norm = np.pad(norm, (0, n_samples - L))
    return out / jnp.maximum(jnp.asarray(norm[:n_samples]), 1e-8)[None, :]


def istft_ref(z, n_samples, window=256, hop=128):
    """Inverse STFT by windowed overlap-add (50% overlap COLA for Hamming
    needs window-squared normalization).

    Gather/scatter-free for hop == window/2: even and odd frame sets each
    tile the timeline contiguously, so overlap-add is two reshapes and one
    shifted add — local under chunk-batch sharding (see frame())."""
    assert 2 * hop == window, "istft_ref implements the 50%-overlap case"
    w = jnp.asarray(hamming(window), jnp.float32)
    frames = jnp.fft.irfft(z, n=window, axis=-1) * w
    B, F, _ = frames.shape
    n_even = (F + 1) // 2
    n_odd = F - n_even
    even = frames[:, 0::2].reshape(B, -1)          # covers [0, n_even*W)
    odd = frames[:, 1::2].reshape(B, -1)           # covers [hop, ...)
    L = n_even * window + hop
    out = jnp.zeros((B, L), jnp.float32)
    out = out.at[:, :n_even * window].set(even)
    out = out.at[:, hop:hop + n_odd * window].add(odd)
    # per-position window^2 normalization (host-precomputed constant)
    wn = (hamming(window) ** 2).astype(np.float32)
    norm = np.zeros(L, np.float32)
    norm[:n_even * window] += np.tile(wn, n_even)
    norm[hop:hop + n_odd * window] += np.tile(wn, n_odd)
    out = out[:, :n_samples]
    if L < n_samples:
        out = jnp.pad(out, ((0, 0), (0, n_samples - L)))
        norm = np.pad(norm, (0, n_samples - L))
    return out / jnp.maximum(jnp.asarray(norm[:n_samples]), 1e-8)[None, :]
