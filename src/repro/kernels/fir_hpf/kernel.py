"""Pallas TPU kernel: fused FIR filter + decimation (high-pass / band-pass).

Replaces the paper's two SoX passes (downsample, then 1 kHz high-pass) with a
single band-pass FIR applied at the source rate with stride-2 decimation —
the kernel-launch analogue of the paper's Fig-2 "two-split" trick (fewer
passes over the data, no intermediate 22.05 kHz buffer in HBM).

Polyphase formulation: within a tile, the input span is reshaped to
(span/stride, stride) so every tap access is a CONTIGUOUS column slice
(no strided loads on the VPU): y[j] = sum_i g[i] * phases[j + i//s, i%s].

Grid: (batch, out_tiles). VMEM per step (f32, OUT_TILE=2048, stride 2):
  main span (1, 4096) 16 KiB + tail (1, 128) + taps (1, 129) + out (1, 2048).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

OUT_TILE = 2048


def _fir_kernel(x_ref, tail_ref, taps_ref, o_ref, *, n_taps, stride,
                out_tile):
    span = jnp.concatenate([x_ref[0, 0], tail_ref[0, 0]])   # (L,)
    L = out_tile * stride + (n_taps - 1)
    pad = (-L) % stride
    if pad:
        span = jnp.concatenate([span, jnp.zeros((pad,), span.dtype)])
    phases = span.reshape(-1, stride)                     # (L//s, s)
    g = taps_ref[0]                                       # flipped taps (T,)
    acc = jnp.zeros((out_tile,), jnp.float32)
    for i in range(n_taps):
        a, r = divmod(i, stride)
        acc = acc + g[i] * phases[a:a + out_tile, r]
    o_ref[0] = acc


def fir_pallas(x, taps, stride=1, interpret=False):
    """x: (B,S); taps: (T,) np/jnp. Returns (B, S//stride).

    Causal: y[n] = sum_k taps[k] * x[n*stride - k] (left zero-pad)."""
    B, S = x.shape
    T = int(np.asarray(taps).shape[0])
    out_len = S // stride
    n_tiles = -(-out_len // OUT_TILE)
    main_len = n_tiles * OUT_TILE * stride
    # left pad T-1 (causal), right pad to tile alignment + tail
    right_pad = max(0, main_len - S)
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (T - 1, right_pad)))
    main = xp[:, :main_len].reshape(B, n_tiles, OUT_TILE * stride)
    tail_idx = (np.arange(n_tiles)[:, None] * OUT_TILE * stride
                + OUT_TILE * stride + np.arange(T - 1)[None, :])
    tail_idx = np.minimum(tail_idx, xp.shape[1] - 1)
    tails = xp[:, tail_idx.reshape(-1)].reshape(B, n_tiles, T - 1)
    g = jnp.asarray(np.asarray(taps, np.float32)[::-1])[None, :]   # (1,T)

    kernel = functools.partial(_fir_kernel, n_taps=T, stride=stride,
                               out_tile=OUT_TILE)
    out = pl.pallas_call(
        kernel,
        grid=(B, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, OUT_TILE * stride), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, 1, T - 1), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, T), lambda b, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, OUT_TILE), lambda b, t: (b, t)),
        out_shape=jax.ShapeDtypeStruct((B, n_tiles * OUT_TILE), jnp.float32),
        interpret=interpret,
    )(main, tails, g)
    return out[:, :out_len]
