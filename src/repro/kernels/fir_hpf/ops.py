"""Public wrappers for the FIR kernel: high-pass and fused
band-pass + decimate (the pipeline's downsample+HPF stage).

Backend dispatch per repro.kernels.backend; plain functions, composable
inside jit.
"""
from repro.kernels import backend
from repro.kernels.fir_hpf import kernel as K
from repro.kernels.fir_hpf import ref as R


def highpass(x, cutoff_hz=1000.0, rate_hz=22_050, n_taps=129):
    """1 kHz high-pass at the working rate. x: (B,S) -> (B,S)."""
    use_pallas, interp = backend.resolve()
    taps = R.highpass_taps(cutoff_hz, rate_hz, n_taps)
    if not use_pallas:
        return R.fir_ref(x, taps, 1)
    return K.fir_pallas(x, taps, stride=1, interpret=interp)


def bandpass_decimate(x, f_lo_hz=1000.0, f_hi_hz=11_025.0, rate_hz=44_100,
                      factor=2, n_taps=129):
    """Fused anti-alias + high-pass + decimate. x: (B,S) @rate ->
    (B, S//factor) @rate/factor, band-limited to [f_lo, f_hi]."""
    use_pallas, interp = backend.resolve()
    taps = R.bandpass_decimate_taps(f_lo_hz, f_hi_hz, rate_hz, n_taps)
    if not use_pallas:
        return R.fir_ref(x, taps, factor)
    return K.fir_pallas(x, taps, stride=factor, interpret=interp)
