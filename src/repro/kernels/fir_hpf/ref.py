"""Pure-jnp oracle for the fused FIR filter + decimation kernel.

Tap design: windowed-sinc. The pipeline's "downsample then high-pass" pair
(two SoX passes in the paper) is fused into ONE band-pass FIR applied at the
source rate with stride-2 decimation: h = lowpass(f_nyq_target) - lowpass(f_hp).
"""
import jax
import jax.numpy as jnp
import numpy as np


def _lowpass_taps(cutoff_norm, n_taps):
    """Windowed-sinc lowpass; cutoff_norm = f_c / f_s (0..0.5)."""
    m = np.arange(n_taps) - (n_taps - 1) / 2.0
    h = 2.0 * cutoff_norm * np.sinc(2.0 * cutoff_norm * m)
    h *= np.hamming(n_taps)
    return h / h.sum()


def highpass_taps(cutoff_hz, rate_hz, n_taps=129):
    """Spectral-inversion highpass (delta - lowpass)."""
    h = -_lowpass_taps(cutoff_hz / rate_hz, n_taps)
    h[(n_taps - 1) // 2] += 1.0
    return np.asarray(h, np.float32)


def bandpass_decimate_taps(f_lo_hz, f_hi_hz, rate_hz, n_taps=129):
    """Band-pass taps for fused HPF + anti-alias decimation (at source rate)."""
    h = _lowpass_taps(f_hi_hz / rate_hz, n_taps) - _lowpass_taps(
        f_lo_hz / rate_hz, n_taps)
    return np.asarray(h, np.float32)


def fir_ref(x, taps, stride=1):
    """Causal FIR + decimation oracle. x: (B,S) -> (B, S//stride).

    y[n] = sum_k h[k] * x[n*stride - k]  (x zero-padded on the left)."""
    taps = jnp.asarray(taps, jnp.float32)
    T = taps.shape[0]
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (T - 1, 0)))
    out = jax.lax.conv_general_dilated(
        xp[:, None, :], jnp.flip(taps)[None, None, :],
        window_strides=(stride,), padding="VALID",
        dimension_numbers=("NCH", "OIH", "NCH"))
    return out[:, 0, :x.shape[1] // stride]
