"""Kernel execution backend selection.

Modes:
  auto      — TPU: compiled pallas_call; CPU: the pure-jnp ref path (XLA-
              compiled, fast). This is the production default: interpret-mode
              Pallas executes the kernel body in Python per grid step and is
              a correctness tool, not an execution engine.
  pallas    — force pallas_call (compiled on TPU, interpret on CPU).
  interpret — force interpret-mode pallas_call (kernel correctness tests).
  ref       — force the jnp oracle.

The FUSED SURVIVOR TAIL (kernels/fused_tail) resolves through the same
modes: when a two-phase-family plan detects the canonical post-removal
chain ([hpf ->] mmse), its survivor dispatch becomes one fused pass whose
backend follows resolve()/matmul_dft() exactly like the per-stage ops it
replaces — ref oracle on CPU auto, pallas/interpret kernel when forced,
bf16 matmul-DFT twin under "matmul" — so fused and staged stay
bit-identical within every mode.
"""
from __future__ import annotations

import contextlib
import os

import jax

_MODE = os.environ.get("REPRO_KERNEL_BACKEND", "auto")
_VALID = ("auto", "pallas", "interpret", "ref", "matmul")


def set_mode(mode: str):
    global _MODE
    if mode not in _VALID:
        raise ValueError(f"mode {mode!r} not in {_VALID}")
    _MODE = mode


def get_mode() -> str:
    return _MODE


@contextlib.contextmanager
def use(mode: str):
    prev = _MODE
    set_mode(mode)
    try:
        yield
    finally:
        set_mode(prev)


def resolve() -> tuple[bool, bool]:
    """Returns (use_pallas, interpret)."""
    on_tpu = jax.default_backend() == "tpu"
    mode = _MODE
    if mode == "auto":
        return (True, False) if on_tpu else (False, False)
    if mode == "pallas":
        return True, not on_tpu
    if mode == "interpret":
        return True, True
    return False, False


def matmul_dft() -> bool:
    """True when the SPMD-partitionable matmul-DFT path should replace the
    XLA FFT op (mode "matmul"; used by the dry-run — see stft ref.py)."""
    return _MODE == "matmul"
