"""Pure-jnp oracle for the MMSE-STSA gain (Ephraim & Malah 1984).

Uses jax.scipy.special.i0e/i1e (the kernel hand-rolls A&S polynomial
approximations — independent code paths for the allclose sweep).

Per frame t, bin k (decision-directed a-priori SNR):
  gamma = |Y|^2 / lambda_noise                    (a-posteriori SNR)
  xi    = alpha * A^2_{t-1}/lambda + (1-alpha) * max(gamma-1, 0)
  v     = xi * gamma / (1 + xi)
  G     = (sqrt(pi)/2) * (sqrt(v)/gamma) * [(1+v) i0e(v/2) + v i1e(v/2)]
  A     = G * |Y|
The exponentially-scaled Bessels absorb exp(-v/2) (stable for large v).
"""
import jax
import jax.numpy as jnp

XI_MIN = 10.0 ** (-25.0 / 10.0)       # a-priori SNR floor (-25 dB)
GAMMA_MAX = 10.0 ** (40.0 / 10.0)     # a-posteriori SNR ceiling (40 dB)
SQRTPI_2 = 0.8862269254527580         # sqrt(pi)/2


def gain_fn(v, gamma):
    """MMSE-STSA gain from v and gamma (elementwise, f32)."""
    v = jnp.maximum(v, 1e-8)
    g = (SQRTPI_2 * jnp.sqrt(v) / gamma
         * ((1.0 + v) * jax.scipy.special.i0e(v / 2.0)
            + v * jax.scipy.special.i1e(v / 2.0)))
    # large-v asymptote is xi/(1+xi) == v/gamma; the scaled-Bessel form
    # converges there numerically, but clip for safety
    return jnp.clip(g, 0.0, 10.0)


def mmse_stsa_gain_ref(power, noise_psd, alpha=0.98, gain_floor=0.1):
    """power: (B,F,K) |Y|^2; noise_psd: (B,K) -> gains (B,F,K) f32."""
    power = power.astype(jnp.float32)
    lam = jnp.maximum(noise_psd.astype(jnp.float32), 1e-10)[:, None, :]
    gamma = jnp.clip(power / lam, 1e-8, GAMMA_MAX)              # (B,F,K)

    def step(a2_prev, gamma_t):
        xi = alpha * a2_prev + (1.0 - alpha) * jnp.maximum(gamma_t - 1.0, 0.0)
        xi = jnp.maximum(xi, XI_MIN)
        v = xi * gamma_t / (1.0 + xi)
        g = gain_fn(v, gamma_t)
        a2 = (g * g) * gamma_t          # A^2/lambda for the next frame
        return a2, jnp.maximum(g, gain_floor)

    a2_0 = jnp.ones_like(gamma[:, 0, :])
    _, gains = jax.lax.scan(step, a2_0, jnp.moveaxis(gamma, 1, 0))
    return jnp.moveaxis(gains, 0, 1)


def estimate_noise_psd(power, n_frames=16):
    """Initial-segment noise PSD estimate: mean of the first n_frames."""
    return jnp.mean(power[:, :n_frames, :], axis=1)


def denoise_power_ref(power, alpha=0.98, gain_floor=0.1, noise_frames=16):
    noise = estimate_noise_psd(power, noise_frames)
    g = mmse_stsa_gain_ref(power, noise, alpha, gain_floor)
    return g
