"""Public wrapper for the MMSE-STSA gain kernel (+ bin padding).

Backend dispatch per repro.kernels.backend; plain functions, composable
inside jit.
"""
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.mmse_stsa import kernel as K
from repro.kernels.mmse_stsa import ref as R


def mmse_gain(power, noise_psd, alpha=0.98, gain_floor=0.1):
    """power: (B,F,K) |Y|^2; noise_psd: (B,K) -> gains (B,F,K)."""
    use_pallas, interp = backend.resolve()
    if not use_pallas:
        return R.mmse_stsa_gain_ref(power, noise_psd, alpha, gain_floor)
    B, F, Kbins = power.shape
    pad = (-Kbins) % K.BIN_TILE
    if pad:
        power = jnp.pad(power, ((0, 0), (0, 0), (0, pad)))
        noise_psd = jnp.pad(noise_psd, ((0, 0), (0, pad)),
                            constant_values=1.0)
    g = K.mmse_gain_pallas(power, noise_psd, alpha, gain_floor,
                           interpret=interp)
    return g[..., :Kbins]


def denoise_spectrum(spec, alpha=0.98, gain_floor=0.1, noise_frames=16):
    """spec: complex (B,F,K) STFT -> gain-filtered complex spectrum."""
    power = jnp.real(spec) ** 2 + jnp.imag(spec) ** 2
    noise = R.estimate_noise_psd(power, noise_frames)
    g = mmse_gain(power, noise, alpha, gain_floor)
    return spec * g.astype(spec.dtype)
