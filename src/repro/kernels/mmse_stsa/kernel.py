"""Pallas TPU kernel: fused MMSE-STSA spectral gain (the paper's dominant
cost — 923-1020 s of a ~1300 s serial pipeline, Table 1).

Design for TPU:
  * The decision-directed recurrence is sequential over FRAMES but parallel
    over BINS. Grid = (batch, bin_tiles); each grid step walks all frames for
    its 128-bin lane tile with a fori_loop, carrying A^2/lambda in registers.
    128-wide rows map directly onto the VPU lanes.
  * exp(-v/2)*I0/I1(v/2) are computed as exponentially-scaled Bessels i0e/i1e
    via Abramowitz-Stegun 9.8.1-9.8.8 polynomials — no table lookups, no
    overflow for large v (loud signal bins).

VMEM per grid step (F frames, 128-bin tile, f32):
  power block (1, F, 128) + gain block (1, F, 128)  ~ F=896: 2 x 448 KiB
  noise block (1, 128)                               ~ 0.5 KiB
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.mmse_stsa.ref import XI_MIN, GAMMA_MAX, SQRTPI_2

BIN_TILE = 128

_I0_SMALL = (1.0, 3.5156229, 3.0899424, 1.2067492, 0.2659732, 0.0360768,
             0.0045813)
_I0_LARGE = (0.39894228, 0.01328592, 0.00225319, -0.00157565, 0.00916281,
             -0.02057706, 0.02635537, -0.01647633, 0.00392377)
_I1_SMALL = (0.5, 0.87890594, 0.51498869, 0.15084934, 0.02658733, 0.00301532,
             0.00032411)
_I1_LARGE = (0.39894228, -0.03988024, -0.00362018, 0.00163801, -0.01031555,
             0.02282967, -0.02895312, 0.01787654, -0.00420059)


def _poly(coeffs, t):
    acc = jnp.full_like(t, coeffs[-1])
    for c in reversed(coeffs[:-1]):
        acc = acc * t + c
    return acc


def i0e_poly(x):
    """exp(-x) * I0(x) for x >= 0 (A&S 9.8.1 / 9.8.2)."""
    t2 = (x / 3.75) ** 2
    small = _poly(_I0_SMALL, t2) * jnp.exp(-x)
    ti = 3.75 / jnp.maximum(x, 3.75)
    large = _poly(_I0_LARGE, ti) / jnp.sqrt(jnp.maximum(x, 1e-8))
    return jnp.where(x <= 3.75, small, large)


def i1e_poly(x):
    """exp(-x) * I1(x) for x >= 0 (A&S 9.8.3 / 9.8.4)."""
    t2 = (x / 3.75) ** 2
    small = x * _poly(_I1_SMALL, t2) * jnp.exp(-x)
    ti = 3.75 / jnp.maximum(x, 3.75)
    large = _poly(_I1_LARGE, ti) / jnp.sqrt(jnp.maximum(x, 1e-8))
    return jnp.where(x <= 3.75, small, large)


def _mmse_kernel(power_ref, noise_ref, gain_ref, *, alpha, gain_floor,
                 n_frames):
    lam = jnp.maximum(noise_ref[0], 1e-10)           # (BIN_TILE,)
    inv_lam = 1.0 / lam

    def frame_step(t, a2_prev):
        p = power_ref[0, t]                           # (BIN_TILE,)
        gamma = jnp.clip(p * inv_lam, 1e-8, GAMMA_MAX)
        xi = alpha * a2_prev + (1.0 - alpha) * jnp.maximum(gamma - 1.0, 0.0)
        xi = jnp.maximum(xi, XI_MIN)
        v = jnp.maximum(xi * gamma / (1.0 + xi), 1e-8)
        g = (SQRTPI_2 * jnp.sqrt(v) / gamma
             * ((1.0 + v) * i0e_poly(v / 2.0) + v * i1e_poly(v / 2.0)))
        g = jnp.clip(g, 0.0, 10.0)
        gain_ref[0, t] = jnp.maximum(g, gain_floor)
        return (g * g) * gamma                        # A^2/lambda carry

    jax.lax.fori_loop(0, n_frames, frame_step,
                      jnp.ones((BIN_TILE,), jnp.float32))


def mmse_gain_pallas(power, noise_psd, alpha=0.98, gain_floor=0.1,
                     interpret=False):
    """power: (B,F,K) f32, K a multiple of BIN_TILE (ops.py pads);
    noise_psd: (B,K). Returns gains (B,F,K) f32."""
    B, F, K = power.shape
    assert K % BIN_TILE == 0, f"bins {K} not a multiple of {BIN_TILE}"
    kernel = functools.partial(_mmse_kernel, alpha=alpha,
                               gain_floor=gain_floor, n_frames=F)
    return pl.pallas_call(
        kernel,
        grid=(B, K // BIN_TILE),
        in_specs=[
            pl.BlockSpec((1, F, BIN_TILE), lambda b, k: (b, 0, k)),
            pl.BlockSpec((1, BIN_TILE), lambda b, k: (b, k)),
        ],
        out_specs=pl.BlockSpec((1, F, BIN_TILE), lambda b, k: (b, 0, k)),
        out_shape=jax.ShapeDtypeStruct((B, F, K), jnp.float32),
        interpret=interpret,
    )(power.astype(jnp.float32), noise_psd.astype(jnp.float32))
