"""Pallas TPU kernel: the fused survivor tail in one VMEM-resident pass.

One `pallas_call` over grid (survivor_rows,) performs, per grid step:

  gather-compact   the padded survivor-index vector rides as a SCALAR
                   PREFETCH argument (`pltpu.PrefetchScalarGridSpec`), so
                   the input BlockSpec's index_map DMAs exactly the one
                   survivor row this step needs straight out of the full
                   pre-denoise batch — the compacted batch is never
                   materialised in HBM. Out-of-range pad indices are
                   clamped for the DMA and zero-masked in VMEM, preserving
                   `jnp.take(mode="fill")`'s zero-row pad convention
                   bit-for-bit.
  FIR high-pass    (optional) fir_hpf's per-tile tap accumulation, run as
                   a lax.scan over FIR_TILE spans so the chain compiles
                   once and its output materialises — the fused row is
                   bitwise the staged `fir_pallas` row in every mode.
  STFT             the 50%-overlap even/odd contiguous-reshape framing and
                   windowed matmul-DFT of stft_dft's kernel, `frame_block`
                   FRAME_TILE tiles per MXU dispatch (row-tiling a dot is
                   bitwise-stable, so the block size is a pure perf knob).
  MMSE-STSA        the sequential-over-frames decision-directed recurrence
                   of mmse_stsa's kernel (same A&S i0e/i1e polynomials,
                   same clip points), `bin_tile` lanes per scan.
  gain apply       the filtered spectrum re*g / im*g is written packed;
                   power, noise, and gain tiles never leave VMEM.

Only the inverse-DFT overlap-add resynthesis stays OUTSIDE the kernel
(`finish`): the staged pipeline's iSTFT is irfft-based in every non-matmul
mode (stft_dft.ops.istft), and an in-kernel matmul iDFT could not be
bit-identical to it — so the kernel hands the one (rows, F, PAD_OUT)
filtered spectrum across the HBM boundary instead of the gathered wave,
the raw spectrum, the power, the noise and the gain arrays the staged tail
streams between its dispatches.

VMEM per grid step at the SERF shape (S5=110250 -> S_pad=114816, F=896):
row ~0.9 MB + frames 0.9 MB + basis 0.4 MB + packed out 1.4 MB + power/
gain ~1.8 MB — ~5.5 MB, comfortably inside the ~16 MB/core budget the
autotuner (ops.py) validates candidates against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fir_hpf.kernel import OUT_TILE as FIR_TILE
from repro.kernels.fir_hpf.ref import highpass_taps
from repro.kernels.mmse_stsa.kernel import i0e_poly, i1e_poly
from repro.kernels.mmse_stsa.ref import GAMMA_MAX, SQRTPI_2, XI_MIN
from repro.kernels.stft_dft.kernel import FRAME_TILE, PAD_OUT, dft_basis
from repro.kernels.stft_dft.ref import istft_ref


def tail_geometry(S, window=256, hop=128):
    """(n_tiles, S_pad, F, Fv) for an S-sample row: the tiling that
    pad_for_stft + stft_pallas produce, shared by kernel and autotuner."""
    tile_span = FRAME_TILE * hop
    tail = window - hop
    n_tiles = max(1, -(-(S - tail) // tile_span))
    return (n_tiles, n_tiles * tile_span + tail, n_tiles * FRAME_TILE,
            (S - window) // hop + 1)


def _fused_tail_kernel(idx_ref, x_ref, basis_ref, o_ref, *, n_rows_in, S,
                       window, hop, bins, alpha, gain_floor, noise_frames,
                       taps, frame_block, bin_tile):
    r = pl.program_id(0)
    row = x_ref[0].astype(jnp.float32)

    if taps is not None:
        # causal FIR, stride 1: y[n] = sum_i g[i] * xp[n+i] with g the
        # flipped taps — fir_hpf._fir_kernel's exact per-FIR_TILE chain,
        # run as a lax.scan over tiles. The loop is load-bearing for
        # bit-identity, not style: an unrolled whole-row tap chain is one
        # giant elementwise graph that XLA duplicates per consumer (the
        # framing slices below), and each duplicate contracts mul+add to
        # FMA differently. The scan compiles the chain ONCE and
        # materialises its output, so every consumer reads the same bits
        # the staged fir_pallas produced.
        T = len(taps)
        g = np.asarray(taps, np.float32)[::-1]
        n_ft = -(-S // FIR_TILE)
        xp = jnp.concatenate([jnp.zeros((T - 1,), jnp.float32), row,
                              jnp.zeros((n_ft * FIR_TILE - S,), jnp.float32)])
        spans = jnp.stack([jax.lax.slice(xp, (t * FIR_TILE,),
                                         (t * FIR_TILE + FIR_TILE + T - 1,))
                           for t in range(n_ft)])

        def fir_tile(carry, span):
            acc = jnp.zeros((FIR_TILE,), jnp.float32)
            for i in range(T):
                acc = acc + g[i] * span[i:i + FIR_TILE]
            return carry, acc

        _, ys = jax.lax.scan(fir_tile, 0, spans)
        row = ys.reshape(-1)[:S]

    # fill-gather semantics: the BlockSpec index_map clamped this step's
    # row id for the DMA; pad slots (idx >= n_rows_in) become zero rows.
    # Masked AFTER the (linear) FIR — FIR(0)=0, so values match the
    # staged take-then-filter order — keeping the predicate out of the
    # tap chain's fusion context.
    row = jnp.where(idx_ref[r] < n_rows_in, row, 0.0)

    n_tiles, S_pad, F, Fv = tail_geometry(S, window, hop)
    row = jnp.concatenate([row, jnp.zeros((S_pad - S,), jnp.float32)])

    # framing: per tile the even/odd contiguous reshapes of
    # stft_dft._stft_kernel (the boundary tail is the next span's head)
    tile_span = FRAME_TILE * hop
    half = FRAME_TILE // 2
    frames = []
    for t in range(n_tiles):
        span = row[t * tile_span:(t + 1) * tile_span + (window - hop)]
        even = span[:half * window].reshape(half, window)
        odd = span[hop:hop + half * window].reshape(half, window)
        frames.append(jnp.stack([even, odd], axis=1)
                      .reshape(FRAME_TILE, window))
    frames = jnp.concatenate(frames)                       # (F, window)

    # windowed DFT as matmul, frame_block tiles per MXU dispatch
    m = frame_block * FRAME_TILE
    packed = jnp.concatenate(
        [jnp.dot(frames[a:a + m], basis_ref[...],
                 preferred_element_type=jnp.float32)
         for a in range(0, F, m)])                         # (F, PAD_OUT)

    re, im = packed[:, :bins], packed[:, bins:2 * bins]
    power = re * re + im * im                              # (F, bins)
    nf = min(noise_frames, Fv)
    noise = jnp.mean(power[:nf], axis=0)                   # (bins,)

    # decision-directed MMSE-STSA recurrence, bin_tile lanes per scan —
    # the identical per-frame arithmetic of mmse_stsa._mmse_kernel; bins
    # are padded to the lane tile (pad noise 1.0, as mmse_stsa.ops does)
    KP = -(-bins // bin_tile) * bin_tile
    powp = jnp.concatenate([power, jnp.zeros((F, KP - bins))], axis=1)
    noisep = jnp.concatenate([noise, jnp.ones((KP - bins,))])
    gains = []
    for c in range(0, KP, bin_tile):
        lam = jnp.maximum(noisep[c:c + bin_tile], 1e-10)
        inv_lam = 1.0 / lam

        def step(a2_prev, p_t):
            gamma = jnp.clip(p_t * inv_lam, 1e-8, GAMMA_MAX)
            xi = alpha * a2_prev \
                + (1.0 - alpha) * jnp.maximum(gamma - 1.0, 0.0)
            xi = jnp.maximum(xi, XI_MIN)
            v = jnp.maximum(xi * gamma / (1.0 + xi), 1e-8)
            gg = (SQRTPI_2 * jnp.sqrt(v) / gamma
                  * ((1.0 + v) * i0e_poly(v / 2.0)
                     + v * i1e_poly(v / 2.0)))
            gg = jnp.clip(gg, 0.0, 10.0)
            return (gg * gg) * gamma, jnp.maximum(gg, gain_floor)

        _, gc = jax.lax.scan(step, jnp.ones((bin_tile,), jnp.float32),
                             powp[:, c:c + bin_tile])
        gains.append(gc)
    gain = jnp.concatenate(gains, axis=1)[:, :bins]        # (F, bins)

    o_ref[0] = jnp.concatenate(
        [re * gain, im * gain, jnp.zeros((F, PAD_OUT - 2 * bins))], axis=1)


def fused_tail_pallas(wave, idx, cfg, hpf=False, frame_block=2,
                      bin_tile=128, interpret=False):
    """wave: (B, S) f32 pre-denoise batch; idx: (R,) padded int32 survivor
    indices. Returns the packed gain-filtered spectrum (R, F, PAD_OUT) —
    feed to `finish` for the overlap-add resynthesis."""
    B, S = wave.shape
    R = idx.shape[0]
    window, hop = cfg.stft_window, cfg.stft_hop
    assert hop * 2 == window, "kernel exploits 50% overlap"
    bins = window // 2 + 1
    _, _, F, _ = tail_geometry(S, window, hop)
    taps = highpass_taps(cfg.hpf_cutoff_hz, cfg.target_rate_hz,
                         cfg.hpf_taps) if hpf else None
    kernel = functools.partial(
        _fused_tail_kernel, n_rows_in=B, S=S, window=window, hop=hop,
        bins=bins, alpha=cfg.mmse_alpha, gain_floor=cfg.mmse_gain_floor,
        noise_frames=cfg.noise_est_frames, taps=taps,
        frame_block=int(frame_block), bin_tile=int(bin_tile))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[
            # the gather: this step's survivor row, clamped for the DMA
            # (the kernel zero-masks pad rows, matching the fill gather)
            pl.BlockSpec((1, S),
                         lambda r, idx_ref: (jnp.minimum(idx_ref[r], B - 1),
                                             0)),
            pl.BlockSpec((window, PAD_OUT), lambda r, idx_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, F, PAD_OUT), lambda r, idx_ref: (r, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, F, PAD_OUT), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), wave.astype(jnp.float32),
      dft_basis(window, jnp.float32))


def finish(packed, S, cfg):
    """Inverse-DFT overlap-add resynthesis of the kernel's packed filtered
    spectrum: complexify, slice the valid frames, irfft-OLA — the same
    istft_ref every staged non-matmul mode runs, so fused == staged
    bitwise."""
    bins = cfg.stft_window // 2 + 1
    Fv = (S - cfg.stft_window) // cfg.stft_hop + 1
    z = jax.lax.complex(packed[..., :bins], packed[..., bins:2 * bins])
    return istft_ref(z[:, :Fv], S, cfg.stft_window, cfg.stft_hop)
