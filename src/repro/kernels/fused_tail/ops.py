"""Dispatch + autotuner for the fused survivor tail.

`fused_tail` is the single public entry: backend-mode resolution follows
kernels/backend.py exactly as the staged per-stage ops do —

    ref / auto-on-CPU  ->  ref.fused_tail_ref      (jnp oracle)
    matmul             ->  ref.fused_tail_matmul   (bf16 DFT dry-run twin)
    pallas / interpret ->  kernel.fused_tail_pallas + kernel.finish
    auto-on-TPU        ->  compiled kernel.fused_tail_pallas

The kernel path takes a `TailConfig` (frame_block x bin_tile) chosen by
the autotuner: `autotune` enumerates CANDIDATES, drops any whose additive
f32 VMEM footprint model (`vmem_bytes`) exceeds the per-core budget,
times the survivors (min-of-reps, block_until_ready) and caches the
winner per (backend mode, survivor bucket, S, hpf). `best_config` is the
hot-path accessor: tuned entry if present, else the first feasible
candidate — it never probes, so plans can call it inside a jit trace
without timing side effects.

Every knob is a pure perf knob: frame_block only re-tiles the DFT dot's
M dimension and bin_tile only re-chunks elementwise lanes, both of which
are bitwise-stable — so the tuner can never change results, only speed.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.fused_tail import kernel as K
from repro.kernels.fused_tail import ref as R
from repro.kernels.stft_dft.kernel import PAD_OUT

VMEM_BUDGET = int(os.environ.get("REPRO_FUSED_VMEM_BYTES", 16 * 2 ** 20))


@dataclasses.dataclass(frozen=True)
class TailConfig:
    frame_block: int = 2   # FRAME_TILE-tiles of frames per DFT dispatch
    bin_tile: int = 128    # spectral lanes per MMSE scan


CANDIDATES = tuple(TailConfig(fb, bt)
                   for fb in (1, 2, 4, 8) for bt in (128, 256))


def vmem_bytes(tc: TailConfig, S, window=256, hop=128, hpf=False,
               hpf_taps=129) -> int:
    """Additive f32 model of the kernel's per-grid-step VMEM residency."""
    _, S_pad, F, _ = K.tail_geometry(S, window, hop)
    bins = window // 2 + 1
    KP = -(-bins // tc.bin_tile) * tc.bin_tile
    n = S_pad                      # zero-padded row
    if hpf:
        n_ft = -(-S // K.FIR_TILE)
        n += S + hpf_taps - 1                 # causal-padded input
        n += n_ft * (K.FIR_TILE + hpf_taps - 1)  # stacked FIR spans
        n += n_ft * K.FIR_TILE                # materialised scan output
    n += F * window                # frames
    n += window * PAD_OUT          # basis
    n += tc.frame_block * 128 * PAD_OUT  # dot chunk in flight
    n += F * PAD_OUT               # packed output block
    n += F * (bins + KP)           # power + lane-padded power
    n += F * KP + 2 * KP           # gains + lam/inv_lam
    return 4 * n


def feasible(S, window=256, hop=128, hpf=False, hpf_taps=129,
             budget=None):
    budget = VMEM_BUDGET if budget is None else budget
    return [tc for tc in CANDIDATES
            if vmem_bytes(tc, S, window, hop, hpf, hpf_taps) <= budget]


# (backend mode, rows, S, hpf) -> TailConfig
_TUNED: dict[tuple, TailConfig] = {}
# same key -> [(TailConfig, seconds)] probe records, for benches/tests
_PROBES: dict[tuple, list] = {}


def _key(rows, S, hpf):
    return (backend.get_mode(), int(rows), int(S), bool(hpf))


def best_config(rows, S, cfg, hpf=False) -> TailConfig:
    """Tuned winner if autotune ran for this key, else the first feasible
    candidate. Never probes — safe on the dispatch hot path."""
    tuned = _TUNED.get(_key(rows, S, hpf))
    if tuned is not None:
        return tuned
    feas = feasible(S, cfg.stft_window, cfg.stft_hop, hpf, cfg.hpf_taps)
    if not feas:
        raise ValueError(
            f"no VMEM-feasible fused-tail config for S={S} "
            f"(budget {VMEM_BUDGET} bytes)")
    default = TailConfig()
    return default if default in feas else feas[0]


def autotune(wave, idx, cfg, hpf=False, reps=2) -> TailConfig:
    """Probe every VMEM-feasible candidate on (wave, idx), cache and
    return the fastest. No-op (returns the cached winner) on a warm key."""
    rows, S = idx.shape[0], wave.shape[1]
    key = _key(rows, S, hpf)
    if key in _TUNED:
        return _TUNED[key]
    use_pallas, interpret = backend.resolve()
    feas = feasible(S, cfg.stft_window, cfg.stft_hop, hpf, cfg.hpf_taps)
    if not feas:
        raise ValueError(f"no VMEM-feasible fused-tail config for S={S}")
    records = []
    for tc in feas:
        if use_pallas:
            fn = jax.jit(lambda w, i, tc=tc: K.finish(
                K.fused_tail_pallas(w, i, cfg, hpf, tc.frame_block,
                                    tc.bin_tile, interpret=interpret),
                w.shape[1], cfg))
        else:
            # ref path ignores tiling; probe once so records stay uniform
            fn = jax.jit(lambda w, i: R.fused_tail_ref(w, i, cfg, hpf))
        fn(wave, idx).block_until_ready()  # compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn(wave, idx).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        records.append((tc, best))
        if not use_pallas:
            break  # tiling is dead on the ref path; one probe suffices
    records.sort(key=lambda r: r[1])
    _PROBES[key] = records
    _TUNED[key] = records[0][0]
    return _TUNED[key]


def clear_tuning():
    _TUNED.clear()
    _PROBES.clear()


def fused_tail(wave, idx, cfg, hpf=False, tile: TailConfig | None = None):
    """The fused survivor tail: (B, S) batch + (R,) padded survivor index
    vector -> cleaned (R, S). Mode-dispatched like every staged op."""
    if backend.matmul_dft():
        return R.fused_tail_matmul(wave, idx, cfg, hpf)
    use_pallas, interpret = backend.resolve()
    if not use_pallas:
        return R.fused_tail_ref(wave, idx, cfg, hpf)
    tc = tile or best_config(idx.shape[0], wave.shape[1], cfg, hpf)
    packed = K.fused_tail_pallas(wave, idx, cfg, hpf, tc.frame_block,
                                 tc.bin_tile, interpret=interpret)
    return K.finish(packed, wave.shape[1], cfg)
