"""Pure-jnp oracle for the fused survivor tail — the bit-exactness anchor.

`fused_tail_ref` is composed from the EXACT per-stage refs the staged tail
dispatches under backend mode "ref":

    take(mode="fill") gather  ->  fir_ref HPF (optional)  ->  pad_for_stft
    ->  stft_ref[:, :Fv]  ->  |.|^2  ->  estimate_noise_psd
    ->  mmse_stsa_gain_ref  ->  spec * gain  ->  istft_ref

so staged-vs-fused bit-identity in ref mode holds BY CONSTRUCTION, and the
Pallas kernel (kernel.py) is tested against this composition. The matmul
twin mirrors the stage library under backend mode "matmul" (bf16 DFT
streams — the dry-run cost model, not bit-compatible with ref).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fir_hpf import ref as FR
from repro.kernels.mmse_stsa import ref as MR
from repro.kernels.stft_dft import ref as SR


def _pad_for_stft(x, window, hop):
    """Tile-aligned right pad — same arithmetic as stft_dft.ops.pad_for_stft
    (duplicated here so ref.py stays import-free of the dispatching ops)."""
    from repro.kernels.stft_dft.kernel import FRAME_TILE
    B, S = x.shape
    tile_span = FRAME_TILE * hop
    tail = window - hop
    n_tiles = max(1, -(-(S - tail) // tile_span))
    target = n_tiles * tile_span + tail
    if target > S:
        x = jnp.pad(x, ((0, 0), (0, target - S)))
    return x


def gather_rows(wave, idx):
    """The device-compaction gather with the scheduler's pad convention:
    out-of-range indices (pad slots) become all-zero rows."""
    return jnp.take(wave, idx, axis=0, mode="fill", fill_value=0.0)


def fused_tail_ref(wave, idx, cfg, hpf=False):
    """wave: (B, S) full pre-denoise batch; idx: (R,) padded int32 survivor
    indices (scheduler.survivor_indices). Returns cleaned (R, S) f32."""
    batch = gather_rows(wave, idx)
    if hpf:
        taps = FR.highpass_taps(cfg.hpf_cutoff_hz, cfg.target_rate_hz,
                                cfg.hpf_taps)
        batch = FR.fir_ref(batch, taps, 1)
    S = batch.shape[1]
    window, hop = cfg.stft_window, cfg.stft_hop
    Fv = (S - window) // hop + 1
    xp = _pad_for_stft(batch, window, hop)
    spec = SR.stft_ref(xp, window, hop)[:, :Fv]
    power = jnp.real(spec) ** 2 + jnp.imag(spec) ** 2
    noise = MR.estimate_noise_psd(power, cfg.noise_est_frames)
    gain = MR.mmse_stsa_gain_ref(power, noise, cfg.mmse_alpha,
                                 cfg.mmse_gain_floor)
    return SR.istft_ref(spec * gain.astype(spec.dtype), S, window, hop)


def fused_tail_matmul(wave, idx, cfg, hpf=False):
    """The backend-mode-"matmul" twin (SPMD-partitionable bf16 DFT streams),
    mirroring what the staged tail computes under that mode."""
    batch = gather_rows(wave, idx)
    if hpf:
        taps = FR.highpass_taps(cfg.hpf_cutoff_hz, cfg.target_rate_hz,
                                cfg.hpf_taps)
        batch = FR.fir_ref(batch, taps, 1)
    S = batch.shape[1]
    window, hop = cfg.stft_window, cfg.stft_hop
    Fv = (S - window) // hop + 1
    xp = _pad_for_stft(batch, window, hop)
    spec = SR.stft_matmul(xp, window, hop)[:, :Fv]
    power = jnp.real(spec) ** 2 + jnp.imag(spec) ** 2
    noise = MR.estimate_noise_psd(power, cfg.noise_est_frames)
    gain = MR.mmse_stsa_gain_ref(power, noise, cfg.mmse_alpha,
                                 cfg.mmse_gain_floor)
    return SR.istft_matmul(spec * gain.astype(spec.dtype), S, window, hop)
