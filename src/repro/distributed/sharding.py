"""Logical-axis sharding rules.

Models/optimizers never mention mesh axes directly; they use logical names.
Rules map logical -> mesh axes per sharding mode; anything not in the mesh is
dropped (so the same model code runs on a 1-device CPU test, a (16,16) pod, or
a (2,16,16) multi-pod mesh).

Modes
  tp       : batch over (pod,data); fused feature dims (q_dim/kv_dim/ff/vocab/
             experts) over model; weights' d_model replicated.
  fsdp_tp  : tp + weights/optimizer d_model ("embed") dim sharded over data
             (ZeRO-3-style; GSPMD inserts the fwd all-gathers / bwd
             reduce-scatters). Needed for arctic-480b training to fit HBM.

Decode overrides: long-context cells re-map kv_seq -> (data,) or (data,model)
and batch -> () via `overrides`.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (filtered by what the mesh provides)
_TABLES = {
    "tp": {
        "batch": ("pod", "data"),
        "seq": (),
        "embed": (),            # residual d_model: replicated
        "q_dim": ("model",),    # fused num_heads*head_dim
        "kv_dim": ("model",),
        "heads": ("model",),    # only used where head count divides
        "kv_heads": (),
        "ff": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_ff": (),
        # activation-side axes (distinct from the weight-side names so modes
        # like zero3 can shard batch over "model" without duplicate specs)
        "act_q": ("model",),
        "act_kv": ("model",),
        "act_ff": ("model",),
        "act_vocab": ("model",),
        "act_experts": ("model",),
        "act_expert_ff": (),
        "kv_seq": (),
        "conv": (),
        "state": (),
        # weight-side d_model (first dim of most projection matrices)
        "w_embed": (),
        # audio pipeline
        "chunks": ("pod", "data", "model"),   # pure data parallel over all devices
        "samples": (),
        "bins": (),
    },
}
_TABLES["fsdp_tp"] = dict(_TABLES["tp"], w_embed=("pod", "data"),
                          expert_ff=())
# zero3: pure data parallelism over the whole pod with ZeRO-3 weight
# sharding — batch over every axis, weights/optimizer sharded on their
# feature dims, activations never all-reduced (the hillclimb profile for
# collective-bound small-model train cells; see EXPERIMENTS.md §Perf).
_TABLES["zero3"] = dict(
    _TABLES["tp"],
    batch=("pod", "data", "model"),
    w_embed=("data",),
    act_q=(), act_kv=(), act_ff=(), act_vocab=(), act_experts=(),
    act_expert_ff=(),
)
# sp_ep: SEQUENCE-PARALLEL residual stream (seq -> model axis) with
# replicated-compute attention/MLP weights (fsdp-stored, gathered per layer)
# and expert-parallel MoE. Every norm/matmul/softmax is local to a seq
# shard; the only collectives are the per-layer KV + weight gathers and the
# MoE all-to-all pair. Fixes the per-block all-reduce storm GSPMD emits for
# uneven kv_heads (arctic hillclimb, EXPERIMENTS.md §Perf iter 2/3).
_TABLES["sp_ep"] = dict(
    _TABLES["fsdp_tp"],
    seq=("model",), seq_cp=("model",),
    q_dim=(), kv_dim=(), ff=(), vocab=(),
    act_q=(), act_kv=(), act_ff=(), act_vocab=(),
)
for _t in ("tp", "fsdp_tp", "zero3"):
    _TABLES[_t]["seq_cp"] = ()


class ShardingRules:
    def __init__(self, mesh: Mesh | None = None, mode: str = "tp",
                 overrides: dict | None = None):
        if mode not in _TABLES:
            raise KeyError(f"unknown sharding mode {mode!r}")
        self.mesh = mesh
        self.mode = mode
        table = dict(_TABLES[mode])
        if overrides:
            table.update(overrides)
        self._table = table
        self._mesh_axes = set(mesh.axis_names) if mesh is not None else set()

    def _resolve(self, name):
        if name is None:
            return None
        axes = tuple(a for a in self._table[name] if a in self._mesh_axes)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    def spec(self, *axes) -> P:
        """PartitionSpec from logical axis names (None = replicated dim)."""
        return P(*(self._resolve(a) for a in axes))

    def sharding(self, *axes) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*axes))

    def constrain(self, x, *axes):
        """with_sharding_constraint; no-op without a mesh (CPU unit tests)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(*axes))

    @property
    def fingerprint(self):
        """Stable hashable identity for compile-cache keying: mesh axis
        names + shape + DEVICE IDS + the resolved rule table. Logically-
        equal rules compare equal, unlike `id(rules)` which can silently
        collide after GC reuses the id; device ids keep two same-shape
        meshes over different devices (elastic restart) from aliasing a
        jitted closure that captured the old mesh."""
        mesh = (() if self.mesh is None
                else (tuple(self.mesh.axis_names),
                      tuple(self.mesh.devices.shape),
                      tuple(int(d.id) for d in self.mesh.devices.flat)))
        table = tuple(sorted((k, tuple(v)) for k, v in self._table.items()))
        return (self.mode, mesh, table)


NULL_RULES = ShardingRules(mesh=None)


def pool_rules(n_shards, meshes=None, mode="tp", overrides=None):
    """Per-shard ShardingRules for a ShardedPlan.

    `meshes` is one mesh shared by every shard (or None for unmeshed CPU
    tests), or a sequence of per-shard meshes (a multi-host pool, each host
    owning its local devices; cycled if shorter than n_shards). Each
    returned rules object carries its own VALUE fingerprint — mesh axis
    names, shape, and device ids — so sharded compiles land in the shared
    `CompileCache` correctly: same-mesh shards dedup to one compiled phase
    per (graph, shape), while shards over disjoint device sets can never
    alias each other's jitted closures."""
    if meshes is None or isinstance(meshes, Mesh):
        meshes = [meshes]
    meshes = list(meshes)
    return [ShardingRules(meshes[j % len(meshes)], mode=mode,
                          overrides=overrides) for j in range(n_shards)]


def _is_spec_leaf(v):
    """A spec leaf is a (possibly empty) tuple of logical names/None —
    tuples of tuples (e.g. xLSTM state tuples) recurse instead."""
    return isinstance(v, tuple) and all(
        e is None or isinstance(e, str) for e in v)


def tree_shardings(rules: ShardingRules, spec_tree):
    """Map a pytree of logical-axis tuples to NamedShardings (or None)."""
    if rules.mesh is None:
        return None
    return jax.tree.map(lambda axes: rules.sharding(*axes),
                        spec_tree, is_leaf=_is_spec_leaf)
