"""Overlapped all-gather matmul ("collective matmul", Wang et al. 2023) via
shard_map + ppermute — a hillclimb lever for collective-bound cells.

Standard GSPMD lowering of  y = x @ W  with W column-sharded and x needing an
all-gather serializes: all-gather(x) THEN matmul. The collective-matmul form
pipelines: each of the N steps matmuls the locally-held x shard while
ppermuting the next shard around the ring — communication hides behind
compute whenever per-step matmul time >= per-step permute time.

Used by the hillclimbed sharding profile for decode MLP/logits layers
(EXPERIMENTS.md §Perf) — correctness is covered by tests/test_distributed.py
against the plain einsum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def ag_matmul(x, w, mesh, axis="model"):
    """y = x @ w with x row-sharded on `axis` (dim 0 blocks), w replicated
    per-shard column block; gathers x shards ring-wise, overlapping each hop
    with the local partial matmul.

    x: (M, K) sharded (axis, None); w: (K, N) sharded (None, axis).
    Returns y: (M, N) sharded (None, axis)."""
    n = mesh.shape[axis]

    def local(x_blk, w_blk):
        # x_blk: (M/n, K); w_blk: (K, N/n)
        idx = jax.lax.axis_index(axis)
        M_blk = x_blk.shape[0]
        # pvary marks the accumulator varying over the ring axis; older jax
        # has no pvary and no varying-axes check either, so identity is safe
        pvary = getattr(jax.lax, "pvary", lambda v, axes: v)
        out = pvary(
            jnp.zeros((M_blk * n, w_blk.shape[1]), x_blk.dtype), (axis,))

        def body(i, carry):
            out, cur = carry
            src_idx = (idx - i) % n          # whose shard we now hold
            out = jax.lax.dynamic_update_slice(
                out, cur @ w_blk, (src_idx * M_blk, 0))
            nxt = jax.lax.ppermute(
                cur, axis, [(j, (j + 1) % n) for j in range(n)])
            return (out, nxt)

        out, _ = jax.lax.fori_loop(0, n, body, (out, x_blk))
        return out

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axis, None), P(None, axis)),
                     out_specs=P(None, axis))(x, w)
