"""Run journal: queue-state checkpointing for cross-restart stream resume.

The paper's master only tracks in-flight work — kill the process and the
stream starts over. `RunJournal` extends the exactly-once story of the
leased `WorkQueue` (PR 2: worker crashes) across PROCESS restarts: after
every emission the consuming plan records the queue snapshot (done ids,
still-leased ids, stream size); a relaunch with `--resume` restores the
queue and skips exactly the work ids the dead run already emitted.

Records ride the existing ckpt layout — each snapshot is a `step_<n>`
directory written by `ckpt.save` with an empty leaf set and the queue state
in manifest meta, so journal writes inherit ckpt's atomic tmp-then-rename
and `prune_old` retention. The queue state is tiny (id lists), so a
per-emission record costs one small JSON write.
"""
from __future__ import annotations

import os

from repro.ckpt import checkpoint as ckpt
from repro.data.queue import WorkQueue


class RunJournal:
    """Append-style journal of WorkQueue snapshots in a directory.

        journal = RunJournal(dir)
        journal.record(queue)          # after each exactly-once emission
        ...process killed, relaunched...
        queue = RunJournal(dir).resume_queue(n_items=n)   # or None, fresh

    Emission gating defines the contract (ShardedPlan's completion-gated
    convention): a plan records IMMEDIATELY BEFORE handing each result to
    its consumer, so everything recorded was emitted and nothing is ever
    emitted twice — exactly-once at the plan boundary across restarts.
    """

    def __init__(self, directory, keep=3):
        self.directory = os.fspath(directory)
        self.keep = keep
        self._step = ckpt.latest_step(self.directory) or 0

    @property
    def step(self) -> int:
        return self._step

    def record(self, queue, meta=None) -> int:
        """Snapshot `queue` (a WorkQueue, or a ready state dict) plus
        optional extra meta. Returns the record's step number."""
        state = queue.state() if hasattr(queue, "state") else dict(queue)
        self._step += 1
        m = {"queue": state, "emitted": len(state["done"])}
        m.update(meta or {})
        ckpt.save(self.directory, self._step, {}, meta=m)
        ckpt.prune_old(self.directory, keep=self.keep)
        return self._step

    def load(self):
        """The latest record's meta dict ({"queue": ..., "emitted": ...,
        **extra}), or None when the journal is empty."""
        step = ckpt.latest_step(self.directory)
        if step is None:
            return None
        _, meta = ckpt.restore(self.directory, step, like=None)
        return meta

    def resume_queue(self, n_items=None, **queue_kw):
        """WorkQueue restored from the latest record; None when the journal
        is empty (fresh run). `n_items`, when given, guards against
        resuming a journal onto a different stream."""
        meta = self.load()
        if meta is None:
            return None
        state = meta["queue"]
        if n_items is not None and int(n_items) != int(state["n_items"]):
            raise ValueError(
                f"journal records a {state['n_items']}-item stream; the "
                f"resume stream has {n_items} items — refusing to mix runs")
        return WorkQueue.from_state(state, **queue_kw)
