"""Persistence subsystem: content-addressed preprocessing results and
cross-restart run journals.

The fourth architectural layer under the graph / plan / shard stack: the
graph fixes WHAT computes, a plan fixes HOW it executes, shards fix WHERE —
the store fixes what never needs to run again. `ChunkStore` persists
per-batch preprocessing results keyed by content hash of (raw chunk bytes,
graph fingerprint, kernel backend mode); `RunJournal` checkpoints work-queue
state through the `ckpt` layout so a killed stream resumes exactly where it
died. Both are consumed by `repro.core.plans.CachedPlan`.
"""
from repro.store.chunk_store import ChunkStore, StoreStats, content_key
from repro.store.journal import RunJournal

__all__ = ["ChunkStore", "StoreStats", "content_key", "RunJournal"]
