"""Content-addressed store for preprocessing results.

Long-running bioacoustic surveys re-preprocess the same recordings every
time a run restarts or a config re-run touches overlapping data (rolling
sensor-network archives: most of today's input overlaps yesterday's). The
store turns those re-runs into lookups: a result is keyed by the content
hash of (raw chunk bytes, graph fingerprint, kernel backend mode) — the
same value identity the CompileCache keys compiles on — so a hit is valid
if and only if the identical bytes would flow through the identical
computation.

Layout (mirrors ckpt/checkpoint.py):

    <dir>/objects/<key>/
        manifest.json      {key, meta, leaves: {name: {file, shape,
                            dtype, crc32}}}
        <leaf>.npy         raw array bytes
    <dir>/objects/<key>.tmp-*   while writing (atomic rename on completion)

Writes are tmp-then-rename atomic: a killed writer leaves only a tmp
directory that never shadows the key, and concurrent writers race benignly
(first rename wins, the loser discards). Reads verify per-leaf crc32
against the manifest.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
import tempfile
import zlib

import numpy as np

from repro.obs import metrics as obs_metrics


def content_key(chunks, graph_fingerprint, backend_mode) -> str:
    """Content hash of one raw chunk batch under one computation identity.

    chunks: the raw (B, C, S) source batch, hashed as float32 bytes;
    graph_fingerprint: `PipelineGraph.fingerprint` (config + stage names +
    source geometry — all frozen, repr-stable); backend_mode: the kernel
    backend mode string. Everything the CompileCache keys on except the
    sharding rules — sharding moves work, never values, so differently-
    sharded runs share entries (plan equivalence is bit-exact on masks).
    """
    h = hashlib.sha256()
    h.update(repr(graph_fingerprint).encode())
    h.update(b"\x00" + str(backend_mode).encode() + b"\x00")
    arr = np.ascontiguousarray(np.asarray(chunks, np.float32))
    h.update(str(arr.shape).encode() + b"\x00")
    h.update(arr.tobytes())
    return h.hexdigest()


_STORE_FIELDS = (
    "hits", "misses", "writes",
    "dup_writes",       # put() of a key that already existed
    "corrupt",          # entries evicted on crc mismatch
    "bytes_saved",      # source bytes whose preprocessing a hit skipped
    "bytes_written",    # bytes of result payload persisted
    "gc_evicted",       # entries evicted by gc() retention sweeps
    "gc_bytes_freed",   # payload bytes those sweeps reclaimed
)


class StoreStats:
    """Hit/miss/volume accounting for one ChunkStore handle.

    The plain integer attributes stay the source of truth (and the only
    surface callers touch), but every increment also mirrors its delta
    into the process metrics registry as
    `store_<field>_total{store=<label>}` — so a ChunkStore shows up in
    `repro.obs` snapshots and Prometheus text without a scrape hook."""

    def __init__(self, label="chunks"):
        object.__setattr__(self, "label", str(label))
        for name in _STORE_FIELDS:
            object.__setattr__(self, name, 0)

    def __setattr__(self, name, value):
        if name in _STORE_FIELDS:
            delta = value - getattr(self, name, 0)
            if delta > 0:
                obs_metrics.counter(
                    "store_" + name + "_total",
                    "ChunkStore ledger (mirrored from StoreStats)",
                    ("store",)).labels(store=self.label).inc(delta)
        object.__setattr__(self, name, value)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self):
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate, "writes": self.writes,
                "dup_writes": self.dup_writes, "corrupt": self.corrupt,
                "bytes_saved": self.bytes_saved,
                "bytes_written": self.bytes_written,
                "gc_evicted": self.gc_evicted,
                "gc_bytes_freed": self.gc_bytes_freed}

    def __str__(self):
        return (f"hits={self.hits} misses={self.misses} "
                f"(hit rate {self.hit_rate:.1%}), "
                f"{self.bytes_saved / 2**20:.1f} MB source not reprocessed, "
                f"{self.bytes_written / 2**20:.1f} MB written")


class ChunkStore:
    """Content-addressed result store with atomic writes and verified reads.

    The store is payload-agnostic: `put`/`get` move {name: ndarray} leaf
    dicts plus a JSON-safe meta dict; `CachedPlan` owns the BatchResult
    <-> entry conversion. `verify_crc=False` skips integrity checks on
    read; `evict_corrupt=True` turns a crc mismatch into an eviction + miss
    (self-healing cache) instead of an IOError (archival strictness).
    """

    def __init__(self, directory, verify_crc=True, evict_corrupt=False):
        self.directory = os.fspath(directory)
        self._objects = os.path.join(self.directory, "objects")
        os.makedirs(self._objects, exist_ok=True)
        self.verify_crc = verify_crc
        self.evict_corrupt = evict_corrupt
        self.stats = StoreStats(
            label=os.path.basename(os.path.normpath(self.directory))
            or "chunks")

    def _path(self, key):
        return os.path.join(self._objects, key)

    # -- write ---------------------------------------------------------------
    def put(self, key, arrays, meta=None) -> bool:
        """Persist {name: ndarray} + meta under `key` atomically. Returns
        False (and writes nothing) when the key already exists — entries
        are immutable, first write wins."""
        final = self._path(key)
        if os.path.isfile(os.path.join(final, "manifest.json")):
            self.stats.dup_writes += 1
            return False
        tmp = tempfile.mkdtemp(prefix=key[:16] + ".tmp-", dir=self._objects)
        manifest = {"key": key, "meta": meta or {}, "leaves": {}}
        written = 0
        for name, arr in arrays.items():
            arr = np.ascontiguousarray(np.asarray(arr))
            fname = name + ".npy"
            fpath = os.path.join(tmp, fname)
            np.save(fpath, arr, allow_pickle=False)
            with open(fpath, "rb") as f:
                crc = zlib.crc32(f.read())
            manifest["leaves"][name] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "crc32": crc,
            }
            written += os.path.getsize(fpath)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        try:
            os.rename(tmp, final)
        except OSError:            # lost the race to a concurrent writer
            shutil.rmtree(tmp, ignore_errors=True)
            self.stats.dup_writes += 1
            return False
        self.stats.writes += 1
        self.stats.bytes_written += written
        return True

    def put_payload(self, key, payload, src_bytes=0) -> bool:
        """Persist one flat payload dict (the `pack_result` /
        `unpack_result` wire shape: ndarray leaves mixed with JSON-safe
        meta) under `key`. The split is by value type — ndarrays become
        leaves, everything else rides the manifest meta — so the dist
        data plane and `CachedPlan` share one entry codec. `src_bytes`
        is recorded in the meta for later `fetch` accounting. Same
        first-write-wins semantics as `put`."""
        arrays = {k: v for k, v in payload.items()
                  if isinstance(v, np.ndarray)}
        meta = {k: v for k, v in payload.items()
                if not isinstance(v, np.ndarray)}
        if src_bytes:
            meta.setdefault("src_bytes", int(src_bytes))
        return self.put(key, arrays, meta)

    # -- read ----------------------------------------------------------------
    def fetch(self, key, src_bytes=0):
        """Fetch-by-key read path: the flat payload dict ({**leaves,
        **meta}) for a hit, None for a miss — the inverse of
        `put_payload` and the shape `unpack_result` consumes. This is
        the data-plane read used by dist workers and the master's
        result resolution; `get` remains the (arrays, meta) pair view."""
        hit = self.get(key, src_bytes=src_bytes)
        if hit is None:
            return None
        arrays, meta = hit
        return {**arrays, **meta}

    def get(self, key, src_bytes=0):
        """({name: ndarray}, meta) for a hit, None for a miss. `src_bytes`
        (the source payload a hit saves reprocessing) feeds bytes_saved.
        crc mismatches raise IOError, or evict + miss under
        evict_corrupt."""
        path = self._path(key)
        mpath = os.path.join(path, "manifest.json")
        if not os.path.isfile(mpath):
            self.stats.misses += 1
            return None
        try:
            with open(mpath) as f:
                manifest = json.load(f)
            out = {}
            for name, ent in manifest["leaves"].items():
                with open(os.path.join(path, ent["file"]), "rb") as f:
                    raw = f.read()
                if self.verify_crc and zlib.crc32(raw) != ent["crc32"]:
                    raise IOError(
                        f"chunk store corruption in {key[:16]}…/{name}: "
                        f"crc mismatch")
                arr = np.load(io.BytesIO(raw), allow_pickle=False)
                out[name] = arr.reshape(ent["shape"])
        except (IOError, ValueError, KeyError):
            if not self.evict_corrupt:
                raise
            self.evict(key)
            self.stats.corrupt += 1
            self.stats.misses += 1
            return None
        self.stats.hits += 1
        self.stats.bytes_saved += int(src_bytes)
        try:                       # recency mark for gc(): last hit wins
            os.utime(mpath)
        except OSError:            # read-only store: gc falls back to
            pass                   # write order, hits still served
        return out, manifest["meta"]

    # -- inventory -----------------------------------------------------------
    def evict(self, key):
        shutil.rmtree(self._path(key), ignore_errors=True)

    def entry_bytes(self, key) -> int:
        """On-disk payload bytes of one entry (0 when absent)."""
        path = self._path(key)
        if not os.path.isdir(path):
            return 0
        return sum(
            os.path.getsize(os.path.join(path, f))
            for f in os.listdir(path)
            if os.path.isfile(os.path.join(path, f)))

    def gc(self, max_bytes) -> dict:
        """Retention sweep: evict least-recently-HIT entries (manifest
        mtime — refreshed on every verified read, so write order is only
        the tie-break for never-hit entries) until the store's payload
        fits in `max_bytes`. The paper-scale archive motivation: a rolling
        survey stream writes results forever, but only the recent window
        keeps re-hitting; everything older is recomputable by definition
        (the store is a cache, not the archive of record).

        Returns a stats dict: entries/bytes before and after, evicted
        count, bytes freed. Also accumulated on `self.stats`."""
        max_bytes = int(max_bytes)
        ages = []
        for key in self.keys():
            mpath = os.path.join(self._path(key), "manifest.json")
            try:
                mtime = os.path.getmtime(mpath)
            except OSError:        # raced a concurrent evict
                continue
            ages.append((mtime, key, self.entry_bytes(key)))
        ages.sort()                # oldest last-hit first
        total = sum(b for _, _, b in ages)
        before = {"entries": len(ages), "bytes": total}
        evicted = freed = 0
        for _, key, nbytes in ages:
            if total <= max_bytes:
                break
            self.evict(key)
            total -= nbytes
            freed += nbytes
            evicted += 1
        self.stats.gc_evicted += evicted
        self.stats.gc_bytes_freed += freed
        return {"entries_before": before["entries"],
                "bytes_before": before["bytes"],
                "evicted": evicted, "bytes_freed": freed,
                "entries_after": before["entries"] - evicted,
                "bytes_after": total}

    def keys(self):
        if not os.path.isdir(self._objects):
            return []
        # a crashed writer leaves <key16>.tmp-* holding a manifest — those
        # are not entries (the rename never happened)
        return sorted(
            d for d in os.listdir(self._objects)
            if ".tmp-" not in d
            and os.path.isfile(os.path.join(self._objects, d,
                                            "manifest.json")))

    def __contains__(self, key):
        return os.path.isfile(os.path.join(self._path(key), "manifest.json"))

    def __len__(self):
        return len(self.keys())
