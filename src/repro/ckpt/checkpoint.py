"""Sharded, atomic, async checkpointing with restore-time resharding.

Layout:  <dir>/step_<N>/
            manifest.json   {leaf_path: {file, shape, dtype, crc32}, meta}
            <leaf>.npy      raw array bytes (bf16 stored as uint16 view)
         <dir>/step_<N>.tmp-*   while writing (atomic rename on completion)

Restore is ELASTIC: arrays are materialized host-side and device_put with the
*target* shardings — any saved mesh -> any restore mesh (grow/shrink), which
is the restart path after node failure or resize. The training-data cursor
(file index / chunk offset / rng key) rides in `meta`, so restart resumes the
exact sample stream (the paper's "master re-sends work of crashed slaves",
made exact).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import zlib

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for kp, _ in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        names.append(_SEP.join(parts))
    return names, [v for _, v in flat], treedef


def _storage_view(arr: np.ndarray):
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _load_view(arr: np.ndarray, logical_dtype: str):
    if logical_dtype == "bfloat16":
        return arr.view(jnp.bfloat16)
    return arr


def save(directory, step, tree, meta=None, async_save=False):
    """Checkpoint `tree` at `directory/step_<step>`. Returns a handle with
    .wait() (no-op for sync saves)."""
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(directory, exist_ok=True)
    # snapshot to host BEFORE going async (training may mutate buffers)
    names, leaves, _ = _leaf_paths(tree)
    host_leaves = [np.asarray(jax.device_get(v)) for v in leaves]

    def _write():
        tmp = tempfile.mkdtemp(prefix=f"step_{step}.tmp-", dir=directory)
        manifest = {"meta": meta or {}, "step": step, "leaves": {}}
        for name, arr in zip(names, host_leaves):
            stored, logical = _storage_view(arr)
            fname = name.replace(_SEP, "__") + ".npy"
            fpath = os.path.join(tmp, fname)
            np.save(fpath, stored, allow_pickle=False)
            with open(fpath, "rb") as f:
                crc = zlib.crc32(f.read())
            manifest["leaves"][name] = {
                "file": fname, "shape": list(arr.shape), "dtype": logical,
                "crc32": crc,
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return _Handle(t)
    _write()
    return _Handle(None)


class _Handle:
    def __init__(self, thread):
        self._thread = thread

    def wait(self):
        if self._thread is not None:
            self._thread.join()


def latest_step(directory):
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_", 1)[1]) for d in os.listdir(directory)
             if d.startswith("step_") and ".tmp" not in d]
    return max(steps) if steps else None


def restore(directory, step, like=None, shardings=None, verify_crc=True):
    """Restore a checkpoint.

    like: a pytree (of arrays or ShapeDtypeStructs) giving the structure; if
    None, a flat {leaf_path: array} dict is returned.
    shardings: optional pytree of NamedShardings (matching `like`) — arrays
    are device_put with these, which is how restore RESHARDS onto a
    different mesh (elastic restart)."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    def load_leaf(name):
        ent = manifest["leaves"][name]
        fpath = os.path.join(path, ent["file"])
        if verify_crc:
            with open(fpath, "rb") as f:
                crc = zlib.crc32(f.read())
            if crc != ent["crc32"]:
                raise IOError(f"checkpoint corruption in {name}: crc mismatch")
        arr = np.load(fpath, allow_pickle=False)
        return _load_view(arr, ent["dtype"]).reshape(ent["shape"])

    if like is None:
        return ({n: load_leaf(n) for n in manifest["leaves"]},
                manifest["meta"])

    names, leaves, treedef = _leaf_paths(like)
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    arrays = [load_leaf(n) for n in names]
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "spec"))
        arrays = [a if s is None else jax.device_put(a, s)
                  for a, s in zip(arrays, shard_leaves)]
    else:
        arrays = [jnp.asarray(a) for a in arrays]
    return treedef.unflatten(arrays), manifest["meta"]


def prune_old(directory, keep=3):
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_", 1)[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and ".tmp" not in d)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)
