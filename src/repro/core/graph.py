"""Composable stage graph for the preprocessing pipeline.

The paper derives ONE stage order from per-stage profiling; its own ablations
(stage reordering, split-length sweeps, per-stage on/off) perturb that order.
Here the order is *data*: `AudioPipelineConfig.stages` names a sequence of
registered stages, and `PipelineGraph` builds + shape-validates the chain at
construction time, long before any audio is traced.

Three layers:

  * `Stage` — a named, config-carrying transform over a `state` dict of
    batched arrays.  Each stage declares what fields it needs (wave / spec /
    power / masks) and how it transforms the chunk geometry
    (`ChunkGeom(split_s, rate_hz, channels)`), so an ill-typed order —
    splitting 5 s chunks into 15 s ones, running the band-stop without an
    STFT, MMSE on stereo — raises `GraphValidationError` at build time.
  * `STAGES` — the registry. `@register` adds a stage class under its name;
    configs refer to stages purely by name.
  * `PipelineGraph` — validates the chain, records `removal_point` markers
    (the early-exit candidates: the GRAPH, not the driver, decides where host
    compaction may occur), and exposes the three traced entry points the
    execution plans jit: `detection` (up to the first removal point),
    `tail` (after it — the survivor phase), and `fused` (straight through,
    masked output).

State fields carried between stages:
  wave            (B, S) mono — or (B, C, S) stereo before `to_mono`
  spec, power     (B, F, K) current-granularity spectra (power is
                  pre-band-stop, as in the paper: indices see raw spectra)
  indices         lazily computed acoustic-index dict, shared by detectors
  rain, silence   (B,) per-chunk removal masks (repeated across splits)
  cicada          (B,) detection-granularity cicada mask (diagnostic)
  keep            (B,) frozen at the removal point

Mask semantics follow the paper: cicada gates on ~rain, silence gates on
~rain, keep = ~rain & ~silence.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

from repro.core import detect as D
from repro.core import indices as I
from repro.core import stages as S
from repro.distributed.sharding import NULL_RULES
from repro.kernels.fused_tail import ops as fused_tail_ops


class GraphValidationError(ValueError):
    """A stage list that cannot execute: unknown stage, geometry mismatch,
    or a stage whose inputs are not produced upstream."""


@dataclass(frozen=True)
class ChunkGeom:
    """Chunk geometry flowing through the graph."""
    split_s: float      # seconds of audio per chunk
    rate_hz: int        # sample rate
    channels: int       # 2 = stereo source, 1 = mono


@dataclass(frozen=True)
class _ValidState:
    """Build-time twin of the runtime state dict: geometry + which state
    fields exist at this point in the chain."""
    geom: ChunkGeom
    has: frozenset


@jax.tree_util.register_dataclass
@dataclass
class PipelineOutput:
    wave5: jnp.ndarray          # (N5, S5) processed final chunks
    keep: jnp.ndarray           # (N5,) bool — survives to output
    rain: jnp.ndarray           # (N5,) bool
    silence: jnp.ndarray        # (N5,) bool
    cicada15: jnp.ndarray       # (N15,) bool — per detect chunk
    stats: dict


# --------------------------------------------------------------- registry

STAGES: dict[str, type] = {}


def register(cls):
    """Register a Stage class under its `name` for config-by-name lookup."""
    if cls.name in STAGES:
        raise ValueError(f"duplicate stage name {cls.name!r}")
    STAGES[cls.name] = cls
    return cls


class Stage:
    """One named pipeline transform. Subclasses set `name`, implement
    `check` (build-time: validate + advance the _ValidState) and `apply`
    (trace-time: transform the state dict)."""
    name: str = ""
    removal_point = False

    def __init__(self, cfg):
        self.cfg = cfg

    def _need(self, vs: _ValidState, *fields):
        missing = [f for f in fields if f not in vs.has]
        if missing:
            raise GraphValidationError(
                f"stage '{self.name}' needs {missing} which no upstream "
                f"stage provides (available: {sorted(vs.has)})")

    def check(self, vs: _ValidState) -> _ValidState:
        return vs

    def apply(self, state: dict, rules) -> dict:
        return state


def _indices(state, cfg):
    """Acoustic indices over the current power spectra, computed once and
    shared by every detector stage (the paper's 'FFT executed once' economy
    extends to the index vector)."""
    if "indices" not in state:
        state["indices"] = I.all_indices(state["power"], cfg)
    return state["indices"]


_MASK_KEYS = ("rain", "silence", "keep")


# ----------------------------------------------------------------- stages

@register
class ToMono(Stage):
    name = "to_mono"

    def check(self, vs):
        self._need(vs, "wave")
        if vs.geom.channels < 2:
            raise GraphValidationError(
                "stage 'to_mono' expects multi-channel input "
                f"(got {vs.geom.channels} channel)")
        return replace(vs, geom=replace(vs.geom, channels=1))

    def apply(self, state, rules):
        state["wave"] = rules.constrain(S.to_mono(state["wave"]),
                                        "chunks", None)
        return state


@register
class Compress(Stage):
    """Fused downsample + high-pass (the paper's 44.1 -> 22.05 kHz + 1 kHz
    HPF, one Pallas band-pass FIR)."""
    name = "compress"

    def check(self, vs):
        self._need(vs, "wave")
        if vs.geom.channels != 1:
            raise GraphValidationError(
                "stage 'compress' needs mono audio — add 'to_mono' first")
        if vs.geom.rate_hz != self.cfg.source_rate_hz:
            raise GraphValidationError(
                f"stage 'compress' expects {self.cfg.source_rate_hz} Hz "
                f"input, got {vs.geom.rate_hz} Hz (already compressed?)")
        return replace(vs, geom=replace(vs.geom,
                                        rate_hz=self.cfg.target_rate_hz))

    def apply(self, state, rules):
        state["wave"] = S.compress(state["wave"], self.cfg)
        return state


class _Split(Stage):
    """(B, S) -> (B*n, S/n). Repeats per-chunk masks, regroups the shared
    power spectra (the paper's 'files can only be split, not joined'), and
    drops the now-stale complex spectra + index vector."""
    target_split_s: float = 0.0

    def check(self, vs):
        self._need(vs, "wave")
        if vs.geom.channels != 1:
            raise GraphValidationError(
                f"stage '{self.name}' needs mono audio")
        factor = vs.geom.split_s / self.target_split_s
        if abs(factor - round(factor)) > 1e-9 or round(factor) < 1:
            raise GraphValidationError(
                f"stage '{self.name}' cannot split {vs.geom.split_s:g} s "
                f"chunks into {self.target_split_s:g} s chunks "
                f"(non-integer factor {factor:g})")
        self.n_sub = int(round(factor))
        return replace(vs, geom=replace(vs.geom,
                                        split_s=self.target_split_s),
                       has=vs.has - {"spec", "indices"})

    def apply(self, state, rules):
        n = self.n_sub
        pre_samples = state["wave"].shape[1]
        state["wave"] = rules.constrain(S.split(state["wave"], n),
                                        "chunks", None)
        for k in _MASK_KEYS:
            if k in state:
                state[k] = jnp.repeat(state[k], n)
        if "power" in state:
            state["power"] = S.group_frames(state["power"], n,
                                            pre_samples, self.cfg)
        state.pop("spec", None)
        state.pop("indices", None)
        return state


@register
class SplitDetect(_Split):
    name = "split_detect"

    def __init__(self, cfg):
        super().__init__(cfg)
        self.target_split_s = cfg.detect_split_s


@register
class SplitFinal(_Split):
    name = "split_final"

    def __init__(self, cfg):
        super().__init__(cfg)
        self.target_split_s = cfg.final_split_s


@register
class Stft(Stage):
    """STFT once per chunk; spectra are shared by every downstream detector."""
    name = "stft"

    def check(self, vs):
        self._need(vs, "wave")
        if vs.geom.channels != 1:
            raise GraphValidationError("stage 'stft' needs mono audio")
        return replace(vs, has=vs.has | {"spec", "power"})

    def apply(self, state, rules):
        spec, power = S.stft_chunks(state["wave"], self.cfg)
        state["spec"], state["power"] = spec, power
        state.pop("indices", None)
        return state


@register
class DetectRain(Stage):
    """Rain removal mask (C4.5-derived rule over acoustic indices)."""
    name = "detect_rain"

    def check(self, vs):
        self._need(vs, "power")
        return replace(vs, has=vs.has | {"rain"})

    def apply(self, state, rules):
        rain = D.detect_rain(_indices(state, self.cfg), self.cfg)
        prev = state.get("rain")
        state["rain"] = rain if prev is None else (prev | rain)
        return state


@register
class CicadaBandstop(Stage):
    """Cicada detection + band-stop around the chorus peak (gated on ~rain,
    as in the paper: rain chunks are deleted, not filtered)."""
    name = "cicada_bandstop"

    def check(self, vs):
        self._need(vs, "spec", "power")
        return replace(vs, has=vs.has | {"cicada"})

    def apply(self, state, rules):
        idx = _indices(state, self.cfg)
        cicada = D.detect_cicada(idx, self.cfg)
        if "rain" in state:
            cicada = cicada & ~state["rain"]
        state["cicada"] = cicada
        state["spec"] = S.remove_cicada_band(
            state["spec"], idx["cicada_peak_bin"], cicada, self.cfg)
        return state


@register
class Istft(Stage):
    name = "istft"

    def check(self, vs):
        self._need(vs, "wave", "spec")
        return vs

    def apply(self, state, rules):
        state["wave"] = S.istft_chunks(state["spec"],
                                       state["wave"].shape[1], self.cfg)
        return state


@register
class DetectSilence(Stage):
    """Silence removal mask: envelope SNR under the paper's 'lower
    threshold', gated on ~rain."""
    name = "detect_silence"

    def check(self, vs):
        self._need(vs, "power")
        return replace(vs, has=vs.has | {"silence"})

    def apply(self, state, rules):
        silence = I.snr_est(state["power"]) < \
            self.cfg.silence_snr_threshold
        if "rain" in state:
            silence = silence & ~state["rain"]
        prev = state.get("silence")
        state["silence"] = silence if prev is None else (prev | silence)
        return state


@register
class DetectFlux(Stage):
    """Spectral-flux energy detector (Stowell-style): chunks whose peak
    half-wave-rectified flux stays under `cfg.flux_threshold` carry no
    transient vocalisation and are marked for removal (folded into the
    silence mask, gated on ~rain like every removal detector). A drop-in
    alternative — or complement — to 'detect_silence', selectable purely
    via `cfg.stages` / the `stages=` override; no executor knows it
    exists."""
    name = "detect_flux"

    def check(self, vs):
        self._need(vs, "power")
        return replace(vs, has=vs.has | {"silence"})

    def apply(self, state, rules):
        idle = D.detect_no_activity(_indices(state, self.cfg), self.cfg)
        if "rain" in state:
            idle = idle & ~state["rain"]
        prev = state.get("silence")
        state["silence"] = idle if prev is None else (prev | idle)
        return state


@register
class RemovalPoint(Stage):
    """Marker: host compaction may occur HERE. Freezes keep = ~rain &
    ~silence; two-phase plans cut the graph at the first marker. Past a
    removal point only the waveform survives compaction, so downstream
    stages may depend on nothing else (enforced at build time)."""
    name = "removal_point"
    removal_point = True

    def check(self, vs):
        self._need(vs, "wave")
        return _ValidState(vs.geom, frozenset({"wave"}))

    def apply(self, state, rules):
        n = state["wave"].shape[0]
        zeros = jnp.zeros((n,), bool)
        state["keep"] = (~state.get("rain", zeros)
                         & ~state.get("silence", zeros))
        return state


@register
class Mmse(Stage):
    """MMSE-STSA denoise — the dominant stage, placed after the removal
    point so execution plans can run it on survivors only."""
    name = "mmse"

    def check(self, vs):
        self._need(vs, "wave")
        if vs.geom.channels != 1:
            raise GraphValidationError("stage 'mmse' needs mono audio")
        return vs

    def apply(self, state, rules):
        wave = rules.constrain(state["wave"], "chunks", None)
        state["wave"] = S.mmse_denoise(wave, self.cfg)
        return state


@register
class TailHighpass(Stage):
    """Stride-1 FIR high-pass on the survivor tail. The paper applies the
    HPF once at long splits (folded into `compress`); declaring this stage
    past the removal point re-sharpens survivors at the target rate and
    completes the canonical fused tail hpf -> stft -> mmse -> istft."""
    name = "hpf"

    def check(self, vs):
        self._need(vs, "wave")
        if vs.geom.channels != 1:
            raise GraphValidationError("stage 'hpf' needs mono audio")
        return vs

    def apply(self, state, rules):
        wave = rules.constrain(state["wave"], "chunks", None)
        state["wave"] = S.tail_highpass(wave, self.cfg)
        return state


# ------------------------------------------------------------------ graph

class PipelineGraph:
    """A validated stage chain built from a config-declared stage list.

    `stage_names` defaults to `cfg.stages` — the paper's order lives in the
    config as data, so ablations (reorder, drop a detector, move the removal
    point) are config edits, not driver forks.
    """

    def __init__(self, cfg, stage_names=None, source_channels=2):
        self.cfg = cfg
        self.names = tuple(stage_names if stage_names is not None
                           else cfg.stages)
        unknown = [n for n in self.names if n not in STAGES]
        if unknown:
            raise GraphValidationError(
                f"unknown stages {unknown}; registered: {sorted(STAGES)}")
        self.stages = [STAGES[n](cfg) for n in self.names]
        self.source_geom = ChunkGeom(cfg.long_split_s, cfg.source_rate_hz,
                                     source_channels)
        self.removal_indices: list[int] = []
        vs = _ValidState(self.source_geom, frozenset({"wave"}))
        for i, st in enumerate(self.stages):
            try:
                vs = st.check(vs)
            except GraphValidationError as e:
                raise GraphValidationError(
                    f"stage {i} ({st.name!r}): {e}") from None
            if st.removal_point:
                self.removal_indices.append(i)
        self.out_geom = vs.geom

    @property
    def fingerprint(self):
        """Stable hashable identity for compile-cache keying."""
        return (self.cfg, self.names, self.source_geom)

    @property
    def has_removal_point(self) -> bool:
        return bool(self.removal_indices)

    def _cut(self) -> int:
        """Index one past the first removal point (= len when none)."""
        if not self.removal_indices:
            return len(self.stages)
        return self.removal_indices[0] + 1

    def _run(self, stages, state, rules):
        for st in stages:
            state = st.apply(state, rules)
        return state

    def _outputs(self, state) -> PipelineOutput:
        wave = state["wave"]
        n = wave.shape[0]
        zeros = jnp.zeros((n,), bool)
        rain = state.get("rain", zeros)
        silence = state.get("silence", zeros)
        keep = state.get("keep", ~rain & ~silence)
        cicada = state.get("cicada", zeros)
        stats = {
            "n_chunks5": n,
            "frac_rain": jnp.mean(rain.astype(jnp.float32)),
            "frac_silence": jnp.mean(silence.astype(jnp.float32)),
            "frac_kept": jnp.mean(keep.astype(jnp.float32)),
            "frac_cicada15": jnp.mean(cicada.astype(jnp.float32)),
        }
        return PipelineOutput(wave5=wave, keep=keep, rain=rain,
                              silence=silence, cicada15=cicada, stats=stats)

    # Traced entry points (jit-able; plans own the jitting + caching).
    def detection(self, audio, rules=NULL_RULES) -> PipelineOutput:
        """Phase A: everything up to (and including) the first removal
        point — wave5 is not yet denoised. A graph that declares NO
        removal point has no phase split: this runs the whole chain
        (including any denoise stages)."""
        state = self._run(self.stages[:self._cut()], {"wave": audio}, rules)
        return self._outputs(state)

    def tail(self, wave, rules=NULL_RULES):
        """Phase B: the survivor stages past the first removal point,
        applied to a (compacted) chunk batch."""
        state = self._run(self.stages[self._cut():], {"wave": wave}, rules)
        return state["wave"]

    def tail_indexed(self, wave, idx, rules=NULL_RULES):
        """Phase B with DEVICE-RESIDENT compaction: gather the survivor
        rows `idx` (padded int32, static shape) out of the full
        pre-denoise batch on device, then run the survivor stages. The
        host only ever supplies the tiny index vector — the waveform never
        round-trips. Out-of-range indices (the pad convention of
        `scheduler.survivor_indices`) become all-zero rows via the fill
        gather, so padding never duplicates real audio."""
        batch = jnp.take(wave, idx, axis=0, mode="fill", fill_value=0.0)
        return self.tail(batch, rules)

    @property
    def fused_tail_spec(self):
        """`{"hpf": bool}` when the post-removal stage list is the
        canonical fused tail — `("mmse",)` or `("hpf", "mmse")`, i.e.
        [HPF ->] STFT -> MMSE gain -> iSTFT on survivors only — else
        None. Plans consult this to decide whether `tail_indexed_fused`
        may replace `tail_indexed` (any other survivor chain falls back
        to the staged path)."""
        if not self.removal_indices:
            return None
        post = self.names[self._cut():]
        if post == ("mmse",):
            return {"hpf": False}
        if post == ("hpf", "mmse"):
            return {"hpf": True}
        return None

    def tail_indexed_fused(self, wave, idx, rules=NULL_RULES):
        """`tail_indexed` through the single fused Pallas pass
        (kernels/fused_tail): gather-compact + [HPF] + STFT + MMSE gain
        happen in one VMEM-resident kernel, with only the iSTFT outside.
        Bit-identical to `tail_indexed` per backend mode; only valid when
        `fused_tail_spec` is not None."""
        spec = self.fused_tail_spec
        if spec is None:
            raise GraphValidationError(
                f"post-removal stages {self.names[self._cut():]} are not "
                "the canonical fused tail; use tail_indexed")
        wave = rules.constrain(wave, "chunks", None)
        return fused_tail_ops.fused_tail(wave, idx, self.cfg,
                                         hpf=spec["hpf"])

    def fused(self, audio, rules=NULL_RULES) -> PipelineOutput:
        """Single-trace mode: the whole chain, removed chunks masked but
        still computed (the paper's no-early-exit baseline)."""
        state = self._run(self.stages, {"wave": audio}, rules)
        out = self._outputs(state)
        masked = jnp.where(out.keep[:, None], out.wave5, 0.0)
        return PipelineOutput(wave5=masked, keep=out.keep, rain=out.rain,
                              silence=out.silence, cicada15=out.cicada15,
                              stats=out.stats)
