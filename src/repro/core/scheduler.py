"""Work distribution for the early-exit pipeline — the paper's master-slave
file management, made TPU-native.

The paper's master tracks which files are deleted and never dispatches them
to the expensive MMSE stage. On TPU the same economy comes from COMPACTION:
survivors are packed dense (global stable argsort — XLA lowers the cross-
device movement to all-to-alls), the host reads one scalar (survivor count)
and dispatches the MMSE phase on a minimally-padded survivor batch. No
central master owns the data path: the "master" role shrinks to a scalar
readback + shape choice, removing the paper's single point of failure.

Also provides the load-balance metrics reported in the paper (Figs 14-18),
and the `Rebalancer` that owns the detection -> MMSE handoff for the
multi-shard `ShardedPlan`: heterogeneous noise regimes leave shards with
skewed survivor counts (Lostanlen-style sensor networks), so survivors are
re-assigned across shards between the phases instead of staying where
detection left them.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def compact(chunks, keep):
    """Pack surviving chunks to the front (stable order preserved).

    chunks: (N, ...); keep: (N,) bool. Returns (packed chunks, packed keep,
    survivor count)."""
    order = jnp.argsort(~keep, stable=True)
    return jnp.take(chunks, order, axis=0), keep[order], jnp.sum(keep)


def shard_load(keep, n_shards):
    """Per-shard surviving-chunk counts: the paper's files-per-slave
    measurement. N not divisible by n_shards is padded with removed
    (False) chunks — the trailing shard just holds fewer real chunks."""
    n = keep.shape[0]
    pad = (-n) % n_shards
    if pad:
        keep = jnp.concatenate(
            [keep, jnp.zeros((pad,), keep.dtype)])
    return jnp.sum(keep.reshape(n_shards, -1), axis=1)


def balance_stats(keep, n_shards):
    """Load-balance metrics (paper Figs 14-16: 'each slave processes almost
    the same number of files').

    'before' = survivors stay where detection left them (mask-only early
    exit); 'after' = survivors are compacted AND re-sliced into a dense
    batch of ceil(n/k) per shard (what survivor_batch dispatches) — the
    residual imbalance is only the ceil-vs-mean padding."""
    loads = shard_load(keep, n_shards)
    mean = jnp.mean(loads.astype(jnp.float32))
    imb = jnp.max(loads) / jnp.maximum(mean, 1e-9)
    n = jnp.sum(keep)
    per_shard_after = jnp.ceil(n / n_shards)
    imb_after = per_shard_after / jnp.maximum(n / n_shards, 1e-9)
    return {"loads": loads, "imbalance": imb,
            "imbalance_after_compact": imb_after}


def quantize_survivors(n, cap, pad_multiple=1, bucket="pow2"):
    """Padded tail-batch size for `n` survivors out of a `cap`-row batch.

    'linear' is the historical quantization — the next multiple of
    pad_multiple — which retraces the tail jit once per distinct survivor
    count when pad_multiple is small. 'pow2' rounds up to the next
    pad_multiple-aligned power-of-two bucket (clipped at the padded cap),
    so a B-row batch compiles O(log B) tail variants total, whatever the
    survivor counts of the stream."""
    n = int(n)
    m = max(1, int(pad_multiple))
    lin = -(-n // m) * m
    if bucket == "linear":
        return lin
    if bucket != "pow2":
        raise ValueError(f"unknown bucket mode {bucket!r} "
                         "(expected 'pow2' or 'linear')")
    hi = max(lin, -(-int(cap) // m) * m)
    size = m
    while size < n:
        size *= 2
    return min(size, hi)


def survivor_indices(keep_np, pad_multiple=1, bucket="pow2"):
    """Device-compaction bookkeeping: the host reads ONLY the keep mask and
    answers with a padded int32 gather-index vector; the tail jit compacts
    on device (`jnp.take(..., mode='fill')`), so the full pre-denoise
    waveform never round-trips through the host.

    Pad slots hold the out-of-range index `len(keep_np)`, which the fill
    gather turns into all-zero rows — never a repeat of real audio, so
    padding costs deterministic zero-row flops and can never leak a
    duplicated chunk into output. Returns (idx, n_real); idx is None when
    nothing survived."""
    idx = np.flatnonzero(keep_np)
    n = len(idx)
    if n == 0:
        return None, 0
    size = quantize_survivors(n, keep_np.size, pad_multiple, bucket)
    out = np.full(size, keep_np.size, np.int32)
    out[:n] = idx
    return out, n


def survivor_batch(chunks_np, keep_np, pad_multiple):
    """Host-side ("master") re-batching of survivors for the MMSE phase:
    pad survivor count up to a multiple of the device count so the phase-B
    jit shards evenly. Returns (batch, n_real). This is the host fallback
    of the device-compaction path (`survivor_indices` + `graph.
    tail_indexed`), kept for host-side consumers and reference tests."""
    idx = np.nonzero(keep_np)[0]
    n = len(idx)
    if n == 0:
        return None, 0
    return pad_batch(chunks_np[idx], pad_multiple)


def pad_batch(rows_np, pad_multiple):
    """Pad an already-packed survivor batch up to a multiple of
    pad_multiple with ZERO rows. (It used to repeat the last row — wasted
    MMSE flops on real audio, and a latent duplicate-output hazard if a
    consumer ever forgot to slice [:n_real].) Returns (batch, n_real)."""
    n = rows_np.shape[0]
    if n == 0:
        return None, 0
    n_pad = -(-n // pad_multiple) * pad_multiple
    if n_pad == n:
        return rows_np, n
    pad = np.zeros((n_pad - n,) + rows_np.shape[1:], rows_np.dtype)
    return np.concatenate([rows_np, pad]), n


# ------------------------------------------------------------- rebalancing

@dataclass
class ShardAssignment:
    """One detection -> MMSE handoff decision: how the packed global
    survivor order (source shards concatenated in slot order) is re-sliced
    across the destination shards."""
    counts_before: np.ndarray   # survivors detected per source shard
    counts_after: np.ndarray    # survivors assigned per destination shard
    bounds: np.ndarray          # (k+1,) prefix offsets into the packed order
    moved: int                  # survivors whose shard changed

    @staticmethod
    def _ratio(counts):
        """max/min shard load; an empty or fully-starved shard counts as
        load 1 so the ratio stays finite (a 0-load shard reads as 'max x
        worse than idle')."""
        if counts.size == 0 or counts.max() == 0:
            return 1.0
        return float(counts.max()) / float(max(counts.min(), 1))

    def stats(self):
        """max/min shard-load ratios before/after the re-shard (the paper's
        Figs 14-16 'each slave processes almost the same number of files'
        claim, measured)."""
        return {
            "loads_before": self.counts_before,
            "loads_after": self.counts_after,
            "max_min_before": self._ratio(self.counts_before),
            "max_min_after": self._ratio(self.counts_after),
            "moved": self.moved,
        }


class Rebalancer:
    """Owns the survivor re-shard decision between detection and MMSE.

    The paper's master re-assigns files so 'each slave processes almost the
    same number of files' even after deletion (Figs 14-16). Here the mask
    readback happens once per round: each source shard reports its keep
    masks, survivors are packed in (shard, item) order, and the packed run
    is re-sliced into near-even contiguous spans — floor(n/k) or
    floor(n/k)+1 per destination shard, so the residual imbalance is the
    +-1 of integer division, never the noise skew of the input stream."""

    def __init__(self, n_shards, pad_multiple=1):
        self.n_shards = int(n_shards)
        self.pad_multiple = max(1, int(pad_multiple))

    def assign(self, keeps, out_shards=None) -> ShardAssignment:
        """keeps: one 1-D bool mask per source shard (its detected items'
        masks, concatenated). out_shards: destination shard count (defaults
        to n_shards; fewer when shards died mid-round)."""
        k = self.n_shards if out_shards is None else int(out_shards)
        if k < 1:
            raise ValueError("rebalance needs at least one live shard")
        counts_before = np.array([int(np.sum(m)) for m in keeps], np.int64)
        n = int(counts_before.sum())
        counts_after = n // k + (np.arange(k) < n % k).astype(np.int64)
        bounds = np.concatenate([[0], np.cumsum(counts_after)])
        src = np.repeat(np.arange(len(keeps)), counts_before)
        dst = np.repeat(np.arange(k), counts_after)
        moved = int(np.sum(src != dst))
        return ShardAssignment(counts_before, counts_after, bounds, moved)

    def split(self, survivors_np, asg: ShardAssignment):
        """Slice the packed (n, S) survivor array per the assignment into
        per-shard padded MMSE batches. Yields (shard_slot, batch, n_real)
        for non-empty slots only."""
        for j in range(len(asg.counts_after)):
            lo, hi = int(asg.bounds[j]), int(asg.bounds[j + 1])
            if hi == lo:
                continue
            batch, n_real = pad_batch(survivors_np[lo:hi], self.pad_multiple)
            yield j, batch, n_real
