"""Work distribution for the early-exit pipeline — the paper's master-slave
file management, made TPU-native.

The paper's master tracks which files are deleted and never dispatches them
to the expensive MMSE stage. On TPU the same economy comes from COMPACTION:
survivors are packed dense (global stable argsort — XLA lowers the cross-
device movement to all-to-alls), the host reads one scalar (survivor count)
and dispatches the MMSE phase on a minimally-padded survivor batch. No
central master owns the data path: the "master" role shrinks to a scalar
readback + shape choice, removing the paper's single point of failure.

Also provides the load-balance metrics reported in the paper (Figs 14-18).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def compact(chunks, keep):
    """Pack surviving chunks to the front (stable order preserved).

    chunks: (N, ...); keep: (N,) bool. Returns (packed chunks, packed keep,
    survivor count)."""
    order = jnp.argsort(~keep, stable=True)
    return jnp.take(chunks, order, axis=0), keep[order], jnp.sum(keep)


def shard_load(keep, n_shards):
    """Per-shard surviving-chunk counts (N divisible by n_shards): the
    paper's files-per-slave measurement."""
    return jnp.sum(keep.reshape(n_shards, -1), axis=1)


def balance_stats(keep, n_shards):
    """Load-balance metrics (paper Figs 14-16: 'each slave processes almost
    the same number of files').

    'before' = survivors stay where detection left them (mask-only early
    exit); 'after' = survivors are compacted AND re-sliced into a dense
    batch of ceil(n/k) per shard (what survivor_batch dispatches) — the
    residual imbalance is only the ceil-vs-mean padding."""
    loads = shard_load(keep, n_shards)
    mean = jnp.mean(loads.astype(jnp.float32))
    imb = jnp.max(loads) / jnp.maximum(mean, 1e-9)
    n = jnp.sum(keep)
    per_shard_after = jnp.ceil(n / n_shards)
    imb_after = per_shard_after / jnp.maximum(n / n_shards, 1e-9)
    return {"loads": loads, "imbalance": imb,
            "imbalance_after_compact": imb_after}


def survivor_batch(chunks_np, keep_np, pad_multiple):
    """Host-side ("master") re-batching of survivors for the MMSE phase:
    pad survivor count up to a multiple of the device count so the phase-B
    jit shards evenly. Returns (batch, n_real)."""
    idx = np.nonzero(keep_np)[0]
    n = len(idx)
    if n == 0:
        return None, 0
    n_pad = -(-n // pad_multiple) * pad_multiple
    sel = np.concatenate([idx, np.repeat(idx[-1:], n_pad - n)])
    return chunks_np[sel], n
