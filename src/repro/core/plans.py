"""Execution plans: HOW a validated `PipelineGraph` runs on a batch stream.

The graph fixes WHAT computes (stage order, removal points); a plan picks
the execution strategy. Six plans, and when to pick each:

  * `FusedPlan`     — one jit straight through; removed chunks are masked
                      but still computed (the paper's no-early-exit
                      baseline). Pick for graphs without a removal point,
                      for correctness references, or when survivor rates
                      are so high that early exit buys nothing.
  * `TwoPhasePlan`  — detection jit -> host reads the keep mask (the
                      paper's master bookkeeping) -> survivors compacted /
                      re-batched -> tail jit on the survivor batch only.
                      The paper's headline economy: MMSE cost scales with
                      surviving audio. Pick as the single-stream default.
  * `StreamingPlan` — two-phase with dispatch-ahead over a loader: phase-A
                      detection of batch k+1 is enqueued on the device
                      before phase B of batch k, so host-side mask readback
                      + compaction overlap device work. Now a depth-1
                      `AsyncPlan` with the historical linear padding — kept
                      as the conservative dispatch-ahead baseline.
  * `AsyncPlan`     — the deep pipeline: a bounded window of `depth`
                      detection batches in flight (keep masks prefetched
                      with `copy_to_host_async` the moment each detect is
                      enqueued), device-resident survivor compaction (the
                      tail jit gathers survivors out of the still-on-device
                      batch; only the B-bool mask and the cleaned survivors
                      ever cross the host boundary), power-of-two survivor
                      buckets (O(log B) tail compiles instead of one per
                      count), optional buffer donation, and double-buffered
                      cleaned readback. Per-batch `BatchResult.timings`
                      record dispatch/readback/compact/tail/emit plus the
                      in-flight depth and transferred bytes. Pick for long
                      single-host streams; `depth` 2-4 is enough to hide
                      mask readback on one device — go deeper only when
                      emission jitter (a slow consumer) must also be
                      absorbed. Emission order is ALWAYS input order.
  * `ShardedPlan`   — the multi-shard execution backbone, now a thin
                      MASTER over a pluggable transport: the shared leased
                      `WorkQueue` is served behind a `repro.dist.
                      QueueService` (lease / complete / heartbeat /
                      fail_worker / state + the fetch/push data planes),
                      and the workers that pull from it are picked by
                      `transport=`:

                        transport   workers                 use when
                        ---------   ---------------------   -----------------
                        "inproc"    simulated loop itera-   tests, single
                                    tions in this process   host, determinism
                                    (the historical mode,   (the default)
                                    preserved bit-for-bit)
                        "proc"      real OS processes        real parallelism
                                    (`python -m repro.       + fault isolation;
                                    dist.worker`), pickled   SIGKILL a worker
                                    messages over authen-    and the stream
                                    ticated localhost        still emits each
                                    sockets                  chunk exactly once
                        "tcp"       real OS processes over   workers on OTHER
                                    a non-loopback bind      hosts; pair with
                                    (0.0.0.0 + advertised    `data_plane=` so
                                    dial address); workers   the master socket
                                    join by announcing at    carries only
                                    hello (registry), same   leases, ids, acks
                                    wire protocol as proc

                      `data_plane=` (a ChunkStore, directory path, or
                      `repro.dist.StoreDataPlane`) moves chunk bytes OFF
                      the master's control socket: leases arrive as
                      content keys (`lease_chunks`), workers read raw
                      batches from and push results into the shared
                      store, and the master materialises payloads by key
                      at acceptance — fetch/push socket traffic drops
                      ≥90% per chunk (graded by the smoke gate via
                      `dist_fetch_bytes_total{plane}`). Works under
                      "proc" too; it is what makes "tcp" scale past one
                      box. Workers lease work ids in batches (`lease_items`,
                      the paper's Table 7 `max_queue_size` knob —
                      amortizes queue round-trips against redelivery
                      exposure), at-least-once redelivery on lease expiry
                      or `fail_worker` replaces the paper's crash-tracking
                      master, the `Rebalancer` owns the detection->MMSE
                      survivor re-shard (in-proc: physically re-slices;
                      proc: the per-round load ledger of the paper's Figs
                      14-16), and completion gates emission so output
                      stays exactly-once on top of at-least-once delivery.
                      Emission order: ascending work id under "proc" (==
                      the crash-free in-proc order); `worker_stats` holds
                      the per-worker progress report of the last run.
                      Single-batch `__call__` (the serve path) always
                      row-splits in-process — spawning processes per
                      request is not a serving latency anyone wants. Pick
                      for multi-worker runs, or whenever fault tolerance
                      matters.
  * `CachedPlan`    — content-addressed persistence around ANY inner plan
                      (including the sharded one): the `repro.store`
                      ChunkStore is consulted before dispatch, only misses
                      run through the inner plan, cached survivors merge
                      back in stream order, fresh results are written after.
                      With a `RunJournal` a killed `--store`d run relaunched
                      with `--resume` emits each chunk exactly once —
                      PR 2's worker-crash guarantee extended across PROCESS
                      restarts. Pick for rolling archives where runs overlap
                      yesterday's data (re-runs become lookups), for config
                      re-runs, and for any stream that must survive kills.
                      Without a store it degrades to a transparent
                      pass-through of its inner plan.

The two-phase family (`two_phase` / `streaming` / `async`) additionally
owns the FUSED SURVIVOR TAIL switch. When the graph's post-removal chain
is the canonical fused tail — `("mmse",)` or `("hpf", "mmse")`, per
`PipelineGraph.fused_tail_spec` — the plan's survivor dispatch swaps the
staged `tail_idx` phase for `tail_idx_fused`: one Pallas pass
(`kernels/fused_tail`) doing gather-compact + [HPF] + STFT + MMSE gain
with power/spec/gain tiles VMEM-resident, only the iSTFT outside. Keyed
per pow2 survivor bucket in the same CompileCache, same donation rules,
bit-identical per backend mode. `fuse_tail=` overrides: None (default)
auto-engages on a canonical tail, False forces the staged path, True
demands fusion and raises on a non-canonical tail. Any other survivor
chain silently falls back to the staged per-stage dispatches.

Serving sits ON TOP of these plans rather than being a seventh one: the
batch-stream plans above amortize compile + dispatch over a stream that
already exists, while `repro.serve` answers requests that arrive one at a
time. `serve.WorkerPool` keeps `repro.dist` workers alive across pumps
(a standing work queue instead of `ShardedPlan`'s per-stream one, so jits
stay warm and pids stable between waves), `serve.ContinuousBatcher`
coalesces concurrent requests into zero-padded pow2 device batches with
admission control and per-request deadlines, and `serve.
PreprocessService` checks a `CachedPlan`-style store before ever touching
a worker. Any batch the serving tier dispatches runs the same `two_phase`
stages as the plans here and stays bit-identical to them.

Observability (`repro.obs`): every plan family reports into the one
process-local metrics registry and the run tracer, zero-cost when both
are off. What each plan emits and where it lands:

  * counters/histograms (`obs.metrics`, via `_record_batch` at each
    plan's emission point): `plan_batches_total` / `plan_chunks_total` /
    `plan_survivors_total` / `plan_src_bytes_total` and
    `plan_{d2h,h2d}_bytes_total`, all labeled `{plan=...}`, plus the
    `plan_stage_seconds{plan,stage}` histogram fed from the same numbers
    the per-batch `BatchResult.timings` dict carries (the dict stays —
    it is the per-batch view, the registry is the aggregate).
    `AsyncPlan.last_timings` is now a bounded ring (`TIMINGS_CAP`).
  * spans (`obs.tracing`, visible in Perfetto): `detect_dispatch`
    (async window fill), `tail` (mask readback + compaction + tail
    dispatch), `emit` (blocking cleaned readback), `fused_batch`;
    ShardedPlan's proc master additionally marks `accept` (result
    accepted at the completion gate) and `emit_gated` instants, whose
    gap makes straggler-blocked emission visible. Worker processes
    record their own lease/fetch_many/compute/push spans (see
    `repro.dist.worker`) parented under the master's run span.
  * durable per-chunk telemetry (`obs.telemetry`): pass `telemetry=`
    (a TelemetryWriter) to ShardedPlan — both transports hand it to
    their QueueService, which writes lease/fetch/push/acceptance
    records master-side; redeliveries are attributed via
    `WorkQueue.on_redeliver`.

Elasticity (`ShardedPlan` proc mode + `serve.WorkerPool`): the fleet a
run starts with is not the fleet it must finish with.

  * membership — `QueueService.hello/bye/drain` is a real registry:
    per-worker state (active/draining/departed/dead) plus a membership
    epoch that bumps on every transition (`dist_membership_epoch` /
    `dist_workers{state}` gauges). A worker may `hello` into a run
    already in progress and receives the SAME setup blob the original
    fleet got; `ShardedPlan`'s proc master exposes this as `plan.fleet`
    (a `FleetControl`: spawn/drain/kill/stall live workers mid-run), and
    `WorkerPool` autoscales between `min_workers`/`max_workers` on
    sustained queue backlog, scaling down by DRAINING idle workers — a
    drained worker finishes its held leases, takes no more, and exits
    through `bye`, so nothing is ever reaped from it.
  * speculation — with `speculate=` armed, a `StragglerDetector` inside
    the QueueService watches lease->complete latencies; when an idle
    ACTIVE worker's lease comes back empty with work still in flight
    (the end-of-stream shape), the slowest flagged item is duplicated to
    it via `WorkQueue.speculate` WITHOUT reaping the original lease.
    First completion wins; the loser is attributed in telemetry under
    reason "speculated".
  * when speculation is safe — exactly-once emission needs no new
    machinery precisely because every plan already gates emission on
    `WorkQueue.complete()` returning the id as newly retired: duplicate
    pushes are discarded at that gate, and emission order (ascending
    work id) is position-, not worker-, determined. Speculation is
    therefore safe whenever the computation is a pure function of the
    fetched bytes — true for every stage graph here. It would NOT be
    safe for side-effecting work (per-item external writes) without an
    idempotency layer at the effect site.

All plans sit behind the `Preprocessor` facade, and all jitted phases live
in one keyed LRU `CompileCache`. Keys are *value* fingerprints — config,
stage list, `ShardingRules.fingerprint` (mesh shape + rule table + device
ids), kernel backend mode — never object ids, so logically-equal rules
objects share compiles and the cache cannot alias after GC reuses an id
(the old `_JIT_CACHE`/`id(rules)` bug). `ShardedPlan` accepts per-shard
rules (`distributed.sharding.pool_rules`): same-mesh shards share one
compile, per-host meshes key separately by device ids.
"""
from __future__ import annotations

import collections
import operator
import os
import threading
import time
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as SCHED
from repro.core.graph import (GraphValidationError, PipelineGraph,
                              PipelineOutput)
from repro.data.loader import ShardedLoader, make_shard_pool
from repro.data.queue import WorkQueue
from repro.dist.data_plane import StoreDataPlane
from repro.dist.service import QueueService, pack_result, unpack_result
from repro.dist.transport import ProcTransport, TcpTransport
from repro.distributed.sharding import NULL_RULES
from repro.ft.failure import StragglerDetector
from repro.kernels import backend
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.store import ChunkStore, RunJournal, content_key


class CompileCache:
    """Keyed LRU for jitted phase functions (capped — the old global grew
    without bound). Tail compiles key per padded survivor size, so the
    cap bounds COMPILE memory too: hot entries (the every-batch detect,
    pow2's O(log B) buckets) stay resident by recency, while a stream
    that insists on linear padding over more distinct survivor counts
    than the cap re-pays those compiles — the pathology pow2 bucketing
    exists to remove, kept bounded rather than hidden."""

    def __init__(self, maxsize=256):
        self.maxsize = maxsize
        self._d = collections.OrderedDict()

    def get(self, key, build):
        if key in self._d:
            self._d.move_to_end(key)
            return self._d[key]
        val = build()
        self._d[key] = val
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)
        return val

    def __len__(self):
        return len(self._d)

    def __contains__(self, key):
        return key in self._d

    def keys(self):
        return list(self._d)

    def clear(self):
        self._d.clear()


JIT_CACHE = CompileCache(maxsize=256)


def _cache_key(kind, graph: PipelineGraph, rules):
    return (kind, graph.fingerprint, rules.fingerprint, backend.get_mode())


def _phase_fn(kind, graph: PipelineGraph, rules):
    """Plain (un-jitted) callable for one phase — what dry-run lowering and
    the jit cache both consume."""
    if kind == "fused":
        return lambda a: graph.fused(a, rules)
    if kind == "detect":
        return lambda a: graph.detection(a, rules)
    if kind in ("tail", "mmse"):
        return lambda w: graph.tail(w, rules)
    if kind == "tail_idx":
        return lambda w, i: graph.tail_indexed(w, i, rules)
    if kind == "tail_idx_fused":
        return lambda w, i: graph.tail_indexed_fused(w, i, rules)
    raise KeyError(f"unknown phase {kind!r}")


def _jitted(kind, graph, rules, donate=(), shape=None):
    """Jitted phase from the shared cache. `donate` (a donate_argnums
    tuple) is part of the key: a donating and a non-donating caller of the
    same phase must not alias one compile. `shape` (the padded survivor
    count for the tail gather) is keyed too, so one cache entry == one
    XLA compile and the cache length is an honest retrace ledger —
    without it, shape retraces would hide inside a single jit wrapper,
    uncountable and uncapped by the LRU."""
    donate = tuple(donate)
    return JIT_CACHE.get(_cache_key(kind, graph, rules) + (donate, shape),
                         lambda: jax.jit(_phase_fn(kind, graph, rules),
                                         donate_argnums=donate))


@dataclass
class BatchResult:
    """One batch through a plan: compacted survivors + the detection record."""
    cleaned: np.ndarray             # (n_kept, S_final) denoised survivors
    det: PipelineOutput             # detection-phase record (masks, stats)
    n_kept: int
    wid: object = None              # loader work id (when run over a loader)
    labels: object = field(default=None, repr=False)   # loader passthrough
    src_bytes: int = 0              # measured input bytes (throughput acct)
    timings: dict = field(default=None, repr=False)
    # per-batch pipeline instrumentation (two-phase-family plans):
    #   dispatch_s  detect enqueue time (async — not detect compute time)
    #   readback_s  blocking part of the keep-mask readback
    #   compact_s   host index bookkeeping (the whole "master" role now)
    #   tail_s      tail enqueue + async cleaned-copy start
    #   emit_s      blocking part of the cleaned readback at emission
    #   in_flight   detect batches in the window when this one dispatched
    #   d2h_bytes / h2d_bytes   host-boundary traffic this batch caused
    #   tail_rows / n_real      padded tail batch rows vs real survivors


# Cap on retained per-batch timing dicts (`AsyncPlan.last_timings`): long-
# lived streams used to grow this list without bound; the registry now
# keeps the aggregate view, so the attribute is a bounded recent-history
# ring.
TIMINGS_CAP = 4096

_STAGE_KEYS = ("dispatch_s", "readback_s", "compact_s", "tail_s", "emit_s")


def _record_batch(plan_name, res: "BatchResult"):
    """Mirror one emitted batch into the metrics registry — counters for
    volume, histograms for the per-stage timings that previously lived
    only in the ad-hoc `BatchResult.timings` dict. The dict itself stays
    on the result (callers depend on it); this is the aggregate view."""
    reg = obs_metrics.get_registry()
    if not reg.enabled:
        return
    lab = {"plan": plan_name}
    reg.counter("plan_batches_total", "batches emitted",
                ("plan",)).labels(**lab).inc()
    if res.det is not None:
        reg.counter("plan_chunks_total", "chunks processed",
                    ("plan",)).labels(**lab).inc(int(np.size(res.det.keep)))
    reg.counter("plan_survivors_total", "chunks surviving detection",
                ("plan",)).labels(**lab).inc(int(res.n_kept))
    reg.counter("plan_src_bytes_total", "input bytes consumed",
                ("plan",)).labels(**lab).inc(int(res.src_bytes))
    t = res.timings
    if not t:
        return
    for k in _STAGE_KEYS:
        if k in t:
            reg.histogram("plan_stage_seconds", "per-batch stage wall time",
                          ("plan", "stage")).labels(
                plan=plan_name, stage=k[:-2]).observe(t[k])
    for k in ("d2h_bytes", "h2d_bytes"):
        if k in t:
            reg.counter(f"plan_{k}_total", "host-boundary traffic",
                        ("plan",)).labels(**lab).inc(int(t[k]))


class _StreamMeta:
    """Internal marker for ShardedPlan's plain-stream wrapper: carries the
    ORIGINAL stream wid + labels through the queue as the item's `extra`,
    unambiguously distinct from user labels that happen to be tuples."""
    __slots__ = ("wid", "labels")

    def __init__(self, wid, labels):
        self.wid = wid
        self.labels = labels


def _iter_batches(batches):
    """Normalise a batch stream: accepts arrays, (chunks, labels) pairs, or
    the (wid, (chunks, labels)) items AudioChunkLoader yields."""
    for i, item in enumerate(batches):
        wid, payload, extra = i, item, None
        if isinstance(item, tuple) and len(item) == 2 \
                and np.ndim(item[0]) == 0:
            wid, payload = item
        if isinstance(payload, tuple):
            chunks = payload[0]
            extra = payload[1] if len(payload) > 1 else None
        else:
            chunks = payload
        yield wid, chunks, extra


class ExecutionPlan:
    """Base: one batch via `__call__`, a stream via `run` (plans override
    `run` to pipeline across batches)."""
    name = ""

    def __init__(self, graph: PipelineGraph, rules=NULL_RULES,
                 pad_multiple=1):
        self.graph = graph
        self.rules = rules
        self.pad_multiple = max(1, int(pad_multiple))

    def __call__(self, audio) -> BatchResult:
        raise NotImplementedError

    def run(self, batches):
        for wid, chunks, extra in _iter_batches(batches):
            res = self(jnp.asarray(chunks))
            yield replace(res, wid=wid, labels=extra)


class FusedPlan(ExecutionPlan):
    name = "fused"

    def __call__(self, audio) -> BatchResult:
        with obs_tracing.span("fused_batch"):
            x = jnp.asarray(audio)
            out = _jitted("fused", self.graph, self.rules)(x)
            keep = np.asarray(out.keep)
            cleaned = np.asarray(out.wave5)[keep]
        res = BatchResult(cleaned=cleaned, det=out, n_kept=int(keep.sum()),
                          src_bytes=int(x.nbytes))
        _record_batch(self.name, res)
        return res


@dataclass
class _PendingTail:
    """A batch whose tail is dispatched but not yet read back: everything
    `_emit` needs, held while the device works and the cleaned rows stream
    host-ward via copy_to_host_async."""
    det: PipelineOutput
    out: object                     # device cleaned batch (None: 0 kept)
    n_real: int
    wid: object
    extra: object
    src_bytes: int
    timings: dict


class TwoPhasePlan(ExecutionPlan):
    name = "two_phase"

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1,
                 bucket="linear", donate=False, fuse_tail=None):
        super().__init__(graph, rules, pad_multiple)
        if not graph.has_removal_point:
            raise GraphValidationError(
                f"plan '{self.name}' needs a 'removal_point' stage in the "
                f"graph (stages: {graph.names}); use the fused plan for "
                f"graphs without early exit")
        self.bucket = bucket
        SCHED.quantize_survivors(0, 1, 1, bucket)     # validate the mode
        if donate is None:                            # auto: off on CPU,
            donate = jax.default_backend() != "cpu"   # on where it pays
        self.donate = bool(donate)
        # fused survivor tail (kernels/fused_tail): None = auto-engage
        # whenever the graph's post-removal chain IS the canonical fused
        # tail; True = require it (error otherwise); False = always staged
        spec = graph.fused_tail_spec
        if fuse_tail is None:
            fuse_tail = spec is not None
        elif fuse_tail and spec is None:
            raise GraphValidationError(
                f"fuse_tail=True but post-removal stages "
                f"{graph.names[graph._cut():]} are not the canonical "
                f"[hpf ->] mmse fused tail")
        self.fuse_tail = bool(fuse_tail)

    def detect(self, audio) -> PipelineOutput:
        return _jitted("detect", self.graph, self.rules)(jnp.asarray(audio))

    def _detect_donated(self, x) -> PipelineOutput:
        """Detect with the input buffer donated to the jit — only valid
        when the caller owns `x` (it made the device copy itself)."""
        donate = (0,) if self.donate else ()
        return _jitted("detect", self.graph, self.rules, donate)(x)

    def _start_tail(self, det: PipelineOutput, wid=None, extra=None,
                    src_bytes=0, timings=None) -> _PendingTail:
        """Master bookkeeping, device-resident: the host reads back ONLY
        the keep mask (B bools), builds a padded survivor-index vector
        (bucketed so the tail jit compiles O(log B) shape variants), and
        the tail jit gathers + compacts + denoises ON DEVICE — the full
        pre-denoise waveform never crosses the host boundary. With
        `donate` the wave5 buffer is donated to the tail gather, so the
        det record's wave5 must not be read after this call."""
        with obs_tracing.span("tail", wid=wid):
            return self._start_tail_inner(det, wid, extra, src_bytes,
                                          timings)

    def _start_tail_inner(self, det, wid, extra, src_bytes, timings):
        t0 = time.perf_counter()
        keep = np.asarray(det.keep)                   # the only readback
        t1 = time.perf_counter()
        idx, n_real = SCHED.survivor_indices(keep, self.pad_multiple,
                                             self.bucket)
        t2 = time.perf_counter()
        out, h2d = None, 0
        if n_real:
            donate = (0,) if self.donate else ()
            kind = "tail_idx_fused" if self.fuse_tail else "tail_idx"
            tail = _jitted(kind, self.graph, self.rules, donate,
                           shape=len(idx))
            out = tail(det.wave5, jnp.asarray(idx))   # async dispatch
            if hasattr(out, "copy_to_host_async"):
                out.copy_to_host_async()              # stream back early
            h2d = idx.nbytes
        t3 = time.perf_counter()
        timings = dict(timings or {})
        timings.update(
            readback_s=t1 - t0, compact_s=t2 - t1, tail_s=t3 - t2,
            h2d_bytes=h2d, d2h_bytes=keep.nbytes,
            tail_rows=0 if idx is None else len(idx), n_real=n_real,
            # what the pre-device-compaction bookkeeping shipped host-ward
            # per batch (the full wave5) — off the aval, no transfer
            wave5_bytes=int(np.prod(det.wave5.shape))
            * det.wave5.dtype.itemsize)
        return _PendingTail(det, out, n_real, wid, extra, src_bytes,
                            timings)

    def _emit(self, pend: _PendingTail) -> BatchResult:
        """Block on (the remainder of) the cleaned readback and build the
        result. Padded rows are sliced off here — and they are zero rows
        from the fill gather, never repeats of real audio."""
        t0 = time.perf_counter()
        with obs_tracing.span("emit", wid=pend.wid):
            if pend.out is None:
                cleaned = np.zeros((0, pend.det.wave5.shape[-1]), np.float32)
            else:
                cleaned = np.asarray(pend.out)[:pend.n_real]
                pend.timings["d2h_bytes"] += pend.out.nbytes
        pend.timings["emit_s"] = time.perf_counter() - t0
        # the pre-device-compaction boundary for THIS batch: full wave5 +
        # mask down, the LINEAR-padded survivor batch up, the same padded
        # tail output down (the old path sliced [:n_real] only after the
        # full transfer) — its actual cost on this stream, not a model
        lin_rows = SCHED.quantize_survivors(
            pend.n_real, pend.det.keep.size, self.pad_multiple,
            "linear") if pend.n_real else 0
        row_bytes = cleaned.shape[-1] * cleaned.dtype.itemsize
        pend.timings["old_boundary_bytes"] = (
            pend.timings["wave5_bytes"] + pend.det.keep.size
            + 2 * lin_rows * row_bytes)
        res = BatchResult(cleaned=cleaned, det=pend.det,
                          n_kept=pend.n_real, wid=pend.wid,
                          labels=pend.extra, src_bytes=pend.src_bytes,
                          timings=pend.timings)
        _record_batch(self.name, res)
        return res

    def _finish(self, det: PipelineOutput, wid=None, extra=None,
                src_bytes=0, timings=None):
        return self._emit(self._start_tail(det, wid, extra, src_bytes,
                                           timings))

    def __call__(self, audio) -> BatchResult:
        x = jnp.asarray(audio)
        return self._finish(self.detect(x), src_bytes=int(x.nbytes))


class AsyncPlan(TwoPhasePlan):
    """Depth-K asynchronous streaming executor: a bounded window of `depth`
    detection batches dispatched ahead, each keep mask prefetched to host
    the moment its detect is enqueued (double-buffered mask readback), the
    tail gathering survivors device-side, and one finished tail held back
    so its cleaned rows stream host-ward while the next batch computes
    (double-buffered emission). Defaults to power-of-two survivor buckets
    and, on non-CPU backends, donated detect/tail buffers. Emission is
    strictly input order; `last_timings` keeps the per-batch records of the
    most recent run()."""
    name = "async"

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1, depth=2,
                 bucket="pow2", donate=None, emit_buffer=1,
                 fuse_tail=None):
        super().__init__(graph, rules, pad_multiple, bucket=bucket,
                         donate=donate, fuse_tail=fuse_tail)
        self.depth = max(1, int(depth))
        # dispatched tails retained before emission: 1 double-buffers the
        # cleaned readback behind the next batch (+1 batch of emission
        # latency and one extra resident batch); 0 emits each result the
        # moment its tail is dispatched (the pre-PR streaming schedule)
        self.emit_buffer = max(0, int(emit_buffer))
        # bounded ring: the registry holds the aggregate (plan_stage_seconds
        # et al. via _record_batch); this keeps only recent history
        self.last_timings = collections.deque(maxlen=TIMINGS_CAP)

    def run(self, batches):
        self.last_timings = collections.deque(maxlen=TIMINGS_CAP)
        dets = collections.deque()       # detect window (<= depth)
        tails = collections.deque()      # dispatched tails (<= 2)

        def start_oldest_tail():
            tails.append(self._start_tail(*dets.popleft()))

        def emit_oldest():
            res = self._emit(tails.popleft())
            self.last_timings.append(res.timings)
            return res

        for wid, chunks, extra in _iter_batches(batches):
            t0 = time.perf_counter()
            with obs_tracing.span("detect_dispatch", wid=wid):
                owned = not isinstance(chunks, jax.Array)
                x = jnp.asarray(chunks)
                det = self._detect_donated(x) if owned and self.donate \
                    else self.detect(x)               # async dispatch
                if hasattr(det.keep, "copy_to_host_async"):
                    det.keep.copy_to_host_async()     # prefetch the mask
            timings = {"dispatch_s": time.perf_counter() - t0,
                       "in_flight": len(dets) + 1}
            dets.append((det, wid, extra, int(x.nbytes), timings))
            if len(dets) > self.depth:
                start_oldest_tail()
            while len(tails) > self.emit_buffer:
                yield emit_oldest()
        while dets:
            start_oldest_tail()
            while len(tails) > self.emit_buffer:
                yield emit_oldest()
        while tails:
            yield emit_oldest()


class StreamingPlan(AsyncPlan):
    """Two-phase with one batch of dispatch-ahead: detection of batch k+1
    is already in the device queue while the host does batch k's mask
    readback, compaction, tail dispatch AND emission — the historical
    schedule, preserved exactly: depth 1, linear tail padding, no
    donation, no emission hold-back (`emit_buffer=0`, so each result is
    yielded the moment its tail is dispatched, one batch earlier than
    `async`'s double-buffered emission). `async` is this plan with the
    dials turned up."""
    name = "streaming"

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1, depth=1,
                 bucket="linear", donate=False, emit_buffer=0,
                 fuse_tail=None):
        super().__init__(graph, rules, pad_multiple, depth=depth,
                         bucket=bucket, donate=donate,
                         emit_buffer=emit_buffer, fuse_tail=fuse_tail)


class FleetControl:
    """Live handle on an elastic proc fleet, published as `plan.fleet`
    while `ShardedPlan._run_proc` is running (and left in place afterwards
    for post-run inspection of the service's counters).

    This is the membership write-side the chaos harness and benches
    drive: spawn a late joiner, drain a worker out gracefully, SIGKILL
    one, or SIGSTOP-stall one. Everything routes through the same
    transport/service the original fleet uses — a late joiner is just
    `spawn_worker` + `hello` at a later time."""

    def __init__(self, plan, service, transport, handles):
        self.plan = plan
        self.service = service
        self.transport = transport
        self.handles = handles          # shard -> WorkerHandle (live dict,
                                        # shared with the emit loop)
        self._next = max(handles, default=-1) + 1
        self._lock = threading.Lock()

    def live(self):
        """shard -> WorkerHandle for workers whose process still runs."""
        return {k: h for k, h in list(self.handles.items())
                if h.poll() is None}

    def spawn(self, shard=None):
        """Spawn a late joiner (next free shard id unless given). The new
        worker hellos into the in-progress run, gets the same setup blob,
        and starts leasing from the shared queue. The shard id never
        rides argv: it is RESERVED with the service registry against the
        child's pid, and the worker adopts it when its announce-hello
        lands (so handles/injector stay keyed by shard while workers stay
        address-by-registration)."""
        with self._lock:
            if shard is None:
                shard = self._next
            self._next = max(self._next, int(shard) + 1)
        h = self.transport.spawn_worker(shard,
                                        lease_items=self.plan.lease_items,
                                        poll_s=self.plan.worker_poll_s)
        self.service.reserve(h.pid, int(shard))
        self.handles[int(shard)] = h
        if self.plan.injector is not None:
            self.plan.injector.attach(int(shard), h.pid)
        return h

    def drain(self, shard):
        """Ask one worker to leave gracefully (finish held leases, take
        no more, exit through bye)."""
        return self.service.drain(self.handles[int(shard)].worker)

    def kill(self, shard):
        """SIGKILL one worker (chaos: dies holding whatever it holds)."""
        self.handles[int(shard)].kill()

    def stall(self, shard, seconds=None):
        """SIGSTOP one worker, SIGCONT after `seconds` (chaos: a genuine
        straggler — lease clock ticks, no heartbeats)."""
        self.handles[int(shard)].stall(seconds)

    def resume_all(self):
        """SIGCONT everything still alive (chaos teardown safety)."""
        for h in list(self.handles.values()):
            h.resume()


class ShardedPlan(TwoPhasePlan):
    """Fault-tolerant multi-shard execution over a shared leased WorkQueue,
    served by this plan (the MASTER) to its workers over a pluggable
    transport (`repro.dist`).

    In-proc mode — the historical simulated round loop (one round = every
    live shard pulls up to lease_items), every queue mutation routed
    through the `QueueService` so progress accounting matches proc mode:

      pull    each live shard leases work ids from the SHARED queue and
              dispatches detection under its own rules/mesh; a scripted
              `CrashInjector` can kill a shard mid-pull, leaving its lease
              un-completed (the recovery paths are lease expiry and
              `fail_worker`, exactly the paper's crashed-slave re-send).
      shuffle the `Rebalancer` reads every keep mask back ONCE, packs
              survivors in (shard, item) order, and re-slices them near-
              evenly across the live shards — the plan, not the driver,
              owns the mask readback + re-shard decision.
      finish  per-shard tail (MMSE) jits run on the re-balanced survivor
              batches; cleaned rows are scattered back to their source work
              ids; `queue.complete` gates emission so each work id is
              emitted exactly once even when redelivery raced a straggler.

    Proc mode — real worker processes (`repro.dist.worker`) lease in
    batches over the transport, fetch chunk bytes from the master, run the
    exact TwoPhasePlan detect+tail locally, and stream results back; the
    master completes each returned work id (exactly-once gate), runs the
    Rebalancer on the returned masks per drain (the paper's Figs 14-16
    load ledger), emits in ascending work-id order, SIGKILLs armed by the
    `CrashInjector` land on real pids, and dead processes are reclaimed
    via `fail_worker` (fast path) or lease expiry (slow path).

    `rules` may be a single ShardingRules (shared mesh) or one per shard
    (`distributed.sharding.pool_rules`); compiles land in the shared
    CompileCache keyed by each shard's value fingerprint. (Proc/tcp
    workers compile in their own processes; under `transport="tcp"` they
    may live on other hosts entirely — pair with `data_plane=` so chunk
    bytes move through the shared store, not the master's socket.)
    """
    name = "sharded"
    accepts_rules_pool = True

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1, shards=2,
                 lease_items=1, injector=None, monitor=None,
                 transport="inproc", worker_poll_s=0.05,
                 stall_timeout_s=300.0, lease_timeout_s=None,
                 telemetry=None, speculate=None, straggler_factor=2.0,
                 straggler_min_history=4, elastic=False, data_plane=None):
        self.shards = max(1, int(shards))
        if isinstance(rules, (list, tuple)):
            if len(rules) != self.shards:
                raise ValueError(
                    f"got {len(rules)} per-shard rules for {self.shards} "
                    f"shards")
            pool = tuple(rules)
        else:
            pool = (rules,) * self.shards
        super().__init__(graph, pool[0], pad_multiple)
        self.rules_pool = pool
        self.lease_items = max(1, int(lease_items))
        self.injector = injector
        self.monitor = monitor
        self.transport = transport
        self.worker_poll_s = float(worker_poll_s)
        self.stall_timeout_s = float(stall_timeout_s)
        # lease deadline for the plan's INTERNAL queue (plain-stream runs;
        # a user-supplied pool brings its own queue). None = transport-
        # sensible default: proc workers pay a first-item jit compile
        # (~minute on CPU), so a healthy compiling worker must not blow
        # its deadline; the simulated loop keeps the WorkQueue default.
        self.lease_timeout_s = lease_timeout_s
        # optional repro.obs.telemetry.TelemetryWriter: handed to the
        # QueueService both transports build, which writes durable
        # per-chunk records master-side at lease/fetch/push/acceptance
        self.telemetry = telemetry
        # speculative re-lease of stragglers (see the module docstring's
        # elasticity section). None = on for proc workers (where a slow
        # process is a real tail-latency event), off for the simulated
        # loop (where "slow" is not observable and duplicate computes
        # only burn the one host).
        self.speculate = speculate
        self.straggler_factor = float(straggler_factor)
        self.straggler_min_history = int(straggler_min_history)
        # elastic=True relaxes the proc master's every-worker-exited
        # fast-fail: with a chaos/autoscale driver on plan.fleet, an
        # empty fleet is a moment, not a verdict — late joiners may be a
        # spawn away (the stall timeout stays as the real backstop)
        self.elastic = bool(elastic)
        # store data plane (ChunkStore | directory | StoreDataPlane):
        # chunk bytes move through a shared store, the control socket
        # carries content keys — proc/tcp transports only.
        self.data_plane = data_plane
        self.fleet = None               # FleetControl while _run_proc lives
        kind = self._transport_kind()   # validate early, not mid-stream
        if data_plane is not None and kind == "inproc":
            raise ValueError("data_plane= rides the proc/tcp worker "
                             "runtime; the in-proc simulated loop never "
                             "serializes chunks")
        self.rebalancer = SCHED.Rebalancer(self.shards, pad_multiple)
        self.redeliveries = 0           # mirrored off the queue after run()
        self.speculations = 0           # mirrored off the queue after run()
        self.speculations_lost = 0      # mirrored off the queue after run()
        self.last_assignment = None     # last round's ShardAssignment
        self.worker_stats = None        # per-worker report of the last run
        self._release = None            # stream-item drop hook (see run())

    def _transport_kind(self) -> str:
        t = self.transport
        if isinstance(t, str):
            if t not in ("inproc", "proc", "tcp"):
                raise ValueError(f"unknown transport {t!r} "
                                 "(expected 'inproc', 'proc' or 'tcp')")
            return t
        kind = getattr(t, "name", None)
        if kind not in ("inproc", "proc", "tcp"):
            raise ValueError(f"transport object {t!r} names no known kind")
        return kind

    # -- per-shard phase dispatch (shared CompileCache, per-shard rules) ----
    def _detect_on(self, shard, audio):
        return _jitted("detect", self.graph, self.rules_pool[shard])(audio)

    def _tail_on(self, shard, batch):
        return _jitted("tail", self.graph, self.rules_pool[shard])(batch)

    # -- single batch: row-split across shards, rebalance, reassemble -------
    def __call__(self, audio) -> BatchResult:
        x = np.asarray(audio, np.float32)
        parts = [(j, p) for j, p in enumerate(np.array_split(x, self.shards))
                 if len(p)]
        dets = [(j, self._detect_on(j, jnp.asarray(p))) for j, p in parts]
        det = _merge_outputs([d for _, d in dets])
        waves_keeps = [(np.asarray(d.wave5), np.asarray(d.keep))
                       for _, d in dets]
        cleaned, asg = self._rebalanced_tail(
            waves_keeps, [k for _, k in waves_keeps],
            live=[j for j, _ in dets])
        self.last_assignment = asg
        res = BatchResult(cleaned=cleaned, det=det,
                          n_kept=int(np.asarray(det.keep).sum()),
                          src_bytes=int(x.nbytes))
        _record_batch(self.name, res)
        return res

    def _rebalanced_tail(self, item_waves_keeps, shard_keeps, live):
        """Rebalanced phase B. item_waves_keeps: [(wave5, keep)] per
        detected item in packed order; shard_keeps: one concatenated keep
        mask per LIVE shard (same packed order) — the assignment is made
        per shard, survivors are packed per item. Returns (cleaned rows in
        packed survivor order, ShardAssignment)."""
        with obs_tracing.span("tail_rebalanced", live=len(live)):
            return self._rebalanced_tail_inner(item_waves_keeps,
                                               shard_keeps, live)

    def _rebalanced_tail_inner(self, item_waves_keeps, shard_keeps, live):
        asg = self.rebalancer.assign(shard_keeps, out_shards=len(live))
        surv = [w[k] for w, k in item_waves_keeps if k.any()]
        if not surv:
            width = (item_waves_keeps[0][0].shape[1]
                     if item_waves_keeps else 0)
            return np.zeros((0, width), np.float32), asg
        packed = np.concatenate(surv)
        cleaned = np.empty_like(packed)
        for slot, batch, n_real in self.rebalancer.split(packed, asg):
            lo = int(asg.bounds[slot])
            out = self._tail_on(live[slot], jnp.asarray(batch))
            cleaned[lo:lo + n_real] = np.asarray(out)[:n_real]
        return cleaned, asg

    # -- streams ------------------------------------------------------------
    def run(self, batches):
        """Accepts a ShardedLoader pool (the multi-host path) or any plain
        batch stream, which is wrapped behind an internal WorkQueue so
        single-stream callers get the same leased, rebalanced execution.
        Sized streams (lists, loaders with __len__) are drawn lazily and
        each item is dropped once its work id completes, so memory stays
        O(in-flight); only unsized generators are materialised up front."""
        if isinstance(batches, (list, tuple)) and batches and \
                all(isinstance(b, ShardedLoader) for b in batches):
            yield from self.run_pool(list(batches))
            return
        n = operator.length_hint(batches, -1)
        it = _iter_batches(batches)
        if n < 0:
            drained = list(it)
            n, it = len(drained), iter(drained)
        store, cursor = {}, [0]
        draw = threading.Lock()    # proc fetches come from handler threads

        def make(i):
            with draw:
                while cursor[0] <= i:
                    wid, chunks, extra = next(it)
                    store[cursor[0]] = (chunks, _StreamMeta(wid, extra))
                    cursor[0] += 1
                return store[i]

        timeout = self.lease_timeout_s
        if timeout is None:
            timeout = 300.0 if self._transport_kind() in ("proc", "tcp") \
                else 60.0
        pool = make_shard_pool(make, n, self.shards,
                               lease_items=self.lease_items,
                               lease_timeout_s=timeout)
        self._release = store.pop
        try:
            yield from self.run_pool(pool)
        finally:
            self._release = None

    def run_pool(self, pool):
        # shard-ascending order keeps the packed survivor order consistent
        # with the per-shard masks handed to the Rebalancer
        pool = sorted(pool, key=lambda ld: ld.shard)
        queue = pool[0].queue
        assert all(ld.queue is queue for ld in pool), \
            "a shard pool must share one WorkQueue"
        bad = sorted({ld.shard for ld in pool} - set(range(self.shards)))
        if bad:
            raise ValueError(
                f"pool shard ids {bad} out of range for a "
                f"{self.shards}-shard plan")
        if self._transport_kind() in ("proc", "tcp"):
            yield from self._run_proc(pool, queue)
        else:
            yield from self._run_sim(pool, queue)

    def _make_straggler(self, kind):
        """The speculation arm: a StragglerDetector for the QueueService,
        or None. Default (speculate=None) arms it only under proc
        transport — see __init__."""
        on = (kind in ("proc", "tcp")) if self.speculate is None \
            else bool(self.speculate)
        if not on:
            return None
        return StragglerDetector(factor=self.straggler_factor,
                                 min_history=self.straggler_min_history)

    # -- in-proc master: the historical simulated round loop ----------------
    def _run_sim(self, pool, queue):
        service = QueueService(queue, monitor=self.monitor,
                               telemetry=self.telemetry,
                               straggler=self._make_straggler("inproc"))
        # every queue mutation flows through the service (pure delegation
        # under the queue's own lock, so behavior is bit-for-bit the old
        # direct path) and the per-worker ledger accrues as in proc mode
        for ld in pool:
            ld.queue = service
        try:
            stalls = 0
            while not service.finished:
                round_work = []      # (shard, wid, det, extra, nbytes)
                for ld in pool:
                    if not self._alive(ld.shard):
                        continue
                    # one beat per live shard per round (note_beat also
                    # forwards to the attached HeartbeatMonitor) — the
                    # historical liveness cadence, through the service
                    service.note_beat(ld.worker)
                    for wid, item in ld.pull():
                        if self.injector is not None and \
                                not self.injector.on_pull(ld.shard):
                            break    # died holding this lease
                        chunks, extra = item if isinstance(item, tuple) \
                            else (item, None)
                        x = jnp.asarray(chunks)
                        det = self._detect_on(ld.shard, x)  # async dispatch
                        round_work.append((ld.shard, wid, det, extra,
                                           int(x.nbytes)))
                if round_work:
                    stalls = 0
                    yield from self._finish_round(service, round_work)
                    continue
                if self._reclaim(service, pool) or service.finished:
                    continue
                deadline = service.next_deadline()
                stalls += 1
                if deadline is not None and stalls <= 8 and \
                        any(self._alive(ld.shard) for ld in pool):
                    # a lease nothing declared dead is still ticking (a
                    # worker outside this pool, or an undetected death):
                    # wait out the deadline so the next pull reaps and
                    # redelivers it. Only wall clocks advance while we
                    # sleep; injected clocks (SettableClock etc.) re-poll
                    # and hit the stall cap fast.
                    if queue.clock in (time.monotonic, time.time):
                        time.sleep(max(0.0, min(deadline - queue.clock(),
                                                queue.lease_timeout_s))
                                   + 1e-3)
                    continue
                raise RuntimeError(
                    "sharded plan stalled: work is leased but no live "
                    f"shard can make progress (progress "
                    f"{service.progress()})")
        finally:
            for ld in pool:
                ld.queue = queue
        self.redeliveries = queue.redeliveries
        self.speculations = queue.speculations
        self.speculations_lost = queue.speculations_lost
        self.worker_stats = service.worker_report()

    # -- proc master: real worker processes over the transport --------------
    def _proc_setup(self):
        """The picklable blob workers rebuild their jits from — value
        identity only (config, stage names, pad/bucket, backend mode), the
        same facts the CompileCache keys on."""
        return {"cfg": self.graph.cfg, "stages": list(self.graph.names),
                "source_channels": self.graph.source_geom.channels,
                "pad_multiple": self.pad_multiple, "bucket": self.bucket,
                "backend_mode": backend.get_mode()}

    def _run_proc(self, pool, queue):
        make_item = pool[0].make_item
        extras = {}                 # wid -> labels/_StreamMeta, master-side

        def fetch(wid):
            """Data plane: materialise the batch on the master, ship ONLY
            the chunk bytes — labels stay here for emission. A fetch whose
            redelivered lease lost the race to a straggler's completion
            gets None (the item may already be emitted AND released from
            the stream buffer): the worker skips it, nothing recomputes."""
            if queue.is_done(wid):
                return None
            try:
                item = make_item(wid)
            except KeyError:
                # completed + released between the is_done check and the
                # buffer read — same race, same answer
                if queue.is_done(wid):
                    return None
                raise
            chunks, extra = item if isinstance(item, tuple) \
                else (item, None)
            extras[wid] = extra
            return np.asarray(chunks, np.float32)

        dp = self.data_plane
        if dp is not None and not isinstance(dp, StoreDataPlane):
            # share the CompileCache/CachedPlan value identity so raw
            # entries dedup across runs of the same graph + backend
            dp = StoreDataPlane(dp, graph_fingerprint=self.graph.fingerprint,
                                backend_mode=backend.get_mode())
        service = QueueService(queue, fetch_item=fetch,
                               setup=self._proc_setup(),
                               monitor=self.monitor,
                               telemetry=self.telemetry,
                               straggler=self._make_straggler("proc"),
                               data_plane=dp)
        if not isinstance(self.transport, str):
            tp = self.transport
        else:
            tp = TcpTransport() if self.transport == "tcp" \
                else ProcTransport()
        handles = {}
        if self.injector is not None:
            def on_grant(worker, wid):
                # the real-process CrashInjector trigger: a doomed shard
                # is SIGKILLed the moment its fatal lease is granted, so
                # it dies HOLDING the lease (attach() below arms the pid)
                shard = service.workers[worker].shard
                self.injector.on_pull(shard)
            service.on_grant = on_grant
        snap = queue.state()
        order = [i for i in range(snap["n_items"])
                 if i not in set(snap["done"])]
        try:
            tp.serve(service)
            # the fleet handle is published BEFORE the initial spawns so
            # a chaos/autoscale driver watching plan.fleet sees the same
            # membership the emit loop does; initial workers and late
            # joiners go through the identical spawn path
            self.fleet = FleetControl(self, service, tp, handles)
            for k in range(self.shards):
                self.fleet.spawn(k)
            yield from self._proc_emit_loop(service, queue, handles,
                                            extras, order)
            # the queue is drained: give workers a moment to observe
            # `finished` and sign off (bye carries their idle/busy split)
            deadline = time.monotonic() + 5.0
            for h in list(handles.values()):
                try:
                    h.proc.wait(max(0.0, deadline - time.monotonic()))
                except Exception:
                    pass
        finally:
            if self.fleet is not None:
                self.fleet.resume_all()   # never TERM a SIGSTOPped worker
            for h in list(handles.values()):
                h.shutdown()
            tp.close()
        self.redeliveries = queue.redeliveries
        self.speculations = queue.speculations
        self.speculations_lost = queue.speculations_lost
        self.worker_stats = service.worker_report()

    def _proc_emit_loop(self, service, queue, handles, extras, order):
        """Drain worker results, gate on completion (exactly-once), emit
        in ascending work-id order (== the crash-free in-proc order, so
        transports are emission-order-identical), and reclaim dead worker
        processes fast via fail_worker."""
        buffered = {}
        emit_i = 0
        reclaimed = set()
        last_progress = time.monotonic()
        while emit_i < len(order):
            drained = service.pop_results()
            if drained:
                last_progress = time.monotonic()
                # store data plane: pushes are key refs — materialize the
                # payloads here, master-side, off the RPC handler threads
                # (losing incarnations cost one redundant store read)
                drained = [(w, wid, service.resolve_result(p))
                           for w, wid, p in drained]
                self._note_assignment(service, drained)
            for worker, wid, payload in drained:
                # the winner's name rides into complete() so a lost
                # speculation race attributes the OTHER incarnation
                if not queue.complete([wid], worker=worker):
                    continue        # redelivery raced a straggler
                det, f = unpack_result(payload)
                # accepted == counted; acceptance is ALSO the durable
                # telemetry point (note_done writes the per-chunk record
                # master-side, so it survives a SIGKILLed worker)
                service.note_done(worker, wid=wid, survivors=f["n_kept"],
                                  bytes_out=f["cleaned"].nbytes)
                obs_tracing.instant("accept", wid=wid, worker=worker)
                buffered[wid] = (det, f)
            progressed = bool(drained)
            while emit_i < len(order) and order[emit_i] in buffered:
                wid = order[emit_i]
                emit_i += 1
                det, f = buffered.pop(wid)
                if self._release is not None:
                    self._release(wid, None)
                extra = extras.pop(wid, None)
                orig_wid, labels = (extra.wid, extra.labels) \
                    if isinstance(extra, _StreamMeta) else (wid, extra)
                # emission gating made visible: the gap between a chunk's
                # "accept" instant and this one is time spent buffered
                # behind a straggler (ascending-wid emission order)
                obs_tracing.instant("emit_gated", wid=wid,
                                    buffered=len(buffered))
                res = BatchResult(cleaned=f["cleaned"], det=det,
                                  n_kept=f["n_kept"], wid=orig_wid,
                                  labels=labels, src_bytes=f["src_bytes"])
                _record_batch(self.name, res)
                yield res
            if emit_i >= len(order) or progressed:
                continue
            # no progress this tick: look for dead workers to reclaim.
            # handles is a LIVE dict (late joiners appear mid-iteration
            # via plan.fleet.spawn) — snapshot it. A worker that exited
            # in state draining/departed left gracefully holding nothing:
            # nothing to reclaim, and it must not be marked dead.
            for k, h in list(handles.items()):
                if k in reclaimed or h.poll() is None or queue.finished:
                    continue
                reclaimed.add(k)
                st = service.workers.get(h.worker)
                if st is not None and st.state in ("draining", "departed"):
                    continue
                service.fail_worker(h.worker)
            if self.monitor is not None:
                for w in sorted(set(self.monitor.dead())):
                    service.fail_worker(w)
                    # reclaimed once is reclaimed: drop the dead worker
                    # from liveness tracking so this loop does not re-fail
                    # it every idle tick
                    self.monitor.forget(w)
            if not self.elastic \
                    and all(h.poll() is not None for h in handles.values()) \
                    and not queue.finished:
                raise RuntimeError(
                    "sharded plan stalled: every worker process exited "
                    f"with work outstanding (progress {queue.progress()})")
            if time.monotonic() - last_progress > self.stall_timeout_s:
                raise RuntimeError(
                    f"sharded plan stalled: no worker progress for "
                    f"{self.stall_timeout_s:.0f}s "
                    f"(progress {queue.progress()})")
            time.sleep(0.01)

    def _note_assignment(self, service, drained):
        """The paper's Figs 14-16 ledger under proc mode: run the
        Rebalancer on the masks this drain returned, grouped per source
        shard. No data moves — workers already denoised their own leases —
        but the would-be re-shard (loads before/after, moved count) is the
        measurement the driver reports."""
        by_shard = {}
        for worker, wid, payload in drained:
            st = service.workers.get(worker)
            shard = st.shard if st is not None else -1
            by_shard.setdefault(shard, []).append(
                np.asarray(payload["keep"]))
        keeps = [np.concatenate(v) for _, v in sorted(by_shard.items())]
        if keeps:
            self.last_assignment = self.rebalancer.assign(
                keeps, out_shards=len(keeps))

    def _alive(self, shard):
        return self.injector is None or self.injector.alive(shard)

    def _reclaim(self, queue, pool):
        """All pending work is held by dead shards: return their leases
        (the heartbeat/injector 'said dead' fast path; a slower deployment
        without either still recovers via lease-deadline expiry on the next
        pull). True if any work came back."""
        dead_workers = {ld.worker for ld in pool if not self._alive(ld.shard)}
        if self.monitor is not None:
            dead_workers |= set(self.monitor.dead())
        got = 0
        for w in sorted(dead_workers):
            got += len(queue.fail_worker(w))
        return got > 0

    def _finish_round(self, service, round_work):
        """Rebalanced phase B for one round, then exactly-once emission in
        work-id completion order."""
        live = sorted({s for s, *_ in round_work})
        item_wk = [(np.asarray(d.wave5), np.asarray(d.keep))
                   for _, _, d, _, _ in round_work]
        # packed per (shard, item) order == round_work order (pool order),
        # so the per-shard masks are contiguous slices of it
        shard_keeps = [np.concatenate(
            [k for (s, *_), (_, k) in zip(round_work, item_wk) if s == s2])
            for s2 in live]
        cleaned_all, asg = self._rebalanced_tail(item_wk, shard_keeps, live)
        self.last_assignment = asg
        offs = np.concatenate(
            [[0], np.cumsum([k.sum() for _, k in item_wk])]).astype(int)
        for i, (shard, wid, det, extra, nbytes) in enumerate(round_work):
            if not service.complete([wid]):
                continue             # redelivery raced a straggler: emitted once
            cleaned = cleaned_all[offs[i]:offs[i + 1]]
            service.note_done(f"shard{shard}", wid=wid,
                              survivors=int(offs[i + 1] - offs[i]),
                              bytes_out=cleaned.nbytes)
            if self._release is not None:
                self._release(wid, None)     # drop the buffered stream item
            orig_wid, labels = (extra.wid, extra.labels) \
                if isinstance(extra, _StreamMeta) else (wid, extra)
            res = BatchResult(
                cleaned=cleaned, det=det,
                n_kept=int(offs[i + 1] - offs[i]), wid=orig_wid,
                labels=labels, src_bytes=nbytes)
            _record_batch(self.name, res)
            yield res


class _SizedIter:
    """One-shot iterable with a length hint: lets CachedPlan hand its miss
    stream to a sharded inner lazily (ShardedPlan sizes its queue from the
    hint and draws items as leases demand) without pinning every raw batch
    in a list."""

    def __init__(self, it, n):
        self._it, self._n = iter(it), n

    def __iter__(self):
        return self._it

    def __length_hint__(self):
        return self._n


class CachedPlan(ExecutionPlan):
    """Content-addressed caching + resumability around any inner plan.

    Execution per stream: every batch is keyed by content hash of (raw
    chunk bytes, graph fingerprint, kernel backend mode) and looked up in
    the `ChunkStore` BEFORE any dispatch; only misses flow through the
    inner plan (one sub-stream, so a sharded inner keeps its leased-queue
    batching); cached survivors merge back in stream order; fresh results
    are written to the store as the inner plan emits them. The cache key
    deliberately omits sharding rules — sharding moves work, never values
    (plan equivalence is bit-exact on masks), so runs under different
    shard counts share entries.

    Resumability: with a `RunJournal`, the plan snapshots its emission
    queue after every yielded result; constructing with `resume=True`
    restores that snapshot and skips exactly the work the dead process
    already emitted — each chunk id is emitted once across the kill.
    Results the dead run computed but never emitted come back as store
    hits, so the resumed run pays recomputation only for truly in-flight
    work.

    `store=None` (the default) degrades to a transparent pass-through, so
    'cached' is always safe to select. Cached `det` records carry masks and
    stats but a zero-filled `wave5` — the pre-denoise waveform is an
    intermediate no downstream consumer reads, and persisting it would
    dwarf the survivors it exists to produce.
    """
    name = "cached"
    accepts_rules_pool = True

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1,
                 inner="two_phase", store=None, journal=None, resume=False,
                 **inner_kwargs):
        inner_cls = PLANS[inner] if isinstance(inner, str) else inner
        if isinstance(rules, (list, tuple)) and not (
                isinstance(inner_cls, type)
                and getattr(inner_cls, "accepts_rules_pool", False)):
            raise ValueError(
                "a per-shard rules list is only valid with the sharded "
                f"plan as inner, not {getattr(inner_cls, 'name', inner_cls)!r}")
        facade_rules = rules[0] \
            if isinstance(rules, (list, tuple)) and rules else rules
        super().__init__(graph, facade_rules, pad_multiple)
        self.inner = inner_cls(graph, rules, pad_multiple, **inner_kwargs)
        if isinstance(store, (str, os.PathLike)):
            # a cache should self-heal: a bit-rotted entry is evicted and
            # recomputed, not fatal on every future run at the same batch.
            # Pass a ChunkStore instance for archival strictness.
            store = ChunkStore(store, evict_corrupt=True)
        self.store = store
        if journal is True:
            if store is None:
                raise ValueError(
                    "journal=True derives the journal path from the store "
                    "directory — pass a store, or an explicit journal")
            journal = os.path.join(store.directory, "journal")
        if isinstance(journal, (str, os.PathLike)):
            journal = RunJournal(journal)
        self.journal = journal
        self.resume = bool(resume)
        if self.resume and self.journal is None:
            raise ValueError("resume=True needs a journal")

    @property
    def stats(self):
        """The store's hit/miss/bytes accounting (None when uncached)."""
        return self.store.stats if self.store is not None else None

    # -- BatchResult <-> store entry ----------------------------------------
    def _key(self, chunks_np):
        return content_key(chunks_np, self.graph.fingerprint,
                           backend.get_mode())

    # one codec for "masks + stats + cleaned, wave5 reduced to its width":
    # repro.dist's pack_result/unpack_result — the store entry and the
    # worker result payload are the SAME shape (ChunkStore.put_payload
    # derives the array/meta split by type, never by a key list that
    # could drift from the codec), which is also what lets the dist
    # store data plane push worker results straight into a ChunkStore

    def _result(self, arrays, meta, wid, extra) -> BatchResult:
        det, f = unpack_result({**arrays, **meta})
        res = BatchResult(cleaned=f["cleaned"], det=det,
                          n_kept=f["n_kept"], wid=wid, labels=extra,
                          src_bytes=f["src_bytes"])
        # store hits bypass the inner plan, so they are counted here —
        # misses are counted at the inner plan's own emission point
        _record_batch(self.name, res)
        return res

    # -- single batch (the warm-cache serving path) -------------------------
    def __call__(self, audio) -> BatchResult:
        if self.store is None:
            return self.inner(audio)
        x = np.asarray(audio, np.float32)
        key = self._key(x)
        hit = self.store.get(key, src_bytes=x.nbytes)
        if hit is not None:
            return self._result(*hit, wid=None, extra=None)
        res = self.inner(x)
        self.store.put_payload(key, pack_result(res))
        return res

    # -- streams ------------------------------------------------------------
    def run(self, batches):
        """Emits BatchResults in STREAM order (cached survivors merged back
        where they belong). Emission follows ShardedPlan's completion-gated
        convention: the queue completes and the journal records IMMEDIATELY
        BEFORE each yield, so at the plan boundary every chunk is emitted
        exactly once across a kill + resume — an abandoned generator resumes
        from precisely the next unemitted item. (The chunk handed over at
        the instant of a hard process kill is the consumer's to recover, as
        with any exactly-once hand-off.)

        Memory: like ShardedPlan, sized streams (lists, loaders with
        __len__) are drawn lazily — hits in the stream-order prefix are
        emitted DURING the probe, raw chunks are retained only for misses,
        and each miss's bytes are released as the inner plan draws them —
        while unsized generators are materialised up front to learn the
        stream length (the journal and resume guard need it)."""
        if isinstance(batches, (list, tuple)) and batches and \
                all(isinstance(b, ShardedLoader) for b in batches):
            raise ValueError(
                "CachedPlan must see chunk content before dispatch — feed "
                "it the plain batch stream; a sharded inner builds its "
                "leased shard pool internally from the misses")

        n = operator.length_hint(batches, -1)
        it = _iter_batches(batches)
        if n < 0:
            drained = list(it)
            n, it = len(drained), iter(drained)

        done, want_key0 = set(), None
        if self.journal is not None and self.resume:
            rec_meta = self.journal.load()
            if rec_meta is not None:
                rec_n = int(rec_meta["queue"]["n_items"])
                if rec_n != n:
                    raise ValueError(
                        f"journal records a {rec_n}-item stream; the "
                        f"resume stream has {n} items — refusing to mix "
                        f"runs")
                done = set(rec_meta["queue"]["done"])
                want_key0 = rec_meta.get("stream_key0")
        queue = WorkQueue.from_state({"n_items": n, "done": sorted(done)})
        order = [p for p in range(n) if p not in done]
        emit_idx = 0
        key0 = None                       # stream identity: first batch key
        results: dict[int, BatchResult] = {}
        misses = []                       # [pos, key, wid, chunks, extra]

        def emit_ready():
            """Completion-gated hand-off of the ready stream-order prefix."""
            nonlocal emit_idx
            while emit_idx < len(order) and order[emit_idx] in results:
                pos = order[emit_idx]
                emit_idx += 1
                queue.complete([pos])
                if self.journal is not None:
                    self.journal.record(queue, meta={"stream_key0": key0})
                yield results.pop(pos)

        for pos, (wid, chunks, extra) in enumerate(it):
            probe = pos not in done and self.store is not None
            if probe or (pos == 0 and self.journal is not None):
                x = np.asarray(chunks, np.float32)
                key = self._key(x)
                if pos == 0:
                    key0 = key
                    if want_key0 is not None and want_key0 != key0:
                        raise ValueError(
                            "journal records a stream with different "
                            "content (first-batch key mismatch) — "
                            "refusing to mix runs")
            if pos in done:
                continue                  # the killed run already emitted it
            if not probe:                 # uncached: everything is a miss
                misses.append([pos, None, wid, chunks, extra])
                continue
            hit = self.store.get(key, src_bytes=x.nbytes)
            if hit is not None:
                results[pos] = self._result(*hit, wid=wid, extra=extra)
                yield from emit_ready()   # warm prefixes flow immediately
            else:
                misses.append([pos, key, wid, x, extra])

        if misses:
            def miss_stream():
                for i, m in enumerate(misses):
                    item = (i, (m[3], m[4]))
                    m[3] = None           # the inner plan owns the bytes now
                    yield item

            for res in self.inner.run(_SizedIter(miss_stream(),
                                                 len(misses))):
                pos, key, wid, _, extra = misses[res.wid]
                if self.store is not None:
                    self.store.put_payload(key, pack_result(res))
                results[pos] = replace(res, wid=wid, labels=extra)
                yield from emit_ready()
        yield from emit_ready()
        assert emit_idx == len(order), "inner plan dropped work ids"


def _merge_outputs(outs):
    """Concatenate per-shard PipelineOutputs (row order preserved) with
    chunk-count-weighted stats — the batch looks as if one shard detected
    it."""
    if len(outs) == 1:
        return outs[0]
    cat = lambda f: np.concatenate([np.asarray(getattr(o, f)) for o in outs])
    ws = np.array([float(o.stats["n_chunks5"]) for o in outs])
    stats = {"n_chunks5": int(ws.sum())}
    for k in outs[0].stats:
        if k != "n_chunks5":
            vals = np.array([float(o.stats[k]) for o in outs])
            stats[k] = float((vals * ws).sum() / ws.sum())
    return PipelineOutput(wave5=cat("wave5"), keep=cat("keep"),
                          rain=cat("rain"), silence=cat("silence"),
                          cicada15=cat("cicada15"), stats=stats)


PLANS = {p.name: p for p in (FusedPlan, TwoPhasePlan, StreamingPlan,
                             AsyncPlan, ShardedPlan, CachedPlan)}


class Preprocessor:
    """The single facade every entry point uses.

        pre = Preprocessor(SERF_AUDIO, rules, plan="streaming",
                           pad_multiple=len(jax.devices()))
        for res in pre.run(loader):        # loader: AudioChunkLoader items
            use(res.cleaned, res.det.stats, res.n_kept)

    `plan` is a name from `PLANS` or an ExecutionPlan subclass; `stages`
    overrides the config-declared stage list for ablations. Extra keyword
    arguments are forwarded to the plan (e.g. `shards=4`, `injector=...`
    for the sharded plan).
    """

    def __init__(self, cfg, rules=NULL_RULES, plan="two_phase",
                 pad_multiple=1, stages=None, source_channels=2,
                 **plan_kwargs):
        self.cfg = cfg
        # facade-level detect()/phase_fn() use one rules object even when
        # the plan gets a per-shard list (sharded multi-host pools)
        self.rules = rules[0] if isinstance(rules, (list, tuple)) and rules \
            else rules
        self.graph = PipelineGraph(cfg, stages, source_channels)
        plan_cls = PLANS[plan] if isinstance(plan, str) else plan
        if isinstance(rules, (list, tuple)) and not (
                isinstance(plan_cls, type)
                and getattr(plan_cls, "accepts_rules_pool", False)):
            raise ValueError(
                "a per-shard rules list is only valid with the sharded "
                "plan (or a cached wrapper around it), not "
                f"{getattr(plan_cls, 'name', plan_cls)!r}")
        self.plan = plan_cls(self.graph, rules, pad_multiple, **plan_kwargs)

    def __call__(self, audio) -> BatchResult:
        """One batch of (B, C, S_long_src) long chunks -> BatchResult."""
        return self.plan(audio)

    def run(self, batches):
        """Iterate BatchResults over a batch stream / AudioChunkLoader."""
        return self.plan.run(batches)

    def detect(self, audio) -> PipelineOutput:
        """The phase-A stages only (shared compile cache; plan-independent).
        For a graph without a removal point this is the whole chain — see
        PipelineGraph.detection."""
        return _jitted("detect", self.graph, self.rules)(jnp.asarray(audio))

    def phase_fn(self, kind):
        """Un-jitted phase callable ('fused' | 'detect' | 'mmse'/'tail')
        for jax.jit(...).lower-style analysis (see launch/dryrun.py)."""
        return _phase_fn(kind, self.graph, self.rules)
