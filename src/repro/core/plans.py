"""Execution plans: HOW a validated `PipelineGraph` runs on a batch stream.

The graph fixes WHAT computes (stage order, removal points); a plan picks
the execution strategy:

  * `FusedPlan`     — one jit straight through; removed chunks are masked
                      but still computed (the paper's no-early-exit
                      baseline).
  * `TwoPhasePlan`  — detection jit -> host reads the keep mask (the
                      paper's master bookkeeping) -> survivors compacted /
                      re-batched -> tail jit on the survivor batch only.
                      The paper's headline economy: MMSE cost scales with
                      surviving audio.
  * `StreamingPlan` — two-phase with dispatch-ahead over a loader: phase-A
                      detection of batch k+1 is enqueued on the device
                      before phase B of batch k, so host-side mask readback
                      + compaction overlap device work.

All plans sit behind the `Preprocessor` facade, and all jitted phases live
in one keyed LRU `CompileCache`. Keys are *value* fingerprints — config,
stage list, `ShardingRules.fingerprint` (mesh shape + rule table), kernel
backend mode — never object ids, so logically-equal rules objects share
compiles and the cache cannot alias after GC reuses an id (the old
`_JIT_CACHE`/`id(rules)` bug).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as SCHED
from repro.core.graph import (GraphValidationError, PipelineGraph,
                              PipelineOutput)
from repro.distributed.sharding import NULL_RULES
from repro.kernels import backend


class CompileCache:
    """Small keyed LRU for jitted phase functions (capped — the old global
    grew without bound)."""

    def __init__(self, maxsize=64):
        self.maxsize = maxsize
        self._d = collections.OrderedDict()

    def get(self, key, build):
        if key in self._d:
            self._d.move_to_end(key)
            return self._d[key]
        val = build()
        self._d[key] = val
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)
        return val

    def __len__(self):
        return len(self._d)

    def __contains__(self, key):
        return key in self._d

    def clear(self):
        self._d.clear()


JIT_CACHE = CompileCache(maxsize=64)


def _cache_key(kind, graph: PipelineGraph, rules):
    return (kind, graph.fingerprint, rules.fingerprint, backend.get_mode())


def _phase_fn(kind, graph: PipelineGraph, rules):
    """Plain (un-jitted) callable for one phase — what dry-run lowering and
    the jit cache both consume."""
    if kind == "fused":
        return lambda a: graph.fused(a, rules)
    if kind == "detect":
        return lambda a: graph.detection(a, rules)
    if kind in ("tail", "mmse"):
        return lambda w: graph.tail(w, rules)
    raise KeyError(f"unknown phase {kind!r}")


def _jitted(kind, graph, rules):
    return JIT_CACHE.get(_cache_key(kind, graph, rules),
                         lambda: jax.jit(_phase_fn(kind, graph, rules)))


@dataclass
class BatchResult:
    """One batch through a plan: compacted survivors + the detection record."""
    cleaned: np.ndarray             # (n_kept, S_final) denoised survivors
    det: PipelineOutput             # detection-phase record (masks, stats)
    n_kept: int
    wid: object = None              # loader work id (when run over a loader)
    labels: object = field(default=None, repr=False)   # loader passthrough
    src_bytes: int = 0              # measured input bytes (throughput acct)


def _iter_batches(batches):
    """Normalise a batch stream: accepts arrays, (chunks, labels) pairs, or
    the (wid, (chunks, labels)) items AudioChunkLoader yields."""
    for i, item in enumerate(batches):
        wid, payload, extra = i, item, None
        if isinstance(item, tuple) and len(item) == 2 \
                and np.ndim(item[0]) == 0:
            wid, payload = item
        if isinstance(payload, tuple):
            chunks = payload[0]
            extra = payload[1] if len(payload) > 1 else None
        else:
            chunks = payload
        yield wid, chunks, extra


class ExecutionPlan:
    """Base: one batch via `__call__`, a stream via `run` (plans override
    `run` to pipeline across batches)."""
    name = ""

    def __init__(self, graph: PipelineGraph, rules=NULL_RULES,
                 pad_multiple=1):
        self.graph = graph
        self.rules = rules
        self.pad_multiple = max(1, int(pad_multiple))

    def __call__(self, audio) -> BatchResult:
        raise NotImplementedError

    def run(self, batches):
        for wid, chunks, extra in _iter_batches(batches):
            res = self(jnp.asarray(chunks))
            yield replace(res, wid=wid, labels=extra)


class FusedPlan(ExecutionPlan):
    name = "fused"

    def __call__(self, audio) -> BatchResult:
        x = jnp.asarray(audio)
        out = _jitted("fused", self.graph, self.rules)(x)
        keep = np.asarray(out.keep)
        cleaned = np.asarray(out.wave5)[keep]
        return BatchResult(cleaned=cleaned, det=out, n_kept=int(keep.sum()),
                           src_bytes=int(x.nbytes))


class TwoPhasePlan(ExecutionPlan):
    name = "two_phase"

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1):
        super().__init__(graph, rules, pad_multiple)
        if not graph.has_removal_point:
            raise GraphValidationError(
                f"plan '{self.name}' needs a 'removal_point' stage in the "
                f"graph (stages: {graph.names}); use the fused plan for "
                f"graphs without early exit")

    def detect(self, audio) -> PipelineOutput:
        return _jitted("detect", self.graph, self.rules)(jnp.asarray(audio))

    def _finish(self, det: PipelineOutput, wid=None, extra=None,
                src_bytes=0):
        """Host-side master bookkeeping: read the mask, compact survivors
        to a padded batch (pad_multiple quantizes phase-B shapes so the
        tail jit rarely retraces), run the tail."""
        wave = np.asarray(det.wave5)
        keep = np.asarray(det.keep)
        batch, n_real = SCHED.survivor_batch(wave, keep, self.pad_multiple)
        if batch is None:
            cleaned = np.zeros((0, wave.shape[1]), np.float32)
        else:
            tail = _jitted("tail", self.graph, self.rules)
            cleaned = np.asarray(tail(jnp.asarray(batch)))[:n_real]
        return BatchResult(cleaned=cleaned, det=det, n_kept=n_real,
                           wid=wid, labels=extra, src_bytes=src_bytes)

    def __call__(self, audio) -> BatchResult:
        x = jnp.asarray(audio)
        return self._finish(self.detect(x), src_bytes=int(x.nbytes))


class StreamingPlan(TwoPhasePlan):
    """Two-phase with one batch of dispatch-ahead: detection of batch k+1
    is already in the device queue while the host does batch k's mask
    readback, compaction, and tail dispatch."""
    name = "streaming"

    def run(self, batches):
        pending = None
        for wid, chunks, extra in _iter_batches(batches):
            x = jnp.asarray(chunks)
            det = self.detect(x)                      # async dispatch
            if pending is not None:
                yield self._finish(*pending)
            pending = (det, wid, extra, int(x.nbytes))
        if pending is not None:
            yield self._finish(*pending)


PLANS = {p.name: p for p in (FusedPlan, TwoPhasePlan, StreamingPlan)}


class Preprocessor:
    """The single facade every entry point uses.

        pre = Preprocessor(SERF_AUDIO, rules, plan="streaming",
                           pad_multiple=len(jax.devices()))
        for res in pre.run(loader):        # loader: AudioChunkLoader items
            use(res.cleaned, res.det.stats, res.n_kept)

    `plan` is a name from `PLANS` or an ExecutionPlan subclass; `stages`
    overrides the config-declared stage list for ablations.
    """

    def __init__(self, cfg, rules=NULL_RULES, plan="two_phase",
                 pad_multiple=1, stages=None, source_channels=2):
        self.cfg = cfg
        self.rules = rules
        self.graph = PipelineGraph(cfg, stages, source_channels)
        plan_cls = PLANS[plan] if isinstance(plan, str) else plan
        self.plan = plan_cls(self.graph, rules, pad_multiple)

    def __call__(self, audio) -> BatchResult:
        """One batch of (B, C, S_long_src) long chunks -> BatchResult."""
        return self.plan(audio)

    def run(self, batches):
        """Iterate BatchResults over a batch stream / AudioChunkLoader."""
        return self.plan.run(batches)

    def detect(self, audio) -> PipelineOutput:
        """The phase-A stages only (shared compile cache; plan-independent).
        For a graph without a removal point this is the whole chain — see
        PipelineGraph.detection."""
        return _jitted("detect", self.graph, self.rules)(jnp.asarray(audio))

    def phase_fn(self, kind):
        """Un-jitted phase callable ('fused' | 'detect' | 'mmse'/'tail')
        for jax.jit(...).lower-style analysis (see launch/dryrun.py)."""
        return _phase_fn(kind, self.graph, self.rules)
