"""Execution plans: HOW a validated `PipelineGraph` runs on a batch stream.

The graph fixes WHAT computes (stage order, removal points); a plan picks
the execution strategy. Six plans, and when to pick each:

  * `FusedPlan`     — one jit straight through; removed chunks are masked
                      but still computed (the paper's no-early-exit
                      baseline). Pick for graphs without a removal point,
                      for correctness references, or when survivor rates
                      are so high that early exit buys nothing.
  * `TwoPhasePlan`  — detection jit -> host reads the keep mask (the
                      paper's master bookkeeping) -> survivors compacted /
                      re-batched -> tail jit on the survivor batch only.
                      The paper's headline economy: MMSE cost scales with
                      surviving audio. Pick as the single-stream default.
  * `StreamingPlan` — two-phase with dispatch-ahead over a loader: phase-A
                      detection of batch k+1 is enqueued on the device
                      before phase B of batch k, so host-side mask readback
                      + compaction overlap device work. Now a depth-1
                      `AsyncPlan` with the historical linear padding — kept
                      as the conservative dispatch-ahead baseline.
  * `AsyncPlan`     — the deep pipeline: a bounded window of `depth`
                      detection batches in flight (keep masks prefetched
                      with `copy_to_host_async` the moment each detect is
                      enqueued), device-resident survivor compaction (the
                      tail jit gathers survivors out of the still-on-device
                      batch; only the B-bool mask and the cleaned survivors
                      ever cross the host boundary), power-of-two survivor
                      buckets (O(log B) tail compiles instead of one per
                      count), optional buffer donation, and double-buffered
                      cleaned readback. Per-batch `BatchResult.timings`
                      record dispatch/readback/compact/tail/emit plus the
                      in-flight depth and transferred bytes. Pick for long
                      single-host streams; `depth` 2-4 is enough to hide
                      mask readback on one device — go deeper only when
                      emission jitter (a slow consumer) must also be
                      absorbed. Emission order is ALWAYS input order.
  * `ShardedPlan`   — the multi-shard execution backbone: per-shard
                      `ShardedLoader`s pull leased work ids from ONE shared
                      `WorkQueue` (at-least-once redelivery on lease expiry
                      replaces the paper's crash-tracking master), and
                      between detection and MMSE a `Rebalancer` re-assigns
                      survivors across shards (the paper's Figs 14-16 even-
                      load claim, kept true under skewed noise regimes).
                      Completion gates emission, so output stays exactly-
                      once on top of at-least-once delivery; a worker crash
                      mid-stream resumes from queue state with no lost or
                      duplicated chunks. Pick for multi-host / multi-worker
                      runs, or whenever fault tolerance matters.
  * `CachedPlan`    — content-addressed persistence around ANY inner plan
                      (including the sharded one): the `repro.store`
                      ChunkStore is consulted before dispatch, only misses
                      run through the inner plan, cached survivors merge
                      back in stream order, fresh results are written after.
                      With a `RunJournal` a killed `--store`d run relaunched
                      with `--resume` emits each chunk exactly once —
                      PR 2's worker-crash guarantee extended across PROCESS
                      restarts. Pick for rolling archives where runs overlap
                      yesterday's data (re-runs become lookups), for config
                      re-runs, and for any stream that must survive kills.
                      Without a store it degrades to a transparent
                      pass-through of its inner plan.

All plans sit behind the `Preprocessor` facade, and all jitted phases live
in one keyed LRU `CompileCache`. Keys are *value* fingerprints — config,
stage list, `ShardingRules.fingerprint` (mesh shape + rule table + device
ids), kernel backend mode — never object ids, so logically-equal rules
objects share compiles and the cache cannot alias after GC reuses an id
(the old `_JIT_CACHE`/`id(rules)` bug). `ShardedPlan` accepts per-shard
rules (`distributed.sharding.pool_rules`): same-mesh shards share one
compile, per-host meshes key separately by device ids.
"""
from __future__ import annotations

import collections
import operator
import os
import time
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as SCHED
from repro.core.graph import (GraphValidationError, PipelineGraph,
                              PipelineOutput)
from repro.data.loader import ShardedLoader, make_shard_pool
from repro.data.queue import WorkQueue
from repro.distributed.sharding import NULL_RULES
from repro.kernels import backend
from repro.store import ChunkStore, RunJournal, content_key


class CompileCache:
    """Keyed LRU for jitted phase functions (capped — the old global grew
    without bound). Tail compiles key per padded survivor size, so the
    cap bounds COMPILE memory too: hot entries (the every-batch detect,
    pow2's O(log B) buckets) stay resident by recency, while a stream
    that insists on linear padding over more distinct survivor counts
    than the cap re-pays those compiles — the pathology pow2 bucketing
    exists to remove, kept bounded rather than hidden."""

    def __init__(self, maxsize=256):
        self.maxsize = maxsize
        self._d = collections.OrderedDict()

    def get(self, key, build):
        if key in self._d:
            self._d.move_to_end(key)
            return self._d[key]
        val = build()
        self._d[key] = val
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)
        return val

    def __len__(self):
        return len(self._d)

    def __contains__(self, key):
        return key in self._d

    def keys(self):
        return list(self._d)

    def clear(self):
        self._d.clear()


JIT_CACHE = CompileCache(maxsize=256)


def _cache_key(kind, graph: PipelineGraph, rules):
    return (kind, graph.fingerprint, rules.fingerprint, backend.get_mode())


def _phase_fn(kind, graph: PipelineGraph, rules):
    """Plain (un-jitted) callable for one phase — what dry-run lowering and
    the jit cache both consume."""
    if kind == "fused":
        return lambda a: graph.fused(a, rules)
    if kind == "detect":
        return lambda a: graph.detection(a, rules)
    if kind in ("tail", "mmse"):
        return lambda w: graph.tail(w, rules)
    if kind == "tail_idx":
        return lambda w, i: graph.tail_indexed(w, i, rules)
    raise KeyError(f"unknown phase {kind!r}")


def _jitted(kind, graph, rules, donate=(), shape=None):
    """Jitted phase from the shared cache. `donate` (a donate_argnums
    tuple) is part of the key: a donating and a non-donating caller of the
    same phase must not alias one compile. `shape` (the padded survivor
    count for the tail gather) is keyed too, so one cache entry == one
    XLA compile and the cache length is an honest retrace ledger —
    without it, shape retraces would hide inside a single jit wrapper,
    uncountable and uncapped by the LRU."""
    donate = tuple(donate)
    return JIT_CACHE.get(_cache_key(kind, graph, rules) + (donate, shape),
                         lambda: jax.jit(_phase_fn(kind, graph, rules),
                                         donate_argnums=donate))


@dataclass
class BatchResult:
    """One batch through a plan: compacted survivors + the detection record."""
    cleaned: np.ndarray             # (n_kept, S_final) denoised survivors
    det: PipelineOutput             # detection-phase record (masks, stats)
    n_kept: int
    wid: object = None              # loader work id (when run over a loader)
    labels: object = field(default=None, repr=False)   # loader passthrough
    src_bytes: int = 0              # measured input bytes (throughput acct)
    timings: dict = field(default=None, repr=False)
    # per-batch pipeline instrumentation (two-phase-family plans):
    #   dispatch_s  detect enqueue time (async — not detect compute time)
    #   readback_s  blocking part of the keep-mask readback
    #   compact_s   host index bookkeeping (the whole "master" role now)
    #   tail_s      tail enqueue + async cleaned-copy start
    #   emit_s      blocking part of the cleaned readback at emission
    #   in_flight   detect batches in the window when this one dispatched
    #   d2h_bytes / h2d_bytes   host-boundary traffic this batch caused
    #   tail_rows / n_real      padded tail batch rows vs real survivors


class _StreamMeta:
    """Internal marker for ShardedPlan's plain-stream wrapper: carries the
    ORIGINAL stream wid + labels through the queue as the item's `extra`,
    unambiguously distinct from user labels that happen to be tuples."""
    __slots__ = ("wid", "labels")

    def __init__(self, wid, labels):
        self.wid = wid
        self.labels = labels


def _iter_batches(batches):
    """Normalise a batch stream: accepts arrays, (chunks, labels) pairs, or
    the (wid, (chunks, labels)) items AudioChunkLoader yields."""
    for i, item in enumerate(batches):
        wid, payload, extra = i, item, None
        if isinstance(item, tuple) and len(item) == 2 \
                and np.ndim(item[0]) == 0:
            wid, payload = item
        if isinstance(payload, tuple):
            chunks = payload[0]
            extra = payload[1] if len(payload) > 1 else None
        else:
            chunks = payload
        yield wid, chunks, extra


class ExecutionPlan:
    """Base: one batch via `__call__`, a stream via `run` (plans override
    `run` to pipeline across batches)."""
    name = ""

    def __init__(self, graph: PipelineGraph, rules=NULL_RULES,
                 pad_multiple=1):
        self.graph = graph
        self.rules = rules
        self.pad_multiple = max(1, int(pad_multiple))

    def __call__(self, audio) -> BatchResult:
        raise NotImplementedError

    def run(self, batches):
        for wid, chunks, extra in _iter_batches(batches):
            res = self(jnp.asarray(chunks))
            yield replace(res, wid=wid, labels=extra)


class FusedPlan(ExecutionPlan):
    name = "fused"

    def __call__(self, audio) -> BatchResult:
        x = jnp.asarray(audio)
        out = _jitted("fused", self.graph, self.rules)(x)
        keep = np.asarray(out.keep)
        cleaned = np.asarray(out.wave5)[keep]
        return BatchResult(cleaned=cleaned, det=out, n_kept=int(keep.sum()),
                           src_bytes=int(x.nbytes))


@dataclass
class _PendingTail:
    """A batch whose tail is dispatched but not yet read back: everything
    `_emit` needs, held while the device works and the cleaned rows stream
    host-ward via copy_to_host_async."""
    det: PipelineOutput
    out: object                     # device cleaned batch (None: 0 kept)
    n_real: int
    wid: object
    extra: object
    src_bytes: int
    timings: dict


class TwoPhasePlan(ExecutionPlan):
    name = "two_phase"

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1,
                 bucket="linear", donate=False):
        super().__init__(graph, rules, pad_multiple)
        if not graph.has_removal_point:
            raise GraphValidationError(
                f"plan '{self.name}' needs a 'removal_point' stage in the "
                f"graph (stages: {graph.names}); use the fused plan for "
                f"graphs without early exit")
        self.bucket = bucket
        SCHED.quantize_survivors(0, 1, 1, bucket)     # validate the mode
        if donate is None:                            # auto: off on CPU,
            donate = jax.default_backend() != "cpu"   # on where it pays
        self.donate = bool(donate)

    def detect(self, audio) -> PipelineOutput:
        return _jitted("detect", self.graph, self.rules)(jnp.asarray(audio))

    def _detect_donated(self, x) -> PipelineOutput:
        """Detect with the input buffer donated to the jit — only valid
        when the caller owns `x` (it made the device copy itself)."""
        donate = (0,) if self.donate else ()
        return _jitted("detect", self.graph, self.rules, donate)(x)

    def _start_tail(self, det: PipelineOutput, wid=None, extra=None,
                    src_bytes=0, timings=None) -> _PendingTail:
        """Master bookkeeping, device-resident: the host reads back ONLY
        the keep mask (B bools), builds a padded survivor-index vector
        (bucketed so the tail jit compiles O(log B) shape variants), and
        the tail jit gathers + compacts + denoises ON DEVICE — the full
        pre-denoise waveform never crosses the host boundary. With
        `donate` the wave5 buffer is donated to the tail gather, so the
        det record's wave5 must not be read after this call."""
        t0 = time.perf_counter()
        keep = np.asarray(det.keep)                   # the only readback
        t1 = time.perf_counter()
        idx, n_real = SCHED.survivor_indices(keep, self.pad_multiple,
                                             self.bucket)
        t2 = time.perf_counter()
        out, h2d = None, 0
        if n_real:
            donate = (0,) if self.donate else ()
            tail = _jitted("tail_idx", self.graph, self.rules, donate,
                           shape=len(idx))
            out = tail(det.wave5, jnp.asarray(idx))   # async dispatch
            if hasattr(out, "copy_to_host_async"):
                out.copy_to_host_async()              # stream back early
            h2d = idx.nbytes
        t3 = time.perf_counter()
        timings = dict(timings or {})
        timings.update(
            readback_s=t1 - t0, compact_s=t2 - t1, tail_s=t3 - t2,
            h2d_bytes=h2d, d2h_bytes=keep.nbytes,
            tail_rows=0 if idx is None else len(idx), n_real=n_real,
            # what the pre-device-compaction bookkeeping shipped host-ward
            # per batch (the full wave5) — off the aval, no transfer
            wave5_bytes=int(np.prod(det.wave5.shape))
            * det.wave5.dtype.itemsize)
        return _PendingTail(det, out, n_real, wid, extra, src_bytes,
                            timings)

    def _emit(self, pend: _PendingTail) -> BatchResult:
        """Block on (the remainder of) the cleaned readback and build the
        result. Padded rows are sliced off here — and they are zero rows
        from the fill gather, never repeats of real audio."""
        t0 = time.perf_counter()
        if pend.out is None:
            cleaned = np.zeros((0, pend.det.wave5.shape[-1]), np.float32)
        else:
            cleaned = np.asarray(pend.out)[:pend.n_real]
            pend.timings["d2h_bytes"] += pend.out.nbytes
        pend.timings["emit_s"] = time.perf_counter() - t0
        # the pre-device-compaction boundary for THIS batch: full wave5 +
        # mask down, the LINEAR-padded survivor batch up, the same padded
        # tail output down (the old path sliced [:n_real] only after the
        # full transfer) — its actual cost on this stream, not a model
        lin_rows = SCHED.quantize_survivors(
            pend.n_real, pend.det.keep.size, self.pad_multiple,
            "linear") if pend.n_real else 0
        row_bytes = cleaned.shape[-1] * cleaned.dtype.itemsize
        pend.timings["old_boundary_bytes"] = (
            pend.timings["wave5_bytes"] + pend.det.keep.size
            + 2 * lin_rows * row_bytes)
        return BatchResult(cleaned=cleaned, det=pend.det,
                           n_kept=pend.n_real, wid=pend.wid,
                           labels=pend.extra, src_bytes=pend.src_bytes,
                           timings=pend.timings)

    def _finish(self, det: PipelineOutput, wid=None, extra=None,
                src_bytes=0, timings=None):
        return self._emit(self._start_tail(det, wid, extra, src_bytes,
                                           timings))

    def __call__(self, audio) -> BatchResult:
        x = jnp.asarray(audio)
        return self._finish(self.detect(x), src_bytes=int(x.nbytes))


class AsyncPlan(TwoPhasePlan):
    """Depth-K asynchronous streaming executor: a bounded window of `depth`
    detection batches dispatched ahead, each keep mask prefetched to host
    the moment its detect is enqueued (double-buffered mask readback), the
    tail gathering survivors device-side, and one finished tail held back
    so its cleaned rows stream host-ward while the next batch computes
    (double-buffered emission). Defaults to power-of-two survivor buckets
    and, on non-CPU backends, donated detect/tail buffers. Emission is
    strictly input order; `last_timings` keeps the per-batch records of the
    most recent run()."""
    name = "async"

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1, depth=2,
                 bucket="pow2", donate=None, emit_buffer=1):
        super().__init__(graph, rules, pad_multiple, bucket=bucket,
                         donate=donate)
        self.depth = max(1, int(depth))
        # dispatched tails retained before emission: 1 double-buffers the
        # cleaned readback behind the next batch (+1 batch of emission
        # latency and one extra resident batch); 0 emits each result the
        # moment its tail is dispatched (the pre-PR streaming schedule)
        self.emit_buffer = max(0, int(emit_buffer))
        self.last_timings = []

    def run(self, batches):
        self.last_timings = []
        dets = collections.deque()       # detect window (<= depth)
        tails = collections.deque()      # dispatched tails (<= 2)

        def start_oldest_tail():
            tails.append(self._start_tail(*dets.popleft()))

        def emit_oldest():
            res = self._emit(tails.popleft())
            self.last_timings.append(res.timings)
            return res

        for wid, chunks, extra in _iter_batches(batches):
            t0 = time.perf_counter()
            owned = not isinstance(chunks, jax.Array)
            x = jnp.asarray(chunks)
            det = self._detect_donated(x) if owned and self.donate \
                else self.detect(x)                   # async dispatch
            if hasattr(det.keep, "copy_to_host_async"):
                det.keep.copy_to_host_async()         # prefetch the mask
            timings = {"dispatch_s": time.perf_counter() - t0,
                       "in_flight": len(dets) + 1}
            dets.append((det, wid, extra, int(x.nbytes), timings))
            if len(dets) > self.depth:
                start_oldest_tail()
            while len(tails) > self.emit_buffer:
                yield emit_oldest()
        while dets:
            start_oldest_tail()
            while len(tails) > self.emit_buffer:
                yield emit_oldest()
        while tails:
            yield emit_oldest()


class StreamingPlan(AsyncPlan):
    """Two-phase with one batch of dispatch-ahead: detection of batch k+1
    is already in the device queue while the host does batch k's mask
    readback, compaction, tail dispatch AND emission — the historical
    schedule, preserved exactly: depth 1, linear tail padding, no
    donation, no emission hold-back (`emit_buffer=0`, so each result is
    yielded the moment its tail is dispatched, one batch earlier than
    `async`'s double-buffered emission). `async` is this plan with the
    dials turned up."""
    name = "streaming"

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1, depth=1,
                 bucket="linear", donate=False, emit_buffer=0):
        super().__init__(graph, rules, pad_multiple, depth=depth,
                         bucket=bucket, donate=donate,
                         emit_buffer=emit_buffer)


class ShardedPlan(TwoPhasePlan):
    """Fault-tolerant multi-shard execution over a shared leased WorkQueue.

    The round loop (one round = every live shard pulls up to lease_items):

      pull    each live shard leases work ids from the SHARED queue and
              dispatches detection under its own rules/mesh; a scripted
              `CrashInjector` can kill a shard mid-pull, leaving its lease
              un-completed (the recovery paths are lease expiry and
              `fail_worker`, exactly the paper's crashed-slave re-send).
      shuffle the `Rebalancer` reads every keep mask back ONCE, packs
              survivors in (shard, item) order, and re-slices them near-
              evenly across the live shards — the plan, not the driver,
              owns the mask readback + re-shard decision.
      finish  per-shard tail (MMSE) jits run on the re-balanced survivor
              batches; cleaned rows are scattered back to their source work
              ids; `queue.complete` gates emission so each work id is
              emitted exactly once even when redelivery raced a straggler.

    `rules` may be a single ShardingRules (shared mesh) or one per shard
    (`distributed.sharding.pool_rules`); compiles land in the shared
    CompileCache keyed by each shard's value fingerprint.
    """
    name = "sharded"
    accepts_rules_pool = True

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1, shards=2,
                 lease_items=1, injector=None, monitor=None):
        self.shards = max(1, int(shards))
        if isinstance(rules, (list, tuple)):
            if len(rules) != self.shards:
                raise ValueError(
                    f"got {len(rules)} per-shard rules for {self.shards} "
                    f"shards")
            pool = tuple(rules)
        else:
            pool = (rules,) * self.shards
        super().__init__(graph, pool[0], pad_multiple)
        self.rules_pool = pool
        self.lease_items = lease_items
        self.injector = injector
        self.monitor = monitor
        self.rebalancer = SCHED.Rebalancer(self.shards, pad_multiple)
        self.redeliveries = 0           # mirrored off the queue after run()
        self.last_assignment = None     # last round's ShardAssignment
        self._release = None            # stream-item drop hook (see run())

    # -- per-shard phase dispatch (shared CompileCache, per-shard rules) ----
    def _detect_on(self, shard, audio):
        return _jitted("detect", self.graph, self.rules_pool[shard])(audio)

    def _tail_on(self, shard, batch):
        return _jitted("tail", self.graph, self.rules_pool[shard])(batch)

    # -- single batch: row-split across shards, rebalance, reassemble -------
    def __call__(self, audio) -> BatchResult:
        x = np.asarray(audio, np.float32)
        parts = [(j, p) for j, p in enumerate(np.array_split(x, self.shards))
                 if len(p)]
        dets = [(j, self._detect_on(j, jnp.asarray(p))) for j, p in parts]
        det = _merge_outputs([d for _, d in dets])
        waves_keeps = [(np.asarray(d.wave5), np.asarray(d.keep))
                       for _, d in dets]
        cleaned, asg = self._rebalanced_tail(
            waves_keeps, [k for _, k in waves_keeps],
            live=[j for j, _ in dets])
        self.last_assignment = asg
        return BatchResult(cleaned=cleaned, det=det,
                           n_kept=int(np.asarray(det.keep).sum()),
                           src_bytes=int(x.nbytes))

    def _rebalanced_tail(self, item_waves_keeps, shard_keeps, live):
        """Rebalanced phase B. item_waves_keeps: [(wave5, keep)] per
        detected item in packed order; shard_keeps: one concatenated keep
        mask per LIVE shard (same packed order) — the assignment is made
        per shard, survivors are packed per item. Returns (cleaned rows in
        packed survivor order, ShardAssignment)."""
        asg = self.rebalancer.assign(shard_keeps, out_shards=len(live))
        surv = [w[k] for w, k in item_waves_keeps if k.any()]
        if not surv:
            width = (item_waves_keeps[0][0].shape[1]
                     if item_waves_keeps else 0)
            return np.zeros((0, width), np.float32), asg
        packed = np.concatenate(surv)
        cleaned = np.empty_like(packed)
        for slot, batch, n_real in self.rebalancer.split(packed, asg):
            lo = int(asg.bounds[slot])
            out = self._tail_on(live[slot], jnp.asarray(batch))
            cleaned[lo:lo + n_real] = np.asarray(out)[:n_real]
        return cleaned, asg

    # -- streams ------------------------------------------------------------
    def run(self, batches):
        """Accepts a ShardedLoader pool (the multi-host path) or any plain
        batch stream, which is wrapped behind an internal WorkQueue so
        single-stream callers get the same leased, rebalanced execution.
        Sized streams (lists, loaders with __len__) are drawn lazily and
        each item is dropped once its work id completes, so memory stays
        O(in-flight); only unsized generators are materialised up front."""
        if isinstance(batches, (list, tuple)) and batches and \
                all(isinstance(b, ShardedLoader) for b in batches):
            yield from self.run_pool(list(batches))
            return
        n = operator.length_hint(batches, -1)
        it = _iter_batches(batches)
        if n < 0:
            drained = list(it)
            n, it = len(drained), iter(drained)
        store, cursor = {}, [0]

        def make(i):
            while cursor[0] <= i:
                wid, chunks, extra = next(it)
                store[cursor[0]] = (chunks, _StreamMeta(wid, extra))
                cursor[0] += 1
            return store[i]

        pool = make_shard_pool(make, n, self.shards,
                               lease_items=self.lease_items)
        self._release = store.pop
        try:
            yield from self.run_pool(pool)
        finally:
            self._release = None

    def run_pool(self, pool):
        # shard-ascending order keeps the packed survivor order consistent
        # with the per-shard masks handed to the Rebalancer
        pool = sorted(pool, key=lambda ld: ld.shard)
        queue = pool[0].queue
        assert all(ld.queue is queue for ld in pool), \
            "a shard pool must share one WorkQueue"
        bad = sorted({ld.shard for ld in pool} - set(range(self.shards)))
        if bad:
            raise ValueError(
                f"pool shard ids {bad} out of range for a "
                f"{self.shards}-shard plan")
        stalls = 0
        while not queue.finished:
            round_work = []          # (shard, wid, det, extra, nbytes)
            for ld in pool:
                if not self._alive(ld.shard):
                    continue
                if self.monitor is not None:
                    self.monitor.beat(ld.worker)
                for wid, item in ld.pull():
                    if self.injector is not None and \
                            not self.injector.on_pull(ld.shard):
                        break        # died holding this lease
                    chunks, extra = item if isinstance(item, tuple) \
                        else (item, None)
                    x = jnp.asarray(chunks)
                    det = self._detect_on(ld.shard, x)   # async dispatch
                    round_work.append((ld.shard, wid, det, extra,
                                       int(x.nbytes)))
            if round_work:
                stalls = 0
                yield from self._finish_round(queue, round_work)
                continue
            if self._reclaim(queue, pool) or queue.finished:
                continue
            deadline = queue.next_deadline()
            stalls += 1
            if deadline is not None and stalls <= 8 and \
                    any(self._alive(ld.shard) for ld in pool):
                # a lease nothing declared dead is still ticking (a worker
                # outside this pool, or an undetected death): wait out the
                # deadline so the next pull reaps and redelivers it. Only
                # wall clocks advance while we sleep; injected clocks
                # (SettableClock etc.) re-poll and hit the stall cap fast.
                if queue.clock in (time.monotonic, time.time):
                    time.sleep(max(0.0, min(deadline - queue.clock(),
                                            queue.lease_timeout_s)) + 1e-3)
                continue
            raise RuntimeError(
                "sharded plan stalled: work is leased but no live shard "
                f"can make progress (progress {queue.progress()})")
        self.redeliveries = queue.redeliveries

    def _alive(self, shard):
        return self.injector is None or self.injector.alive(shard)

    def _reclaim(self, queue, pool):
        """All pending work is held by dead shards: return their leases
        (the heartbeat/injector 'said dead' fast path; a slower deployment
        without either still recovers via lease-deadline expiry on the next
        pull). True if any work came back."""
        dead_workers = {ld.worker for ld in pool if not self._alive(ld.shard)}
        if self.monitor is not None:
            dead_workers |= set(self.monitor.dead())
        got = 0
        for w in sorted(dead_workers):
            got += len(queue.fail_worker(w))
        return got > 0

    def _finish_round(self, queue, round_work):
        """Rebalanced phase B for one round, then exactly-once emission in
        work-id completion order."""
        live = sorted({s for s, *_ in round_work})
        item_wk = [(np.asarray(d.wave5), np.asarray(d.keep))
                   for _, _, d, _, _ in round_work]
        # packed per (shard, item) order == round_work order (pool order),
        # so the per-shard masks are contiguous slices of it
        shard_keeps = [np.concatenate(
            [k for (s, *_), (_, k) in zip(round_work, item_wk) if s == s2])
            for s2 in live]
        cleaned_all, asg = self._rebalanced_tail(item_wk, shard_keeps, live)
        self.last_assignment = asg
        offs = np.concatenate(
            [[0], np.cumsum([k.sum() for _, k in item_wk])]).astype(int)
        for i, (shard, wid, det, extra, nbytes) in enumerate(round_work):
            if not queue.complete([wid]):
                continue             # redelivery raced a straggler: emitted once
            if self._release is not None:
                self._release(wid, None)     # drop the buffered stream item
            orig_wid, labels = (extra.wid, extra.labels) \
                if isinstance(extra, _StreamMeta) else (wid, extra)
            yield BatchResult(
                cleaned=cleaned_all[offs[i]:offs[i + 1]], det=det,
                n_kept=int(offs[i + 1] - offs[i]), wid=orig_wid,
                labels=labels, src_bytes=nbytes)


class _SizedIter:
    """One-shot iterable with a length hint: lets CachedPlan hand its miss
    stream to a sharded inner lazily (ShardedPlan sizes its queue from the
    hint and draws items as leases demand) without pinning every raw batch
    in a list."""

    def __init__(self, it, n):
        self._it, self._n = iter(it), n

    def __iter__(self):
        return self._it

    def __length_hint__(self):
        return self._n


class CachedPlan(ExecutionPlan):
    """Content-addressed caching + resumability around any inner plan.

    Execution per stream: every batch is keyed by content hash of (raw
    chunk bytes, graph fingerprint, kernel backend mode) and looked up in
    the `ChunkStore` BEFORE any dispatch; only misses flow through the
    inner plan (one sub-stream, so a sharded inner keeps its leased-queue
    batching); cached survivors merge back in stream order; fresh results
    are written to the store as the inner plan emits them. The cache key
    deliberately omits sharding rules — sharding moves work, never values
    (plan equivalence is bit-exact on masks), so runs under different
    shard counts share entries.

    Resumability: with a `RunJournal`, the plan snapshots its emission
    queue after every yielded result; constructing with `resume=True`
    restores that snapshot and skips exactly the work the dead process
    already emitted — each chunk id is emitted once across the kill.
    Results the dead run computed but never emitted come back as store
    hits, so the resumed run pays recomputation only for truly in-flight
    work.

    `store=None` (the default) degrades to a transparent pass-through, so
    'cached' is always safe to select. Cached `det` records carry masks and
    stats but a zero-filled `wave5` — the pre-denoise waveform is an
    intermediate no downstream consumer reads, and persisting it would
    dwarf the survivors it exists to produce.
    """
    name = "cached"
    accepts_rules_pool = True

    def __init__(self, graph, rules=NULL_RULES, pad_multiple=1,
                 inner="two_phase", store=None, journal=None, resume=False,
                 **inner_kwargs):
        inner_cls = PLANS[inner] if isinstance(inner, str) else inner
        if isinstance(rules, (list, tuple)) and not (
                isinstance(inner_cls, type)
                and getattr(inner_cls, "accepts_rules_pool", False)):
            raise ValueError(
                "a per-shard rules list is only valid with the sharded "
                f"plan as inner, not {getattr(inner_cls, 'name', inner_cls)!r}")
        facade_rules = rules[0] \
            if isinstance(rules, (list, tuple)) and rules else rules
        super().__init__(graph, facade_rules, pad_multiple)
        self.inner = inner_cls(graph, rules, pad_multiple, **inner_kwargs)
        if isinstance(store, (str, os.PathLike)):
            # a cache should self-heal: a bit-rotted entry is evicted and
            # recomputed, not fatal on every future run at the same batch.
            # Pass a ChunkStore instance for archival strictness.
            store = ChunkStore(store, evict_corrupt=True)
        self.store = store
        if journal is True:
            if store is None:
                raise ValueError(
                    "journal=True derives the journal path from the store "
                    "directory — pass a store, or an explicit journal")
            journal = os.path.join(store.directory, "journal")
        if isinstance(journal, (str, os.PathLike)):
            journal = RunJournal(journal)
        self.journal = journal
        self.resume = bool(resume)
        if self.resume and self.journal is None:
            raise ValueError("resume=True needs a journal")

    @property
    def stats(self):
        """The store's hit/miss/bytes accounting (None when uncached)."""
        return self.store.stats if self.store is not None else None

    # -- BatchResult <-> store entry ----------------------------------------
    def _key(self, chunks_np):
        return content_key(chunks_np, self.graph.fingerprint,
                           backend.get_mode())

    def _entry(self, res: BatchResult):
        det = res.det
        arrays = {
            "cleaned": np.asarray(res.cleaned, np.float32),
            "keep": np.asarray(det.keep), "rain": np.asarray(det.rain),
            "silence": np.asarray(det.silence),
            "cicada15": np.asarray(det.cicada15),
        }
        stats = {k: (int(v) if k == "n_chunks5" else float(v))
                 for k, v in det.stats.items()}
        meta = {"stats": stats, "n_kept": int(res.n_kept),
                "src_bytes": int(res.src_bytes),
                # shape comes off the aval — no device->host transfer of
                # the full wave5 (which a donating tail may have consumed)
                "wave_width": int(det.wave5.shape[-1])}
        return arrays, meta

    def _result(self, arrays, meta, wid, extra) -> BatchResult:
        keep = arrays["keep"]
        wave5 = np.zeros((keep.shape[0], int(meta["wave_width"])),
                         np.float32)
        det = PipelineOutput(wave5=wave5, keep=keep, rain=arrays["rain"],
                             silence=arrays["silence"],
                             cicada15=arrays["cicada15"],
                             stats=dict(meta["stats"]))
        return BatchResult(cleaned=arrays["cleaned"], det=det,
                           n_kept=int(meta["n_kept"]), wid=wid,
                           labels=extra, src_bytes=int(meta["src_bytes"]))

    # -- single batch (the warm-cache serving path) -------------------------
    def __call__(self, audio) -> BatchResult:
        if self.store is None:
            return self.inner(audio)
        x = np.asarray(audio, np.float32)
        key = self._key(x)
        hit = self.store.get(key, src_bytes=x.nbytes)
        if hit is not None:
            return self._result(*hit, wid=None, extra=None)
        res = self.inner(x)
        self.store.put(key, *self._entry(res))
        return res

    # -- streams ------------------------------------------------------------
    def run(self, batches):
        """Emits BatchResults in STREAM order (cached survivors merged back
        where they belong). Emission follows ShardedPlan's completion-gated
        convention: the queue completes and the journal records IMMEDIATELY
        BEFORE each yield, so at the plan boundary every chunk is emitted
        exactly once across a kill + resume — an abandoned generator resumes
        from precisely the next unemitted item. (The chunk handed over at
        the instant of a hard process kill is the consumer's to recover, as
        with any exactly-once hand-off.)

        Memory: like ShardedPlan, sized streams (lists, loaders with
        __len__) are drawn lazily — hits in the stream-order prefix are
        emitted DURING the probe, raw chunks are retained only for misses,
        and each miss's bytes are released as the inner plan draws them —
        while unsized generators are materialised up front to learn the
        stream length (the journal and resume guard need it)."""
        if isinstance(batches, (list, tuple)) and batches and \
                all(isinstance(b, ShardedLoader) for b in batches):
            raise ValueError(
                "CachedPlan must see chunk content before dispatch — feed "
                "it the plain batch stream; a sharded inner builds its "
                "leased shard pool internally from the misses")

        n = operator.length_hint(batches, -1)
        it = _iter_batches(batches)
        if n < 0:
            drained = list(it)
            n, it = len(drained), iter(drained)

        done, want_key0 = set(), None
        if self.journal is not None and self.resume:
            rec_meta = self.journal.load()
            if rec_meta is not None:
                rec_n = int(rec_meta["queue"]["n_items"])
                if rec_n != n:
                    raise ValueError(
                        f"journal records a {rec_n}-item stream; the "
                        f"resume stream has {n} items — refusing to mix "
                        f"runs")
                done = set(rec_meta["queue"]["done"])
                want_key0 = rec_meta.get("stream_key0")
        queue = WorkQueue.from_state({"n_items": n, "done": sorted(done)})
        order = [p for p in range(n) if p not in done]
        emit_idx = 0
        key0 = None                       # stream identity: first batch key
        results: dict[int, BatchResult] = {}
        misses = []                       # [pos, key, wid, chunks, extra]

        def emit_ready():
            """Completion-gated hand-off of the ready stream-order prefix."""
            nonlocal emit_idx
            while emit_idx < len(order) and order[emit_idx] in results:
                pos = order[emit_idx]
                emit_idx += 1
                queue.complete([pos])
                if self.journal is not None:
                    self.journal.record(queue, meta={"stream_key0": key0})
                yield results.pop(pos)

        for pos, (wid, chunks, extra) in enumerate(it):
            probe = pos not in done and self.store is not None
            if probe or (pos == 0 and self.journal is not None):
                x = np.asarray(chunks, np.float32)
                key = self._key(x)
                if pos == 0:
                    key0 = key
                    if want_key0 is not None and want_key0 != key0:
                        raise ValueError(
                            "journal records a stream with different "
                            "content (first-batch key mismatch) — "
                            "refusing to mix runs")
            if pos in done:
                continue                  # the killed run already emitted it
            if not probe:                 # uncached: everything is a miss
                misses.append([pos, None, wid, chunks, extra])
                continue
            hit = self.store.get(key, src_bytes=x.nbytes)
            if hit is not None:
                results[pos] = self._result(*hit, wid=wid, extra=extra)
                yield from emit_ready()   # warm prefixes flow immediately
            else:
                misses.append([pos, key, wid, x, extra])

        if misses:
            def miss_stream():
                for i, m in enumerate(misses):
                    item = (i, (m[3], m[4]))
                    m[3] = None           # the inner plan owns the bytes now
                    yield item

            for res in self.inner.run(_SizedIter(miss_stream(),
                                                 len(misses))):
                pos, key, wid, _, extra = misses[res.wid]
                if self.store is not None:
                    self.store.put(key, *self._entry(res))
                results[pos] = replace(res, wid=wid, labels=extra)
                yield from emit_ready()
        yield from emit_ready()
        assert emit_idx == len(order), "inner plan dropped work ids"


def _merge_outputs(outs):
    """Concatenate per-shard PipelineOutputs (row order preserved) with
    chunk-count-weighted stats — the batch looks as if one shard detected
    it."""
    if len(outs) == 1:
        return outs[0]
    cat = lambda f: np.concatenate([np.asarray(getattr(o, f)) for o in outs])
    ws = np.array([float(o.stats["n_chunks5"]) for o in outs])
    stats = {"n_chunks5": int(ws.sum())}
    for k in outs[0].stats:
        if k != "n_chunks5":
            vals = np.array([float(o.stats[k]) for o in outs])
            stats[k] = float((vals * ws).sum() / ws.sum())
    return PipelineOutput(wave5=cat("wave5"), keep=cat("keep"),
                          rain=cat("rain"), silence=cat("silence"),
                          cicada15=cat("cicada15"), stats=stats)


PLANS = {p.name: p for p in (FusedPlan, TwoPhasePlan, StreamingPlan,
                             AsyncPlan, ShardedPlan, CachedPlan)}


class Preprocessor:
    """The single facade every entry point uses.

        pre = Preprocessor(SERF_AUDIO, rules, plan="streaming",
                           pad_multiple=len(jax.devices()))
        for res in pre.run(loader):        # loader: AudioChunkLoader items
            use(res.cleaned, res.det.stats, res.n_kept)

    `plan` is a name from `PLANS` or an ExecutionPlan subclass; `stages`
    overrides the config-declared stage list for ablations. Extra keyword
    arguments are forwarded to the plan (e.g. `shards=4`, `injector=...`
    for the sharded plan).
    """

    def __init__(self, cfg, rules=NULL_RULES, plan="two_phase",
                 pad_multiple=1, stages=None, source_channels=2,
                 **plan_kwargs):
        self.cfg = cfg
        # facade-level detect()/phase_fn() use one rules object even when
        # the plan gets a per-shard list (sharded multi-host pools)
        self.rules = rules[0] if isinstance(rules, (list, tuple)) and rules \
            else rules
        self.graph = PipelineGraph(cfg, stages, source_channels)
        plan_cls = PLANS[plan] if isinstance(plan, str) else plan
        if isinstance(rules, (list, tuple)) and not (
                isinstance(plan_cls, type)
                and getattr(plan_cls, "accepts_rules_pool", False)):
            raise ValueError(
                "a per-shard rules list is only valid with the sharded "
                "plan (or a cached wrapper around it), not "
                f"{getattr(plan_cls, 'name', plan_cls)!r}")
        self.plan = plan_cls(self.graph, rules, pad_multiple, **plan_kwargs)

    def __call__(self, audio) -> BatchResult:
        """One batch of (B, C, S_long_src) long chunks -> BatchResult."""
        return self.plan(audio)

    def run(self, batches):
        """Iterate BatchResults over a batch stream / AudioChunkLoader."""
        return self.plan.run(batches)

    def detect(self, audio) -> PipelineOutput:
        """The phase-A stages only (shared compile cache; plan-independent).
        For a graph without a removal point this is the whole chain — see
        PipelineGraph.detection."""
        return _jitted("detect", self.graph, self.rules)(jnp.asarray(audio))

    def phase_fn(self, kind):
        """Un-jitted phase callable ('fused' | 'detect' | 'mmse'/'tail')
        for jax.jit(...).lower-style analysis (see launch/dryrun.py)."""
        return _phase_fn(kind, self.graph, self.rules)
