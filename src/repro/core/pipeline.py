"""Compatibility re-exports for the unified preprocessing pipeline.

The pipeline is a composable stage graph (the paper's one profiled order,
expressed as config data):

  * `repro.core.graph`  — `Stage` protocol + `STAGES` registry +
    `PipelineGraph` (build-time shape validation, `removal_point` markers).
  * `repro.core.plans`  — `FusedPlan` / `TwoPhasePlan` / `StreamingPlan` /
    `AsyncPlan` / `ShardedPlan` / `CachedPlan` behind the `Preprocessor`
    facade, with a keyed LRU compile cache.

The paper's stage order lives on `AudioPipelineConfig.stages`:

  to_mono -> compress (fused downsample+HPF) -> split_detect(15 s) ->
  stft (once) -> detect_rain -> cicada_bandstop -> istft ->
  split_final(5 s) -> detect_silence -> removal_point -> mmse

Use:

    from repro.core.plans import Preprocessor
    pre = Preprocessor(cfg, rules, plan="two_phase")
    res = pre(audio_src)                  # one batch
    for res in pre.run(loader): ...       # a stream

The seed-era shims (`detection_phase`, `mmse_phase`, `preprocess_fused`,
`preprocess_two_phase`) have been REMOVED now that nothing imports them;
only the graph re-exports below remain for older call sites.
"""
from __future__ import annotations

from repro.core.graph import PipelineGraph, PipelineOutput  # noqa: F401
