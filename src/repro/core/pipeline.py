"""The unified preprocessing pipeline (the paper's core contribution).

Stage order (derived in the paper from per-stage cost + accuracy coupling):

  split(60 s) -> mono -> [fused downsample+HPF] -> split(15 s) -> STFT(once)
  -> rain detect (removes) -> cicada detect+bandstop -> split(5 s)
  -> silence detect (removes) -> MMSE-STSA (dominant cost, survivors only)

Two execution modes:
  * fused      — one jit; removed chunks are masked but still computed
                 (the "no early exit" baseline).
  * two_phase  — detection jit, host reads the keep mask (the paper's master
                 bookkeeping), survivors are compacted/re-batched, MMSE jit
                 runs on the survivor batch only. This realises the paper's
                 headline saving: MMSE cost scales with surviving audio.

Distribution: chunk batch dim is sharded over every mesh axis (pure data
parallelism — the paper's file parallelisation). No collectives are needed
inside the pipeline except the compaction argsort.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stages as S
from repro.core import detect as D
from repro.core import scheduler as SCHED
from repro.distributed.sharding import NULL_RULES


@jax.tree_util.register_dataclass
@dataclass
class PipelineOutput:
    wave5: jnp.ndarray          # (N5, S5) processed 5 s chunks
    keep: jnp.ndarray           # (N5,) bool — survives to output
    rain: jnp.ndarray           # (N5,) bool
    silence: jnp.ndarray        # (N5,) bool
    cicada15: jnp.ndarray       # (N15,) bool — per detect chunk
    stats: dict


def detection_phase(cfg, audio_src, rules=NULL_RULES):
    """audio_src: (B, C, S_long_src) @44.1 kHz stereo long chunks.

    Returns PipelineOutput with wave5 NOT yet MMSE-filtered."""
    B = audio_src.shape[0]
    n15 = int(cfg.long_split_s / cfg.detect_split_s)
    n5 = int(cfg.detect_split_s / cfg.final_split_s)

    x = S.to_mono(audio_src)                        # (B, S60src)
    x = rules.constrain(x, "chunks", None)
    x = S.compress(x, cfg)                          # (B, S60) @22.05k
    c15 = S.split(x, n15)                           # (B*4, S15)
    c15 = rules.constrain(c15, "chunks", None)

    spec, power = S.stft_chunks(c15, cfg)           # STFT once per chunk
    cls = D.classify_chunks(power, cfg)
    rain15 = cls["rain"]
    cicada15 = cls["cicada"]

    spec = S.remove_cicada_band(spec, cls["indices"]["cicada_peak_bin"],
                                cicada15, cfg)
    wave15 = S.istft_chunks(spec, c15.shape[1], cfg)

    wave5 = S.split(wave15, n5)                     # (B*12, S5)
    power5 = S.group_frames(power, n5, c15.shape[1], cfg)
    from repro.core import indices as I
    silence5 = I.snr_est(power5) < cfg.silence_snr_threshold
    rain5 = jnp.repeat(rain15, n5)
    silence5 = silence5 & ~rain5
    keep = ~rain5 & ~silence5

    stats = {
        "n_chunks5": wave5.shape[0],
        "frac_rain": jnp.mean(rain5.astype(jnp.float32)),
        "frac_silence": jnp.mean(silence5.astype(jnp.float32)),
        "frac_kept": jnp.mean(keep.astype(jnp.float32)),
        "frac_cicada15": jnp.mean(cicada15.astype(jnp.float32)),
    }
    return PipelineOutput(wave5=wave5, keep=keep, rain=rain5,
                          silence=silence5, cicada15=cicada15, stats=stats)


def mmse_phase(cfg, wave5, rules=NULL_RULES):
    """The dominant stage, applied to (surviving) 5 s chunks."""
    wave5 = rules.constrain(wave5, "chunks", None)
    return S.mmse_denoise(wave5, cfg)


def preprocess_fused(cfg, audio_src, rules=NULL_RULES):
    """Single-jit mode: masked MMSE (no early exit — baseline)."""
    out = detection_phase(cfg, audio_src, rules)
    cleaned = mmse_phase(cfg, out.wave5, rules)
    wave = jnp.where(out.keep[:, None], cleaned, 0.0)
    return PipelineOutput(wave5=wave, keep=out.keep, rain=out.rain,
                          silence=out.silence, cicada15=out.cicada15,
                          stats=out.stats)


_JIT_CACHE = {}


def _cached_jit(kind, cfg, rules, fn):
    key = (kind, cfg, id(rules))
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = jax.jit(fn)
    return _JIT_CACHE[key]


def preprocess_two_phase(cfg, audio_src, rules=NULL_RULES, pad_multiple=1):
    """Paper-faithful early exit: detection jit -> host compaction ->
    MMSE jit on the survivor batch only.

    The two phase functions are cached per (cfg, rules) — the master loop
    calls this per batch and must not retrace (phase B retraces only when
    the padded survivor count changes, which pad_multiple quantizes).

    Returns (cleaned survivors (N_kept_padded, S5), PipelineOutput,
    n_survivors)."""
    det_fn = _cached_jit("detect", cfg, rules,
                         lambda a: detection_phase(cfg, a, rules))
    det = det_fn(audio_src)
    wave5 = np.asarray(det.wave5)
    keep = np.asarray(det.keep)
    batch, n_real = SCHED.survivor_batch(wave5, keep, pad_multiple)
    if batch is None:
        return np.zeros((0, wave5.shape[1]), np.float32), det, 0
    mmse_fn = _cached_jit("mmse", cfg, rules,
                          lambda w: mmse_phase(cfg, w, rules))
    cleaned = mmse_fn(jnp.asarray(batch))
    return np.asarray(cleaned)[:n_real], det, n_real
