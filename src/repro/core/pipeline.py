"""DEPRECATED entry points for the unified preprocessing pipeline.

The pipeline is now a composable stage graph (the paper's one profiled
order, expressed as config data):

  * `repro.core.graph`  — `Stage` protocol + `STAGES` registry +
    `PipelineGraph` (build-time shape validation, `removal_point` markers).
  * `repro.core.plans`  — `FusedPlan` / `TwoPhasePlan` / `StreamingPlan`
    behind the `Preprocessor` facade, with a keyed LRU compile cache.

The paper's stage order lives on `AudioPipelineConfig.stages`:

  to_mono -> compress (fused downsample+HPF) -> split_detect(15 s) ->
  stft (once) -> detect_rain -> cicada_bandstop -> istft ->
  split_final(5 s) -> detect_silence -> removal_point -> mmse

New code should use:

    from repro.core.plans import Preprocessor
    pre = Preprocessor(cfg, rules, plan="two_phase")
    res = pre(audio_src)                  # one batch
    for res in pre.run(loader): ...       # a stream

This module keeps thin shims for the seed API (`detection_phase`,
`mmse_phase`, `preprocess_fused`, `preprocess_two_phase`); they delegate to
the graph built from `cfg.stages` and will be removed once nothing imports
them.
"""
from __future__ import annotations

import functools
import warnings

import numpy as np

from repro.core.graph import PipelineGraph, PipelineOutput  # noqa: F401
from repro.core.plans import TwoPhasePlan
from repro.distributed.sharding import NULL_RULES


@functools.lru_cache(maxsize=16)
def _default_graph(cfg) -> PipelineGraph:
    return PipelineGraph(cfg)


def _deprecated(name):
    warnings.warn(
        f"repro.core.pipeline.{name} is deprecated; use "
        f"repro.core.plans.Preprocessor", DeprecationWarning, stacklevel=3)


def detection_phase(cfg, audio_src, rules=NULL_RULES):
    """Deprecated: `Preprocessor(cfg, rules).detect(audio_src)`."""
    _deprecated("detection_phase")
    return _default_graph(cfg).detection(audio_src, rules)


def mmse_phase(cfg, wave5, rules=NULL_RULES):
    """Deprecated: the graph tail past the removal point."""
    _deprecated("mmse_phase")
    return _default_graph(cfg).tail(wave5, rules)


def preprocess_fused(cfg, audio_src, rules=NULL_RULES):
    """Deprecated: `Preprocessor(cfg, rules, plan="fused")(audio_src)`."""
    _deprecated("preprocess_fused")
    return _default_graph(cfg).fused(audio_src, rules)


def preprocess_two_phase(cfg, audio_src, rules=NULL_RULES, pad_multiple=1):
    """Deprecated: `Preprocessor(cfg, rules, plan="two_phase")`.

    Returns (cleaned survivors (n_kept, S5) np, PipelineOutput, n_kept) —
    the seed signature."""
    _deprecated("preprocess_two_phase")
    plan = TwoPhasePlan(_default_graph(cfg), rules, pad_multiple)
    res = plan(audio_src)
    return np.asarray(res.cleaned), res.det, res.n_kept
