"""Pipeline stage library (device-side, batched, static-shape).

Stage order and chunk geometry follow the paper:
  60 s long chunks (HPF at long splits — Fig 2) -> 15 s detect chunks
  (Tables 4/5: most accurate for rain/cicada) -> 5 s final chunks (silence
  resolution) -> MMSE-STSA last (Table 1: dominant cost, skipped for removed
  audio).

Hot spots run through the Pallas kernels (fir_hpf, stft_dft, mmse_stsa);
everything else is jnp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fir_hpf import ops as fir
from repro.kernels.stft_dft import ops as stft_ops
from repro.kernels.mmse_stsa import ops as mmse_ops
from repro.kernels.mmse_stsa import ref as mmse_ref


def to_mono(x):
    """(B, C, S) -> (B, S). The paper drops all but one channel; averaging
    keeps SNR slightly better at identical cost."""
    return jnp.mean(x, axis=1)


def compress(x_mono, cfg):
    """Fused downsample (44.1 -> 22.05 kHz) + 1 kHz high-pass: one band-pass
    FIR + stride-2 decimation (Pallas)."""
    return fir.bandpass_decimate(
        x_mono, f_lo_hz=cfg.hpf_cutoff_hz,
        f_hi_hz=cfg.target_rate_hz / 2.0, rate_hz=cfg.source_rate_hz,
        factor=cfg.source_rate_hz // cfg.target_rate_hz, n_taps=cfg.hpf_taps)


def split(x, n_sub):
    """(B, S) -> (B * n_sub, S // n_sub)."""
    B, S = x.shape
    return x.reshape(B * n_sub, S // n_sub)


def valid_frames(n_samples, window, hop):
    return (n_samples - window) // hop + 1


def stft_chunks(x, cfg):
    """(B, S) -> (spec complex (B, Fv, K), power (B, Fv, K)).

    The STFT is computed ONCE per chunk and shared by every acoustic index
    (the paper's 'FFT executed once' design point)."""
    Fv = valid_frames(x.shape[1], cfg.stft_window, cfg.stft_hop)
    xp = stft_ops.pad_for_stft(x, cfg.stft_window, cfg.stft_hop)
    spec = stft_ops.stft(xp, cfg.stft_window, cfg.stft_hop)[:, :Fv]
    power = jnp.real(spec) ** 2 + jnp.imag(spec) ** 2
    return spec, power


def remove_cicada_band(spec, peak_bin, mask, cfg):
    """Band-stop around the detected chorus peak, applied only where mask.

    spec: (B,F,K) complex; peak_bin/mask: (B,)."""
    K = spec.shape[-1]
    width_bins = int(round(cfg.cicada_stop_width_hz
                           / (cfg.target_rate_hz / cfg.stft_window)))
    k = jnp.arange(K)[None, :]
    stop = jnp.abs(k - peak_bin[:, None]) <= (width_bins // 2)
    stop = stop & mask[:, None]
    return jnp.where(stop[:, None, :], 0.0, spec)


def istft_chunks(spec, n_samples, cfg):
    return stft_ops.istft(spec, n_samples, cfg.stft_window, cfg.stft_hop)


def group_frames(power, n_groups, chunk_samples, cfg):
    """Regroup a chunk's frames into n_groups sub-chunks (the paper's
    'files can only be split, not joined': 15 s spectra -> 3 x 5 s frame
    groups, reusing the single STFT). Returns (B*n_groups, Fg, K)."""
    B, F, K = power.shape
    sub = chunk_samples // n_groups
    Fg = valid_frames(sub, cfg.stft_window, cfg.stft_hop)
    starts = [min(int(round(i * sub / cfg.stft_hop)), F - Fg)
              for i in range(n_groups)]
    groups = jnp.stack([power[:, s:s + Fg] for s in starts], axis=1)
    return groups.reshape(B * n_groups, Fg, K)


def tail_highpass(wave, cfg):
    """Stride-1 FIR high-pass at the target rate — the survivor-tail
    variant of the long-split HPF (Fig 2), re-applicable past the removal
    point. wave: (B, S5) -> (B, S5)."""
    return fir.highpass(wave, cfg.hpf_cutoff_hz, cfg.target_rate_hz,
                        cfg.hpf_taps)


def mmse_denoise(wave, cfg):
    """The dominant stage: STFT -> MMSE-STSA gain (Pallas) -> ISTFT.

    wave: (B, S5) -> cleaned (B, S5)."""
    spec, power = stft_chunks(wave, cfg)
    noise = mmse_ref.estimate_noise_psd(power, cfg.noise_est_frames)
    gain = mmse_ops.mmse_gain(power, noise, alpha=cfg.mmse_alpha,
                              gain_floor=cfg.mmse_gain_floor)
    return istft_chunks(spec * gain.astype(spec.dtype), wave.shape[1], cfg)
