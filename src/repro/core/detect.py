"""Rule classifiers for rain / cicada / silence.

The paper trains a C4.5 tree offline and hard-codes its rules; we keep the
same structure — fixed conjunctions of index thresholds — with constants fit
on the synthetic labelled set (data/synthetic.py), since SERF audio is not
redistributable. The decision *order* and early-exit semantics follow the
paper exactly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import indices as I


def detect_rain(idx, cfg):
    """Heavy rain: high broadband power, flat spectrum, flat envelope."""
    return ((idx["psd"] > cfg.rain_psd_min)
            & (idx["flatness"] > cfg.rain_flatness_min)
            & (idx["snr"] < cfg.rain_snr_max))


def detect_cicada(idx, cfg):
    """Cicada chorus: sustained narrowband peak in the cicada band."""
    return ((idx["cicada_peakiness"] > cfg.cicada_peakiness_min)
            & (idx["cicada_band"] > cfg.cicada_band_ratio_min)
            & (idx["cicada_persistence"] > cfg.cicada_persistence_min))


def detect_silence(idx, cfg, threshold=None):
    """Silence: envelope SNR below threshold (paper: the 'lower threshold'
    at 5 s splits was chosen as the operating point)."""
    thr = cfg.silence_snr_threshold if threshold is None else threshold
    return idx["snr"] < thr


def detect_no_activity(idx, cfg, threshold=None):
    """Spectral-flux energy detection (Stowell-style): a chunk with no
    onset — peak rectified flux below threshold — holds no transient
    vocalisation and can be removed. Complements `detect_silence`: flux
    also rejects loud-but-flat chunks whose envelope SNR sneaks over the
    silence threshold."""
    thr = cfg.flux_threshold if threshold is None else threshold
    return idx["flux"] < thr


def classify_chunks(power, cfg):
    """Full detector pass over chunk power spectra: (B,F,K) -> dict of (B,)
    masks + the index vector (for benchmarks)."""
    idx = I.all_indices(power, cfg)
    rain = detect_rain(idx, cfg)
    cicada = detect_cicada(idx, cfg) & ~rain
    silence = detect_silence(idx, cfg) & ~rain
    return {"rain": rain, "cicada": cicada, "silence": silence,
            "indices": idx}
