"""Acoustic indices over STFT power spectra (Bedoya et al. 2017 style).

All functions take `power`: (B, F, K) f32 — F frames, K bins — and return
per-chunk (B,) indices. `freqs(k) = k * rate / window`.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

EPS = 1e-10


def bin_freqs(window=256, rate_hz=22_050):
    return np.arange(window // 2 + 1) * rate_hz / window


def psd_mean(power):
    """Broadband mean power spectral density (log-compressed)."""
    return jnp.log1p(jnp.mean(power, axis=(1, 2)))


def frame_energy(power):
    """Per-frame energy envelope: (B,F)."""
    return jnp.sum(power, axis=-1)


def snr_est(power):
    """Estimated SNR in [0,1): 1 - mean(envelope)/peak(envelope).

    The paper's silence measure: 'peak volume to average volume'. Silence and
    steady rain have flat envelopes (-> ~0); bird calls are peaky (-> ~1)."""
    env = frame_energy(power)
    return jnp.clip(1.0 - jnp.mean(env, axis=1) / (jnp.max(env, axis=1)
                                                   + EPS), 0.0, 1.0)


def spectral_flatness(power):
    """Wiener entropy averaged over frames: geometric/arithmetic mean ratio.
    White-ish noise (rain) -> ~1; tonal signals -> ~0."""
    p = power + EPS
    geo = jnp.exp(jnp.mean(jnp.log(p), axis=-1))
    arith = jnp.mean(p, axis=-1)
    return jnp.mean(geo / arith, axis=1)


def band_energy_ratio(power, lo_hz, hi_hz, window=256, rate_hz=22_050):
    """Fraction of total energy inside [lo_hz, hi_hz]."""
    f = bin_freqs(window, rate_hz)
    band = jnp.asarray((f >= lo_hz) & (f <= hi_hz), power.dtype)
    total = jnp.sum(power, axis=(1, 2)) + EPS
    return jnp.sum(power * band, axis=(1, 2)) / total


def band_peakiness(power, lo_hz, hi_hz, window=256, rate_hz=22_050):
    """Peak-bin to median-bin mean-PSD ratio within a band, plus the peak bin.

    Cicada choruses put sustained narrowband energy in 2.5-8 kHz: high
    peakiness for long fractions of the chunk."""
    f = bin_freqs(window, rate_hz)
    sel = (f >= lo_hz) & (f <= hi_hz)
    psd = jnp.mean(power, axis=1)                    # (B,K)
    band_psd = psd[:, sel]
    peak = jnp.max(band_psd, axis=1)
    med = jnp.median(psd, axis=1) + EPS
    peak_bin = jnp.argmax(band_psd, axis=1) + int(np.argmax(sel))
    return peak / med, peak_bin


def temporal_persistence(power, lo_hz, hi_hz, window=256, rate_hz=22_050,
                         frac=0.5):
    """Fraction of frames whose band energy exceeds frac * broadband energy —
    separates sustained choruses (cicada/rain) from transient calls."""
    f = bin_freqs(window, rate_hz)
    band = jnp.asarray((f >= lo_hz) & (f <= hi_hz), power.dtype)
    be = jnp.sum(power * band, axis=-1)              # (B,F)
    te = jnp.sum(power, axis=-1) + EPS
    return jnp.mean((be / te) > frac, axis=1)


def spectral_flux(power):
    """Onset strength via half-wave-rectified spectral flux (Stowell-style
    energy detection): per frame, sum the positive per-bin power rises from
    the previous frame; report the chunk's PEAK flux relative to its mean
    envelope energy. Transient bird calls spike it (>2); silence, steady
    rain, and sustained choruses keep near-flat spectra (<1)."""
    rise = jnp.maximum(power[:, 1:] - power[:, :-1], 0.0)   # (B, F-1, K)
    peak = jnp.max(jnp.sum(rise, axis=-1), axis=1)
    return peak / (jnp.mean(frame_energy(power), axis=1) + EPS)


def all_indices(power, cfg):
    """The index vector used by the rule classifiers (and exported for the
    benchmark reproducing the paper's classifier-feature table)."""
    pk, peak_bin = band_peakiness(power, *cfg.cicada_band_hz,
                                  cfg.stft_window, cfg.target_rate_hz)
    return {
        "psd": psd_mean(power),
        "snr": snr_est(power),
        "flux": spectral_flux(power),
        "flatness": spectral_flatness(power),
        "rain_band": band_energy_ratio(power, *cfg.rain_low_band_hz,
                                       cfg.stft_window, cfg.target_rate_hz),
        "cicada_band": band_energy_ratio(power, *cfg.cicada_band_hz,
                                         cfg.stft_window, cfg.target_rate_hz),
        "cicada_peakiness": pk,
        "cicada_peak_bin": peak_bin,
        "cicada_persistence": temporal_persistence(
            power, *cfg.cicada_band_hz, cfg.stft_window, cfg.target_rate_hz),
    }
