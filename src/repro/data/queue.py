"""Host-side work queue: the paper's master-slave dispatch, made fault-
tolerant and decentralized-friendly.

The paper: master holds a file list; slaves pull when their local queue drops
below `max_queue_size`; master tracks sent/completed files and re-sends work
of crashed slaves; slaves return results every `send_interval`.

Here: a LEASED work queue. Workers lease chunk ranges (leases carry
deadlines); completed leases retire work; expired leases (crash, straggler)
return work to the queue automatically. The queue state is tiny and is
checkpointed with the training state (ckpt meta), so a restart resumes the
exact stream — no loss, no duplication beyond at-least-once redelivery.

Straggler mitigation rides the same machinery: `speculate()` grants a
SECOND, duplicate lease on an in-flight work id to an idle worker without
reaping the original. Whichever incarnation completes first wins —
`complete()` already gates exactly-once emission, so the loser's push is
simply discarded, and the losing holder is attributed through
`on_redeliver(wid, worker, "speculated")`. If the primary lease expires or
its holder dies while a live speculative copy exists, the copy is PROMOTED
to primary instead of re-queueing the id (the backup is already computing
it — a third computation would only add load).

Every mutating entry point takes `self.lock` (an RLock), because the queue
is now served to REAL worker processes by `repro.dist`: each transport
connection gets its own handler thread on the master, so lease/complete/
fail_worker race unless serialized here. Single-threaded in-process users
pay one uncontended RLock acquire per call — noise.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field


@dataclass
class Lease:
    work_id: int
    worker: str
    deadline: float


class SettableClock:
    """Deterministic injectable clock for tests and simulations:

        clock = SettableClock()
        q = WorkQueue(n, lease_timeout_s=5.0, clock=clock)
        clock.t = 10.0        # every outstanding lease is now expired

    Consumers (e.g. ShardedPlan's stall path) treat any clock other than
    `time.monotonic` / `time.time` as non-wall and skip real sleeps."""

    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t


class WorkQueue:
    def __init__(self, n_items, lease_timeout_s=60.0, clock=time.monotonic):
        self.n_items = n_items
        self.lease_timeout_s = lease_timeout_s
        self.clock = clock
        self.lock = threading.RLock()
        self._pending = list(range(n_items - 1, -1, -1))   # stack, pop() = 0..
        self._leases: dict[int, Lease] = {}
        # speculative duplicate leases, wid -> Lease: at most ONE backup
        # copy per in-flight id, held by a different worker than the
        # primary. First completion wins; see speculate().
        self._spec: dict[int, Lease] = {}
        self._done = set()
        self.redeliveries = 0
        self.speculations = 0           # speculative leases ever granted
        self.speculations_lost = 0      # incarnations that lost the race
        # per-worker attribution of lost leases (expiry or fail_worker):
        # who HELD the lease that had to be redelivered — the launch
        # driver's per-worker summary reads this.
        self.redelivered_from = collections.Counter()
        # Optional hook fired (under the queue lock) whenever a lease is
        # reclaimed: on_redeliver(wid, worker, reason) with reason
        # "expired" (deadline passed), "failed" (fail_worker), or
        # "speculated" (this incarnation lost a first-completion-wins race
        # against its duplicate). repro.obs wires this to durable
        # telemetry + redelivery counters.
        self.on_redeliver = None
        # Optional hook fired (under the queue lock) with the list of
        # NEWLY retired ids whenever complete() makes progress — the
        # QueueService feeds its StragglerDetector from here so every
        # completion path (proc emit loop, sim rounds, pool pump) counts.
        self.on_complete = None

    # -- worker API ---------------------------------------------------------
    def lease(self, worker, max_items=1):
        """Lease up to max_items work ids (the slave's pull request —
        max_items is the paper's Table 7 queue-size knob).

        Ids completed late — after their expired lease was already reaped
        back into pending — are dropped here instead of re-delivered, so a
        straggler that finishes just past its deadline costs nothing."""
        with self.lock:
            self._reap_expired()
            out = []
            while self._pending and len(out) < max_items:
                wid = self._pending.pop()
                if wid in self._done:
                    continue
                self._leases[wid] = Lease(wid, worker,
                                          self.clock() + self.lease_timeout_s)
                out.append(wid)
            return out

    def complete(self, work_ids, worker=None):
        """Retire work ids. Returns the ids that were NEWLY retired: a late
        completion of already-done work (the at-least-once overlap) comes
        back empty, so callers can gate result emission on it and keep
        exactly-once output on top of at-least-once delivery.

        `worker` (optional) names who produced the winning result. It only
        matters for ids carrying a speculative duplicate lease: the OTHER
        incarnation lost the first-completion-wins race and is attributed
        via `on_redeliver(wid, loser, "speculated")`. Without a winner
        name the primary is presumed to have won (the historical path —
        only the emit loops that speculate pass it)."""
        with self.lock:
            newly = []
            for wid in work_ids:
                if wid in self._done:
                    continue
                primary = self._leases.pop(wid, None)
                spec = self._spec.pop(wid, None)
                self._done.add(wid)
                newly.append(wid)
                if spec is None:
                    continue
                if worker is None:
                    losers = [spec]
                else:
                    losers = [l for l in (primary, spec)
                              if l is not None and l.worker != worker]
                for l in losers:
                    self.speculations_lost += 1
                    if self.on_redeliver is not None:
                        self.on_redeliver(wid, l.worker, "speculated")
            if newly and self.on_complete is not None:
                self.on_complete(newly)
            return newly

    def speculate(self, worker, wid) -> bool:
        """Grant `worker` a SPECULATIVE duplicate lease on the in-flight
        id `wid` WITHOUT reaping the primary lease (the backup-task rule:
        near end-of-stream an idle worker re-runs the slowest in-flight
        item). Refused — returns False — when the id is not currently
        leased, already done, already has a backup, or `worker` is the
        primary holder itself. Exactly-once emission needs no new
        machinery: both incarnations push, `complete()` retires the id
        once, and the loser is attributed there."""
        with self.lock:
            self._reap_expired()
            lease = self._leases.get(wid)
            if (lease is None or wid in self._done or wid in self._spec
                    or lease.worker == worker):
                return False
            self._spec[wid] = Lease(wid, worker,
                                    self.clock() + self.lease_timeout_s)
            self.speculations += 1
            return True

    def speculated(self):
        """Work ids currently carrying a speculative duplicate lease."""
        with self.lock:
            return sorted(self._spec)

    def heartbeat_extend(self, worker):
        with self.lock:
            now = self.clock()
            for lease in self._leases.values():
                if lease.worker == worker:
                    lease.deadline = now + self.lease_timeout_s
            for lease in self._spec.values():
                if lease.worker == worker:
                    lease.deadline = now + self.lease_timeout_s

    def leases_held(self, worker):
        """Work ids currently leased by `worker`, speculative duplicates
        included (progress/busy reporting — a worker re-running a
        straggler's item is busy)."""
        with self.lock:
            held = {wid for wid, l in self._leases.items()
                    if l.worker == worker}
            held |= {wid for wid, l in self._spec.items()
                     if l.worker == worker}
            return sorted(held)

    def is_done(self, wid) -> bool:
        """True once `wid` is retired — lets a data plane refuse to serve
        (or regenerate) an item whose redelivered lease lost the race to a
        straggler's completion."""
        with self.lock:
            return wid in self._done

    # -- failure handling ---------------------------------------------------
    def _reap_expired(self):
        now = self.clock()
        # expired speculative copies just evaporate: the primary still
        # owns the id, nothing returns to pending, no redelivery counted
        for wid in [w for w, l in self._spec.items() if l.deadline < now]:
            del self._spec[wid]
        expired = [wid for wid, l in self._leases.items() if l.deadline < now]
        for wid in expired:
            worker = self._leases[wid].worker
            self.redelivered_from[worker] += 1
            del self._leases[wid]
            spec = self._spec.pop(wid, None)
            if spec is not None:
                # a live backup is already computing this id: promote it
                # to primary instead of re-queueing (third copies add
                # nothing but load)
                self._leases[wid] = spec
            else:
                self._pending.append(wid)
            self.redeliveries += 1
            if self.on_redeliver is not None:
                self.on_redeliver(wid, worker, "expired")

    def next_deadline(self):
        """Earliest outstanding lease deadline (None when nothing is
        leased) — lets a stalled consumer wait out exactly the time until
        the next reap can make progress."""
        with self.lock:
            return min((l.deadline for l in self._leases.values()),
                       default=None)

    def fail_worker(self, worker):
        """Immediately return a dead worker's leases (heartbeat said dead).
        Ids whose speculative copy is still alive are promoted to that
        copy instead of re-queued; the dead worker's own speculative
        copies evaporate (their primaries are alive and computing)."""
        with self.lock:
            for wid in [w for w, l in self._spec.items()
                        if l.worker == worker]:
                del self._spec[wid]
            back = [wid for wid, l in self._leases.items()
                    if l.worker == worker]
            for wid in back:
                del self._leases[wid]
                spec = self._spec.pop(wid, None)
                if spec is not None:
                    self._leases[wid] = spec
                else:
                    self._pending.append(wid)
                self.redeliveries += 1
                if self.on_redeliver is not None:
                    self.on_redeliver(wid, worker, "failed")
            if back:
                # attribute only real losses: `Counter[w] += 0` would
                # CREATE a phantom zero-count entry, polluting the launch
                # driver's per-worker summary with workers that never
                # lost a lease
                self.redelivered_from[worker] += len(back)
            return back

    # -- checkpoint ---------------------------------------------------------
    def state(self):
        """Serializable snapshot: done ids plus the ids still leased at
        snapshot time. Leased ids are recorded so a journal shows what was
        in flight when the process died; on restore they re-enter pending
        (their lease holder died with the process)."""
        with self.lock:
            self._reap_expired()
            return {"n_items": self.n_items, "done": sorted(self._done),
                    "leased": sorted(self._leases)}

    @classmethod
    def from_state(cls, state, **kw):
        """Rebuild from a snapshot: everything not done — including ids the
        snapshot recorded as leased — re-enters pending, so outstanding
        leases are redelivered, never lost."""
        q = cls(state["n_items"], **kw)
        done = set(state["done"])
        q._done = done
        q._pending = [i for i in range(state["n_items"] - 1, -1, -1)
                      if i not in done]
        return q

    @property
    def finished(self):
        with self.lock:
            return len(self._done) == self.n_items

    def progress(self):
        with self.lock:
            return len(self._done), self.n_items


class StandingWorkQueue(WorkQueue):
    """Open-ended WorkQueue for a persistent serving pool.

    A batch run knows its item count up front; a serving pool does not —
    work arrives continuously (`add()`), and the pool's long-lived workers
    must keep polling through idle gaps instead of exiting the moment the
    queue momentarily drains. So `finished` only turns True after
    `close()` once every admitted item is done: the worker runtime's
    "lease came back empty AND finished" exit condition becomes the
    pool's graceful-drain signal, with zero worker-side changes.

    Items lease oldest-first (FIFO admission order); redelivered items
    (lease expiry, `fail_worker`) keep the base class's
    go-to-the-front-of-the-line priority, so a crashed worker's request
    is re-served before newer traffic."""

    def __init__(self, lease_timeout_s=60.0, clock=time.monotonic):
        super().__init__(0, lease_timeout_s, clock)
        self.closed = False

    def add(self) -> int:
        """Admit one new work item; returns its work id."""
        with self.lock:
            if self.closed:
                raise RuntimeError(
                    "standing queue is closed to new work (draining)")
            wid = self.n_items
            self.n_items += 1
            # pending is a stack popped from the END; oldest ids must sit
            # there, so new admissions go to the FRONT
            self._pending.insert(0, wid)
            return wid

    def close(self):
        """Stop admission; already-admitted work still drains."""
        with self.lock:
            self.closed = True

    def abort(self):
        """Hard stop: close AND discard all unfinished work, so workers
        polling for `finished` exit without draining. The pool's
        non-graceful shutdown path (in-proc worker threads have no pid to
        TERM — this is how they are told to stop)."""
        with self.lock:
            self.closed = True
            self._done = set(range(self.n_items))
            self._leases.clear()
            self._spec.clear()
            self._pending.clear()

    def depth(self):
        """(queued, leased): admitted items waiting for a worker vs
        currently in flight — the pool-level backlog gauges."""
        with self.lock:
            self._reap_expired()
            leased = len(self._leases)
            return self.n_items - len(self._done) - leased, leased

    @property
    def finished(self):
        with self.lock:
            return self.closed and len(self._done) == self.n_items
