"""Host data pipeline: leased-queue-fed loaders with a deterministic,
checkpointable cursor.

Three instantiations of the same machinery (the paper's contribution is the
scheduling, not the payload):
  * AudioChunkLoader — yields (B, 2, S_long_src) long-chunk batches from the
    synthetic SERF-like stream (examples/preprocess drivers); background-
    threaded prefetch, completion on yield.
  * TokenLoader — yields {"tokens","targets"} LM batches (train drivers).
  * ShardedLoader — one shard's pull-side view of a SHARED leased WorkQueue
    (the paper's slave pull loop). Completion is left to the CONSUMER (the
    execution plan), so a shard that dies after pulling leaves its lease to
    expire and the queue redelivers — at-least-once, no crash-tracking
    master. Its `lease_items` is the paper's Table 7 `max_queue_size` knob:
    ids leased per round-trip — the same knob real worker processes
    (`repro.dist.worker --lease-items`) sweep, and
    `benchmarks/bench_queue_depth.py` measures.

Prefetch depth == the paper's slave queue size (Table 7 sweeps it). The
cursor (next work id + RNG seed) rides in checkpoint meta for exact resume.
"""
from __future__ import annotations

import queue as _q
import threading

import numpy as np

from repro.data import synthetic
from repro.data.queue import WorkQueue


def audio_batch_maker(seed, batch_long_chunks=4, segment_s=5.0, rate=44_100):
    """work id -> (chunks, labels): one (B, 2, S_long_src) long-chunk batch
    of the seeded synthetic SERF-like stream. Shared by AudioChunkLoader
    and the sharded pools, so every loader flavour sees the SAME stream for
    a given seed (plan-equivalence tests depend on this)."""
    per_long = int(round(60.0 / segment_s))

    def make(wid):
        audio, labels = synthetic.generate_labelled(
            seed * 100_003 + wid, batch_long_chunks * per_long,
            segment_s=segment_s, rate=rate)
        S5 = audio.shape[-1]
        chunks = audio.reshape(batch_long_chunks, per_long, 2, S5)
        chunks = chunks.transpose(0, 2, 1, 3).reshape(
            batch_long_chunks, 2, per_long * S5)
        return chunks, labels

    return make


class _PrefetchLoader:
    def __init__(self, make_item, n_items, prefetch=5, start_at=0):
        self.make_item = make_item
        if start_at:
            self.queue = WorkQueue.from_state(
                {"n_items": n_items, "done": list(range(start_at))})
        else:
            self.queue = WorkQueue(n_items)
        self._buf = _q.Queue(maxsize=prefetch)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._started = False

    def _run(self):
        while True:
            ids = self.queue.lease("loader", max_items=1)
            if not ids:
                self._buf.put(None)
                return
            wid = ids[0]
            item = self.make_item(wid)
            self._buf.put((wid, item))

    def __iter__(self):
        if not self._started:
            self._thread.start()
            self._started = True
        while True:
            got = self._buf.get()
            if got is None:
                return
            wid, item = got
            yield wid, item
            self.queue.complete([wid])

    def cursor(self):
        return self.queue.state()

    def __len__(self):
        """Items still to be yielded — lets stream consumers (ShardedPlan)
        size a work queue without materialising the stream."""
        done, n = self.queue.progress()
        return n - done


class AudioChunkLoader(_PrefetchLoader):
    """Batches of 60 s long chunks, built from 12 x 5 s labelled segments."""

    def __init__(self, seed=0, n_batches=100, batch_long_chunks=4,
                 prefetch=5, start_at=0, segment_s=5.0, rate=44_100):
        self.seed = seed
        self.rate = rate
        self.segment_s = segment_s
        self.batch_long = batch_long_chunks
        self.per_long = int(round(60.0 / segment_s))
        super().__init__(
            audio_batch_maker(seed, batch_long_chunks, segment_s, rate),
            n_batches, prefetch, start_at)


# ------------------------------------------------------------ sharded pool

class ShardedLoader:
    """One shard's pull handle on a shared leased WorkQueue.

    Unlike `_PrefetchLoader` (which completes a work id the moment it is
    yielded), completion belongs to the consumer: the execution plan calls
    `queue.complete` only after the shard's results are materialised, so a
    crash between pull and completion leaves the lease to expire and the
    work to be redelivered to a surviving shard."""

    def __init__(self, make_item, queue, shard, lease_items=1):
        self.make_item = make_item
        self.queue = queue
        self.shard = int(shard)
        self.lease_items = max(1, int(lease_items))

    @property
    def worker(self) -> str:
        """Worker id under which this shard's leases are registered."""
        return f"shard{self.shard}"

    def pull(self):
        """Lease up to lease_items work ids and materialise their batches.
        Returns [(wid, item), ...]; empty when the queue has nothing
        leasable right now (drained, or all remaining work is leased)."""
        ids = self.queue.lease(self.worker, self.lease_items)
        return [(wid, self.make_item(wid)) for wid in ids]

    def complete(self, wid):
        """Retire one work id; returns True if it was newly retired."""
        return bool(self.queue.complete([wid]))

    def cursor(self):
        return self.queue.state()


def make_shard_pool(make_item, n_items, n_shards, queue=None, lease_items=1,
                    **queue_kw):
    """Build n_shards ShardedLoaders over ONE shared WorkQueue (pass
    `queue` to supply a pre-seeded / fake-clock queue; `queue_kw` feeds the
    WorkQueue constructor otherwise)."""
    if queue is None:
        queue = WorkQueue(n_items, **queue_kw)
    return [ShardedLoader(make_item, queue, j, lease_items)
            for j in range(n_shards)]


def audio_shard_pool(seed=0, n_batches=100, batch_long_chunks=4, n_shards=2,
                     segment_s=5.0, rate=44_100, **pool_kw):
    """Shard pool over the same synthetic stream AudioChunkLoader yields
    for this seed — the multi-host path of launch/preprocess."""
    return make_shard_pool(
        audio_batch_maker(seed, batch_long_chunks, segment_s, rate),
        n_batches, n_shards, **pool_kw)


class TokenLoader(_PrefetchLoader):
    """Synthetic-corpus LM batches: Zipf-distributed tokens with structure
    (repeated n-grams) so losses move during the example training runs."""

    def __init__(self, vocab_size, batch, seq_len, n_batches=10_000,
                 seed=0, prefetch=5, start_at=0):
        self.vocab_size = vocab_size

        def make(wid):
            rng = np.random.RandomState(seed * 99_991 + wid)
            a = rng.zipf(1.3, size=(batch, seq_len + 1)) % vocab_size
            # inject copyable structure: second half repeats the first
            half = seq_len // 2
            a[:, half:2 * half] = a[:, :half]
            a = a.astype(np.int32)
            return {"tokens": a[:, :-1], "targets": a[:, 1:]}

        super().__init__(make, n_batches, prefetch, start_at)
