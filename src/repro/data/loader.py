"""Host data pipeline: background-threaded, leased-queue-fed loaders with a
deterministic, checkpointable cursor.

Two instantiations of the same machinery (the paper's contribution is the
scheduling, not the payload):
  * AudioChunkLoader — yields (B, 2, S_long_src) long-chunk batches from the
    synthetic SERF-like stream (examples/preprocess drivers).
  * TokenLoader — yields {"tokens","targets"} LM batches (train drivers).

Prefetch depth == the paper's slave queue size (Table 7 sweeps it). The
cursor (next work id + RNG seed) rides in checkpoint meta for exact resume.
"""
from __future__ import annotations

import queue as _q
import threading

import numpy as np

from repro.data import synthetic
from repro.data.queue import WorkQueue


class _PrefetchLoader:
    def __init__(self, make_item, n_items, prefetch=5, start_at=0):
        self.make_item = make_item
        if start_at:
            self.queue = WorkQueue.from_state(
                {"n_items": n_items, "done": list(range(start_at))})
        else:
            self.queue = WorkQueue(n_items)
        self._buf = _q.Queue(maxsize=prefetch)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._started = False

    def _run(self):
        while True:
            ids = self.queue.lease("loader", max_items=1)
            if not ids:
                self._buf.put(None)
                return
            wid = ids[0]
            item = self.make_item(wid)
            self._buf.put((wid, item))

    def __iter__(self):
        if not self._started:
            self._thread.start()
            self._started = True
        while True:
            got = self._buf.get()
            if got is None:
                return
            wid, item = got
            yield wid, item
            self.queue.complete([wid])

    def cursor(self):
        return self.queue.state()


class AudioChunkLoader(_PrefetchLoader):
    """Batches of 60 s long chunks, built from 12 x 5 s labelled segments."""

    def __init__(self, seed=0, n_batches=100, batch_long_chunks=4,
                 prefetch=5, start_at=0, segment_s=5.0, rate=44_100):
        self.seed = seed
        self.rate = rate
        self.segment_s = segment_s
        self.batch_long = batch_long_chunks
        self.per_long = int(round(60.0 / segment_s))

        def make(wid):
            audio, labels = synthetic.generate_labelled(
                seed * 100_003 + wid, self.batch_long * self.per_long,
                segment_s=segment_s, rate=rate)
            S5 = audio.shape[-1]
            chunks = audio.reshape(self.batch_long, self.per_long, 2, S5)
            chunks = chunks.transpose(0, 2, 1, 3).reshape(
                self.batch_long, 2, self.per_long * S5)
            return chunks, labels

        super().__init__(make, n_batches, prefetch, start_at)


class TokenLoader(_PrefetchLoader):
    """Synthetic-corpus LM batches: Zipf-distributed tokens with structure
    (repeated n-grams) so losses move during the example training runs."""

    def __init__(self, vocab_size, batch, seq_len, n_batches=10_000,
                 seed=0, prefetch=5, start_at=0):
        self.vocab_size = vocab_size

        def make(wid):
            rng = np.random.RandomState(seed * 99_991 + wid)
            a = rng.zipf(1.3, size=(batch, seq_len + 1)) % vocab_size
            # inject copyable structure: second half repeats the first
            half = seq_len // 2
            a[:, half:2 * half] = a[:, :half]
            a = a.astype(np.int32)
            return {"tokens": a[:, :-1], "targets": a[:, 1:]}

        super().__init__(make, n_batches, prefetch, start_at)
