"""Synthetic SERF-like labelled audio.

SERF recordings are not redistributable, so benchmarks and detector
calibration use a seeded generator that reproduces the paper's noise
taxonomy: bird chirps (FM sweeps 2-8 kHz, transient), heavy rain (loud
broadband noise), cicada chorus (sustained narrowband noise 3.5-7 kHz, AM),
silence (low-level background), over stereo 44.1 kHz audio with ground-truth
labels at 5 s resolution (the paper's labelling resolution).
"""
from __future__ import annotations

import numpy as np

LABELS = ("bird", "rain", "cicada", "silence")


def _chirp(rng, n, rate):
    """One FM bird chirp."""
    dur = int(rate * rng.uniform(0.05, 0.4))
    f0 = rng.uniform(2000, 6000)
    f1 = f0 * rng.uniform(0.7, 1.6)
    t = np.arange(dur) / rate
    freq = np.linspace(f0, min(f1, 10_000), dur)
    phase = 2 * np.pi * np.cumsum(freq) / rate
    env = np.hanning(dur)
    return (np.sin(phase) * env).astype(np.float32)


def _bird_segment(rng, n, rate, density=3.0):
    """Sparse chirps over quiet background."""
    x = np.zeros(n, np.float32)
    n_calls = max(1, rng.poisson(density * n / rate))
    for _ in range(n_calls):
        c = _chirp(rng, n, rate)
        start = rng.randint(0, max(1, n - len(c)))
        amp = rng.uniform(0.15, 0.6)
        x[start:start + len(c)] += amp * c
    return x


def _bandnoise(rng, n, rate, lo, hi, order=4):
    """Band-limited noise via FFT masking (generator-side only — the
    pipeline under test never uses FFTs from here)."""
    w = rng.randn(n).astype(np.float32)
    spec = np.fft.rfft(w)
    f = np.fft.rfftfreq(n, 1.0 / rate)
    mask = ((f >= lo) & (f <= hi)).astype(np.float32)
    # soft edges
    return np.fft.irfft(spec * mask, n).astype(np.float32)


def _rain_segment(rng, n, rate):
    """Heavy rain: loud broadband noise + audible drop transients."""
    x = 0.35 * _bandnoise(rng, n, rate, 300, 16_000)
    n_drops = rng.poisson(30 * n / rate)
    for _ in range(n_drops):
        d = int(rate * 0.004)
        start = rng.randint(0, n - d)
        x[start:start + d] += rng.uniform(0.2, 0.6) * np.hanning(d).astype(
            np.float32)
    return x.astype(np.float32)


def _cicada_segment(rng, n, rate):
    """Cicada chorus: strong sustained narrowband noise with slow AM."""
    f0 = rng.uniform(3800, 6500)
    x = 0.5 * _bandnoise(rng, n, rate, f0 - 250, f0 + 250)
    am = 1.0 + 0.3 * np.sin(2 * np.pi * rng.uniform(8, 15)
                            * np.arange(n) / rate)
    x = x * am.astype(np.float32)
    # faint bird activity can coexist under the chorus
    if rng.rand() < 0.3:
        x += 0.3 * _bird_segment(rng, n, rate, density=1.0)
    return x.astype(np.float32)


def _silence_segment(rng, n, rate):
    return np.zeros(n, np.float32)


_GEN = {"bird": _bird_segment, "rain": _rain_segment,
        "cicada": _cicada_segment, "silence": _silence_segment}


def generate_labelled(seed, n_segments, segment_s=5.0, rate=44_100,
                      stereo=True, label_probs=(0.45, 0.2, 0.15, 0.2),
                      background_level=0.012, persistence=0.85):
    """Returns (audio (n, [2,] S) f32, labels (n,) int in LABELS order).

    Labels follow a sticky Markov chain (persistence = P[keep previous
    label]): rain and cicada choruses are episodic over minutes in the SERF
    recordings, not independent per 5 s. Every segment gets low-level
    stationary background noise (the component MMSE-STSA removes)."""
    rng = np.random.RandomState(seed)
    n = int(segment_s * rate)
    audio, labels = [], []
    li = rng.choice(len(LABELS), p=label_probs)
    for _ in range(n_segments):
        if rng.rand() > persistence:
            li = rng.choice(len(LABELS), p=label_probs)
        x = _GEN[LABELS[li]](rng, n, rate)
        x = x + background_level * rng.randn(n).astype(np.float32)
        if stereo:
            x2 = x + 0.003 * rng.randn(n).astype(np.float32)
            x = np.stack([x, x2])
        audio.append(x)
        labels.append(li)
    return np.stack(audio), np.asarray(labels, np.int32)


def generate_hours(seed, hours, rate=44_100, **kw):
    """Convenience: enough 5 s segments to cover `hours` of audio."""
    n = int(hours * 3600 / 5.0)
    return generate_labelled(seed, n, 5.0, rate, **kw)
