"""Fault-tolerance primitives: heartbeat failure detection, straggler
detection (backup-task rule), elastic mesh re-planning.

These run on the launcher/host side; clocks are injectable so the logic is
unit-testable without wall-time sleeps. The paper's master "re-sends files to
different slaves if a slave disconnects or crashes" — here that becomes:
heartbeat timeout -> worker marked dead -> its queue lease is returned (see
data/queue.py) -> elastic planner recomputes the mesh if capacity changed ->
training restarts from the last checkpoint with restore-time resharding.
"""
from __future__ import annotations

import os
import signal
import time
from dataclasses import dataclass, field


class CrashInjector:
    """Scripted worker crashes — simulated shards AND real processes.

    `kill(shard, after_items=n)` arms a fuse: the shard detects n more
    pulled items normally, then dies while HOLDING its next lease — the
    lease is neither completed nor returned, so recovery exercises the real
    path (lease expiry or `WorkQueue.fail_worker`), mirroring the paper's
    master that "re-sends files to different slaves if a slave disconnects
    or crashes". `revive(shard)` brings a shard back (elastic rejoin).

    Process mode: `attach(shard, pid)` binds the shard to a real worker
    process (the sharded plan's proc transport does this at spawn). When
    the fuse burns, the injected death is a genuine SIGKILL of that pid —
    no atexit, no socket shutdown, the worker just stops existing
    mid-lease, and the queue's redelivery machinery is observed end to
    end."""

    def __init__(self):
        self._fuse: dict[int, int] = {}
        self._dead: set[int] = set()
        self._pids: dict[int, int] = {}

    def kill(self, shard, after_items=0):
        self._fuse[shard] = int(after_items)

    def attach(self, shard, pid):
        """Bind `shard` to a live worker process id: its injected death
        becomes a real SIGKILL."""
        self._pids[shard] = int(pid)

    def revive(self, shard):
        self._dead.discard(shard)
        self._fuse.pop(shard, None)
        self._pids.pop(shard, None)

    def alive(self, shard) -> bool:
        return shard not in self._dead

    def _die(self, shard):
        self._dead.add(shard)
        pid = self._pids.get(shard)
        if pid is not None:
            try:
                os.kill(pid, signal.SIGKILL)
            except ProcessLookupError:    # already gone — dead is dead
                pass

    def on_pull(self, shard) -> bool:
        """Called once per pulled work item BEFORE it is processed.
        Returns False exactly when the shard dies on this pull (its lease
        stays registered in the queue, un-completed). With an attached
        pid, dying means SIGKILL — the caller's return-value handling is
        then moot, the process is gone."""
        if shard in self._dead:
            return False
        fuse = self._fuse.get(shard)
        if fuse is not None:
            if fuse <= 0:
                self._die(shard)
                return False
            self._fuse[shard] = fuse - 1
        return True

    @property
    def crashed(self) -> frozenset:
        return frozenset(self._dead)


class HeartbeatMonitor:
    def __init__(self, timeout_s=30.0, clock=time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self._last = {}

    def beat(self, worker_id):
        self._last[worker_id] = self.clock()

    def forget(self, worker_id):
        """Drop a worker from liveness tracking entirely. A drained or
        departed worker stops heartbeating BY DESIGN — without this it
        would sit in `dead()` forever, and every elastic scale-down would
        permanently trip the dead-worker fast path (fail_worker storms on
        a worker that left cleanly holding nothing)."""
        self._last.pop(worker_id, None)

    def alive(self):
        now = self.clock()
        return {w for w, t in self._last.items()
                if now - t <= self.timeout_s}

    def dead(self):
        now = self.clock()
        return {w for w, t in self._last.items() if now - t > self.timeout_s}


class StragglerDetector:
    """Backup-task rule: a task is a straggler if it has run longer than
    `factor` x the rolling p95 of completed-task latencies (min history
    before firing). Mirrors the paper's observation that even load needs
    re-dispatch when a slave slows down."""

    def __init__(self, factor=2.0, min_history=20, clock=time.monotonic):
        self.factor = factor
        self.min_history = min_history
        self.clock = clock
        self._latencies = []
        self._inflight = {}

    def start(self, task_id):
        self._inflight[task_id] = self.clock()

    def complete(self, task_id):
        t0 = self._inflight.pop(task_id, None)
        if t0 is not None:
            self._latencies.append(self.clock() - t0)
            if len(self._latencies) > 1000:
                self._latencies = self._latencies[-500:]

    def p95(self):
        if not self._latencies:
            return float("inf")
        xs = sorted(self._latencies)
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]

    def stragglers(self):
        """In-flight task ids past the backup-task limit, LONGEST-running
        first — the speculation path re-leases from the front, so the
        slowest item gets the first idle backup worker."""
        if len(self._latencies) < self.min_history:
            return []
        limit = self.factor * self.p95()
        now = self.clock()
        return sorted((t for t, t0 in self._inflight.items()
                       if now - t0 > limit),
                      key=lambda t: self._inflight[t])


@dataclass
class MeshPlan:
    shape: tuple
    axes: tuple
    reason: str = ""


def plan_mesh(n_devices, model_parallel=16, multi_pod_size=256):
    """Elastic mesh planning: keep TP fixed (weights shard cleanly at 16),
    flex the data axis, add the pod axis above one pod's worth of chips.

    Degrades gracefully: if n_devices isn't divisible, the largest usable
    subset is planned (the launcher drops the spare hosts)."""
    tp = model_parallel
    if n_devices < tp:                  # tiny fleets: shrink TP instead
        tp = 1 << (n_devices.bit_length() - 1)
    usable = (n_devices // tp) * tp
    dp = usable // tp
    if usable > multi_pod_size and usable % multi_pod_size == 0:
        pods = usable // multi_pod_size
        per_pod_dp = multi_pod_size // tp
        return MeshPlan((pods, per_pod_dp, tp), ("pod", "data", "model"),
                        f"{pods} pods x ({per_pod_dp}x{tp})")
    return MeshPlan((dp, tp), ("data", "model"),
                    f"single pod {dp}x{tp}, {n_devices - usable} spare")
