"""Seeded chaos schedules over an elastic proc fleet.

The sensor-network scenario (Lostanlen et al., PAPERS.md) is long-lived
streams on flaky remote nodes with no fixed fleet: workers crash, stall,
join and leave while the stream runs. This module turns that into a
repeatable adversary: `make_schedule(seed, n_items)` derives a randomized
but fully seed-determined event schedule — SIGKILL, mid-run join,
graceful drain, SIGSTOP stall — and `ChaosRunner` fires it against a live
`ShardedPlan` proc run through the plan's `FleetControl` handle while the
stream is being consumed.

Events trigger on PROGRESS (chunks accepted so far), not wall time, so a
schedule lands at comparable stream positions regardless of compile cost
or host speed. Target choice is necessarily runtime state (who is alive,
who holds leases): kills and stalls prefer lease holders, because a
victim holding work is what exercises redelivery and speculation; if no
preferred target exists the event defers briefly, then fires anyway.

Safety guards, not mercy: an event that would leave ZERO active workers
(killing or draining the last one) spawns a replacement first — the gate
is testing elasticity, not the obvious theorem that an empty fleet makes
no progress. Everything else is fair game, and the acceptance bar is
absolute: every chunk exactly once, bit-identical to `two_phase`.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass

ACTIONS = ("kill", "join", "drain", "stall")


@dataclass
class ChaosEvent:
    """One scheduled disruption: fires once `after_done` chunks have been
    accepted. `target`/`fired_at_done` are filled at fire time."""
    after_done: int
    action: str
    stall_s: float = 6.0
    fired: bool = False
    deferred: int = 0
    target: int = None
    fired_at_done: int = None


def make_schedule(seed, n_items, actions=ACTIONS, extra_events=0,
                  stall_s=(5.0, 9.0)):
    """Derive a seed-determined schedule with AT LEAST one event per
    action in `actions`, plus `extra_events` extra random ones. Biases
    baked in from what each action needs to be observable: the join goes
    EARLY (a late joiner must sign in before the stream drains — process
    start + imports cost real seconds), the stall goes LATE (a stalled
    lease holder near end-of-stream is the shape speculative re-lease
    exists for). Same seed -> same schedule, always."""
    rng = random.Random(int(seed))
    n_items = int(n_items)
    hi = max(1, n_items - 2)
    events = []
    for a in actions:
        if a == "join":
            after = rng.randint(1, min(2, hi))
        elif a == "stall":
            after = rng.randint(max(1, n_items - 3), hi)
        else:
            after = rng.randint(1, hi)
        events.append(ChaosEvent(after, a, round(rng.uniform(*stall_s), 2)))
    for _ in range(max(0, int(extra_events))):
        events.append(ChaosEvent(rng.randint(1, hi), rng.choice(actions),
                                 round(rng.uniform(*stall_s), 2)))
    order = {a: i for i, a in enumerate(actions)}
    events.sort(key=lambda e: (e.after_done, order[e.action]))
    return events


class ChaosRunner:
    """Consume `plan.run(stream)` on a thread while firing `schedule`
    against `plan.fleet`. Returns (results, fired_events).

    The plan is flipped to `elastic=True`: with a chaos driver attached,
    an all-dead instant is a moment between a kill and its replacement,
    not a verdict — the plan's stall timeout stays as the backstop."""

    def __init__(self, plan, stream, schedule, seed=0, poll_s=0.1,
                 defer_s=4.0):
        self.plan = plan
        self.stream = stream
        self.schedule = list(schedule)
        self.seed = int(seed)
        self.poll_s = float(poll_s)
        # how long kill/stall may wait for a lease-holding victim before
        # firing at whoever is alive
        self.defer_ticks = max(1, int(float(defer_s) / self.poll_s))
        plan.elastic = True
        self.fired: list[ChaosEvent] = []

    # -- targeting ----------------------------------------------------------
    def _active(self, fleet):
        """Live shards not on their way out (drained workers are dying by
        request — disrupting them proves nothing)."""
        out = []
        for k, h in fleet.live().items():
            st = fleet.service.workers.get(h.worker)
            if st is None or st.state == "active":
                out.append(k)
        return sorted(out)

    def _holders(self, fleet, shards):
        qs = fleet.service
        return [k for k in shards
                if qs.queue.leases_held(fleet.handles[k].worker)]

    def _ensure_capacity(self, fleet, losing):
        """About to remove the last active worker: spawn a replacement
        first (recorded as an extra join) so the stream keeps a path
        forward."""
        active = self._active(fleet)
        if len(active) - 1 < 1 and losing in active:
            h = fleet.spawn()
            ev = ChaosEvent(after_done=-1, action="join", fired=True,
                            target=h.shard)
            self.fired.append(ev)

    # -- firing -------------------------------------------------------------
    def _fire(self, ev: ChaosEvent, fleet, rng, done):
        if ev.action == "join":
            h = fleet.spawn()
            ev.target = h.shard
        else:
            # prefer fully-active victims; fall back to anything alive
            # (killing a draining worker is still legitimate chaos, and
            # the schedule's every-action guarantee must not starve)
            candidates = self._active(fleet) or sorted(fleet.live())
            if not candidates:
                ev.deferred += 1     # fleet momentarily empty; retry
                return ev.deferred > 10 * self.defer_ticks
            if ev.action in ("kill", "stall"):
                holders = self._holders(fleet, candidates)
                if not holders and ev.deferred < self.defer_ticks:
                    ev.deferred += 1     # wait for a victim holding work
                    return False
                pick = rng.choice(holders or candidates)
                if ev.action == "kill":
                    self._ensure_capacity(fleet, pick)
                    fleet.kill(pick)
                else:
                    fleet.stall(pick, ev.stall_s)
            else:                        # drain
                pick = rng.choice(candidates)
                self._ensure_capacity(fleet, pick)
                fleet.drain(pick)
            ev.target = pick
        ev.fired = True
        ev.fired_at_done = int(done)
        self.fired.append(ev)
        return True

    def run(self):
        results, err = [], []

        def consume():
            try:
                for res in self.plan.run(self.stream):
                    results.append(res)
            except BaseException as e:     # noqa: BLE001 — reraised below
                err.append(e)

        t = threading.Thread(target=consume, daemon=True,
                             name="chaos-consumer")
        t.start()
        # target choice is seeded separately from the schedule so adding
        # events to a schedule does not reshuffle every pick
        rng = random.Random(self.seed * 7919 + 13)
        pending = list(self.schedule)
        try:
            while t.is_alive():
                fleet = self.plan.fleet
                if fleet is None:           # plan still setting up
                    time.sleep(self.poll_s)
                    continue
                done, _total = fleet.service.progress()
                for ev in list(pending):
                    if done >= ev.after_done and not err:
                        if self._fire(ev, fleet, rng, done):
                            pending.remove(ev)
                t.join(self.poll_s)
        finally:
            if self.plan.fleet is not None:
                self.plan.fleet.resume_all()   # no stalled orphans
            t.join()
        if err:
            raise err[0]
        return results, self.fired
