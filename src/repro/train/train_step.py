"""Train-step builder: loss -> grads (with optional microbatch accumulation
and int8-EF gradient compression) -> clipped AdamW update.

The returned function is pure and donation-friendly:
  train_step(params, opt_state, batch) -> (params, opt_state, metrics)
and is jit'd by the caller with in/out shardings from the logical rules
(see launch/train.py and launch/dryrun.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train import optimizer as O
from repro.train import compression as C


def _split_microbatches(batch, n):
    def sp(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(model, rules, opt_cfg: O.OptConfig, num_microbatches=1,
                    compress_grads=False):
    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch, rules)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        mb = _split_microbatches(batch, num_microbatches)

        def body(acc, mbatch):
            (loss, metrics), grads = grad_fn(params, mbatch)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return acc, (loss, metrics)

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, (losses, metricses) = jax.lax.scan(body, acc0, mb)
        grads = jax.tree.map(lambda a: a / num_microbatches, acc)
        metrics = jax.tree.map(jnp.mean, metricses)
        return jnp.mean(losses), metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        if compress_grads:
            grads, residuals = C.compress_grads_ef(
                grads, opt_state["ef_residual"])
        params, inner, opt_metrics = O.apply_updates(
            opt_cfg, params, {k: v for k, v in opt_state.items()
                              if k != "ef_residual"}, grads)
        new_state = dict(inner)
        if compress_grads:
            new_state["ef_residual"] = residuals
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, new_state, metrics

    return train_step


def init_train_state(model, opt_cfg: O.OptConfig, key, compress_grads=False):
    params = model.init(key)
    opt_state = O.init_opt_state(opt_cfg, params)
    if compress_grads:
        opt_state["ef_residual"] = C.init_residuals(params)
    return params, opt_state


def train_state_specs(model, opt_cfg: O.OptConfig, compress_grads=False):
    pspecs = model.param_specs()
    ospecs = O.opt_state_specs(pspecs, opt_cfg.quantize_state)
    if compress_grads:
        ospecs["ef_residual"] = pspecs
    return pspecs, ospecs
