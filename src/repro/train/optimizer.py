"""Hand-rolled AdamW with optional 8-bit first moment (block-quantized) and
f32 master weights. No optax dependency — the optimizer state layout must be
shardable by our logical rules and checkpointable by repro.ckpt.

State layout (pytree mirroring params):
  master : f32 master copy of the (bf16) params
  m      : first moment  — f32, or {"codes": int8, "scale": f32} if quantized
  v      : second moment — f32, or bf16 if quantized ("8-bit Adam" profile)
  step   : scalar int32
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.train import compression as C


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantize_state: bool = False     # 8-bit m / bf16 v (memory compression)


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(cfg: OptConfig, params):
    def init_m(p):
        if cfg.quantize_state:
            return {"codes": jnp.zeros(p.shape, jnp.int8),
                    "scale": jnp.full(p.shape[:-1], 1e-12, jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    def init_v(p):
        dt = jnp.bfloat16 if cfg.quantize_state else jnp.float32
        return jnp.zeros(p.shape, dt)

    return {
        # copy=True: an f32 param would otherwise ALIAS its master (eager
        # astype is a no-op) and donation would see the same buffer twice
        "master": jax.tree.map(
            lambda p: jnp.array(p, jnp.float32, copy=True), params),
        "m": jax.tree.map(init_m, params),
        "v": jax.tree.map(init_v, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def _is_m_leaf(x):
    return isinstance(x, dict) and "codes" in x


def apply_updates(cfg: OptConfig, params, opt_state, grads):
    """One AdamW step. Returns (new bf16 params, new opt state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p_master, m, v, g):
        g32 = g.astype(jnp.float32)
        if _is_m_leaf(m):
            m_val = C.dequantize_rowwise_int8(m["codes"], m["scale"])
        else:
            m_val = m
        m_new = b1 * m_val + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_master = p_master - lr * (delta + cfg.weight_decay * p_master)
        if _is_m_leaf(m):
            codes, scale = C.quantize_rowwise_int8(m_new)
            m_out = {"codes": codes, "scale": scale}
            v_out = v_new.astype(jnp.bfloat16)
        else:
            m_out, v_out = m_new, v_new
        return new_master, m_out, v_out

    flat_p, tree = jax.tree.flatten(opt_state["master"])
    flat_m = tree.flatten_up_to(opt_state["m"])
    flat_v = tree.flatten_up_to(opt_state["v"])
    flat_g = tree.flatten_up_to(grads)
    new = [upd(p, m, v, g) for p, m, v, g in
           zip(flat_p, flat_m, flat_v, flat_g)]
    new_master = tree.unflatten([t[0] for t in new])
    new_m = tree.unflatten([t[1] for t in new])
    new_v = tree.unflatten([t[2] for t in new])
    new_params = jax.tree.map(
        lambda master, p: master.astype(p.dtype), new_master, params)
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}


def opt_state_specs(param_specs, quantize_state=False):
    """Logical-axis specs for the optimizer state (mirrors init_opt_state).

    Quantized m codes keep the tensor shape -> inherit the param spec; the
    per-row scales drop the last axis."""
    is_leaf = lambda v: isinstance(v, tuple)
    if quantize_state:
        m = jax.tree.map(lambda t: {"codes": t, "scale": t[:-1]},
                         param_specs, is_leaf=is_leaf)
    else:
        m = param_specs
    return {"master": param_specs, "m": m, "v": param_specs, "step": ()}
