"""Compression utilities for distributed optimization.

Two distinct mechanisms (see DESIGN.md §7):

1. Gradient wire compression. Parameters (and therefore grads) are bf16, so
   GSPMD's gradient all-reduces already move half the bytes of an f32
   framework — visible in the roofline collective term. For harsher
   compression, `quantize_ef`/`dequantize` implement int8 block quantization
   with ERROR FEEDBACK (the residual is carried and re-added next step), the
   standard convergence-preserving trick; tested on a quadratic in
   tests/test_compression.py.

2. Optimizer-state memory compression (8-bit Adam first moment, bf16 second
   moment, block-wise scales) — what lets arctic-480b's optimizer state
   approach single-pod HBM (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(flat):
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize_block_int8(x):
    """x: any shape f32/bf16 -> (int8 codes, f32 block scales, orig shape)."""
    flat = x.astype(jnp.float32).reshape(-1)
    flat, _ = _pad_to_block(flat)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale[:, 0]


def dequantize_block_int8(codes, scale, shape):
    flat = (codes.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def quantize_rowwise_int8(x):
    """Per-row (last-dim) int8 quantization that PRESERVES SHAPE — codes
    inherit the tensor's sharding spec (used for optimizer moments)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), axis=-1, keepdims=False) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(x32 / scale[..., None]), -127, 127)
    return codes.astype(jnp.int8), scale


def dequantize_rowwise_int8(codes, scale):
    return codes.astype(jnp.float32) * scale[..., None]


def quantize_ef(grad, residual):
    """Error-feedback int8 quantization of one gradient tensor.

    Returns (codes, scale, new_residual). dequantize(codes) + new_residual
    == grad + residual (up to float error)."""
    g = grad.astype(jnp.float32) + residual
    codes, scale = quantize_block_int8(g)
    deq = dequantize_block_int8(codes, scale, g.shape)
    return codes, scale, g - deq


def compress_grads_ef(grads, residuals):
    """Tree-wise error-feedback int8 round-trip (emulating the compressed
    all-reduce payload). Returns (dequantized grads, new residuals)."""
    flat_g, tree = jax.tree.flatten(grads)
    flat_r = tree.flatten_up_to(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        codes, scale, new_r = quantize_ef(g, r)
        out_g.append(dequantize_block_int8(codes, scale, g.shape))
        out_r.append(new_r)
    return tree.unflatten(out_g), tree.unflatten(out_r)


def init_residuals(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
