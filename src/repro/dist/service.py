"""QueueService: the master's serviceable surface over one shared WorkQueue.

The paper's master owns three things: the file list (here: the leased
`WorkQueue`), the data hand-off to slaves (here: `fetch`), and the result
collection that gates what counts as done (here: `push_result` + the
master-side `pop_results` drain). `QueueService` packages exactly that as a
set of named methods a transport can serve — `RPC_METHODS` is the whole
wire surface, nothing else on the object is reachable remotely.

It also DUCK-TYPES the WorkQueue it wraps (lease / complete /
heartbeat_extend / fail_worker / state / next_deadline / progress /
finished / clock / lease_timeout_s / redeliveries), so the in-process
simulated path can route every queue mutation through the service and the
per-worker accounting accrues identically under both transports. All
compound operations take the queue's own RLock, so N transport handler
threads and the master loop interleave safely.
"""
from __future__ import annotations

import collections
import hashlib
import os
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.graph import PipelineOutput
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing

# The complete remote surface. A transport must refuse anything else —
# the service object carries master-side state (result inbox, kill hooks)
# that workers have no business reaching. `metrics` is read-only: a
# snapshot of the master's registry (scrape endpoint over the transport).
RPC_METHODS = frozenset({
    "hello", "lease", "fetch", "fetch_many", "complete", "push_result",
    "heartbeat", "fail_worker", "state", "progress", "finished",
    "next_deadline", "bye", "metrics",
})


@dataclass
class WorkerStats:
    """Per-worker progress ledger (the launch driver's end-of-run summary).

    `leases_held` / `redeliveries` / `last_beat_age_s` are filled in by
    `QueueService.worker_report()` at snapshot time; the rest accrue as the
    worker talks to the service."""
    worker: str
    shard: int = -1
    pid: int = None
    lease_calls: int = 0            # queue round-trips (Table 7's axis)
    leased_total: int = 0           # work ids ever granted
    chunks_done: int = 0            # results ACCEPTED by the master (the
                                    # completion gate, not raw pushes — a
                                    # redelivery race's duplicate push is
                                    # not work done)
    idle_s: float = 0.0             # worker-reported: blocked on the queue
    busy_s: float = 0.0             # worker-reported: computing
    last_beat: float = field(default=None, repr=False)
    # snapshot-time fields (worker_report):
    leases_held: int = 0
    redeliveries: int = 0
    last_beat_age_s: float = None


class QueueService:
    """Master-side service: the WorkQueue plus the data/result planes.

    Parameters:
      queue       the shared WorkQueue (its RLock serializes everything)
      fetch_item  wid -> chunk batch (np.ndarray) — the data plane; the
                  master materialises/regenerates the bytes, workers never
                  see the loader (the paper's master hands slaves files)
      setup       picklable blob returned from `hello` — everything a
                  worker needs to build its jits (cfg, stage names,
                  pad_multiple, bucket, kernel backend mode)
      monitor     optional ft.failure.HeartbeatMonitor fed on heartbeats
      telemetry   optional repro.obs.telemetry.TelemetryWriter — per-chunk
                  records written MASTER-side at acceptance/redelivery so
                  they survive SIGKILLed workers
    """

    def __init__(self, queue, fetch_item=None, setup=None, monitor=None,
                 telemetry=None):
        self.queue = queue
        self._fetch_item = fetch_item
        self._setup = dict(setup or {})
        self.monitor = monitor
        self.telemetry = telemetry
        self.workers: dict[str, WorkerStats] = {}
        self.lease_calls = 0
        self._results = collections.deque()
        # per-chunk event times (lease/fetch/push, content key), keyed by
        # wid; popped into a durable telemetry record at acceptance.
        self._timeline: dict[int, dict] = {}
        # Observe redeliveries at the source: the queue fires this under
        # its own lock for BOTH reclaim paths (expiry and fail_worker),
        # including direct fail_worker calls on the raw queue.
        queue.on_redeliver = self._on_redeliver
        # master-side hook, called INSIDE lease() once per granted work id
        # with (worker, wid): the CrashInjector's process-mode trigger — a
        # doomed worker is SIGKILLed while its fresh lease is registered
        # and un-completed, so recovery exercises the real redelivery path.
        self.on_grant = None

    # -- bookkeeping --------------------------------------------------------
    def _w(self, worker) -> WorkerStats:
        st = self.workers.get(worker)
        if st is None:
            st = self.workers[worker] = WorkerStats(worker)
        return st

    def note_beat(self, worker):
        """Record liveness WITHOUT extending lease deadlines (the simulated
        in-process path beats once per round; extending there would change
        redelivery timing, which the proc path deliberately does via
        `heartbeat`)."""
        with self.queue.lock:
            self._w(worker).last_beat = self.queue.clock()
        if self.monitor is not None:
            self.monitor.beat(worker)

    def note_done(self, worker, n=1, wid=None, survivors=None,
                  bytes_out=None):
        """Credit accepted work to `worker`. Callers that know WHICH chunk
        was accepted pass `wid` (+ survivor count / output bytes): that is
        the acceptance point, so the durable per-chunk telemetry record —
        with the full lease→fetch→push→accept timeline — is written here,
        master-side, exactly once per chunk (acceptance is gated on
        `WorkQueue.complete` returning the id as newly-done)."""
        with self.queue.lock:
            st = self._w(worker)
            st.chunks_done += n
            obs_metrics.counter(
                "dist_chunks_done_total",
                "results accepted by the master", ("worker",)
            ).labels(worker=worker).inc(n)
            if self.telemetry is not None and wid is not None:
                tl = self._timeline.pop(wid, {})
                self.telemetry.record(
                    event="chunk", status="done", wid=int(wid),
                    worker=worker, shard=st.shard, pid=st.pid,
                    content_key=tl.get("content_key"),
                    lease_ts=tl.get("lease_ts"), fetch_ts=tl.get("fetch_ts"),
                    push_ts=tl.get("push_ts"), accept_ts=time.time(),
                    survivors=None if survivors is None else int(survivors),
                    bytes_in=tl.get("bytes_in"),
                    bytes_out=None if bytes_out is None else int(bytes_out),
                    redelivered=int(tl.get("redelivered", 0)))

    def _on_redeliver(self, wid, worker, reason):
        """Queue-level reclaim hook (fires under the queue lock): count
        the redelivery and durably attribute the LOSING incarnation, so a
        SIGKILLed worker's half-processed chunk shows both attempts."""
        obs_metrics.counter(
            "dist_redeliveries_total", "leases reclaimed",
            ("worker", "reason")).labels(worker=worker, reason=reason).inc()
        if self.telemetry is None:
            return
        st = self.workers.get(worker)
        tl = self._timeline.get(wid, {})
        self.telemetry.record(
            event="chunk", status="redelivered", reason=reason,
            wid=int(wid), worker=worker,
            shard=st.shard if st else -1, pid=st.pid if st else None,
            content_key=tl.get("content_key"),
            lease_ts=tl.get("lease_ts"), fetch_ts=tl.get("fetch_ts"))
        # the next lease of this wid starts a fresh timeline but keeps the
        # redelivery count, so the eventual "done" record carries it
        self._timeline[wid] = {"redelivered": tl.get("redelivered", 0) + 1}

    # -- RPC surface --------------------------------------------------------
    def hello(self, worker, pid=None, shard=-1):
        """Worker sign-in: registers identity, returns the setup blob.
        When the master has a live tracer, its propagation context (trace
        id + run-span parent id) rides along under "trace" — that is how
        worker-side spans get parented under the master's run span across
        the pickle boundary."""
        with self.queue.lock:
            st = self._w(worker)
            st.pid, st.shard = pid, int(shard)
            st.last_beat = self.queue.clock()
        prop = obs_tracing.get_tracer().propagate()
        if prop is None:
            return self._setup
        setup = dict(self._setup)
        setup["trace"] = prop
        return setup

    def lease(self, worker, max_items=1):
        with self.queue.lock:
            ids = self.queue.lease(worker, max_items)
            st = self._w(worker)
            st.lease_calls += 1
            st.leased_total += len(ids)
            st.last_beat = self.queue.clock()
            self.lease_calls += 1
            obs_metrics.counter(
                "dist_lease_calls_total", "queue round-trips",
                ("worker",)).labels(worker=worker).inc()
            if ids:
                obs_metrics.counter(
                    "dist_leased_ids_total", "work ids granted",
                    ("worker",)).labels(worker=worker).inc(len(ids))
            if self.telemetry is not None and ids:
                now = time.time()
                for wid in ids:
                    tl = self._timeline.setdefault(wid, {})
                    tl["lease_ts"] = now
                    tl["worker"] = worker
        if self.monitor is not None:
            self.monitor.beat(worker)
        hook = self.on_grant
        if hook is not None:
            for wid in ids:
                hook(worker, wid)
        return ids

    def fetch(self, wid):
        """Data plane: the chunk batch for one leased work id."""
        if self._fetch_item is None:
            raise RuntimeError("this QueueService serves no data plane "
                               "(no fetch_item)")
        item = self._fetch_item(wid)
        if self.telemetry is not None and item is not None:
            raw = np.ascontiguousarray(item)
            with self.queue.lock:
                tl = self._timeline.setdefault(wid, {})
                tl["fetch_ts"] = time.time()
                tl["bytes_in"] = int(raw.nbytes)
                tl["content_key"] = hashlib.sha256(
                    raw.tobytes()).hexdigest()[:16]
        return item

    def fetch_many(self, worker, wids):
        """Batched data plane: one round-trip for a whole lease batch
        (without this, lease_items > 1 would amortize the lease call only
        to re-pay per-item fetch RTTs). Doubles as a heartbeat — the
        worker is provably alive and about to be busy for a while."""
        items = [self.fetch(wid) for wid in wids]
        self.heartbeat(worker)
        return items

    def complete(self, work_ids):
        return self.queue.complete(work_ids)

    def push_result(self, worker, wid, payload):
        """Result plane: worker hands back one finished work id. The
        master drains with `pop_results` and gates emission on
        `queue.complete`, so pushes from a redelivery race are accepted
        here and discarded there — exactly-once stays the master's call
        (and so does `chunks_done` credit, via `note_done`). Each push
        extends the worker's remaining leases: mid-batch progress IS a
        heartbeat."""
        with self.queue.lock:
            self.queue.heartbeat_extend(worker)
            self._w(worker).last_beat = self.queue.clock()
            self._results.append((worker, wid, payload))
            obs_metrics.counter(
                "dist_pushes_total", "results pushed (pre-acceptance)",
                ("worker",)).labels(worker=worker).inc()
            if self.telemetry is not None:
                self._timeline.setdefault(wid, {})["push_ts"] = time.time()
        if self.monitor is not None:
            self.monitor.beat(worker)
        return True

    def heartbeat(self, worker):
        with self.queue.lock:
            self.queue.heartbeat_extend(worker)
            self._w(worker).last_beat = self.queue.clock()
        if self.monitor is not None:
            self.monitor.beat(worker)
        return True

    def fail_worker(self, worker):
        return self.queue.fail_worker(worker)

    def state(self):
        return self.queue.state()

    def progress(self):
        return self.queue.progress()

    @property
    def finished(self):
        return self.queue.finished

    def next_deadline(self):
        return self.queue.next_deadline()

    def bye(self, worker, stats=None):
        """Worker sign-off with its idle/busy split (per-worker idle time
        is a Table 7 observable: deeper lease batches shrink it). A worker
        that traced locally ships its buffered span events here
        (stats["spans"]) — the master merges them into its tracer, which
        is how worker spans cross the pickle boundary."""
        with self.queue.lock:
            st = self._w(worker)
            for k in ("idle_s", "busy_s"):
                if stats and k in stats:
                    setattr(st, k, float(stats[k]))
        if stats and stats.get("spans"):
            obs_tracing.get_tracer().add_events(stats["spans"])
        return True

    def metrics(self, render=False):
        """Read-only scrape of the master's metrics registry: a JSON/
        pickle-safe snapshot, or Prometheus text when `render` is set."""
        reg = obs_metrics.get_registry()
        return reg.render() if render else reg.snapshot()

    # -- master-side (NOT served) -------------------------------------------
    def pop_results(self):
        """Drain the result inbox: [(worker, wid, payload), ...]."""
        out = []
        with self.queue.lock:
            while self._results:
                out.append(self._results.popleft())
        return out

    def worker_report(self):
        """Snapshot of every known worker's progress, sorted by shard:
        leases held right now, chunks done, redeliveries charged to it,
        seconds since its last heartbeat."""
        with self.queue.lock:
            now = self.queue.clock()
            out = []
            for st in self.workers.values():
                st.leases_held = len(self.queue.leases_held(st.worker))
                st.redeliveries = int(
                    self.queue.redelivered_from.get(st.worker, 0))
                st.last_beat_age_s = (None if st.last_beat is None
                                      else float(now - st.last_beat))
                out.append(st)
            return sorted(out, key=lambda s: (s.shard, s.worker))

    # -- WorkQueue duck-typing extras (simulated in-process path) -----------
    def heartbeat_extend(self, worker):
        self.heartbeat(worker)

    def leases_held(self, worker):
        return self.queue.leases_held(worker)

    @property
    def clock(self):
        return self.queue.clock

    @property
    def lease_timeout_s(self):
        return self.queue.lease_timeout_s

    @property
    def redeliveries(self):
        return self.queue.redeliveries

    @property
    def redelivered_from(self):
        return self.queue.redelivered_from

    @property
    def n_items(self):
        return self.queue.n_items


# -------------------------------------------------------- result protocol

def pack_result(res) -> dict:
    """BatchResult -> picklable payload (mirrors the store-entry layout:
    masks + stats + cleaned survivors; the pre-denoise wave5 intermediate
    never crosses the process boundary — only its width does, so the
    master can rebuild a shape-correct det record)."""
    det = res.det
    return {
        "cleaned": np.asarray(res.cleaned, np.float32),
        "keep": np.asarray(det.keep), "rain": np.asarray(det.rain),
        "silence": np.asarray(det.silence),
        "cicada15": np.asarray(det.cicada15),
        "stats": {k: (int(v) if k == "n_chunks5" else float(v))
                  for k, v in det.stats.items()},
        "n_kept": int(res.n_kept), "src_bytes": int(res.src_bytes),
        "wave_width": int(det.wave5.shape[-1]),
    }


def unpack_result(payload):
    """payload -> (PipelineOutput, fields) — fields carries cleaned /
    n_kept / src_bytes for the master's BatchResult. wave5 is zero-filled
    at the recorded shape, the same convention CachedPlan uses for store
    hits: it is an intermediate no downstream consumer reads."""
    keep = payload["keep"]
    wave5 = np.zeros((keep.shape[0], int(payload["wave_width"])),
                     np.float32)
    det = PipelineOutput(wave5=wave5, keep=keep, rain=payload["rain"],
                         silence=payload["silence"],
                         cicada15=payload["cicada15"],
                         stats=dict(payload["stats"]))
    return det, {"cleaned": payload["cleaned"],
                 "n_kept": int(payload["n_kept"]),
                 "src_bytes": int(payload["src_bytes"])}
