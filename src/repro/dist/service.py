"""QueueService: the master's serviceable surface over one shared WorkQueue.

The paper's master owns three things: the file list (here: the leased
`WorkQueue`), the data hand-off to slaves (here: `fetch`), and the result
collection that gates what counts as done (here: `push_result` + the
master-side `pop_results` drain). `QueueService` packages exactly that as a
set of named methods a transport can serve — `RPC_METHODS` is the whole
wire surface, nothing else on the object is reachable remotely.

It also DUCK-TYPES the WorkQueue it wraps (lease / complete /
heartbeat_extend / fail_worker / state / next_deadline / progress /
finished / clock / lease_timeout_s / redeliveries), so the in-process
simulated path can route every queue mutation through the service and the
per-worker accounting accrues identically under both transports. All
compound operations take the queue's own RLock, so N transport handler
threads and the master loop interleave safely.
"""
from __future__ import annotations

import collections
import hashlib
import os
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.graph import PipelineOutput
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing

# The complete remote surface. A transport must refuse anything else —
# the service object carries master-side state (result inbox, kill hooks)
# that workers have no business reaching. `metrics` is read-only: a
# snapshot of the master's registry (scrape endpoint over the transport).
# `drain`/`draining` are the graceful-leave pair: a departing worker (or
# the master's autoscaler) calls `drain`, the worker polls `draining` and
# exits once its held leases are finished. `lease_chunks` is the store
# data plane's lease: grants ride back as (wid, content key) pairs so the
# socket never carries chunk bytes.
RPC_METHODS = frozenset({
    "hello", "lease", "lease_chunks", "fetch", "fetch_many", "complete",
    "push_result", "heartbeat", "fail_worker", "state", "progress",
    "finished", "next_deadline", "bye", "metrics", "drain", "draining",
})

# Worker membership states (WorkerStats.state). Transitions bump the
# service's membership epoch and are mirrored into the metrics registry.
WORKER_STATES = ("active", "draining", "departed", "dead")


@dataclass
class WorkerStats:
    """Per-worker progress ledger (the launch driver's end-of-run summary).

    `leases_held` / `redeliveries` / `last_beat_age_s` are filled in by
    `QueueService.worker_report()` at snapshot time; the rest accrue as the
    worker talks to the service."""
    worker: str
    shard: int = -1
    pid: int = None
    state: str = "active"           # membership: active/draining/departed/dead
    lease_calls: int = 0            # queue round-trips (Table 7's axis)
    leased_total: int = 0           # work ids ever granted
    chunks_done: int = 0            # results ACCEPTED by the master (the
                                    # completion gate, not raw pushes — a
                                    # redelivery race's duplicate push is
                                    # not work done)
    idle_s: float = 0.0             # worker-reported: blocked on the queue
    busy_s: float = 0.0             # worker-reported: computing
    last_beat: float = field(default=None, repr=False)
    # snapshot-time fields (worker_report):
    leases_held: int = 0
    redeliveries: int = 0
    last_beat_age_s: float = None


class QueueService:
    """Master-side service: the WorkQueue plus the data/result planes.

    Parameters:
      queue       the shared WorkQueue (its RLock serializes everything)
      fetch_item  wid -> chunk batch (np.ndarray) — the data plane; the
                  master materialises/regenerates the bytes, workers never
                  see the loader (the paper's master hands slaves files)
      setup       picklable blob returned from `hello` — everything a
                  worker needs to build its jits (cfg, stage names,
                  pad_multiple, bucket, kernel backend mode)
      monitor     optional ft.failure.HeartbeatMonitor fed on heartbeats
      telemetry   optional repro.obs.telemetry.TelemetryWriter — per-chunk
                  records written MASTER-side at acceptance/redelivery so
                  they survive SIGKILLed workers
      straggler   optional ft.failure.StragglerDetector — arms speculative
                  re-lease: fed a start per granted id and a complete per
                  retirement; when an ACTIVE worker's lease comes back
                  empty with work still in flight (the end-of-stream
                  shape), the slowest flagged item is duplicated to that
                  idle worker via `WorkQueue.speculate`

    Membership: `hello`/`bye`/`drain` and observed deaths drive a real
    registry — per-worker `state` on WorkerStats plus a monotonically
    increasing `epoch` that bumps on every join/leave/death, mirrored into
    the metrics registry (`dist_membership_epoch`, `dist_workers{state}`).
    Late joiners are first-class: a `hello` mid-run gets the SAME setup
    blob the original fleet got and leases from the same queue.
    """

    def __init__(self, queue, fetch_item=None, setup=None, monitor=None,
                 telemetry=None, straggler=None, data_plane=None):
        self.queue = queue
        self._fetch_item = fetch_item
        self._setup = dict(setup or {})
        self.monitor = monitor
        self.telemetry = telemetry
        self.straggler = straggler
        # optional StoreDataPlane: when set, workers lease via
        # `lease_chunks` (keys, not bytes) and push tiny store refs — the
        # control socket stops carrying chunk payloads entirely.
        self.data_plane = data_plane
        self.workers: dict[str, WorkerStats] = {}
        # registry assignment state: pid -> shard reservations made
        # master-side at spawn, and the next free shard id for workers
        # that join with no reservation (a hand-started remote worker).
        self._reserved: dict[int, int] = {}
        self._next_shard = 0
        # wid -> offered store key (lease_chunks): a redelivered or
        # speculated lease re-offers without re-hashing the batch.
        self._offered: dict[int, str] = {}
        self.lease_calls = 0
        # membership epoch: a version counter over the worker set; every
        # join, drain, departure, and observed death bumps it (gauged as
        # dist_membership_epoch so dashboards see churn, not just counts)
        self.epoch = 0
        self._results = collections.deque()
        # per-chunk event times (lease/fetch/push, content key), keyed by
        # wid; popped into a durable telemetry record at acceptance.
        self._timeline: dict[int, dict] = {}
        # Observe redeliveries at the source: the queue fires this under
        # its own lock for BOTH reclaim paths (expiry and fail_worker),
        # including direct fail_worker calls on the raw queue.
        queue.on_redeliver = self._on_redeliver
        # Observe retirements at the source for the same reason: the
        # detector's latency history must accrue no matter which emit
        # loop (proc, sim, pool) completes the id.
        queue.on_complete = self._on_complete
        # master-side hook, called INSIDE lease() once per granted work id
        # with (worker, wid): the CrashInjector's process-mode trigger — a
        # doomed worker is SIGKILLed while its fresh lease is registered
        # and un-completed, so recovery exercises the real redelivery path.
        self.on_grant = None

    # -- bookkeeping --------------------------------------------------------
    def _w(self, worker) -> WorkerStats:
        st = self.workers.get(worker)
        if st is None:
            st = self.workers[worker] = WorkerStats(worker)
        return st

    # -- membership registry ------------------------------------------------
    def _set_state(self, st: WorkerStats, state: str):
        """Transition one worker's membership state; bumps the epoch and
        re-publishes the membership gauges only on a real change."""
        if st.state == state:
            return
        st.state = state
        self.epoch += 1
        self._publish_membership()

    def _publish_membership(self):
        reg = obs_metrics.get_registry()
        if not reg.enabled:
            return
        by_state = collections.Counter(st.state for st in
                                       self.workers.values())
        g = reg.gauge("dist_workers", "registered workers by membership "
                      "state", ("state",))
        for s in WORKER_STATES:
            g.labels(state=s).set(by_state.get(s, 0))
        reg.gauge("dist_membership_epoch",
                  "membership version: bumps on every join/drain/"
                  "departure/death").set(self.epoch)

    def active_workers(self):
        """Names of workers currently in state 'active'."""
        with self.queue.lock:
            return sorted(w for w, st in self.workers.items()
                          if st.state == "active")

    def note_beat(self, worker):
        """Record liveness WITHOUT extending lease deadlines (the simulated
        in-process path beats once per round; extending there would change
        redelivery timing, which the proc path deliberately does via
        `heartbeat`)."""
        with self.queue.lock:
            self._w(worker).last_beat = self.queue.clock()
        if self.monitor is not None:
            self.monitor.beat(worker)

    def note_done(self, worker, n=1, wid=None, survivors=None,
                  bytes_out=None):
        """Credit accepted work to `worker`. Callers that know WHICH chunk
        was accepted pass `wid` (+ survivor count / output bytes): that is
        the acceptance point, so the durable per-chunk telemetry record —
        with the full lease→fetch→push→accept timeline — is written here,
        master-side, exactly once per chunk (acceptance is gated on
        `WorkQueue.complete` returning the id as newly-done)."""
        with self.queue.lock:
            st = self._w(worker)
            st.chunks_done += n
            obs_metrics.counter(
                "dist_chunks_done_total",
                "results accepted by the master", ("worker",)
            ).labels(worker=worker).inc(n)
            if self.telemetry is not None and wid is not None:
                tl = self._timeline.pop(wid, {})
                self.telemetry.record(
                    event="chunk", status="done", wid=int(wid),
                    worker=worker, shard=st.shard, pid=st.pid,
                    content_key=tl.get("content_key"),
                    lease_ts=tl.get("lease_ts"), fetch_ts=tl.get("fetch_ts"),
                    push_ts=tl.get("push_ts"), accept_ts=time.time(),
                    survivors=None if survivors is None else int(survivors),
                    bytes_in=tl.get("bytes_in"),
                    bytes_out=None if bytes_out is None else int(bytes_out),
                    redelivered=int(tl.get("redelivered", 0)),
                    speculated=int(tl.get("speculated", 0)))

    def _on_redeliver(self, wid, worker, reason):
        """Queue-level reclaim hook (fires under the queue lock): count
        the redelivery and durably attribute the LOSING incarnation, so a
        SIGKILLed worker's half-processed chunk shows both attempts. A
        "speculated" reason is the first-completion-wins race resolving:
        the id is ALREADY done, so the record attributes the loser but the
        timeline is left for the winner's `done` record (written next)."""
        obs_metrics.counter(
            "dist_redeliveries_total", "leases reclaimed",
            ("worker", "reason")).labels(worker=worker, reason=reason).inc()
        if self.telemetry is None:
            return
        st = self.workers.get(worker)
        tl = self._timeline.get(wid, {})
        self.telemetry.record(
            event="chunk", status="redelivered", reason=reason,
            wid=int(wid), worker=worker,
            shard=st.shard if st else -1, pid=st.pid if st else None,
            content_key=tl.get("content_key"),
            lease_ts=tl.get("lease_ts"), fetch_ts=tl.get("fetch_ts"))
        if reason == "speculated":
            return
        # the next lease of this wid starts a fresh timeline but keeps the
        # redelivery and speculation counts, so the eventual "done" record
        # carries them
        self._timeline[wid] = {
            "redelivered": tl.get("redelivered", 0) + 1,
            "speculated": tl.get("speculated", 0)}

    # -- RPC surface --------------------------------------------------------
    def reserve(self, pid, shard):
        """Master-side (NOT served): pin the shard id a spawned process
        will be assigned when its `hello` lands. The spawn path calls
        this right after Popen, long before the child can finish its
        interpreter start-up, so handles/injectors keyed by shard stay
        valid without a shard ever riding argv."""
        with self.queue.lock:
            self._reserved[int(pid)] = int(shard)
            self._next_shard = max(self._next_shard, int(shard) + 1)

    def hello(self, worker=None, pid=None, shard=-1):
        """Worker sign-in: registers identity, returns the setup blob —
        the SAME blob whether the worker is part of the original fleet or
        joins a run already in progress (late joiners are how an elastic
        fleet absorbs churn). A rejoin after departure/death is a fresh
        incarnation: state returns to active and the epoch bumps.

        With `worker=None` the caller is ANNOUNCING, not asserting, its
        identity (the saxml join pattern): the registry assigns it the
        shard reserved for its pid at spawn — or the next free id for a
        walk-up joiner — and ships the assignment back in the setup blob
        under "assigned". When the master has a live tracer, its
        propagation context rides along under "trace"; when a store data
        plane is configured, its spec rides under "data_plane"."""
        assigned = None
        with self.queue.lock:
            if worker is None:
                shard = self._reserved.pop(int(pid), None) \
                    if pid is not None else None
                if shard is None:
                    shard = self._next_shard
                self._next_shard = max(self._next_shard, int(shard) + 1)
                worker = f"shard{int(shard)}"
                assigned = {"worker": worker, "shard": int(shard)}
            elif int(shard) >= 0:
                # explicit identities keep the assignment counter ahead
                # so a later announce never collides with them
                self._next_shard = max(self._next_shard, int(shard) + 1)
            known = worker in self.workers
            st = self._w(worker)
            st.pid, st.shard = pid, int(shard)
            st.last_beat = self.queue.clock()
            if not known or st.state != "active":
                obs_metrics.counter(
                    "dist_workers_joined_total",
                    "workers that signed in (first hello or rejoin)",
                    ("worker",)).labels(worker=worker).inc()
                st.state = "active"
                self.epoch += 1
                self._publish_membership()
        prop = obs_tracing.get_tracer().propagate()
        if prop is None and assigned is None and self.data_plane is None:
            return self._setup
        setup = dict(self._setup)
        if prop is not None:
            setup["trace"] = prop
        if assigned is not None:
            setup["assigned"] = assigned
        if self.data_plane is not None:
            setup["data_plane"] = self.data_plane.spec()
        return setup

    def lease(self, worker, max_items=1):
        with self.queue.lock:
            st = self._w(worker)
            st.lease_calls += 1
            st.last_beat = self.queue.clock()
            self.lease_calls += 1
            obs_metrics.counter(
                "dist_lease_calls_total", "queue round-trips",
                ("worker",)).labels(worker=worker).inc()
            if st.state != "active":
                # draining (or formally departed) workers take no more
                # work — an empty lease + the `draining` poll is their
                # exit signal once held leases are finished
                return []
            ids = self.queue.lease(worker, max_items)
            if not ids:
                # end-of-stream shape: nothing pending but work still in
                # flight, and THIS worker is idle — the backup-task rule
                # duplicates the slowest flagged in-flight item onto it
                ids = self._speculate_for(worker)
            if self.straggler is not None:
                for wid in ids:
                    self.straggler.start(wid)
            st.leased_total += len(ids)
            if ids:
                obs_metrics.counter(
                    "dist_leased_ids_total", "work ids granted",
                    ("worker",)).labels(worker=worker).inc(len(ids))
            if self.telemetry is not None and ids:
                now = time.time()
                for wid in ids:
                    tl = self._timeline.setdefault(wid, {})
                    tl["lease_ts"] = now
                    tl["worker"] = worker
        if self.monitor is not None:
            self.monitor.beat(worker)
        hook = self.on_grant
        if hook is not None:
            for wid in ids:
                hook(worker, wid)
        return ids

    def _speculate_for(self, worker):
        """Try to grant `worker` a speculative duplicate lease on the
        slowest straggling in-flight id. Returns [wid] or []. Called with
        the queue lock held, from an empty normal lease."""
        if self.straggler is None:
            return []
        for wid in self.straggler.stragglers():
            if self.queue.speculate(worker, wid):
                obs_metrics.counter(
                    "dist_speculations_total",
                    "speculative duplicate leases granted",
                    ("worker",)).labels(worker=worker).inc()
                # the eventual `done` record carries the speculation count
                # no matter which incarnation wins
                tl = self._timeline.setdefault(wid, {})
                tl["speculated"] = tl.get("speculated", 0) + 1
                return [wid]
        return []

    def _on_complete(self, wids):
        """Queue-level retirement hook (fires under the queue lock):
        closes the straggler detector's latency samples so its rolling
        p95 reflects every completion path."""
        if self.straggler is not None:
            for wid in wids:
                self.straggler.complete(wid)
        for wid in wids:           # retired ids never get re-offered
            self._offered.pop(wid, None)

    def lease_chunks(self, worker, max_items=1):
        """Store-plane lease: grant work ids AND publish their raw chunk
        batches to the shared store in the same round-trip, returning
        [[wid, key], ...] — the socket carries content keys (~70 bytes),
        never the batches. A key of None means the id retired between
        grant and offer (a redelivery race); the worker skips it. This is
        the whole data plane collapsed into the lease call: zero
        `fetch`/`fetch_many` round-trips remain."""
        if self.data_plane is None:
            raise RuntimeError("this QueueService has no store data plane")
        ids = self.lease(worker, max_items)
        with self.queue.lock:    # one pass over the key manifest, not per-item
            cached = {wid: self._offered.get(wid) for wid in ids}
        out, fresh = [], {}
        for wid in ids:
            item = self._materialize(wid)
            if item is None:     # retired between grant and offer
                out.append([wid, None])
                continue
            key = cached.get(wid)
            if key is None:      # first offer: hash + publish once
                key = fresh[wid] = self.data_plane.offer(wid, item)
            self._note_fetch(wid, item, plane="store", key=key)
            out.append([wid, key])
        if fresh:
            with self.queue.lock:
                self._offered.update(fresh)
        return out

    def _materialize(self, wid):
        """wid -> chunk batch via the master's loader (None when retired)."""
        if self._fetch_item is None:
            raise RuntimeError("this QueueService serves no data plane "
                               "(no fetch_item)")
        return self._fetch_item(wid)

    def _note_fetch(self, wid, item, plane, key=None):
        """Per-item data-plane accounting: the socket plane is charged
        the batch's bytes, the store plane only the key that replaced
        them — `dist_fetch_bytes_total{plane}` is how the smoke gate
        proves the ≥90% cut."""
        raw = np.ascontiguousarray(item)
        wire = len(key) if plane == "store" else int(raw.nbytes)
        obs_metrics.counter(
            "dist_fetch_bytes_total",
            "data-plane bytes the master's socket carried for chunk "
            "fetches", ("plane",)).labels(plane=plane).inc(wire)
        if self.telemetry is not None:
            with self.queue.lock:
                tl = self._timeline.setdefault(wid, {})
                tl["fetch_ts"] = time.time()
                tl["bytes_in"] = int(raw.nbytes)
                tl["content_key"] = key[:21] if key is not None else \
                    hashlib.sha256(raw.tobytes()).hexdigest()[:16]

    def fetch(self, wid):
        """Data plane (socket plane): the chunk batch for one leased work
        id, materialized master-side and shipped over the control socket."""
        item = self._materialize(wid)
        if item is not None:
            self._note_fetch(wid, item, plane="socket")
        return item

    def fetch_many(self, worker, wids):
        """Batched data plane: one round-trip for a whole lease batch
        (without this, lease_items > 1 would amortize the lease call only
        to re-pay per-item fetch RTTs). One server-side pass — the batch
        is materialized and accounted item by item but heartbeats ONCE,
        and with a store data plane configured it degrades gracefully to
        the socket-plane fallback (the bytes still flow, still counted).
        Doubles as a heartbeat — the worker is provably alive and about
        to be busy for a while."""
        items = [self._materialize(wid) for wid in wids]
        for wid, item in zip(wids, items):
            if item is not None:
                self._note_fetch(wid, item, plane="socket")
        self.heartbeat(worker)
        return items

    def complete(self, work_ids, worker=None):
        return self.queue.complete(work_ids, worker=worker)

    def drain(self, worker):
        """Graceful leave: `worker` finishes the leases it holds and takes
        no more. Caller may be the worker itself (a node being
        decommissioned announces its own exit) or the master's autoscaler.
        The worker's runtime polls `draining` and exits once its lease
        comes back empty — the same exit shape as `finished`, scoped to
        one worker."""
        with self.queue.lock:
            st = self._w(worker)
            if st.state == "active":
                obs_metrics.counter(
                    "dist_workers_drained_total",
                    "workers asked to leave gracefully",
                    ("worker",)).labels(worker=worker).inc()
                self._set_state(st, "draining")
        return True

    def draining(self, worker) -> bool:
        """Worker-side poll: has this worker been asked to leave?"""
        with self.queue.lock:
            st = self.workers.get(worker)
            return st is not None and st.state in ("draining", "departed")

    def push_result(self, worker, wid, payload):
        """Result plane: worker hands back one finished work id. The
        master drains with `pop_results` and gates emission on
        `queue.complete`, so pushes from a redelivery race are accepted
        here and discarded there — exactly-once stays the master's call
        (and so does `chunks_done` credit, via `note_done`). Each push
        extends the worker's remaining leases: mid-batch progress IS a
        heartbeat. On the store data plane the payload is a tiny
        `{"store_key": ...}` ref (the result bytes went to the shared
        store); either way the bytes this socket carried are counted
        under `dist_push_bytes_total{plane}`."""
        plane = ("store" if isinstance(payload, dict)
                 and "store_key" in payload else "socket")
        obs_metrics.counter(
            "dist_push_bytes_total",
            "data-plane bytes the master's socket carried for result "
            "pushes", ("plane",)).labels(plane=plane).inc(
                _payload_nbytes(payload))
        with self.queue.lock:
            self.queue.heartbeat_extend(worker)
            self._w(worker).last_beat = self.queue.clock()
            self._results.append((worker, wid, payload))
            obs_metrics.counter(
                "dist_pushes_total", "results pushed (pre-acceptance)",
                ("worker",)).labels(worker=worker).inc()
            if self.telemetry is not None:
                self._timeline.setdefault(wid, {})["push_ts"] = time.time()
        if self.monitor is not None:
            self.monitor.beat(worker)
        return True

    def heartbeat(self, worker):
        with self.queue.lock:
            self.queue.heartbeat_extend(worker)
            self._w(worker).last_beat = self.queue.clock()
        if self.monitor is not None:
            self.monitor.beat(worker)
        return True

    def fail_worker(self, worker):
        """Reclaim a dead worker's leases AND record the death in the
        registry (state -> dead, epoch bump). Safe to call repeatedly —
        the state transition and the gauges settle on first call."""
        with self.queue.lock:
            back = self.queue.fail_worker(worker)
            st = self.workers.get(worker)
            if st is not None and st.state not in ("departed", "dead"):
                self._set_state(st, "dead")
        return back

    def state(self):
        return self.queue.state()

    def progress(self):
        return self.queue.progress()

    @property
    def finished(self):
        return self.queue.finished

    def next_deadline(self):
        return self.queue.next_deadline()

    def bye(self, worker, stats=None):
        """Worker sign-off with its idle/busy split (per-worker idle time
        is a Table 7 observable: deeper lease batches shrink it). A worker
        that traced locally ships its buffered span events here
        (stats["spans"]) — the master merges them into its tracer, which
        is how worker spans cross the pickle boundary."""
        with self.queue.lock:
            st = self._w(worker)
            for k in ("idle_s", "busy_s"):
                if stats and k in stats:
                    setattr(st, k, float(stats[k]))
            if st.state != "dead":
                if st.state != "departed":
                    obs_metrics.counter(
                        "dist_workers_left_total",
                        "workers that signed off gracefully",
                        ("worker",)).labels(worker=worker).inc()
                self._set_state(st, "departed")
        # a departed worker stops heartbeating BY DESIGN — drop it from
        # liveness tracking so it never surfaces in monitor.dead()
        if self.monitor is not None:
            self.monitor.forget(worker)
        if stats and stats.get("spans"):
            obs_tracing.get_tracer().add_events(stats["spans"])
        return True

    def metrics(self, render=False):
        """Read-only scrape of the master's metrics registry: a JSON/
        pickle-safe snapshot, or Prometheus text when `render` is set."""
        reg = obs_metrics.get_registry()
        return reg.render() if render else reg.snapshot()

    # -- master-side (NOT served) -------------------------------------------
    def pop_results(self):
        """Drain the result inbox: [(worker, wid, payload), ...]."""
        out = []
        with self.queue.lock:
            while self._results:
                out.append(self._results.popleft())
        return out

    def resolve_result(self, payload):
        """Materialize a store-plane result ref into the full payload
        (`ChunkStore.fetch` by key); socket-plane payloads pass through.
        Called by the master's emit loop — never in a handler thread, so
        the store read happens off the RPC path."""
        if (self.data_plane is not None and isinstance(payload, dict)
                and "store_key" in payload):
            full = self.data_plane.take(payload["store_key"])
            if full is None:
                raise RuntimeError(
                    "store data plane lost result entry "
                    f"{payload['store_key'][:21]}…")
            return full
        return payload

    def worker_report(self):
        """Snapshot of every known worker's progress, sorted by shard:
        leases held right now, chunks done, redeliveries charged to it,
        seconds since its last heartbeat."""
        with self.queue.lock:
            now = self.queue.clock()
            out = []
            for st in self.workers.values():
                st.leases_held = len(self.queue.leases_held(st.worker))
                st.redeliveries = int(
                    self.queue.redelivered_from.get(st.worker, 0))
                st.last_beat_age_s = (None if st.last_beat is None
                                      else float(now - st.last_beat))
                out.append(st)
            return sorted(out, key=lambda s: (s.shard, s.worker))

    # -- WorkQueue duck-typing extras (simulated in-process path) -----------
    def heartbeat_extend(self, worker):
        self.heartbeat(worker)

    def leases_held(self, worker):
        return self.queue.leases_held(worker)

    @property
    def clock(self):
        return self.queue.clock

    @property
    def lease_timeout_s(self):
        return self.queue.lease_timeout_s

    @property
    def redeliveries(self):
        return self.queue.redeliveries

    @property
    def redelivered_from(self):
        return self.queue.redelivered_from

    @property
    def n_items(self):
        return self.queue.n_items


# -------------------------------------------------------- result protocol

def _payload_nbytes(payload) -> int:
    """Wire-size estimate of one data-plane value: array bytes dominate;
    strings/bytes count their length; scalars a flat 8. Close enough to
    pickled size to grade the socket-vs-store byte cut."""
    if payload is None:
        return 0
    if isinstance(payload, np.ndarray):
        return int(payload.nbytes)
    if isinstance(payload, dict):
        return sum(_payload_nbytes(v) for v in payload.values())
    if isinstance(payload, (list, tuple)):
        return sum(_payload_nbytes(v) for v in payload)
    if isinstance(payload, (str, bytes)):
        return len(payload)
    return 8


def pack_result(res) -> dict:
    """BatchResult -> picklable payload (mirrors the store-entry layout:
    masks + stats + cleaned survivors; the pre-denoise wave5 intermediate
    never crosses the process boundary — only its width does, so the
    master can rebuild a shape-correct det record)."""
    det = res.det
    return {
        "cleaned": np.asarray(res.cleaned, np.float32),
        "keep": np.asarray(det.keep), "rain": np.asarray(det.rain),
        "silence": np.asarray(det.silence),
        "cicada15": np.asarray(det.cicada15),
        "stats": {k: (int(v) if k == "n_chunks5" else float(v))
                  for k, v in det.stats.items()},
        "n_kept": int(res.n_kept), "src_bytes": int(res.src_bytes),
        "wave_width": int(det.wave5.shape[-1]),
    }


def unpack_result(payload):
    """payload -> (PipelineOutput, fields) — fields carries cleaned /
    n_kept / src_bytes for the master's BatchResult. wave5 is zero-filled
    at the recorded shape, the same convention CachedPlan uses for store
    hits: it is an intermediate no downstream consumer reads."""
    keep = payload["keep"]
    wave5 = np.zeros((keep.shape[0], int(payload["wave_width"])),
                     np.float32)
    det = PipelineOutput(wave5=wave5, keep=keep, rain=payload["rain"],
                         silence=payload["silence"],
                         cicada15=payload["cicada15"],
                         stats=dict(payload["stats"]))
    return det, {"cleaned": payload["cleaned"],
                 "n_kept": int(payload["n_kept"]),
                 "src_bytes": int(payload["src_bytes"])}
