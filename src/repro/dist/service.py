"""QueueService: the master's serviceable surface over one shared WorkQueue.

The paper's master owns three things: the file list (here: the leased
`WorkQueue`), the data hand-off to slaves (here: `fetch`), and the result
collection that gates what counts as done (here: `push_result` + the
master-side `pop_results` drain). `QueueService` packages exactly that as a
set of named methods a transport can serve — `RPC_METHODS` is the whole
wire surface, nothing else on the object is reachable remotely.

It also DUCK-TYPES the WorkQueue it wraps (lease / complete /
heartbeat_extend / fail_worker / state / next_deadline / progress /
finished / clock / lease_timeout_s / redeliveries), so the in-process
simulated path can route every queue mutation through the service and the
per-worker accounting accrues identically under both transports. All
compound operations take the queue's own RLock, so N transport handler
threads and the master loop interleave safely.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field

import numpy as np

from repro.core.graph import PipelineOutput

# The complete remote surface. A transport must refuse anything else —
# the service object carries master-side state (result inbox, kill hooks)
# that workers have no business reaching.
RPC_METHODS = frozenset({
    "hello", "lease", "fetch", "fetch_many", "complete", "push_result",
    "heartbeat", "fail_worker", "state", "progress", "finished",
    "next_deadline", "bye",
})


@dataclass
class WorkerStats:
    """Per-worker progress ledger (the launch driver's end-of-run summary).

    `leases_held` / `redeliveries` / `last_beat_age_s` are filled in by
    `QueueService.worker_report()` at snapshot time; the rest accrue as the
    worker talks to the service."""
    worker: str
    shard: int = -1
    pid: int = None
    lease_calls: int = 0            # queue round-trips (Table 7's axis)
    leased_total: int = 0           # work ids ever granted
    chunks_done: int = 0            # results ACCEPTED by the master (the
                                    # completion gate, not raw pushes — a
                                    # redelivery race's duplicate push is
                                    # not work done)
    idle_s: float = 0.0             # worker-reported: blocked on the queue
    busy_s: float = 0.0             # worker-reported: computing
    last_beat: float = field(default=None, repr=False)
    # snapshot-time fields (worker_report):
    leases_held: int = 0
    redeliveries: int = 0
    last_beat_age_s: float = None


class QueueService:
    """Master-side service: the WorkQueue plus the data/result planes.

    Parameters:
      queue       the shared WorkQueue (its RLock serializes everything)
      fetch_item  wid -> chunk batch (np.ndarray) — the data plane; the
                  master materialises/regenerates the bytes, workers never
                  see the loader (the paper's master hands slaves files)
      setup       picklable blob returned from `hello` — everything a
                  worker needs to build its jits (cfg, stage names,
                  pad_multiple, bucket, kernel backend mode)
      monitor     optional ft.failure.HeartbeatMonitor fed on heartbeats
    """

    def __init__(self, queue, fetch_item=None, setup=None, monitor=None):
        self.queue = queue
        self._fetch_item = fetch_item
        self._setup = dict(setup or {})
        self.monitor = monitor
        self.workers: dict[str, WorkerStats] = {}
        self.lease_calls = 0
        self._results = collections.deque()
        # master-side hook, called INSIDE lease() once per granted work id
        # with (worker, wid): the CrashInjector's process-mode trigger — a
        # doomed worker is SIGKILLed while its fresh lease is registered
        # and un-completed, so recovery exercises the real redelivery path.
        self.on_grant = None

    # -- bookkeeping --------------------------------------------------------
    def _w(self, worker) -> WorkerStats:
        st = self.workers.get(worker)
        if st is None:
            st = self.workers[worker] = WorkerStats(worker)
        return st

    def note_beat(self, worker):
        """Record liveness WITHOUT extending lease deadlines (the simulated
        in-process path beats once per round; extending there would change
        redelivery timing, which the proc path deliberately does via
        `heartbeat`)."""
        with self.queue.lock:
            self._w(worker).last_beat = self.queue.clock()
        if self.monitor is not None:
            self.monitor.beat(worker)

    def note_done(self, worker, n=1):
        with self.queue.lock:
            self._w(worker).chunks_done += n

    # -- RPC surface --------------------------------------------------------
    def hello(self, worker, pid=None, shard=-1):
        """Worker sign-in: registers identity, returns the setup blob."""
        with self.queue.lock:
            st = self._w(worker)
            st.pid, st.shard = pid, int(shard)
            st.last_beat = self.queue.clock()
        return self._setup

    def lease(self, worker, max_items=1):
        with self.queue.lock:
            ids = self.queue.lease(worker, max_items)
            st = self._w(worker)
            st.lease_calls += 1
            st.leased_total += len(ids)
            st.last_beat = self.queue.clock()
            self.lease_calls += 1
        if self.monitor is not None:
            self.monitor.beat(worker)
        hook = self.on_grant
        if hook is not None:
            for wid in ids:
                hook(worker, wid)
        return ids

    def fetch(self, wid):
        """Data plane: the chunk batch for one leased work id."""
        if self._fetch_item is None:
            raise RuntimeError("this QueueService serves no data plane "
                               "(no fetch_item)")
        return self._fetch_item(wid)

    def fetch_many(self, worker, wids):
        """Batched data plane: one round-trip for a whole lease batch
        (without this, lease_items > 1 would amortize the lease call only
        to re-pay per-item fetch RTTs). Doubles as a heartbeat — the
        worker is provably alive and about to be busy for a while."""
        items = [self.fetch(wid) for wid in wids]
        self.heartbeat(worker)
        return items

    def complete(self, work_ids):
        return self.queue.complete(work_ids)

    def push_result(self, worker, wid, payload):
        """Result plane: worker hands back one finished work id. The
        master drains with `pop_results` and gates emission on
        `queue.complete`, so pushes from a redelivery race are accepted
        here and discarded there — exactly-once stays the master's call
        (and so does `chunks_done` credit, via `note_done`). Each push
        extends the worker's remaining leases: mid-batch progress IS a
        heartbeat."""
        with self.queue.lock:
            self.queue.heartbeat_extend(worker)
            self._w(worker).last_beat = self.queue.clock()
            self._results.append((worker, wid, payload))
        if self.monitor is not None:
            self.monitor.beat(worker)
        return True

    def heartbeat(self, worker):
        with self.queue.lock:
            self.queue.heartbeat_extend(worker)
            self._w(worker).last_beat = self.queue.clock()
        if self.monitor is not None:
            self.monitor.beat(worker)
        return True

    def fail_worker(self, worker):
        return self.queue.fail_worker(worker)

    def state(self):
        return self.queue.state()

    def progress(self):
        return self.queue.progress()

    @property
    def finished(self):
        return self.queue.finished

    def next_deadline(self):
        return self.queue.next_deadline()

    def bye(self, worker, stats=None):
        """Worker sign-off with its idle/busy split (per-worker idle time
        is a Table 7 observable: deeper lease batches shrink it)."""
        with self.queue.lock:
            st = self._w(worker)
            for k in ("idle_s", "busy_s"):
                if stats and k in stats:
                    setattr(st, k, float(stats[k]))
        return True

    # -- master-side (NOT served) -------------------------------------------
    def pop_results(self):
        """Drain the result inbox: [(worker, wid, payload), ...]."""
        out = []
        with self.queue.lock:
            while self._results:
                out.append(self._results.popleft())
        return out

    def worker_report(self):
        """Snapshot of every known worker's progress, sorted by shard:
        leases held right now, chunks done, redeliveries charged to it,
        seconds since its last heartbeat."""
        with self.queue.lock:
            now = self.queue.clock()
            out = []
            for st in self.workers.values():
                st.leases_held = len(self.queue.leases_held(st.worker))
                st.redeliveries = int(
                    self.queue.redelivered_from.get(st.worker, 0))
                st.last_beat_age_s = (None if st.last_beat is None
                                      else float(now - st.last_beat))
                out.append(st)
            return sorted(out, key=lambda s: (s.shard, s.worker))

    # -- WorkQueue duck-typing extras (simulated in-process path) -----------
    def heartbeat_extend(self, worker):
        self.heartbeat(worker)

    def leases_held(self, worker):
        return self.queue.leases_held(worker)

    @property
    def clock(self):
        return self.queue.clock

    @property
    def lease_timeout_s(self):
        return self.queue.lease_timeout_s

    @property
    def redeliveries(self):
        return self.queue.redeliveries

    @property
    def redelivered_from(self):
        return self.queue.redelivered_from

    @property
    def n_items(self):
        return self.queue.n_items


# -------------------------------------------------------- result protocol

def pack_result(res) -> dict:
    """BatchResult -> picklable payload (mirrors the store-entry layout:
    masks + stats + cleaned survivors; the pre-denoise wave5 intermediate
    never crosses the process boundary — only its width does, so the
    master can rebuild a shape-correct det record)."""
    det = res.det
    return {
        "cleaned": np.asarray(res.cleaned, np.float32),
        "keep": np.asarray(det.keep), "rain": np.asarray(det.rain),
        "silence": np.asarray(det.silence),
        "cicada15": np.asarray(det.cicada15),
        "stats": {k: (int(v) if k == "n_chunks5" else float(v))
                  for k, v in det.stats.items()},
        "n_kept": int(res.n_kept), "src_bytes": int(res.src_bytes),
        "wave_width": int(det.wave5.shape[-1]),
    }


def unpack_result(payload):
    """payload -> (PipelineOutput, fields) — fields carries cleaned /
    n_kept / src_bytes for the master's BatchResult. wave5 is zero-filled
    at the recorded shape, the same convention CachedPlan uses for store
    hits: it is an intermediate no downstream consumer reads."""
    keep = payload["keep"]
    wave5 = np.zeros((keep.shape[0], int(payload["wave_width"])),
                     np.float32)
    det = PipelineOutput(wave5=wave5, keep=keep, rain=payload["rain"],
                         silence=payload["silence"],
                         cicada15=payload["cicada15"],
                         stats=dict(payload["stats"]))
    return det, {"cleaned": payload["cleaned"],
                 "n_kept": int(payload["n_kept"]),
                 "src_bytes": int(payload["src_bytes"])}
