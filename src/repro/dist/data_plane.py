"""The off-master data plane: chunk bytes move through a shared store.

With the socket data plane, every raw chunk batch and every result
payload crosses the master's one control socket (`fetch_many` /
`push_result` carry megabytes) — the master becomes the bandwidth
bottleneck the moment workers leave the box, which is exactly the
regime the paper's 8-VM scaling curve lives in. `StoreDataPlane` moves
the bytes to a shared `ChunkStore` backend (any directory both sides
can reach: local disk on one box, NFS/fuse mounts across hosts):

  * master `offer(wid, chunks)` publishes a raw batch under a
    content-addressed key (`raw-<content_key>`) and hands the KEY to
    the worker inside the lease reply (`lease_chunks` RPC) — the
    socket carries ~70 bytes instead of the batch;
  * worker `fetch_chunks(key)` reads the raw batch from the store,
    computes, and `push(raw_key, payload)` writes the result under the
    paired `res-<content_key>` entry (the `pack_result` payload is
    already store-entry-shaped — `ChunkStore.put_payload` splits it),
    returning the tiny `{"store_key": ...}` ref that rides
    `push_result`;
  * master `take(key)` materializes the payload at acceptance
    (`ChunkStore.fetch`), after the exactly-once `complete()` gate has
    already decided the incarnation won.

Content addressing makes redelivery free: a SIGKILLed worker that
pushed its result to the store but never got the ack leaves an entry
the recomputing incarnation dedups against (`put` is first-write-wins;
the second write is a counted no-op), and the master still accepts
exactly once.
"""
from __future__ import annotations

import os

import numpy as np

from repro.store.chunk_store import ChunkStore, content_key

RAW_PREFIX = "raw-"
RESULT_PREFIX = "res-"


def result_key(raw_key: str) -> str:
    """The result entry paired with one raw entry: same content hash,
    `res-` prefix. Computable by the worker from the lease alone."""
    return RESULT_PREFIX + raw_key.split("-", 1)[1]


class StoreDataPlane:
    """Shared-store data plane for the dist runtime.

    Wraps one `ChunkStore` (or a directory path) that master and
    workers both open. The master constructs it with the run's graph
    fingerprint + backend mode so raw keys share the CompileCache /
    CachedPlan value identity; workers reconstruct it from `spec()`
    shipped in the `hello` setup blob (they never hash — keys arrive
    in leases, result keys derive from them).
    """

    kind = "store"

    def __init__(self, store, graph_fingerprint=None, backend_mode=None):
        if isinstance(store, (str, os.PathLike)):
            store = ChunkStore(store)
        self.store = store
        self._fingerprint = graph_fingerprint
        self._backend_mode = backend_mode

    def spec(self) -> dict:
        """JSON-safe description a worker rebuilds its handle from."""
        return {"kind": self.kind, "dir": self.store.directory}

    # -- master side ---------------------------------------------------------
    def offer(self, wid, chunks) -> str:
        """Publish one raw chunk batch; return its content key. Repeat
        offers of identical content (redelivery, speculation) dedup on
        the store's first-write-wins `put`."""
        arr = np.ascontiguousarray(np.asarray(chunks, np.float32))
        key = RAW_PREFIX + content_key(arr, self._fingerprint,
                                       self._backend_mode)
        if key not in self.store:
            self.store.put(key, {"chunks": arr}, meta={"wid": int(wid)})
        return key

    def take(self, key):
        """Materialize a result payload at acceptance (None on miss)."""
        return self.store.fetch(key)

    # -- worker side ---------------------------------------------------------
    def fetch_chunks(self, key):
        """Read one raw chunk batch by lease key (None on miss)."""
        hit = self.store.get(key)
        if hit is None:
            return None
        return np.asarray(hit[0]["chunks"], np.float32)

    def push(self, raw_key, payload) -> dict:
        """Write one result payload under the key paired with its raw
        entry; return the small ref dict that rides `push_result`."""
        key = result_key(raw_key)
        self.store.put_payload(key, payload)
        return {"store_key": key}
