"""The worker runtime: one shard of the paper's slave loop, as a real
process.

  python -m repro.dist.worker --master HOST:PORT --lease-items N

The worker ANNOUNCES itself — no shard id on the command line: `hello`
returns its assigned identity along with the setup blob (pipeline
config, stage names, pad_multiple, tail bucket, kernel backend mode),
so the same invocation joins from any host that can reach the master.
It builds its OWN `PipelineGraph` + jitted detect/tail phases
(per-process CompileCache — compiles never cross the boundary), then
loops:

  lease      up to `lease_items` work ids in ONE round-trip — the paper's
             Table 7 queue-size knob (`max_queue_size`): deeper batches
             amortize master round-trips against redelivery exposure.
             With the store data plane (setup blob carries "data_plane")
             the grant arrives as (wid, content key) pairs via
             `lease_chunks` and the fetch step below disappears from the
             master's socket entirely
  fetch      the chunk bytes for the whole lease batch in one round-trip
             (the master owns the loader; the paper's master hands slaves
             files the same way) — or, store plane, read by key from the
             shared ChunkStore
  compute    detect -> device-resident survivor compaction -> tail, the
             exact TwoPhasePlan path, so output bytes match the
             single-process plans
  push       results stream back per item (the paper's send_interval),
             each push doubling as a heartbeat; the MASTER completes the
             work id, so a worker killed after push but before the master
             drains it still resolves exactly-once. Store plane: the
             payload goes to the shared store under the result key paired
             with the lease's raw key (first-write-wins dedups a
             redelivered incarnation's duplicate), and the push carries
             only the tiny key ref

A SIGKILL anywhere in that loop leaves leases registered un-completed —
recovery is the queue's lease expiry or the master's `fail_worker`, never
worker-side cleanup. The runtime is also importable (`run_worker`) so
tests can drive it in-process over an `InProcTransport`.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def run_worker(master, shard=None, lease_items=1, poll_s=0.05,
               transport=None, max_items=None):
    """Run one worker against a served QueueService. Returns the
    idle/busy stats dict it also reports via `bye`. `master` is an
    address for the given transport (HOST:PORT for proc; the service
    object itself for in-proc). `shard=None` (the spawned default)
    announces to the registry and adopts the identity `hello` assigns;
    an explicit shard keeps the legacy self-asserted name (tests).
    `max_items` caps total processed items (tests); None means run
    until the queue is finished."""
    # imports deferred past arg parsing so `--help` stays instant
    from repro.core.graph import PipelineGraph
    from repro.core.plans import TwoPhasePlan
    from repro.dist.transport import InProcTransport, ProcTransport
    from repro.kernels import backend

    if transport is None:
        transport = ProcTransport()
    proxy = transport.connect(master)
    if shard is None:
        spec = proxy.call("hello", None, os.getpid(), -1)
        assigned = spec.get("assigned") or {}
        worker, shard = assigned.get("worker"), assigned.get("shard", -1)
        if worker is None:
            raise RuntimeError("master assigned no identity at hello")
    else:
        worker = f"shard{int(shard)}"
        spec = proxy.call("hello", worker, os.getpid(), int(shard))
    # Trace propagation: when the master runs a tracer, `hello` carries
    # its trace id + run-span parent. The worker traces locally into its
    # own buffer (own pid, master's parent) and ships the events back in
    # `bye` — a SIGKILLed worker simply loses its spans, never the run.
    from repro.obs import tracing as obs_tracing
    tracer = obs_tracing.NULL_TRACER
    if spec.get("trace"):
        tracer = obs_tracing.Tracer(**spec["trace"])
        # Install globally (so plan-internal spans land in it) only in a
        # real worker process. In-proc workers share the master's process:
        # there the master's tracer IS the global one and already catches
        # plan spans — replacing it would clobber the run.
        if not obs_tracing.get_tracer().enabled:
            obs_tracing.set_tracer(tracer)
    if spec.get("backend_mode"):
        backend.set_mode(spec["backend_mode"])
    graph = PipelineGraph(spec["cfg"], spec.get("stages"),
                          spec.get("source_channels", 2))
    plan = TwoPhasePlan(graph, pad_multiple=spec.get("pad_multiple", 1),
                        bucket=spec.get("bucket", "linear"))
    from repro.dist.service import pack_result

    # Store data plane: chunk bytes move through a shared ChunkStore the
    # setup blob points at; the master's socket carries only keys.
    plane = None
    dp_spec = spec.get("data_plane") or {}
    if dp_spec.get("kind") == "store":
        from repro.dist.data_plane import StoreDataPlane
        plane = StoreDataPlane(dp_spec["dir"])

    lease_items = max(1, int(lease_items))
    idle = busy = 0.0
    done = 0
    while max_items is None or done < max_items:
        t0 = time.perf_counter()
        w0 = time.time()
        if plane is None:
            ids = proxy.call("lease", worker, lease_items)
            keys = {}
        else:
            pairs = proxy.call("lease_chunks", worker, lease_items)
            ids = [wid for wid, _ in pairs]
            keys = dict(pairs)
        if not ids:
            # exit on the queue-global signal (finished) OR the per-worker
            # one (drain): a draining worker's lease always comes back
            # empty, and at the top of this loop everything previously
            # leased is already pushed — held leases are finished, so
            # leaving now is the graceful exit drain() promises
            if proxy.call("finished") or proxy.call("draining", worker):
                idle += time.perf_counter() - t0
                break
            proxy.call("heartbeat", worker)
            idle += time.perf_counter() - t0
            time.sleep(poll_s)
            continue
        # `X` complete events, recorded only for NON-empty iterations so
        # an idle worker's poll loop does not flood the trace
        tracer.complete("lease", w0, worker=worker, ids=ids)
        w1 = time.time()
        if plane is None:
            items = list(zip(ids, proxy.call("fetch_many", worker, ids)))
            tracer.complete("fetch_many", w1, worker=worker, n=len(ids))
        else:
            items = [(wid, None if keys[wid] is None
                      else plane.fetch_chunks(keys[wid])) for wid in ids]
            tracer.complete("fetch_store", w1, worker=worker, n=len(ids))
        idle += time.perf_counter() - t0
        for wid, chunks in items:
            if chunks is None:
                # this lease lost a redelivery race: the id completed (and
                # its stream buffer may be released) before our fetch —
                # nothing to compute, the master already has the result
                continue
            t1 = time.perf_counter()
            w2 = time.time()
            # a heartbeat per item bounds lease-expiry exposure to ONE
            # item's compute time (first-item jit compiles are the long
            # pole), not the whole lease batch
            proxy.call("heartbeat", worker)
            res = plan(np.asarray(chunks, np.float32))
            payload = pack_result(res)
            busy += time.perf_counter() - t1
            tracer.complete("compute", w2, worker=worker, wid=wid,
                            n_kept=int(res.n_kept))
            t2 = time.perf_counter()
            w3 = time.time()
            if plane is None:
                proxy.call("push_result", worker, wid, payload)
            else:
                ref = plane.push(keys[wid], payload)
                proxy.call("push_result", worker, wid, ref)
            tracer.complete("push", w3, worker=worker, wid=wid)
            idle += time.perf_counter() - t2
            done += 1
    stats = {"idle_s": idle, "busy_s": busy, "chunks": done}
    if tracer.enabled:
        stats["spans"] = tracer.drain()
    try:
        proxy.call("bye", worker, stats)
    finally:
        proxy.close()
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="repro.dist worker process (spawned by the sharded "
                    "plan's proc transport; authkey via env "
                    "REPRO_DIST_AUTHKEY)")
    ap.add_argument("--master", required=True, metavar="HOST:PORT")
    ap.add_argument("--shard", type=int, default=None,
                    help="self-asserted shard id (debugging only; spawned "
                         "workers announce and let the registry assign)")
    ap.add_argument("--lease-items", type=int, default=1,
                    help="work ids per queue round-trip (the paper's "
                         "max_queue_size knob)")
    ap.add_argument("--poll-s", type=float, default=0.05,
                    help="sleep between empty lease polls")
    args = ap.parse_args(argv)
    run_worker(args.master, args.shard, lease_items=args.lease_items,
               poll_s=args.poll_s)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
