"""repro.dist — the master/worker runtime behind the multi-shard plans.

Four pieces, four files:

  * `service.QueueService` — the master's RPC surface over one shared
    `data.queue.WorkQueue` (lease / complete / heartbeat / fail_worker /
    state) plus the data plane (fetch a chunk batch, push a result) and
    per-worker progress accounting. `hello` is a registry: workers
    ANNOUNCE themselves and are assigned their identity there (honoring
    spawn-time `reserve(pid, shard)` pins) — no shard ids on argv.
  * `transport` — how that surface is reached:

      transport        wire                        scope
      ---------        ------------------------   --------------------
      InProcTransport  direct calls, no pickling   simulated mode, tests
      ProcTransport    authenticated localhost      real processes, one
                       sockets (authkey env-only)   box
      TcpTransport     same protocol, non-loopback  real processes, many
                       bind + advertised address    boxes

  * `data_plane.StoreDataPlane` — the off-master data plane: raw chunk
    batches and result payloads move through a shared `ChunkStore`
    (content-addressed keys ride the `lease_chunks` grant and the
    `push_result` ref), so the master's socket carries only leases, ids,
    and acks. Byte traffic per plane is counted under
    `dist_fetch_bytes_total{plane}` / `dist_push_bytes_total{plane}`.
  * `worker` — the worker runtime: announces at `hello`, owns its
    shard's jits, pulls leases in batches (`--lease-items`, the paper's
    Table 7 queue-size knob), fetches from the socket or the store,
    runs detect+tail locally, streams results back, heartbeats.
"""
from repro.dist.data_plane import StoreDataPlane
from repro.dist.service import (QueueService, WorkerStats, pack_result,
                                unpack_result)
from repro.dist.transport import (InProcTransport, ProcTransport,
                                  RemoteError, TcpTransport, WorkerHandle)

__all__ = ["QueueService", "WorkerStats", "pack_result", "unpack_result",
           "InProcTransport", "ProcTransport", "TcpTransport",
           "RemoteError", "WorkerHandle", "StoreDataPlane"]
