"""repro.dist — the master/worker runtime behind the multi-shard plans.

Three pieces, three files:

  * `service.QueueService` — the master's RPC surface over one shared
    `data.queue.WorkQueue` (lease / complete / heartbeat / fail_worker /
    state) plus the data plane (fetch a chunk batch, push a result) and
    per-worker progress accounting.
  * `transport` — how that surface is reached: `InProcTransport` (direct
    calls, the simulated single-process mode `ShardedPlan` always had) and
    `ProcTransport` (pickled messages over authenticated localhost
    sockets, real OS worker processes spawned via
    `python -m repro.dist.worker`).
  * `worker` — the worker runtime: owns its shard's jits, pulls leases in
    batches (`--lease-items`, the paper's Table 7 queue-size knob), runs
    detect+tail locally, streams results back, heartbeats.
"""
from repro.dist.service import (QueueService, WorkerStats, pack_result,
                                unpack_result)
from repro.dist.transport import (InProcTransport, ProcTransport,
                                  RemoteError, WorkerHandle)

__all__ = ["QueueService", "WorkerStats", "pack_result", "unpack_result",
           "InProcTransport", "ProcTransport", "RemoteError",
           "WorkerHandle"]
