"""Pluggable transports for the master/worker runtime.

A transport answers one question: how does a worker reach the master's
`QueueService`? Two answers ship:

  * `InProcTransport` — the address IS the service; `connect` hands back
    the object and calls are plain function calls under the queue's lock.
    This is the simulated mode `ShardedPlan` has always run (every shard a
    loop iteration in one process), preserved bit-for-bit — and the mode
    unit tests use to drive the worker runtime without process spawns.
  * `ProcTransport` — real OS processes. The master serves the RPC surface
    over `multiprocessing.connection` (pickled `(method, args, kwargs)`
    messages on an authenticated localhost socket, one handler thread per
    accepted connection); workers are spawned as
    `python -m repro.dist.worker --master HOST:PORT --shard K` and can be
    SIGKILLed mid-lease — which is the point: lease-expiry redelivery and
    `fail_worker` reclamation are exercised across a genuine process
    boundary, the way the paper's master survived crashed slaves.

  * `TcpTransport` — `ProcTransport` with a non-loopback bind address
    (default `0.0.0.0`) and a separately advertised dial address, for
    workers on OTHER hosts. Pair it with the store data plane
    (`repro.dist.data_plane.StoreDataPlane` over a shared directory) so
    the master's socket carries only leases, ids, and acks — the paper's
    8-VM regime, where chunk bytes through one master socket would be
    the bottleneck.

Workers are addressed by REGISTRATION, not argv: `spawn_worker` never
passes a shard id on the command line — the worker announces itself at
`hello` (the saxml join/locate pattern) and the master assigns its
identity there, honoring any `QueueService.reserve(pid, shard)` made at
spawn time. A worker started by hand on another box
(`python -m repro.dist.worker --master HOST:PORT`) joins the same way
and receives the next free shard id.

The authkey never rides the command line: it is handed to workers via the
`REPRO_DIST_AUTHKEY` environment variable (never argv, never logged; a
wrong key fails the connection handshake inside `Listener.accept()`, so
no handler thread is ever spawned for an unauthenticated peer).
"""
from __future__ import annotations

import os
import secrets
import signal
import subprocess
import sys
import threading
from multiprocessing.connection import Client, Listener

from repro.dist.service import RPC_METHODS
from repro.obs import metrics as obs_metrics

AUTHKEY_ENV = "REPRO_DIST_AUTHKEY"


class RemoteError(RuntimeError):
    """An RPC raised on the master; the worker sees type + message (the
    traceback stays in the master's log)."""


class InProcTransport:
    """Direct-call transport: serve() returns the service itself and
    connect() hands it back. Exists so the worker runtime and the tests
    can run against the SAME code path proc mode uses, minus pickling."""
    name = "inproc"

    def serve(self, service):
        self._service = service
        return service

    def connect(self, address):
        return _LocalProxy(address if address is not None
                           else self._service)

    def close(self):
        self._service = None


class _LocalProxy:
    """The in-proc twin of _RpcProxy: same .call surface, no wire."""

    def __init__(self, service):
        self._service = service

    def call(self, method, *args, **kwargs):
        if method not in RPC_METHODS:
            raise RemoteError(f"method {method!r} is not served")
        attr = getattr(self._service, method)
        return attr(*args, **kwargs) if callable(attr) else attr

    def close(self):
        self._service = None


class _RpcProxy:
    """Client side of one proc-transport connection. One in-flight call at
    a time per connection (the worker runtime is a single loop; a lock
    keeps any auxiliary thread honest)."""

    def __init__(self, conn):
        self._conn = conn
        self._lock = threading.Lock()

    def call(self, method, *args, **kwargs):
        with self._lock:
            self._conn.send((method, args, kwargs))
            ok, val = self._conn.recv()
        if ok:
            return val
        raise RemoteError(val)

    def close(self):
        try:
            self._conn.close()
        except OSError:
            pass


class WorkerHandle:
    """Master-side handle on one spawned worker process. `shard` is the
    identity the master reserved for it at spawn (None for a worker left
    to the registry's own assignment until its `hello` lands)."""

    def __init__(self, shard, proc):
        self.shard = None if shard is None else int(shard)
        self.proc = proc

    @property
    def worker(self):
        return None if self.shard is None else f"shard{self.shard}"

    @property
    def pid(self) -> int:
        return self.proc.pid

    def poll(self):
        """Exit code, or None while the process runs."""
        return self.proc.poll()

    def kill(self):
        """SIGKILL — no cleanup, no goodbye: the crash the paper's master
        must survive. Leases the worker holds stay registered un-completed
        and come back via expiry or `fail_worker`."""
        try:
            os.kill(self.proc.pid, signal.SIGKILL)
        except ProcessLookupError:
            pass

    def stall(self, seconds=None):
        """SIGSTOP — freeze the worker mid-whatever (a genuine straggler:
        no heartbeats, no pushes, the lease clock keeps ticking). With
        `seconds` a timer SIGCONTs it back; without, call resume()
        yourself. The chaos harness's stall injection."""
        try:
            os.kill(self.proc.pid, signal.SIGSTOP)
        except ProcessLookupError:
            return
        if seconds is not None:
            t = threading.Timer(float(seconds), self.resume)
            t.daemon = True
            t.start()

    def resume(self):
        """SIGCONT a stalled worker (no-op if it is gone or running)."""
        try:
            os.kill(self.proc.pid, signal.SIGCONT)
        except ProcessLookupError:
            pass

    def shutdown(self, timeout=5.0):
        """Best-effort teardown at end of run: TERM, wait, then KILL."""
        if self.proc.poll() is None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout)
            except subprocess.TimeoutExpired:
                self.kill()
        try:
            self.proc.wait(1.0)
        except subprocess.TimeoutExpired:
            pass


class ProcTransport:
    """Real-process transport over authenticated sockets (loopback bind
    by default; `host=` opens it up, `advertise_host=` overrides the
    address handed to workers when the bind address is a wildcard)."""
    name = "proc"

    def __init__(self, host="127.0.0.1", port=0, advertise_host=None):
        self._host, self._port = host, int(port)
        self._advertise_host = advertise_host
        self._listener = None
        self._stop = threading.Event()
        self._authkey = None
        self.address = None

    # -- master side --------------------------------------------------------
    def serve(self, service) -> str:
        """Start serving `service`; returns the address workers dial."""
        if self._listener is not None:
            raise RuntimeError("transport already serving")
        self._authkey = secrets.token_hex(16)
        self._listener = Listener((self._host, self._port),
                                  authkey=self._authkey.encode())
        host, port = self._listener.address
        adv = self._advertise_host or (
            "127.0.0.1" if host in ("0.0.0.0", "::") else host)
        self.address = f"{adv}:{port}"
        self._stop.clear()
        threading.Thread(target=self._accept_loop, args=(service,),
                         daemon=True, name="repro-dist-accept").start()
        return self.address

    def _accept_loop(self, service):
        while not self._stop.is_set():
            try:
                conn = self._listener.accept()
            except Exception:      # closed listener / failed auth handshake
                if self._stop.is_set():
                    return
                continue
            threading.Thread(target=self._serve_conn, args=(conn, service),
                             daemon=True, name="repro-dist-conn").start()

    def _serve_conn(self, conn, service):
        """One handler thread per worker connection: recv (method, args,
        kwargs), dispatch against the RPC surface, send (ok, value). A
        worker SIGKILLed mid-call just drops the connection — the handler
        exits and the queue's lease machinery owns recovery."""
        try:
            while True:
                try:
                    method, args, kwargs = conn.recv()
                except (EOFError, OSError):
                    return
                if method not in RPC_METHODS:
                    msg = (False, f"method {method!r} is not served")
                else:
                    obs_metrics.counter(
                        "dist_rpc_calls_total",
                        "proc-transport RPCs served, by method",
                        ("method",)).labels(method=method).inc()
                    try:
                        attr = getattr(service, method)
                        val = attr(*args, **kwargs) if callable(attr) \
                            else attr
                        msg = (True, val)
                    except Exception as e:          # ship, don't crash
                        obs_metrics.counter(
                            "dist_rpc_errors_total",
                            "RPCs that raised on the master",
                            ("method",)).labels(method=method).inc()
                        msg = (False, f"{type(e).__name__}: {e}")
                try:
                    conn.send(msg)
                except (OSError, ValueError):
                    return
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def spawn_worker(self, shard=None, lease_items=1, poll_s=0.05,
                     env_extra=None) -> WorkerHandle:
        """Launch `python -m repro.dist.worker` against this transport's
        address. The child inherits stdio (worker tracebacks surface in
        the master's terminal) and gets PYTHONPATH + the authkey via env.

        No shard id rides the argv: the worker adopts its identity from
        the registry at `hello`. `shard` here only stamps the returned
        handle with the id the caller reserved master-side (via
        `QueueService.reserve`); pass None for a pure late joiner."""
        if self.address is None:
            raise RuntimeError("serve() first: workers need an address")
        import repro
        # repro may be a namespace package (no __init__.py): resolve the
        # directory ABOVE the package from its path entries
        pkg_dir = (os.path.dirname(os.path.abspath(repro.__file__))
                   if getattr(repro, "__file__", None)
                   else os.path.abspath(next(iter(repro.__path__))))
        pkg_root = os.path.dirname(pkg_dir)
        env = dict(os.environ)
        env[AUTHKEY_ENV] = self._authkey
        env["PYTHONPATH"] = pkg_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env.update(env_extra or {})
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.dist.worker",
             "--master", self.address,
             "--lease-items", str(int(lease_items)),
             "--poll-s", str(float(poll_s))],
            env=env)
        return WorkerHandle(shard, proc)

    # -- worker side --------------------------------------------------------
    def connect(self, address, authkey=None) -> _RpcProxy:
        host, _, port = str(address).rpartition(":")
        key = authkey or self._authkey or os.environ.get(AUTHKEY_ENV)
        if not key:
            raise RuntimeError(
                f"no authkey: set {AUTHKEY_ENV} or pass authkey=")
        return _RpcProxy(Client((host, int(port)), authkey=key.encode()))

    def close(self):
        self._stop.set()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None


class TcpTransport(ProcTransport):
    """ProcTransport with a non-loopback bind: serve on `0.0.0.0` (or an
    explicit interface) so workers on other hosts can dial in, while the
    wire protocol, authkey handshake, and worker runtime stay identical.
    `advertise_host` is the address workers are told to dial — it
    defaults to loopback for the wildcard bind (the single-box case the
    tests and smoke gates run); set it to the master's routable address
    when the fleet spans machines. Pair with `StoreDataPlane` over a
    shared directory so chunk bytes never transit this socket."""
    name = "tcp"

    def __init__(self, host="0.0.0.0", port=0, advertise_host=None):
        super().__init__(host=host, port=port, advertise_host=advertise_host)
