"""Batched serving engine: prefill + decode loop with a host-side request
queue (static-batch continuous-batching-lite: finished slots are refilled
from the queue at each refill interval).

This is the LM decode twin of `serve.preprocess_service`; the
preprocessing traffic path with persistent workers and true continuous
batching lives in `repro.serve.pool` + `repro.serve.batcher`.
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import NULL_RULES


class ServeEngine:
    def __init__(self, model, params, rules=NULL_RULES, max_seq=512,
                 eos_id=None, temperature=0.0):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.rules = rules
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.temperature = temperature

        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, rules))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, rules),
            donate_argnums=(1,))

    def _sample(self, logits, key):
        logits = logits[..., :self.cfg.vocab_size]
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature).astype(jnp.int32)

    def generate(self, prompts, n_tokens, seed=0, extra_batch=None):
        """prompts: (B, S_prompt) int32 np. Returns (B, n_tokens) int32.

        Runs prefill once, then n_tokens decode steps against the growing
        cache (cache buffers donated each step)."""
        prompts = np.asarray(prompts)
        B, S = prompts.shape
        total = S + n_tokens
        assert total <= self.max_seq

        batch = {"tokens": jnp.asarray(prompts)}
        if extra_batch:
            batch.update(extra_batch)
        logits, pf_caches = self._prefill(self.params, batch)

        # decode caches sized to max_seq; copy prefill KV in
        kwargs = {}
        if self.cfg.is_enc_dec:
            kwargs["enc_len"] = pf_caches["xk"].shape[2]
        if self.cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            caches = self.model.init_cache(B, self.max_seq, **kwargs)
            for k in pf_caches:
                if k in ("k", "v", "xk", "xv"):
                    src = pf_caches[k].astype(caches[k].dtype)
                    caches[k] = jax.lax.dynamic_update_slice(
                        caches[k], src, (0, 0, 0, 0, 0))
                else:
                    caches[k] = pf_caches[k]
        else:   # recurrent state: prefill states ARE the cache
            caches = pf_caches

        key = jax.random.key(seed)
        prefix_off = (self.cfg.num_prefix_tokens
                      if self.cfg.num_prefix_tokens else 0)
        out = np.zeros((B, n_tokens), np.int32)
        key, k0 = jax.random.split(key)
        tok = self._sample(logits, k0)
        out[:, 0] = np.asarray(tok)
        for i in range(1, n_tokens):
            pos = prefix_off + S + i - 1
            key, ki = jax.random.split(key)
            logits, caches = self._decode(self.params, caches, tok,
                                          jnp.int32(pos))
            tok = self._sample(logits, ki)
            out[:, i] = np.asarray(tok)
        return out


class RequestQueue:
    """Host-side batched request pump: collects requests, serves them in
    fixed-size batches (the serving analogue of the paper's slave pull
    queue)."""

    def __init__(self, engine, batch_size, prompt_len, n_tokens):
        self.engine = engine
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.n_tokens = n_tokens
        self._queue = collections.deque()
        self._results = {}
        self._next_id = 0

    def submit(self, prompt):
        rid = self._next_id
        self._next_id += 1
        p = np.asarray(prompt, np.int32)[:self.prompt_len]
        p = np.pad(p, (0, self.prompt_len - len(p)))
        self._queue.append((rid, p))
        return rid

    def pump(self):
        """Serve one full (padded) batch from the queue."""
        if not self._queue:
            return []
        batch, rids = [], []
        while self._queue and len(batch) < self.batch_size:
            rid, p = self._queue.popleft()
            rids.append(rid)
            batch.append(p)
        while len(batch) < self.batch_size:      # zero-pad, never copies:
            batch.append(np.zeros(self.prompt_len, np.int32))
        toks = self.engine.generate(np.stack(batch), self.n_tokens)
        for i, rid in enumerate(rids):
            self._results[rid] = toks[i]
        return rids

    def result(self, rid):
        """Pop a finished request's tokens (handed over exactly once, so
        the result map stays bounded by in-flight work)."""
        return self._results.pop(rid, None)
