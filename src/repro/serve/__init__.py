"""repro.serve — the serving subsystem: request traffic -> warm devices.

Three tiers, by traffic shape:

  * In-process pumps (`PreprocessService` without a pool,
    `engine.RequestQueue`): requests batched per pump wave, computed in
    the calling process. Simplest; right for offline drains, notebooks,
    and tests. No process isolation, batch latency = compute latency.
  * Persistent worker pool (`pool.WorkerPool`): long-lived
    `repro.dist` workers over a standing leased queue — spawned once,
    jits warm across waves, SIGKILL-survivable (leases redeliver, the
    completion gate keeps results exactly-once), with per-worker stats
    and pool gauges. Right whenever serving outlives one batch.
  * Continuous batching (`batcher.ContinuousBatcher`): concurrent small
    requests coalesced into pow2-bucketed zero-padded batches, with
    admission control, per-request deadlines, and a linger-bounded pump
    that serves partial batches. Front-end for the pool (or any plan)
    under live concurrent traffic.

Batch/stream workloads (archives, resumable runs) belong to the
execution plans (`repro.core.plans`); this package is for requests that
arrive over time and want answers back individually.
"""
from repro.serve.batcher import AdmissionError, ContinuousBatcher
from repro.serve.pool import WorkerPool
from repro.serve.preprocess_service import PreprocessService

__all__ = ["AdmissionError", "ContinuousBatcher", "PreprocessService",
           "WorkerPool"]
