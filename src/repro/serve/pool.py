"""Persistent worker pool: long-lived `repro.dist` workers serving a
standing queue.

The batch runtime (`ShardedPlan` proc mode) spawns workers per run and
tears them down with the stream — correct for archives, hopeless for
serving: every request wave would re-pay process spawn + jit compile.
`WorkerPool` inverts the lifecycle. Workers are spawned ONCE over the
existing transports (`InProcTransport` threads or `ProcTransport`
processes — the identical `repro.dist.worker.run_worker` loop either
way), and they stay alive across submissions because the pool's
`StandingWorkQueue` reports `finished` only after `close()` drains: an
idle worker's empty lease turns into heartbeat + poll, not exit. After
the first item per worker, every jit is warm — wave 2 of a pump runs at
steady-state latency on the same pids as wave 1.

Work enters via `submit(chunks) -> wid` (any (B, C, S_long_src) batch —
the continuous batcher assembles those from single-chunk requests) and
leaves via `poll()` / `wait()` as the same `BatchResult` the in-process
plans produce: workers run the exact TwoPhasePlan detect -> device
compaction -> tail path, so pool output is bit-identical to a direct
`two_phase` call on the same batch.

Fault story is inherited, not reinvented: leases + completion gating give
at-least-once delivery with exactly-once emission. A SIGKILLed worker's
leases come back via `fail_worker` (the pool notices the dead pid on the
next poll) or lease expiry, and the redelivered request goes to the front
of the line. `respawn=True` additionally replaces dead proc workers.

Observability: `worker_stats` is the per-worker `WorkerStats` ledger the
batch runtime already keeps; `gauges()` adds the pool-level serving view
(busy/idle workers, queue depth, in-flight leases, oldest-request age).
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core.plans import BatchResult
from repro.data.queue import StandingWorkQueue
from repro.dist.data_plane import StoreDataPlane
from repro.dist.service import QueueService, unpack_result
from repro.dist.transport import InProcTransport, ProcTransport, TcpTransport
from repro.dist.worker import run_worker
from repro.ft.failure import StragglerDetector
from repro.kernels import backend
from repro.obs import metrics as obs_metrics


class WorkerPool:
    """Long-lived preprocessing workers over a standing QueueService.

    Parameters:
      cfg              pipeline config (the setup blob workers build
                       their jits from — same facts ShardedPlan ships)
      workers          pool size
      transport        "proc" (real processes, SIGKILL-able), "tcp" (real
                       processes over a non-loopback bind — workers may
                       join from other hosts; pair with `store=`) or
                       "inproc" (daemon threads driving the same worker
                       runtime — tests and single-host serving without
                       spawn cost)
      store            optional shared-store data plane (a ChunkStore,
                       directory path, or StoreDataPlane): request bytes
                       and result payloads move through the store, the
                       control socket carries only content keys
      stages           optional stage-name override (None = config list)
      pad_multiple / bucket
                       worker-side tail policy; "pow2" bounds tail
                       retraces across the varying survivor counts a
                       request mix produces
      lease_timeout_s  None = transport default (proc workers pay a
                       first-item compile, so their deadline is generous)
      poll_s           worker sleep between empty leases (sets the idle
                       wake-up latency floor for new work)
      respawn          replace dead PROC workers automatically (dead
                       workers always have their leases reclaimed either
                       way; respawn=False lets chaos tests prove the
                       survivors absorb the load)
      min_workers /    queue-depth-driven autoscaling band. max_workers
      max_workers      arms it (None = fixed-size pool): sustained
                       backlog (> autoscale_backlog_s with unleased work
                       queued) spawns a late joiner up to max_workers;
                       a sustained fully-idle pool (no queued or leased
                       work for autoscale_idle_s) DRAINS one idle worker
                       down to min_workers (defaults to `workers`) — the
                       drained worker exits through bye, never reaped
      speculate        arm speculative re-lease: an idle worker whose
                       lease comes back empty may duplicate the slowest
                       straggling in-flight item (first completion wins —
                       exactly-once is already the completion gate's job)
    """

    def __init__(self, cfg, workers=2, transport="proc", stages=None,
                 source_channels=2, pad_multiple=1, bucket="pow2",
                 lease_items=1, lease_timeout_s=None, poll_s=0.01,
                 respawn=True, monitor=None, telemetry=None,
                 min_workers=None, max_workers=None,
                 autoscale_backlog_s=0.75, autoscale_idle_s=5.0,
                 speculate=False, straggler_factor=2.0,
                 straggler_min_history=4, store=None):
        if transport not in ("proc", "tcp", "inproc"):
            raise ValueError(f"unknown transport {transport!r} "
                             "(expected 'proc', 'tcp' or 'inproc')")
        self.cfg = cfg
        self.workers = max(1, int(workers))
        self.transport = transport
        self.lease_items = max(1, int(lease_items))
        self.poll_s = float(poll_s)
        self.respawn = bool(respawn)
        self.min_workers = (self.workers if min_workers is None
                            else max(1, int(min_workers)))
        self.max_workers = None if max_workers is None \
            else max(self.min_workers, int(max_workers))
        self.autoscale_backlog_s = float(autoscale_backlog_s)
        self.autoscale_idle_s = float(autoscale_idle_s)
        self.scale_ups = 0
        self.scale_downs = 0
        self._backlog_since = None      # monotonic ts backlog first seen
        self._idle_since = None         # monotonic ts full idle first seen
        self.monitor = monitor
        if lease_timeout_s is None:
            lease_timeout_s = 300.0 if transport in ("proc", "tcp") else 60.0
        self.queue = StandingWorkQueue(lease_timeout_s=lease_timeout_s)
        self._setup = {"cfg": cfg,
                       "stages": list(stages) if stages else None,
                       "source_channels": int(source_channels),
                       "pad_multiple": int(pad_multiple),
                       "bucket": bucket,
                       "backend_mode": backend.get_mode()}
        straggler = StragglerDetector(
            factor=float(straggler_factor),
            min_history=int(straggler_min_history)) if speculate else None
        if store is not None and not isinstance(store, StoreDataPlane):
            store = StoreDataPlane(store, backend_mode=backend.get_mode())
        self.service = QueueService(self.queue, fetch_item=self._fetch,
                                    setup=self._setup, monitor=monitor,
                                    telemetry=telemetry,
                                    straggler=straggler, data_plane=store)
        self._items = {}        # wid -> chunk bytes (the data plane)
        self._submit_t = {}     # wid -> submit time (oldest-age gauge)
        self._completed = {}    # wid -> BatchResult awaiting claim
        self._claim_lock = threading.Lock()
        self._handles = {}      # shard -> WorkerHandle (proc)
        self._threads = {}      # shard -> Thread (inproc)
        self._dead = set()      # shards whose leases were reclaimed
        self._next_shard = self.workers   # late joiners get fresh ids
        self.respawns = 0
        self._tp = None
        self._started = False
        self._shut = False

    # -- lifecycle ----------------------------------------------------------
    def start(self):
        """Spawn the workers once; they live until shutdown()."""
        if self._started:
            raise RuntimeError("pool already started")
        self._started = True
        if self.transport in ("proc", "tcp"):
            self._tp = TcpTransport() if self.transport == "tcp" \
                else ProcTransport()
            self._tp.serve(self.service)
            for k in range(self.workers):
                self._handles[k] = self._spawn(k)
        else:
            self._tp = InProcTransport()
            self._tp.serve(self.service)
            for k in range(self.workers):
                self._threads[k] = self._spawn_thread(k)
        return self

    def _spawn(self, shard):
        # the shard id never rides argv: reserve it with the registry so
        # the worker's announce-hello adopts it (handles/pids stay keyed
        # by the id the pool chose)
        h = self._tp.spawn_worker(shard, lease_items=self.lease_items,
                                  poll_s=self.poll_s)
        self.service.reserve(h.pid, shard)
        return h

    def _spawn_thread(self, shard):
        t = threading.Thread(
            target=run_worker, args=(self.service, shard),
            kwargs=dict(lease_items=self.lease_items, poll_s=self.poll_s,
                        transport=InProcTransport()),
            daemon=True, name=f"repro-pool-shard{shard}")
        t.start()
        return t

    def __enter__(self):
        return self.start()

    def __exit__(self, exc_type, exc, tb):
        self.shutdown(drain=exc_type is None)

    # -- work plane ---------------------------------------------------------
    def submit(self, chunks) -> int:
        """Admit one (B, C, S_long_src) batch; returns its work id. The
        item is registered under the queue's own lock TOGETHER with the
        admission, so a worker's lease can never observe a wid whose
        bytes are not yet fetchable."""
        x = np.asarray(chunks, np.float32)
        with self.queue.lock:
            wid = self.queue.add()
            self._items[wid] = x
            self._submit_t[wid] = time.monotonic()
        return wid

    def _fetch(self, wid):
        """Data plane. None answers a redelivered lease that lost the
        race to a straggler's completion — the worker skips it."""
        if self.queue.is_done(wid):
            return None
        with self.queue.lock:
            item = self._items.get(wid)
        if item is None:
            if self.queue.is_done(wid):
                return None
            raise KeyError(f"work id {wid} has no registered item")
        return item

    def _pump(self):
        """Drain worker pushes into the completed set, gating on
        `queue.complete` so at-least-once pushes stay exactly-once
        results; then reclaim dead workers."""
        for worker, wid, payload in self.service.pop_results():
            # winner's name rides into complete() so a lost speculation
            # race attributes the other incarnation
            if not self.queue.complete([wid], worker=worker):
                continue            # a redelivery raced a straggler
            # store data plane: the push was a key ref — materialize it
            # here, after the gate (losers never cost a store read)
            det, f = unpack_result(self.service.resolve_result(payload))
            self.service.note_done(worker, wid=wid,
                                   survivors=int(f["n_kept"]),
                                   bytes_out=f["cleaned"].nbytes)
            with self.queue.lock:
                self._items.pop(wid, None)
                self._submit_t.pop(wid, None)
            res = BatchResult(cleaned=f["cleaned"], det=det,
                              n_kept=f["n_kept"], wid=wid,
                              src_bytes=f["src_bytes"])
            with self._claim_lock:
                self._completed[wid] = res
        self._reap_dead()
        self._autoscale()

    def _departed(self, worker) -> bool:
        st = self.service.workers.get(worker)
        return st is not None and st.state in ("draining", "departed")

    def _reap_dead(self):
        """Return a dead worker's leases immediately (the fail_worker
        fast path — lease expiry is the slow fallback) and, for proc
        pools with respawn, replace the process. A worker that exited in
        state draining/departed left GRACEFULLY (scale-down or its own
        drain request): it holds nothing — forget it, never fail it."""
        for k, h in list(self._handles.items()):
            if h.poll() is None:
                continue
            if self._departed(h.worker):
                del self._handles[k]
                self._dead.discard(k)
                continue
            if k in self._dead:
                continue
            self._dead.add(k)
            self.service.fail_worker(h.worker)
            if self.respawn and not self.queue.closed:
                self._handles[k] = self._spawn(k)
                self._dead.discard(k)
                self.respawns += 1
                obs_metrics.counter(
                    "pool_respawns_total",
                    "dead proc workers replaced").inc()
        for k, t in list(self._threads.items()):
            if t.is_alive():
                continue
            if self._departed(f"shard{k}"):
                del self._threads[k]
                self._dead.discard(k)
                continue
            if k not in self._dead and not self.queue.finished:
                self._dead.add(k)
                self.service.fail_worker(f"shard{k}")

    # -- elasticity ---------------------------------------------------------
    def _live_active(self):
        """Live workers not already on their way out: the autoscaler's
        capacity measure."""
        out = []
        for k, h in self._handles.items():
            if h.poll() is None and not self._departed(h.worker):
                out.append(k)
        for k, t in self._threads.items():
            if t.is_alive() and not self._departed(f"shard{k}"):
                out.append(k)
        return sorted(out)

    def add_worker(self):
        """Spawn one late joiner on a fresh shard id (manual scale-up —
        the autoscaler calls this too). Returns the new shard id."""
        k = self._next_shard
        self._next_shard += 1
        if self.transport in ("proc", "tcp"):
            self._handles[k] = self._spawn(k)
        else:
            self._threads[k] = self._spawn_thread(k)
        self.scale_ups += 1
        obs_metrics.counter(
            "pool_scale_ups_total",
            "late joiners spawned on sustained backlog").inc()
        return k

    def drain_worker(self, shard=None):
        """Ask one worker to leave gracefully: finish held leases, take
        no more, exit through bye (manual scale-down — the autoscaler
        calls this with an idle pick). Returns the drained shard id or
        None if no drainable worker exists."""
        with self.queue.lock:
            if shard is None:
                for k in reversed(self._live_active()):
                    if not self.queue.leases_held(f"shard{k}"):
                        shard = k
                        break
            if shard is None:
                return None
            self.service.drain(f"shard{shard}")
        if self.monitor is not None:
            self.monitor.forget(f"shard{shard}")
        self.scale_downs += 1
        obs_metrics.counter(
            "pool_scale_downs_total",
            "idle workers drained out on sustained idleness").inc()
        return shard

    def _autoscale(self):
        """Queue-depth-driven elasticity, armed by max_workers: sustained
        unleased backlog spawns a late joiner; a sustained fully-idle
        pool drains one idle worker. One transition per sustain window —
        the since-timestamps re-arm after every action, so the pool walks
        toward the band edge instead of jumping."""
        if self.max_workers is None or self._shut or self.queue.closed:
            return
        queued, leased = self.queue.depth()
        now = time.monotonic()
        live = len(self._live_active())
        if queued > 0:
            self._idle_since = None
            if self._backlog_since is None:
                self._backlog_since = now
            elif (now - self._backlog_since >= self.autoscale_backlog_s
                    and live < self.max_workers):
                self.add_worker()
                self._backlog_since = now
        elif queued == 0 and leased == 0:
            self._backlog_since = None
            if self._idle_since is None:
                self._idle_since = now
            elif (now - self._idle_since >= self.autoscale_idle_s
                    and live > self.min_workers):
                self.drain_worker()
                self._idle_since = now
        else:
            self._backlog_since = None
            self._idle_since = None

    def poll(self):
        """Non-blocking: drain and return every newly completed
        {wid: BatchResult}. Results are handed over exactly once — a
        claimed wid is forgotten (no unbounded result growth)."""
        self._pump()
        with self._claim_lock:
            out, self._completed = self._completed, {}
        return out

    def claim(self, wids):
        """Non-blocking targeted claim: drain, then return whichever of
        `wids` are done as {wid: BatchResult}. Unlike poll() this leaves
        other submitters' results unclaimed, so several front-ends can
        share one pool."""
        self._pump()
        out = {}
        with self._claim_lock:
            for wid in set(wids) & self._completed.keys():
                out[wid] = self._completed.pop(wid)
        return out

    def wait(self, wids, timeout_s=600.0):
        """Block until every wid in `wids` completes; returns
        {wid: BatchResult}. Claims ONLY the asked-for wids — results for
        other submitters stay available to their own poll/wait."""
        want = set(wids)
        got = {}
        deadline = time.monotonic() + timeout_s
        while True:
            self._pump()
            with self._claim_lock:
                for wid in want & self._completed.keys():
                    got[wid] = self._completed.pop(wid)
                want -= got.keys()
            if not want:
                return got
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"pool did not complete {sorted(want)} within "
                    f"{timeout_s:.0f}s (gauges: {self.gauges()})")
            time.sleep(0.002)

    # -- observability ------------------------------------------------------
    @property
    def pids(self):
        """shard -> pid of the live proc workers ({} for inproc): the
        'same workers across waves' acceptance observable."""
        return {k: h.pid for k, h in self._handles.items()
                if h.poll() is None}

    @property
    def worker_stats(self):
        """The per-worker WorkerStats ledger (lease calls, chunks done,
        leases held, redeliveries charged, heartbeat age)."""
        return self.service.worker_report()

    def gauges(self):
        """Pool-level serving gauges: busy/idle workers, queue depth,
        in-flight leases, oldest unserved request age."""
        queued, leased = self.queue.depth()
        with self.queue.lock:
            busy = sum(1 for st in self.service.workers.values()
                       if self.queue.leases_held(st.worker))
            oldest = min(self._submit_t.values(), default=None)
        live = (len([h for h in self._handles.values()
                     if h.poll() is None])
                or len([t for t in self._threads.values() if t.is_alive()]))
        done, total = self.queue.progress()
        out = {"workers": live, "busy": busy,
               "idle": max(0, live - busy),
               "queue_depth": queued, "in_flight": leased,
               "oldest_age_s": (None if oldest is None
                                else time.monotonic() - oldest),
               "submitted": total, "completed": done,
               "epoch": self.service.epoch,
               "scale_ups": self.scale_ups,
               "scale_downs": self.scale_downs}
        reg = obs_metrics.get_registry()
        if reg.enabled:
            # mirror into the registry so metrics_text()/snapshot() carry
            # the live pool view without a second collection path
            reg.gauge("pool_workers", "live workers").set(live)
            reg.gauge("pool_busy", "workers holding leases").set(busy)
            reg.gauge("pool_queue_depth", "unleased work ids").set(queued)
            reg.gauge("pool_in_flight", "leased, uncompleted ids").set(leased)
            reg.gauge("pool_oldest_age_s",
                      "age of the oldest unserved request").set(
                          out["oldest_age_s"] or 0.0)
            reg.gauge("pool_membership_epoch",
                      "pool membership version (joins/drains/deaths)").set(
                          self.service.epoch)
        return out

    def kill_worker(self, shard):
        """SIGKILL a proc worker (chaos testing — the pool must redeliver
        its in-flight request exactly once)."""
        self._handles[shard].kill()

    # -- teardown -----------------------------------------------------------
    def drain(self, timeout_s=600.0):
        """Close admission and pump until every admitted item completed."""
        self.queue.close()
        deadline = time.monotonic() + timeout_s
        while not self.queue.finished:
            self._pump()
            if self.queue.finished:
                break
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"pool drain timed out (gauges: {self.gauges()})")
            time.sleep(0.005)

    def shutdown(self, drain=True, timeout_s=600.0):
        """Stop the pool. drain=True serves everything admitted first;
        drain=False abandons unfinished work (`queue.abort`). Workers
        observe `finished`, sign off via `bye` (their idle/busy split
        lands in the ledger), and exit; stragglers are TERM/KILLed."""
        if self._shut:
            return
        self._shut = True
        try:
            if drain:
                self.drain(timeout_s=timeout_s)
            else:
                self.queue.abort()
            deadline = time.monotonic() + 10.0
            for h in self._handles.values():
                try:
                    h.proc.wait(max(0.0, deadline - time.monotonic()))
                except Exception:
                    pass
            for t in self._threads.values():
                t.join(max(0.0, deadline - time.monotonic()))
        finally:
            for h in self._handles.values():
                h.shutdown()
            if self._tp is not None:
                self._tp.close()
