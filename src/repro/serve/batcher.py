"""Continuous batching front-end: many small requests -> padded device
batches.

Serving traffic arrives as single long chunks from many concurrent
clients; devices want batches. `ContinuousBatcher` sits between them:

  coalesce   waiting requests are assembled into one batch the moment a
             full `max_batch` is available OR the oldest request has
             waited `linger_s` (partial batches are served after the
             linger, never blocked on a full batch)
  pad        batch sizes are the pow2 survivor buckets from the device-
             compaction work (`scheduler.quantize_survivors`), so an
             arbitrary request mix produces O(log max_batch) distinct
             detect shapes — bounded retraces, warm jits. Pad rows are
             ZEROS via `scheduler.pad_batch`: no real request's bytes
             ride along twice, and batch content keys stay honest
  admit      `max_queue` bounds waiting + in-flight requests; beyond it
             `submit` raises `AdmissionError` — the backpressure signal
             a client retries on, instead of silently growing the queue
  deadline   a request past its deadline is FAILED, at dispatch-assembly
             time if it expired waiting, or at delivery time if its
             batch finished too late — stale results are never served
  dispatch   batches go to a `WorkerPool` (`pool=`, asynchronous — new
             batches keep dispatching while earlier ones are in flight)
             or any plan-like callable (`plan=`, synchronous in-process)

`pump()` is the serving loop body (single-threaded by design — run it
from one loop or via `start()`'s background thread); `submit`/`result`/
`wait` are thread-safe for any number of client threads. `result(rid)`
POPS: a delivered record is handed over exactly once and forgotten.

Every dispatched batch is recorded in `batch_log` (request ids, real
rows, padded rows, occupancy, linger wait) — the load-test bench reads
occupancy histograms from it and can rebuild any batch bit-exactly for
parity checks against the in-process two_phase path.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core import scheduler as SCHED
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing

# batch_log used to grow one record per dispatched batch for the life of
# the service; the metrics registry now keeps the aggregate (occupancy /
# wait histograms, dispatch counters), so the attribute is a bounded
# recent-history ring with the same read surface (iteration, indexing).
BATCH_LOG_CAP = 1024


class AdmissionError(RuntimeError):
    """The request queue is full (`max_queue`): backpressure, not growth."""


@dataclass
class _Request:
    rid: int
    chunk: np.ndarray           # (C, S_long_src) one long chunk
    deadline: float             # absolute, or None
    submit_t: float


class ContinuousBatcher:
    def __init__(self, pool=None, plan=None, max_batch=8, max_queue=64,
                 linger_s=0.02, pad_multiple=1, clock=time.monotonic):
        if (pool is None) == (plan is None):
            raise ValueError("exactly one of pool= / plan= must be given")
        self.pool = pool
        self.plan = plan
        self.max_batch = max(1, int(max_batch))
        self.max_queue = max(1, int(max_queue))
        self.linger_s = float(linger_s)
        self.pad_multiple = max(1, int(pad_multiple))
        self.clock = clock
        self._lock = threading.RLock()
        self._waiting = collections.deque()     # _Request, FIFO
        self._inflight = {}     # pool wid -> (requests, padded_rows)
        self._results = {}      # rid -> record (popped by result())
        self._next_id = 0
        # ring of per-dispatch occupancy records (aggregates live in the
        # metrics registry — see BATCH_LOG_CAP)
        self.batch_log = collections.deque(maxlen=BATCH_LOG_CAP)
        self.rejected = 0       # admission-control refusals
        self.expired = 0        # deadline failures (waiting or delivery)
        self._thread = None
        self._stop = threading.Event()

    # -- client surface -----------------------------------------------------
    def submit(self, long_chunk, timeout_s=None) -> int:
        """Admit one (C, S_long_src) request; returns a request id.
        `timeout_s` sets a deadline relative to now: a request that
        cannot be served in time is failed, never served stale. Raises
        AdmissionError when waiting + in-flight >= max_queue."""
        x = np.asarray(long_chunk, np.float32)
        now = self.clock()
        with self._lock:
            depth = len(self._waiting) + sum(
                len(reqs) for reqs, _ in self._inflight.values())
            if depth >= self.max_queue:
                self.rejected += 1
                obs_metrics.counter(
                    "batcher_rejected_total",
                    "requests refused by admission control").inc()
                raise AdmissionError(
                    f"queue full ({depth}/{self.max_queue} requests "
                    f"waiting or in flight)")
            rid = self._next_id
            self._next_id += 1
            deadline = None if timeout_s is None else now + float(timeout_s)
            self._waiting.append(_Request(rid, x, deadline, now))
        obs_metrics.counter("batcher_requests_total",
                            "requests admitted").inc()
        # request lifetime as an async span pair: submit here, resolve in
        # _deliver/_expire — a request may start and finish on different
        # threads, which plain B/E nesting cannot express
        obs_tracing.get_tracer().async_begin("request", rid)
        return rid

    def result(self, rid):
        """Pop a finished request's record, or None if not (yet) done.
        Success: {"ok": True, keep/rain/silence/cleaned, latency_s}.
        Failure: {"ok": False, "error": ...}. Each record is handed over
        exactly once — a second call returns None."""
        with self._lock:
            return self._results.pop(rid, None)

    def wait(self, rid, timeout_s=600.0):
        """Block until `rid` resolves; pops and returns its record. Runs
        the pump inline when no background pump thread is active."""
        deadline = time.monotonic() + timeout_s
        while True:
            with self._lock:
                if rid in self._results:
                    return self._results.pop(rid)
            if self._thread is None:
                self.pump()
            if time.monotonic() > deadline:
                raise TimeoutError(f"request {rid} unresolved after "
                                   f"{timeout_s:.0f}s")
            time.sleep(0.001)

    # -- serving loop -------------------------------------------------------
    def pump(self, force=False):
        """One serving-loop tick: fail expired waiters, dispatch every
        ready batch (full, or lingered past linger_s, or force=True for
        drain), and deliver finished pool batches. Returns the request
        ids resolved this tick. Call from ONE thread."""
        done = []
        now = self.clock()
        while True:
            with self._lock:
                self._expire_waiting(now, done)
                batch = self._assemble(now, force)
            if batch is None:
                break
            reqs, padded, n_real = batch
            if self.pool is not None:
                wid = self.pool.submit(padded)
                with self._lock:
                    self._inflight[wid] = (reqs, padded.shape[0])
            else:
                res = self.plan(padded)
                done += self._deliver(reqs, padded.shape[0], res)
        if self.pool is not None:
            with self._lock:
                wids = list(self._inflight)
            for wid, res in self.pool.claim(wids).items():
                with self._lock:
                    reqs, rows = self._inflight.pop(wid)
                done += self._deliver(reqs, rows, res)
        return done

    def _expire_waiting(self, now, done):
        """Fail queued requests whose deadline passed — they never reach
        a batch. Caller holds the lock."""
        alive = collections.deque()
        for r in self._waiting:
            if r.deadline is not None and now > r.deadline:
                self.expired += 1
                obs_metrics.counter(
                    "batcher_expired_total",
                    "requests failed on deadline").inc()
                self._results[r.rid] = {
                    "ok": False, "error": "deadline",
                    "waited_s": now - r.submit_t}
                obs_tracing.get_tracer().async_end("request", r.rid,
                                                   ok=False)
                done.append(r.rid)
            else:
                alive.append(r)
        self._waiting = alive

    def _assemble(self, now, force):
        """Take up to max_batch waiting requests once the dispatch
        condition holds; zero-pad them to the pow2 bucket size. Caller
        holds the lock; returns (requests, padded_batch, n_real) or
        None."""
        if not self._waiting:
            return None
        waited = now - self._waiting[0].submit_t
        if not (force or len(self._waiting) >= self.max_batch
                or waited >= self.linger_s):
            return None
        reqs = [self._waiting.popleft()
                for _ in range(min(len(self._waiting), self.max_batch))]
        rows = np.stack([r.chunk for r in reqs])
        size = SCHED.quantize_survivors(len(reqs), self.max_batch,
                                        self.pad_multiple, "pow2")
        padded, n_real = SCHED.pad_batch(rows, size)
        assert n_real == len(reqs) and padded.shape[0] == size
        # pad rows must be zeros — never a copy of any request's bytes
        assert n_real == size or not padded[n_real:].any()
        self.batch_log.append({
            "rids": [r.rid for r in reqs], "n_real": n_real,
            "rows": size, "occupancy": n_real / size,
            "waited_s": waited})
        reg = obs_metrics.get_registry()
        if reg.enabled:
            reg.counter("batcher_batches_total", "batches dispatched").inc()
            reg.histogram(
                "batcher_occupancy", "real rows / padded rows per batch",
                buckets=obs_metrics.OCCUPANCY_BUCKETS).observe(n_real / size)
            reg.histogram("batcher_wait_seconds",
                          "oldest-request linger at dispatch").observe(waited)
        obs_tracing.instant("batch_dispatch", n_real=n_real, rows=size)
        return reqs, padded, n_real

    def _deliver(self, reqs, rows, res):
        """Slice one finished batch back into per-request records.
        Survivors are compacted in stable row order, so request j's
        cleaned rows sit at [sum(keep[:j*per]), sum(keep[:(j+1)*per]));
        pad rows trail every real request and are never attributed. A
        request whose deadline passed while its batch computed is failed
        here — late results are dropped, not served stale."""
        keep = np.asarray(res.det.keep)
        rain = np.asarray(res.det.rain)
        silence = np.asarray(res.det.silence)
        per = keep.size // rows
        offs = np.concatenate([[0], np.cumsum(keep)]).astype(int)
        now = self.clock()
        out = []
        tracer = obs_tracing.get_tracer()
        latency_h = obs_metrics.histogram(
            "serve_request_latency_seconds", "submit-to-delivery latency")
        with self._lock:
            for j, r in enumerate(reqs):
                if r.deadline is not None and now > r.deadline:
                    self.expired += 1
                    obs_metrics.counter(
                        "batcher_expired_total",
                        "requests failed on deadline").inc()
                    self._results[r.rid] = {
                        "ok": False, "error": "deadline",
                        "waited_s": now - r.submit_t}
                    tracer.async_end("request", r.rid, ok=False)
                else:
                    lo, hi = j * per, (j + 1) * per
                    self._results[r.rid] = {
                        "ok": True,
                        "keep": keep[lo:hi], "rain": rain[lo:hi],
                        "silence": silence[lo:hi],
                        "cleaned": res.cleaned[offs[lo]:offs[hi]],
                        "latency_s": now - r.submit_t}
                    latency_h.observe(now - r.submit_t)
                    tracer.async_end("request", r.rid, ok=True)
                out.append(r.rid)
        return out

    def flush(self, timeout_s=600.0):
        """Drain: force-dispatch the waiting tail and pump until nothing
        is waiting or in flight. Returns all request ids resolved."""
        done = self.pump(force=True)
        deadline = time.monotonic() + timeout_s
        while True:
            with self._lock:
                idle = not self._waiting and not self._inflight
            if idle:
                return done
            if time.monotonic() > deadline:
                raise TimeoutError("batcher flush timed out")
            done += self.pump(force=True)
            time.sleep(0.001)

    # -- background pump loop ----------------------------------------------
    def start(self):
        """Run pump() on a background thread (the serving loop); client
        threads then only submit() and wait()."""
        if self._thread is not None:
            raise RuntimeError("batcher already started")
        self._stop.clear()

        def loop():
            tick = max(0.001, min(self.linger_s / 4, 0.005))
            while not self._stop.is_set():
                self.pump()
                time.sleep(tick)

        t = threading.Thread(target=loop, daemon=True,
                             name="repro-batcher-pump")
        self._thread = t
        t.start()
        return self

    def stop(self):
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=10.0)
            self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, exc_type, exc, tb):
        self.stop()

    # -- observability ------------------------------------------------------
    def stats(self):
        with self._lock:
            waiting = len(self._waiting)
            inflight = sum(len(reqs) for reqs, _ in
                           self._inflight.values())
        occ = [b["occupancy"] for b in self.batch_log]
        return {"waiting": waiting, "in_flight": inflight,
                "dispatched_batches": len(self.batch_log),
                "rejected": self.rejected, "expired": self.expired,
                "mean_occupancy": float(np.mean(occ)) if occ else None}
