"""Serving glue for the preprocessing facade: a host-side request queue
that pumps 60 s long-chunk requests through a `Preprocessor` plan in
fixed-size batches (the audio twin of `serve.engine.RequestQueue`, and the
serving analogue of the paper's slave pull queue).

Each request is one stereo long chunk; its result is the per-final-chunk
keep mask plus the cleaned surviving chunks — what a downstream species
classifier or archive-compaction consumer needs. Results are handed over
exactly once: `result(rid)` POPS its record, so the result map cannot
grow without bound under sustained traffic.

Extra keyword arguments are forwarded to the execution plan, so
`PreprocessService(cfg, plan="sharded", shards=4)` serves each pumped
batch through the multi-shard path (rows split across shards, survivors
re-balanced before MMSE) without the service knowing anything about it.
Note the sharded plan's `transport=` knob does NOT change serving:
single-batch pumps always row-split in-process — per-request worker
process spawns are not a serving latency anyone wants. For REAL worker
processes behind serving, pass `pool=` (a started
`repro.serve.pool.WorkerPool`): pumped batches are then submitted to the
pool's long-lived workers (warm jits across pumps, same pids wave after
wave) instead of computing in-process; `repro.serve.batcher.
ContinuousBatcher` is the lower-latency front-end when requests arrive
continuously rather than in pump waves.

Warm-cache serving rides the same passthrough:
`PreprocessService(cfg, plan="cached", store=DIR)` consults the
content-addressed `repro.store.ChunkStore` per pumped batch — a batch
whose exact bytes were served (or preprocessed offline) before returns
from the store without touching a device. Batches are keyed as pumped,
i.e. padded composition included, so recurring request groups hit; and
because pad rows are ZEROS (never copies of a request), the key of a
partial batch never depends on which request happened to arrive last.
With `pool=` AND a cached plan, store hits short-circuit BEFORE touching
a worker: only misses cost pool latency, and fresh results are written
back so the next identical batch is a hit. `cache_stats` reports the
hit/miss/bytes-saved ledger.

`PreprocessService(cfg, plan="async", depth=4)` serves each pumped batch
through the device-compaction path (only the keep mask and the cleaned
survivors cross the host boundary); the per-batch pipeline timing record
of the most recent pump is exposed as `last_timings` so a serving loop
can watch its readback/tail/emit latency split without instrumenting the
plan itself.
"""
from __future__ import annotations

import collections

import numpy as np

from repro.core import scheduler as SCHED
from repro.core.plans import Preprocessor
from repro.dist.service import pack_result
from repro.distributed.sharding import NULL_RULES
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing


class PreprocessService:
    def __init__(self, cfg, rules=NULL_RULES, plan="two_phase",
                 batch_long_chunks=4, pad_multiple=1, pool=None,
                 **plan_kwargs):
        self.cfg = cfg
        self.batch = batch_long_chunks
        self.pool = pool
        self.pre = Preprocessor(cfg, rules, plan=plan,
                                pad_multiple=pad_multiple, **plan_kwargs)
        self._queue = collections.deque()
        self._results = {}
        self._next_id = 0
        self.last_timings = None   # plan timing record of the last pump

    def submit(self, long_chunk) -> int:
        """long_chunk: (C, S_long_src) one 60 s stereo chunk. Returns a
        request id."""
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, np.asarray(long_chunk, np.float32)))
        obs_metrics.counter("serve_requests_total",
                            "requests admitted to PreprocessService").inc()
        return rid

    def pump(self):
        """Run one full (zero-padded) batch through the plan — or through
        the worker pool when one was given — and return the completed
        request ids."""
        if not self._queue:
            return []
        rids, chunks = [], []
        while self._queue and len(chunks) < self.batch:
            rid, c = self._queue.popleft()
            rids.append(rid)
            chunks.append(c)
        batch, n_real = SCHED.pad_batch(np.stack(chunks), self.batch)
        # pad rows are ZERO rows, never copies of a request: real bytes
        # must not ride the batch twice (duplicate MMSE flops, and a
        # cached plan would store a request's audio under a key that
        # depends on which request happened to arrive last)
        assert n_real == len(rids)
        assert n_real == batch.shape[0] or not batch[n_real:].any(), \
            "pad rows leaked real request bytes into the batch"
        res = self._serve(batch)
        self.last_timings = res.timings
        keep = np.asarray(res.det.keep)
        rain = np.asarray(res.det.rain)
        silence = np.asarray(res.det.silence)
        per = keep.size // batch.shape[0]        # final chunks per request
        # survivors are compacted in stable order: request j's cleaned rows
        # sit at [sum(keep[:j*per]), sum(keep[:(j+1)*per])). Masks are
        # sliced PER REQUEST — batch-level stats would be skewed by the
        # pad rows and the other requests in the batch; zero pad rows can
        # survive detection (their cleaned rows are zeros) but they trail
        # every real request in the stable order, so no request is ever
        # attributed a pad row.
        offs = np.concatenate([[0], np.cumsum(keep)])
        for j, rid in enumerate(rids):
            lo, hi = j * per, (j + 1) * per
            self._results[rid] = {
                "keep": keep[lo:hi],
                "rain": rain[lo:hi],
                "silence": silence[lo:hi],
                "cleaned": res.cleaned[offs[lo]:offs[hi]],
            }
        return rids

    def _serve(self, batch):
        """One assembled batch -> BatchResult. In-process plan by
        default; with `pool=`, a cached plan's store is consulted FIRST
        (warm hits never touch a worker), misses go to the pool's
        persistent workers, and fresh results are written back."""
        if self.pool is None:
            with obs_tracing.span("serve_pump", rows=int(batch.shape[0])):
                return self.pre(batch)
        plan = self.pre.plan
        store = getattr(plan, "store", None)
        key = None
        if store is not None:
            key = plan._key(batch)
            hit = store.get(key, src_bytes=batch.nbytes)
            if hit is not None:
                obs_metrics.counter(
                    "serve_store_hits_total",
                    "pumped batches answered from the chunk store").inc()
                return plan._result(*hit, wid=None, extra=None)
        with obs_tracing.span("serve_pool_pump", rows=int(batch.shape[0])):
            wid = self.pool.submit(batch)
            res = self.pool.wait([wid])[wid]
        if store is not None:
            store.put_payload(key, pack_result(res))
        return res

    def result(self, rid):
        """Pop a finished request's record (None if unknown/pending).
        Each record is handed over exactly once — the result map stays
        bounded by in-flight work, not service lifetime."""
        return self._results.pop(rid, None)

    @property
    def cache_stats(self):
        """Store hit/miss accounting when serving through a cached plan
        (None otherwise)."""
        return getattr(self.pre.plan, "stats", None)

    @property
    def worker_stats(self):
        """Per-worker progress ledger: the pool's live ledger when
        serving through a worker pool, else the sharded plan's report of
        its most recent stream run (None for other plans)."""
        if self.pool is not None:
            return self.pool.worker_stats
        return getattr(self.pre.plan, "worker_stats", None)

    # -- observability ------------------------------------------------------
    def metrics_snapshot(self):
        """JSON-safe dump of the process-wide metrics registry (plan,
        dist, pool, serving and store series alike — the service is just
        a convenient place to scrape from). Refreshes the pool gauges
        first so the snapshot carries the live serving view."""
        if self.pool is not None:
            self.pool.gauges()
        return obs_metrics.snapshot()

    def metrics_text(self):
        """The same registry in Prometheus text exposition format — what
        an HTTP /metrics endpoint would serve."""
        if self.pool is not None:
            self.pool.gauges()
        return obs_metrics.render()
