"""Serving glue for the preprocessing facade: a host-side request queue
that pumps 60 s long-chunk requests through a `Preprocessor` plan in
fixed-size batches (the audio twin of `serve.engine.RequestQueue`, and the
serving analogue of the paper's slave pull queue).

Each request is one stereo long chunk; its result is the per-final-chunk
keep mask plus the cleaned surviving chunks — what a downstream species
classifier or archive-compaction consumer needs.

Extra keyword arguments are forwarded to the execution plan, so
`PreprocessService(cfg, plan="sharded", shards=4)` serves each pumped
batch through the multi-shard path (rows split across shards, survivors
re-balanced before MMSE) without the service knowing anything about it.
Note the sharded plan's `transport=` knob does NOT change serving:
single-batch pumps always row-split in-process — per-request worker
process spawns are not a serving latency anyone wants (a persistent
worker pool for serving is future work, see ROADMAP); `worker_stats`
reports per-worker progress when a stream-mode run happened on the plan.

Warm-cache serving rides the same passthrough:
`PreprocessService(cfg, plan="cached", store=DIR)` consults the
content-addressed `repro.store.ChunkStore` per pumped batch — a batch
whose exact bytes were served (or preprocessed offline) before returns
from the store without touching a device. Batches are keyed as pumped,
i.e. padded composition included, so recurring request groups hit;
`cache_stats` reports the hit/miss/bytes-saved ledger.

`PreprocessService(cfg, plan="async", depth=4)` serves each pumped batch
through the device-compaction path (only the keep mask and the cleaned
survivors cross the host boundary); the per-batch pipeline timing record
of the most recent pump is exposed as `last_timings` so a serving loop
can watch its readback/tail/emit latency split without instrumenting the
plan itself.
"""
from __future__ import annotations

import collections

import numpy as np

from repro.core.plans import Preprocessor
from repro.distributed.sharding import NULL_RULES


class PreprocessService:
    def __init__(self, cfg, rules=NULL_RULES, plan="two_phase",
                 batch_long_chunks=4, pad_multiple=1, **plan_kwargs):
        self.cfg = cfg
        self.batch = batch_long_chunks
        self.pre = Preprocessor(cfg, rules, plan=plan,
                                pad_multiple=pad_multiple, **plan_kwargs)
        self._queue = collections.deque()
        self._results = {}
        self._next_id = 0
        self.last_timings = None   # plan timing record of the last pump

    def submit(self, long_chunk) -> int:
        """long_chunk: (C, S_long_src) one 60 s stereo chunk. Returns a
        request id."""
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, np.asarray(long_chunk, np.float32)))
        return rid

    def pump(self):
        """Run one full (padded) batch through the plan; returns the
        completed request ids."""
        if not self._queue:
            return []
        rids, chunks = [], []
        while self._queue and len(chunks) < self.batch:
            rid, c = self._queue.popleft()
            rids.append(rid)
            chunks.append(c)
        while len(chunks) < self.batch:          # pad with copies
            chunks.append(chunks[-1])
        res = self.pre(np.stack(chunks))
        self.last_timings = res.timings
        keep = np.asarray(res.det.keep)
        rain = np.asarray(res.det.rain)
        silence = np.asarray(res.det.silence)
        per = keep.size // len(chunks)           # final chunks per request
        # survivors are compacted in stable order: request j's cleaned rows
        # sit at [sum(keep[:j*per]), sum(keep[:(j+1)*per])). Masks are
        # sliced PER REQUEST — batch-level stats would be skewed by the
        # pad copies and the other requests in the batch.
        offs = np.concatenate([[0], np.cumsum(keep)])
        for j, rid in enumerate(rids):
            lo, hi = j * per, (j + 1) * per
            self._results[rid] = {
                "keep": keep[lo:hi],
                "rain": rain[lo:hi],
                "silence": silence[lo:hi],
                "cleaned": res.cleaned[offs[lo]:offs[hi]],
            }
        return rids

    def result(self, rid):
        return self._results.get(rid)

    @property
    def cache_stats(self):
        """Store hit/miss accounting when serving through a cached plan
        (None otherwise)."""
        return getattr(self.pre.plan, "stats", None)

    @property
    def worker_stats(self):
        """Per-worker progress ledger of the sharded plan's most recent
        stream run (None for other plans / before any run)."""
        return getattr(self.pre.plan, "worker_stats", None)
