"""Durable per-chunk telemetry: crash-safe JSONL records + aggregation.

Every chunk that moves through a leased queue leaves records written
MASTER-side (the paper's master is the only box guaranteed to survive a
slave crash), at the moments the master learns something:

  * status "done"        — written at `complete` acceptance
    (`QueueService.note_done`), carrying the full lease→fetch→push→accept
    timeline, worker/shard/pid, content key, survivor count and bytes
    moved.  Exactly one per chunk id, because acceptance is gated on
    `WorkQueue.complete` returning the id as newly-done.
  * status "redelivered" — written when a lease is reclaimed
    (`WorkQueue.on_redeliver`: reason "expired" for lease-timeout, reason
    "failed" for `fail_worker`), attributing the LOSING incarnation, so a
    SIGKILLed worker's half-processed chunk shows both attempts.

Records survive SIGKILLed workers by construction (workers never write
them) and survive a killed master up to the last flushed line: each
record is a single buffered `write()` of one line followed by `flush()`,
and the reader skips a torn trailing line.

`worker_ledger` aggregates records into the paper's Figure-style
per-worker load view (chunks, survivors, bytes, redeliveries, span of
acceptance times).
"""
from __future__ import annotations

import glob
import json
import os
import threading
import time


class TelemetryWriter:
    """Append-only JSONL writer, one file per writing process."""

    def __init__(self, directory, name=None, fsync=False):
        self.directory = str(directory)
        os.makedirs(self.directory, exist_ok=True)
        fname = name or f"telemetry-{os.getpid()}.jsonl"
        self.path = os.path.join(self.directory, fname)
        self._fsync = fsync
        self._lock = threading.Lock()
        self._f = open(self.path, "a", encoding="utf-8")
        self.records_written = 0

    def record(self, **fields):
        fields.setdefault("ts", time.time())
        line = json.dumps(fields, separators=(",", ":"), default=_json_safe)
        with self._lock:
            if self._f.closed:
                return
            self._f.write(line + "\n")
            self._f.flush()
            if self._fsync:
                os.fsync(self._f.fileno())
            self.records_written += 1

    def close(self):
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _json_safe(obj):
    for cast in (int, float):
        try:
            return cast(obj)
        except (TypeError, ValueError):
            continue
    return str(obj)


def record_result(writer, wid, res, worker="master"):
    """Acceptance record for a result emitted OUTSIDE a queue service
    (single-process plans in the launch driver, benches): same shape as
    the master-side "done" records, minus the RPC timeline."""
    if writer is None:
        return
    writer.record(event="chunk", status="done", wid=int(wid),
                  worker=worker, pid=os.getpid(), accept_ts=time.time(),
                  survivors=int(getattr(res, "n_kept", 0)),
                  bytes_in=int(getattr(res, "src_bytes", 0)),
                  bytes_out=int(getattr(res, "cleaned", None).nbytes
                                if getattr(res, "cleaned", None) is not None
                                else 0))


# ------------------------------------------------------------------ read

def read_records(path):
    """Load every record under `path` (a directory of *.jsonl, or one
    file).  A torn trailing line — the writing process died mid-write —
    is skipped, not fatal; a torn line anywhere else raises."""
    if os.path.isdir(path):
        files = sorted(glob.glob(os.path.join(path, "*.jsonl")))
    else:
        files = [path]
    records = []
    for fp in files:
        with open(fp, encoding="utf-8") as f:
            lines = f.read().splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                records.append(json.loads(line))
            except ValueError:
                if i == len(lines) - 1:
                    continue    # torn tail: writer was killed mid-line
                raise
    return records


def chunk_ledger(records):
    """Per-chunk view: {wid: {"statuses": [...], "workers": [...],
    "survivors": int|None, "done": bool}} in record order."""
    out = {}
    for r in records:
        if r.get("event") != "chunk":
            continue
        wid = r.get("wid")
        c = out.setdefault(wid, {"statuses": [], "workers": [],
                                 "survivors": None, "done": False})
        c["statuses"].append(r.get("status"))
        if r.get("worker") is not None:
            c["workers"].append(r.get("worker"))
        if r.get("status") == "done":
            c["done"] = True
            c["survivors"] = r.get("survivors")
    return out


def worker_ledger(records):
    """The Figure-style per-worker load ledger: how many chunks each
    worker actually carried, what it produced, and what it dropped."""
    out = {}

    def w(name):
        return out.setdefault(name, {
            "chunks_done": 0, "survivors": 0, "bytes_in": 0, "bytes_out": 0,
            "redelivered_from": 0, "speculation_lost": 0,
            "first_accept_ts": None, "last_accept_ts": None})

    for r in records:
        if r.get("event") != "chunk":
            continue
        name = r.get("worker") or "?"
        entry = w(name)
        if r.get("status") == "done":
            entry["chunks_done"] += 1
            entry["survivors"] += int(r.get("survivors") or 0)
            entry["bytes_in"] += int(r.get("bytes_in") or 0)
            entry["bytes_out"] += int(r.get("bytes_out") or 0)
            ts = r.get("accept_ts")
            if ts is not None:
                if entry["first_accept_ts"] is None:
                    entry["first_accept_ts"] = ts
                entry["last_accept_ts"] = ts
        elif r.get("status") == "redelivered":
            entry["redelivered_from"] += 1
            # a "speculated" reason is not a lost LEASE but a lost RACE:
            # this incarnation computed an id whose duplicate finished
            # first — break it out so wasted-work dashboards see it
            if r.get("reason") == "speculated":
                entry["speculation_lost"] += 1
    return out
