"""Process-local metrics registry: counters, gauges, histograms.

One registry per process (module-global, swappable for tests/benches).
Instruments are named + labeled Prometheus-style:

    metrics.counter("plan_batches_total", labels=("plan",)) \
           .labels(plan="async").inc()

Design constraints, in order:

  1. Zero-cost-when-off.  `metrics.counter(...)` on a disabled registry
     returns the shared `NULL_INSTRUMENT`, whose every method is a no-op;
     enabled instruments re-check `registry.enabled` on mutation so a
     registry can be toggled mid-run (the overhead bench does).
  2. No new wire surface beyond `snapshot()`: a plain-dict, JSON- and
     pickle-safe dump that backs the `metrics` RPC of
     `repro.dist.service.QueueService`.
  3. Prometheus text exposition via `render()` for
     `serve.preprocess_service.PreprocessService.metrics_text()` —
     scrape-ready without any HTTP dependency.

The historic ledgers (`StoreStats`, `WorkerStats`, `batch_log`,
per-batch `timings`) stay as attribute views at their old homes and
mirror deltas in here, so both old callers and the one registry see the
same truth.
"""
from __future__ import annotations

import threading

# Latency-ish buckets (seconds), log-spaced 0.5 ms .. 30 s.
DEFAULT_TIME_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)
# Fill-fraction buckets for batch occupancy (1/8 .. 1).
OCCUPANCY_BUCKETS = tuple(i / 8 for i in range(1, 9))
# Byte-size buckets, log-spaced 1 KiB .. 1 GiB.
BYTES_BUCKETS = tuple(float(1 << k) for k in range(10, 31, 2))


class _NullInstrument:
    """Shared do-nothing stand-in for every instrument kind."""
    __slots__ = ()

    def labels(self, **kv):
        return self

    def inc(self, n=1):
        pass

    def dec(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    @property
    def value(self):
        return 0.0


NULL_INSTRUMENT = _NullInstrument()


class _Child:
    """One labeled series of a parent instrument (`.labels(...)` result)."""
    __slots__ = ("_parent", "_key")

    def __init__(self, parent, key):
        self._parent = parent
        self._key = key

    def inc(self, n=1):
        self._parent._inc(self._key, n)

    def dec(self, n=1):
        self._parent._inc(self._key, -n)

    def set(self, v):
        self._parent._set(self._key, v)

    def observe(self, v):
        self._parent._observe(self._key, v)

    @property
    def value(self):
        return self._parent._value(self._key)


class _Instrument:
    kind = "untyped"

    def __init__(self, registry, name, help="", label_names=()):
        self._reg = registry
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._series = {}          # label-values tuple -> mutable cell
        if not self.label_names:   # unlabeled: single default series
            self._series[()] = self._new_cell()

    # -- label plumbing ---------------------------------------------------
    def labels(self, **kv):
        key = tuple(str(kv.get(k, "")) for k in self.label_names)
        if key not in self._series:
            with self._reg._lock:
                self._series.setdefault(key, self._new_cell())
        return _Child(self, key)

    def _cell(self, key):
        cell = self._series.get(key)
        if cell is None:
            with self._reg._lock:
                cell = self._series.setdefault(key, self._new_cell())
        return cell

    # -- unlabeled convenience (mirrors _Child) ---------------------------
    def inc(self, n=1):
        self._inc((), n)

    def dec(self, n=1):
        self._inc((), -n)

    def set(self, v):
        self._set((), v)

    def observe(self, v):
        self._observe((), v)

    @property
    def value(self):
        return self._value(())

    # -- per-kind cells ---------------------------------------------------
    def _new_cell(self):
        return [0.0]

    def _inc(self, key, n):
        raise TypeError(f"{self.kind} does not support inc()")

    def _set(self, key, v):
        raise TypeError(f"{self.kind} does not support set()")

    def _observe(self, key, v):
        raise TypeError(f"{self.kind} does not support observe()")

    def _value(self, key):
        cell = self._series.get(key)
        return cell[0] if cell else 0.0

    def _series_snapshot(self):
        out = []
        with self._reg._lock:
            for key, cell in sorted(self._series.items()):
                out.append({"labels": dict(zip(self.label_names, key)),
                            "value": cell[0]})
        return out


class Counter(_Instrument):
    kind = "counter"

    def _inc(self, key, n):
        if n < 0:
            raise ValueError("counters only go up")
        if self._reg.enabled:
            with self._reg._lock:
                self._cell(key)[0] += n


class Gauge(_Instrument):
    kind = "gauge"

    def _inc(self, key, n):
        if self._reg.enabled:
            with self._reg._lock:
                self._cell(key)[0] += n

    def _set(self, key, v):
        if self._reg.enabled:
            with self._reg._lock:
                self._cell(key)[0] = float(v)


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(self, registry, name, help="", label_names=(),
                 buckets=DEFAULT_TIME_BUCKETS):
        self.buckets = tuple(sorted(float(b) for b in buckets))
        super().__init__(registry, name, help, label_names)

    def _new_cell(self):
        # [per-bucket counts..., +Inf count] + [sum, count] trailer
        return {"counts": [0] * (len(self.buckets) + 1), "sum": 0.0, "n": 0}

    def _observe(self, key, v):
        if not self._reg.enabled:
            return
        v = float(v)
        with self._reg._lock:
            cell = self._cell(key)
            i = 0
            for i, b in enumerate(self.buckets):
                if v <= b:
                    break
            else:
                i = len(self.buckets)
            cell["counts"][i] += 1
            cell["sum"] += v
            cell["n"] += 1

    def _value(self, key):
        cell = self._series.get(key)
        return cell["n"] if cell else 0

    def _series_snapshot(self):
        out = []
        with self._reg._lock:
            for key, cell in sorted(self._series.items()):
                cum, counts = 0, {}
                for b, c in zip(self.buckets, cell["counts"]):
                    cum += c
                    counts[repr(b)] = cum
                counts["+Inf"] = cum + cell["counts"][-1]
                out.append({"labels": dict(zip(self.label_names, key)),
                            "buckets": counts,
                            "sum": cell["sum"], "count": cell["n"]})
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named-instrument registry.  Getter methods create-or-return, so hot
    paths can call `registry.counter(name).inc()` without pre-declaring;
    redeclaring with a different kind is an error."""

    def __init__(self, enabled=True):
        self.enabled = enabled
        self._metrics = {}
        self._lock = threading.RLock()

    # -- instrument getters ----------------------------------------------
    def _get(self, cls, name, help, label_names, **kw):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(self, name, help, label_names, **kw)
                    self._metrics[name] = m
        if not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}")
        return m

    def counter(self, name, help="", labels=()):
        return self._get(Counter, name, help, labels)

    def gauge(self, name, help="", labels=()):
        return self._get(Gauge, name, help, labels)

    def histogram(self, name, help="", labels=(), buckets=DEFAULT_TIME_BUCKETS):
        return self._get(Histogram, name, help, labels, buckets=buckets)

    # -- export -----------------------------------------------------------
    def snapshot(self):
        """Plain-dict dump: JSON- and pickle-safe (backs the `metrics` RPC)."""
        out = {}
        with self._lock:
            for name, m in sorted(self._metrics.items()):
                out[name] = {"type": m.kind, "help": m.help,
                             "labels": list(m.label_names),
                             "series": m._series_snapshot()}
        return out

    def render(self):
        """Prometheus text exposition (text/plain; version=0.0.4)."""
        lines = []
        for name, m in sorted(self.snapshot().items()):
            if m["help"]:
                lines.append(f"# HELP {name} {m['help']}")
            lines.append(f"# TYPE {name} {m['type']}")
            for s in m["series"]:
                lab = _fmt_labels(s["labels"])
                if m["type"] == "histogram":
                    for le, c in s["buckets"].items():
                        blab = _fmt_labels({**s["labels"], "le": le})
                        lines.append(f"{name}_bucket{blab} {c}")
                    lines.append(f"{name}_sum{lab} {_fmt_val(s['sum'])}")
                    lines.append(f"{name}_count{lab} {s['count']}")
                else:
                    lines.append(f"{name}{lab} {_fmt_val(s['value'])}")
        return "\n".join(lines) + "\n"

    def summary_lines(self, prefix=""):
        """Compact human report: one `name{labels} value` line per non-zero
        series (histograms render count/mean).  Drives the end-of-run
        report in the launch drivers."""
        lines = []
        for name, m in sorted(self.snapshot().items()):
            if prefix and not name.startswith(prefix):
                continue
            for s in m["series"]:
                lab = _fmt_labels(s["labels"])
                if m["type"] == "histogram":
                    if s["count"]:
                        mean = s["sum"] / s["count"]
                        lines.append(
                            f"{name}{lab} n={s['count']} mean={mean:.6g}")
                elif s["value"]:
                    lines.append(f"{name}{lab} {_fmt_val(s['value'])}")
        return lines

    def reset(self):
        with self._lock:
            self._metrics.clear()


class NullRegistry(MetricsRegistry):
    """Disabled registry: every getter returns the shared no-op instrument,
    so instrumented code pays one attribute check and nothing else."""

    def __init__(self):
        super().__init__(enabled=False)

    def counter(self, name, help="", labels=()):
        return NULL_INSTRUMENT

    def gauge(self, name, help="", labels=()):
        return NULL_INSTRUMENT

    def histogram(self, name, help="", labels=(), buckets=DEFAULT_TIME_BUCKETS):
        return NULL_INSTRUMENT


def _fmt_labels(labels):
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels.items())
    return "{" + inner + "}"


def _escape(v):
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _fmt_val(v):
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


# ---------------------------------------------------------------- globals

_REGISTRY = MetricsRegistry()
NULL_REGISTRY = NullRegistry()


def get_registry():
    return _REGISTRY


def set_registry(registry):
    global _REGISTRY
    _REGISTRY = registry


def enabled():
    return _REGISTRY.enabled


def counter(name, help="", labels=()):
    r = _REGISTRY
    return r.counter(name, help, labels) if r.enabled else NULL_INSTRUMENT


def gauge(name, help="", labels=()):
    r = _REGISTRY
    return r.gauge(name, help, labels) if r.enabled else NULL_INSTRUMENT


def histogram(name, help="", labels=(), buckets=DEFAULT_TIME_BUCKETS):
    r = _REGISTRY
    return (r.histogram(name, help, labels, buckets)
            if r.enabled else NULL_INSTRUMENT)


def snapshot():
    return _REGISTRY.snapshot()


def render():
    return _REGISTRY.render()


def summary_lines(prefix=""):
    return _REGISTRY.summary_lines(prefix)
