"""repro.obs — one observability spine for the whole stack.

Three cooperating components, each usable alone:

  * `obs.metrics`   — process-local metrics registry (counters / gauges /
    histograms with fixed buckets, Prometheus-style labels).  The five
    historic ad-hoc ledgers (`BatchResult.timings`, `dist.service
    .WorkerStats`, `store.StoreStats`, `serve.batcher.batch_log`,
    `launch.preprocess.pipeline_report`) now mirror into it; their
    original attribute surfaces are preserved as thin views so no caller
    breaks.  `snapshot()` is JSON/pickle-safe (it backs the `metrics`
    RPC) and `render()` is Prometheus text exposition.
  * `obs.telemetry` — durable per-chunk JSONL records written MASTER-side
    at `push_result`/`complete` acceptance, so they survive SIGKILLed
    workers; a reader aggregates them into the paper's Figure-style
    per-worker load ledger.
  * `obs.tracing`   — span tracing with a run-level trace id propagated
    through the `repro.dist` RPC surface (worker spans carry the
    master-issued parent id across the pickle boundary), exported as
    Chrome trace-event JSON that loads directly in Perfetto.

Everything is zero-cost-when-off: the disabled registry and the null
tracer are shared no-op objects, and `benchmarks/bench_obs_overhead.py`
enforces <5% wall-clock impact when ON (with bit-identical outputs).
"""
from repro.obs import metrics, telemetry, tracing
from repro.obs.metrics import MetricsRegistry, NullRegistry, get_registry, set_registry
from repro.obs.telemetry import TelemetryWriter, read_records, worker_ledger
from repro.obs.tracing import NULL_TRACER, Tracer, get_tracer, set_tracer, validate_chrome_trace

__all__ = [
    "metrics", "telemetry", "tracing",
    "MetricsRegistry", "NullRegistry", "get_registry", "set_registry",
    "TelemetryWriter", "read_records", "worker_ledger",
    "Tracer", "NULL_TRACER", "get_tracer", "set_tracer",
    "validate_chrome_trace",
]
