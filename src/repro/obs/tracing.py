"""Span tracing exported as Chrome trace-event JSON (Perfetto-loadable).

A run gets one `Tracer` with a run-level `trace_id`.  The master opens a
root "run" span (`start_run()`); every span opened afterwards — master- or
worker-side — carries `{"trace": trace_id, "parent": run_span_id}` in its
`args`, which is how worker spans are parented under the master's run span
across the pickle boundary:

  * master: `tracer.propagate()` -> small dict, injected into the `hello`
    setup blob by `repro.dist.service.QueueService`;
  * worker: builds its own `Tracer(**propagated)` (different pid, same
    trace id / parent), buffers events locally, and ships them back as
    `bye(stats={"spans": [...]})`; the master merges with `add_events`.

Event kinds used:
  * `B`/`E` pairs from `span()` — strictly nested per (pid, tid) because
    they come from a context manager;
  * `X` complete events from `complete()` — for hot worker-loop phases
    (lease / fetch / compute / push) where only non-empty iterations
    should land in the trace;
  * `i` instants from `instant()`; `b`/`e` async pairs from
    `async_begin`/`async_end` for request lifetimes that start and finish
    on different threads (the continuous batcher).

`validate_chrome_trace` is the schema gate: every event must carry
`ph`/`ts`/`pid`/`tid`/`name`, and `B`/`E` must balance LIFO per
(pid, tid).  The smoke gate and tests call it so a Perfetto-breaking
regression fails CI, not a human.

Zero-cost-when-off: the module-level tracer defaults to `NULL_TRACER`,
whose `span()` returns a shared no-op context manager.
"""
from __future__ import annotations

import json
import os
import threading
import time
import uuid


def _now_us():
    # Wall-clock (not monotonic) so master and worker events share a
    # comparable timebase across processes.
    return time.time() * 1e6


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Context manager emitting a B/E pair on one tracer."""
    __slots__ = ("_tracer", "_name")

    def __init__(self, tracer, name, args):
        self._tracer = tracer
        self._name = name
        tracer._emit("B", name, args=args)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._tracer._emit("E", self._name)
        return False


class Tracer:
    """Event buffer with Chrome trace-event output.

    `max_events` bounds memory on long-lived services; once full, new
    events are dropped and counted (`dropped`) — short smoke/validation
    runs never get near the cap, so B/E balance is preserved where it is
    checked.
    """

    enabled = True

    def __init__(self, trace_id=None, parent=None, max_events=200_000):
        self.trace_id = trace_id or uuid.uuid4().hex[:16]
        self.parent = parent          # span id worker events attach under
        self.run_span_id = None
        self.max_events = int(max_events)
        self.dropped = 0
        self.events = []
        self._lock = threading.Lock()
        self._pid = os.getpid()

    # -- low-level emit ---------------------------------------------------
    def _emit(self, ph, name, ts=None, args=None, **extra):
        ev = {"name": name, "ph": ph,
              "ts": _now_us() if ts is None else ts,
              "pid": self._pid, "tid": threading.get_ident(),
              "cat": extra.pop("cat", "repro")}
        a = dict(args) if args else {}
        a["trace"] = self.trace_id
        if self.parent is not None and ph in ("B", "X", "i", "b", "e"):
            a["parent"] = self.parent
        ev["args"] = a
        ev.update(extra)
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped += 1
            else:
                self.events.append(ev)
        return ev

    # -- public span API --------------------------------------------------
    def span(self, name, **args):
        return _Span(self, name, args)

    def complete(self, name, start_s, end_s=None, **args):
        """X (complete) event from wall-clock seconds — for after-the-fact
        recording, e.g. a worker lease poll kept only when it got ids."""
        end_s = time.time() if end_s is None else end_s
        self._emit("X", name, ts=start_s * 1e6,
                   dur=max(0.0, (end_s - start_s) * 1e6), args=args)

    def instant(self, name, **args):
        self._emit("i", name, args=args, s="t")

    def async_begin(self, name, id, **args):
        self._emit("b", name, args=args, id=str(id), cat="request")

    def async_end(self, name, id, **args):
        self._emit("e", name, args=args, id=str(id), cat="request")

    # -- run-root span ----------------------------------------------------
    def start_run(self, name="run", **args):
        ev = self._emit("B", name, args=args)
        self.run_span_id = ev["args"]["span"] = f"{self.trace_id}:0"
        self._run_name = name
        self.parent = self.run_span_id
        return self.run_span_id

    def finish_run(self):
        if self.run_span_id is not None:
            self._emit("E", getattr(self, "_run_name", "run"))

    # -- cross-process plumbing -------------------------------------------
    def propagate(self):
        """Picklable context for a child tracer in another process."""
        return {"trace_id": self.trace_id, "parent": self.parent}

    def add_events(self, events):
        """Merge events shipped from a worker tracer (already dicts)."""
        if not events:
            return
        with self._lock:
            room = self.max_events - len(self.events)
            if room < len(events):
                self.dropped += len(events) - max(0, room)
                events = events[:max(0, room)]
            self.events.extend(events)

    def drain(self):
        """Pop and return all buffered events (worker -> bye payload)."""
        with self._lock:
            evs, self.events = self.events, []
            return evs

    # -- export -----------------------------------------------------------
    def chrome(self):
        with self._lock:
            evs = sorted(self.events, key=lambda e: (e["pid"], e["tid"], e["ts"]))
        return {"traceEvents": evs,
                "otherData": {"trace_id": self.trace_id,
                              "dropped": self.dropped}}

    def save(self, path):
        data = self.chrome()
        with open(path, "w") as f:
            json.dump(data, f)
        return len(data["traceEvents"])


class NullTracer:
    """Shared no-op tracer: the off state."""

    enabled = False
    trace_id = None
    parent = None
    run_span_id = None
    events = ()
    dropped = 0

    def span(self, name, **args):
        return _NULL_SPAN

    def complete(self, name, start_s, end_s=None, **args):
        pass

    def instant(self, name, **args):
        pass

    def async_begin(self, name, id, **args):
        pass

    def async_end(self, name, id, **args):
        pass

    def start_run(self, name="run", **args):
        return None

    def finish_run(self):
        pass

    def propagate(self):
        return None

    def add_events(self, events):
        pass

    def drain(self):
        return []

    def chrome(self):
        return {"traceEvents": [], "otherData": {}}


NULL_TRACER = NullTracer()
_TRACER = NULL_TRACER


def get_tracer():
    return _TRACER


def set_tracer(tracer):
    global _TRACER
    _TRACER = tracer if tracer is not None else NULL_TRACER


def span(name, **args):
    t = _TRACER
    return t.span(name, **args) if t.enabled else _NULL_SPAN


def instant(name, **args):
    t = _TRACER
    if t.enabled:
        t.instant(name, **args)


# ---------------------------------------------------------------- schema

_REQUIRED = ("ph", "ts", "pid", "tid", "name")
_KNOWN_PH = {"B", "E", "X", "i", "I", "b", "e", "n", "M", "C"}


def validate_chrome_trace(data):
    """Schema-check a Chrome trace-event dump (dict or event list).

    Enforces: every event carries ph/ts/pid/tid/name; `ph` is a known
    phase; `X` events carry `dur`; `B`/`E` pairs balance LIFO per
    (pid, tid) with matching names.  Returns per-phase counts.
    Raises ValueError on the first violation.
    """
    events = data.get("traceEvents", []) if isinstance(data, dict) else data
    counts = {}
    stacks = {}
    for i, ev in enumerate(events):
        for k in _REQUIRED:
            if k not in ev:
                raise ValueError(f"event {i} missing {k!r}: {ev}")
        ph = ev["ph"]
        if ph not in _KNOWN_PH:
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        if ph == "X" and "dur" not in ev:
            raise ValueError(f"event {i} is 'X' without dur")
        counts[ph] = counts.get(ph, 0) + 1
        if ph in ("B", "E"):
            key = (ev["pid"], ev["tid"])
            stack = stacks.setdefault(key, [])
            if ph == "B":
                stack.append(ev["name"])
            else:
                if not stack:
                    raise ValueError(
                        f"event {i}: 'E' {ev['name']!r} with empty stack on {key}")
                top = stack.pop()
                if top != ev["name"]:
                    raise ValueError(
                        f"event {i}: 'E' {ev['name']!r} closes {top!r} on {key}")
    for key, stack in stacks.items():
        if stack:
            raise ValueError(f"unclosed spans {stack} on {key}")
    return counts
