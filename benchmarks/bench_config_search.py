"""Paper Table 7: configuration search over (split length, long split
length, slave queue size, send interval) — 90 configurations through the
calibrated DES, plus real two-phase throughput for the winning config.

The paper's key insight: the top configurations are within <1% of each
other, so the split length can be chosen for detector ACCURACY (15 s) at no
meaningful throughput cost. We assert the same here.
"""
from __future__ import annotations

import itertools

import numpy as np

from benchmarks.des import simulate
from benchmarks.bench_scaling import paper_costs
from benchmarks.util import table, save_json


def run(hours=2.0):
    total_s = hours * 3600
    grid = list(itertools.product(
        (5, 10, 15, 20, 30),        # split length (s)
        (60, 120, 180),             # long split length (s)
        (3, 5, 7),                  # slave queue size
        (2, 3),                     # send interval (s)
    ))
    rows = []
    for split_s, long_s, qsize, send_s in grid:
        costs = paper_costs(split_s)
        # longer long-splits amortize the HPF (the paper's Fig-2 effect)
        costs.master_prep *= (60.0 / long_s) ** 0.15
        sim = simulate(total_s, costs, [4, 4, 4, 4], chunk_s=float(split_s),
                       queue_size=qsize, send_interval_s=float(send_s))
        rows.append([split_s, long_s, qsize, send_s, sim["makespan_s"]])
    rows.sort(key=lambda r: r[-1])
    table([r for r in rows[:10]],
          ["split_s", "long_split_s", "queue", "send_s", "exec time (s)"],
          title="Table-7 equivalent: top-10 of 90 configurations "
                "(DES, 4x4-core VMs)")
    times = np.array([r[-1] for r in rows])
    spread_top10 = (times[9] - times[0]) / times[0]
    # the paper's one BAD combo: 5 s splits with queue size 3
    bad = [r for r in rows if r[0] == 5 and r[2] == 3]
    good5 = [r for r in rows if r[0] == 5 and r[2] >= 5]
    if bad and good5:
        print(f"bad-combo check (split=5,queue=3): {bad[0][-1]:.1f}s vs "
              f"{good5[0][-1]:.1f}s for queue>=5 (paper: ~25 s slower)")
    print(f"\ntop-10 spread: {100 * spread_top10:.2f}% of fastest "
          f"(paper: 0.8%) -> split length chosen for ACCURACY (15 s)")
    save_json("config_search", {
        "top10": rows[:10], "n_configs": len(rows),
        "top10_spread_frac": float(spread_top10),
        "finding_flat_optimum": bool(spread_top10 < 0.05),
    })


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=2.0)
    run(hours=ap.parse_args().hours)


if __name__ == "__main__":
    main()
