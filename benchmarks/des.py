"""Discrete-event simulator of the paper's master-slave system, calibrated
with per-stage costs MEASURED on this machine (bench_stage_times writes the
calibration json).

This is how Figs 11-18 are reproduced without a 32-core cluster: this
container has ONE core, so wall-clock multi-process scaling cannot be
measured directly; the simulator replays the paper's architecture with
measured per-second-of-audio stage costs.

Model (faithful to the paper's description):
  * The master splits + downsamples + high-pass filters long chunks and
    feeds a bounded pull queue. The master process SHARES its 4-core VM
    with a slave process (paper: "a slave node is also executed on the same
    machine as the master"), so prep work competes with that slave's
    processing — no free cores.
  * Slaves run detection on every chunk, the cicada filter on the detected
    fraction, silence detection, and MMSE on the surviving fraction.
  * Results return at the next send-interval boundary; transfers cost
    comm_per_mb (measured, Fig-10 bench).
  * Each slave pays a per-chunk coordination overhead amortized over its
    cores (the paper's central-slave-thread overhead, which made 1-core
    slaves slightly slower — Fig 13).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field


@dataclass
class StageCosts:
    """Seconds of compute per second of source audio, per stage (measured)."""
    master_prep: float      # split + mono + downsample + HPF (on master)
    detect: float           # STFT + indices + rain/cicada rules
    cicada_filter: float    # band-stop + ISTFT (applied to cicada fraction)
    silence: float          # envelope SNR at 5 s
    mmse: float             # MMSE-STSA (applied to survivor fraction)
    comm_per_mb: float      # transfer cost per MB (measured)

    frac_cicada: float = 0.09
    frac_survive: float = 0.45

    def slave_cost_per_chunk(self, chunk_s):
        """Expected compute seconds for one chunk of chunk_s seconds."""
        return chunk_s * (self.detect
                          + self.frac_cicada * self.cicada_filter
                          + self.silence
                          + self.frac_survive * self.mmse)


def simulate(total_audio_s, costs: StageCosts, slaves_cores,
             chunk_s=15.0, queue_size=5, send_interval_s=2.0,
             chunk_mb=None, master_cores=4, coord_s_per_chunk=0.004,
             pull_latency_s=1.0, trace_dt=None):
    """Simulate preprocessing total_audio_s seconds of audio.

    slaves_cores: cores per slave process; slave 0 lives on the master's VM
    and its cores also execute the master's prep tasks.

    queue_size models the paper's bounded pull queue: when the per-chunk
    processing time is short relative to the pull round-trip latency, a
    too-small queue drains and the slave stalls (the paper's one bad
    configuration: 5 s splits with queue 3).
    Returns makespan, per-slave chunk counts, utilization, optional trace."""
    if chunk_mb is None:
        chunk_mb = chunk_s * 44_100 * 2 * 2 / 2**20   # stereo int16 source
    n_chunks = int(total_audio_s / chunk_s)
    prep_per_chunk = chunk_s * costs.master_prep

    # per-slave core heaps: (next_free_time, core_id)
    heaps = [[(0.0, c) for c in range(cores)] for cores in slaves_cores]
    for h in heaps:
        heapq.heapify(h)
    processed = [0] * len(slaves_cores)
    busy = [0.0] * len(slaves_cores)

    # 1) master prep tasks occupy slave 0's VM cores
    ready = []
    for i in range(n_chunks):
        free_t, core = heapq.heappop(heaps[0])
        end = free_t + prep_per_chunk
        heapq.heappush(heaps[0], (end, core))
        busy[0] += prep_per_chunk
        ready.append(end + costs.comm_per_mb * chunk_mb)

    # queue-drain stall (per chunk, amortized)
    base_dur = costs.slave_cost_per_chunk(chunk_s)
    stall = max(0.0, pull_latency_s - max(queue_size - 1, 0) * base_dur)

    # 2) processing tasks go to the slave whose earliest core is free first
    #    (rotating tie-break = the master's round-robin dispatch)
    finish = []
    trace = []
    n_slaves = len(heaps)
    for i in range(n_chunks):
        best = min(range(n_slaves),
                   key=lambda s: (max(heaps[s][0][0], ready[i]),
                                  (s - i) % n_slaves))
        free_t, core = heapq.heappop(heaps[best])
        start = max(free_t, ready[i])
        dur = (base_dur + stall
               + coord_s_per_chunk / max(slaves_cores[best], 1))
        end = start + dur
        heapq.heappush(heaps[best], (end, core))
        processed[best] += 1
        busy[best] += dur
        ret = ((int(end / send_interval_s) + 1) * send_interval_s
               + costs.comm_per_mb * chunk_mb * costs.frac_survive)
        finish.append(ret)
        if trace_dt:
            trace.append((start, end, best))

    makespan = max(finish) if finish else 0.0
    util = [busy[s] / (makespan * slaves_cores[s])
            for s in range(len(slaves_cores))]
    out = {
        "makespan_s": makespan,
        "per_slave_chunks": processed,
        "per_slave_utilization": util,
        "n_chunks": n_chunks,
    }
    if trace_dt:
        cores_total = sum(slaves_cores)
        ts = [i * trace_dt for i in range(int(makespan / trace_dt) + 1)]
        usage = []
        for t in ts:
            b = sum(1 for (a, b_, _) in trace if a <= t < b_)
            usage.append(min(1.0, b / cores_total))
        out["utilization_trace"] = list(zip(ts, usage))
    return out


def serial_time(total_audio_s, costs: StageCosts):
    """1-core sequential execution (the paper's baseline process)."""
    per_s = (costs.master_prep + costs.detect
             + costs.frac_cicada * costs.cicada_filter + costs.silence
             + costs.frac_survive * costs.mmse)
    return total_audio_s * per_s
