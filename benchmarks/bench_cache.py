"""Content-addressed store economics: cold vs warm vs partial-overlap
streams through `CachedPlan`.

The rolling-archive scenario (Stowell/Lostanlen sensor networks): each
day's run overlaps most of yesterday's input. Measured here as three runs
over the same synthetic stream generator:

  cold     every batch is new — pure store overhead on top of the inner
           plan (hash + write per batch)
  warm     the identical stream again — every batch hits, no device work
  partial  `overlap` of the batches seen before, the rest new — the
           realistic daily mix

Reported per run: wall time, hit rate, MB/s of source audio, speedup vs
cold, plus a bit-exactness check of warm-run survivor masks against an
uncached reference run.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.util import table, save_json


def _stream(make, wids):
    return [(w, make(w)) for w in wids]


def run(minutes=8.0, batch_long_chunks=2, overlap=0.5, inner="two_phase"):
    from repro.configs import SERF_AUDIO as cfg
    from repro.core.plans import Preprocessor
    from repro.data.loader import audio_batch_maker

    n = max(2, int(round(minutes / batch_long_chunks)))
    make = audio_batch_maker(seed=17, batch_long_chunks=batch_long_chunks)
    base_wids = list(range(n))
    n_new = max(1, int(round(n * (1.0 - overlap))))
    mix_wids = base_wids[n_new:] + [n + i for i in range(n_new)]

    store_dir = tempfile.mkdtemp(prefix="bench_cache_")
    out, rows = {}, []
    try:
        # uncached reference for the bit-exactness claim + baseline timing
        ref_pre = Preprocessor(cfg, plan=inner)
        t0 = time.time()
        ref = {r.wid: np.asarray(r.det.keep)
               for r in ref_pre.run(_stream(make, base_wids))}
        t_ref = time.time() - t0

        runs = [("cold", base_wids), ("warm", base_wids),
                (f"partial({overlap:.0%})", mix_wids)]
        t_cold = None
        for name, wids in runs:
            pre = Preprocessor(cfg, plan="cached", inner=inner,
                               store=store_dir)
            t0 = time.time()
            results = list(pre.run(_stream(make, wids)))
            dt = time.time() - t0
            src = sum(r.src_bytes for r in results)
            st = pre.plan.stats
            if t_cold is None:
                t_cold = dt
            rows.append([name, len(wids), st.hits, f"{st.hit_rate:.0%}",
                         f"{dt:.2f}", f"{src / 2**20 / dt:.1f}",
                         f"{t_cold / dt:.1f}x"])
            out[name] = {"n": len(wids), "hits": st.hits,
                         "hit_rate": st.hit_rate, "seconds": dt,
                         "speedup_vs_cold": t_cold / dt}
            if name == "warm":
                for r in results:
                    np.testing.assert_array_equal(np.asarray(r.det.keep),
                                                  ref[r.wid])
        table(rows, ["stream", "batches", "hits", "hit rate", "s",
                     "MB/s", "vs cold"],
              title=f"ChunkStore economics (inner={inner}, "
                    f"{minutes:.0f} min stream)")
        print(f"warm-run survivor masks bit-identical to uncached "
              f"{inner} reference ({t_ref:.2f}s) OK")
        out["bit_identical_masks"] = True
        save_json("cache", out)
    finally:
        shutil.rmtree(store_dir, ignore_errors=True)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=8.0)
    ap.add_argument("--overlap", type=float, default=0.5)
    ap.add_argument("--inner", default="two_phase")
    args = ap.parse_args()
    run(minutes=args.minutes, overlap=args.overlap, inner=args.inner)


if __name__ == "__main__":
    main()
