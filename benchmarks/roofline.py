"""Roofline analysis from the dry-run's compiled artifacts (§g).

Terms (seconds, per device, TPU v5e constants):
  compute    = dot FLOPs / 197e12            (bf16 peak per chip)
  memory     = dot stream bytes / 819e9      (HBM bandwidth)
  collective = collective bytes / (4 links * 50e9)   (ICI, ring model)

All inputs come from the trip-count-aware HLO walker
(repro/launch/hlo_analysis.py; XLA's own cost_analysis counts scan bodies
once). DTYPE CORRECTION: XLA:CPU float-normalizes bf16 to f32, so walker
byte counts for bf16 programs (all model cells) are 2x the TPU values —
corrected by 0.5 here (flops are dtype-independent). The audio-pipeline
cells mix f32 I/O with bf16 DFT streams; they are left uncorrected (upper
bound).

Roofline fraction ("roof%"):
  train/prefill: useful model FLOPs (6*N_active*D or 2*N_active*D) per
                 device vs peak, over the bounding term (perfect overlap).
  decode:        streaming efficiency — the bytes that MUST move per step
                 (weights + caches = argument bytes) over the bounding term.
  pipeline:      reported terms only (the §Perf log carries the iterations).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_LINKS = 4
LINK_BW = 50e9
HBM_GB = 16.0
BF16_CORRECTION = 0.5


def fused_tail_record(R, S, window=256, hop=128, hpf=False, hpf_taps=129):
    """A `roofline_terms`-compatible record for the fused survivor tail's
    single kernel pass (kernels/fused_tail): DFT-dot + FIR + MMSE FLOPs
    against the kernel's true HBM traffic (gathered rows in, packed
    filtered spectrum out — the VMEM-resident intermediates move nothing).
    kind="pipeline" so the f32 byte counts skip the bf16 correction."""
    from repro.kernels.fused_tail.kernel import tail_geometry
    from repro.kernels.stft_dft.kernel import PAD_OUT
    _, S_pad, F, _ = tail_geometry(S, window, hop)
    bins = window // 2 + 1
    flops = 2 * R * F * window * PAD_OUT          # matmul DFT
    if hpf:
        flops += 2 * R * S * hpf_taps             # FIR tap chain
    flops += R * F * bins * 40                    # MMSE recurrence (approx)
    bytes_ = R * S * 4 + window * PAD_OUT * 4     # rows + basis in
    bytes_ += R * F * PAD_OUT * 4                 # packed spectrum out
    return {"kind": "pipeline", "flops_per_device": flops,
            "bytes_per_device": bytes_, "collective_bytes_per_device": 0,
            "n_devices": 1}


def load_records(pattern):
    recs = []
    for path in sorted(glob.glob(pattern)):
        with open(path) as f:
            recs.extend(json.load(f))
    return recs


def roofline_terms(rec):
    corr = 1.0 if rec.get("kind") == "pipeline" else BF16_CORRECTION
    comp = rec["flops_per_device"] / PEAK_FLOPS
    mem = corr * rec["bytes_per_device"] / HBM_BW
    coll = corr * rec["collective_bytes_per_device"] / (ICI_LINKS * LINK_BW)
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda kv: kv[1])
    t_bound = max(comp, mem, coll, 1e-12)
    out = {"compute_s": comp, "memory_s": mem, "collective_s": coll,
           "dominant": dom[0], "bound_s": t_bound}
    if rec.get("kind") == "pipeline" or not rec.get("model_flops"):
        out["useful_flops_ratio"] = None
        out["roofline_fraction"] = None
        return out
    model_flops_dev = rec["model_flops"] / rec["n_devices"]
    out["useful_flops_ratio"] = model_flops_dev / max(
        rec["flops_per_device"], 1.0)
    if rec["kind"] == "decode":
        # decode must stream weights+caches every step: efficiency = that
        # minimal traffic time over the bounding time
        need = rec["memory"]["argument_bytes"] / HBM_BW
        out["roofline_fraction"] = min(1.0, need / t_bound)
    else:
        out["roofline_fraction"] = (model_flops_dev / PEAK_FLOPS) / t_bound
    return out


def what_would_move_it(rec, terms):
    d = terms["dominant"]
    if d == "compute":
        if (terms["useful_flops_ratio"] or 1) < 0.5:
            return ("compute-bound with low useful-FLOPs ratio: cut remat "
                    "recompute / causal-attention waste")
        return "compute-bound near useful peak: good placement"
    if d == "memory":
        if rec["kind"] == "decode":
            return ("memory-bound on weight+KV streaming: quantize KV/"
                    "weights or raise batch to amortize weight reads")
        return "memory-bound: fuse elementwise chains, avoid f32 round-trips"
    return ("collective-bound: reshard (zero3/sp_ep profiles) or overlap "
            "(collective-matmul); move the axis with the largest transfer")


def fmt_table(recs, md=False):
    headers = ["arch", "shape", "mesh", "mode", "mb", "peakGB", "compute_s",
               "memory_s", "collective_s", "dominant", "useful%", "roof%"]
    rows = []
    for rec in recs:
        if rec.get("skipped"):
            rows.append([rec["arch"], rec["shape"], _mesh(rec.get("mesh")),
                         "-", "-", "-", "-", "-", "-", "SKIP(brief)", "-",
                         "-"])
            continue
        if rec.get("error"):
            rows.append([rec["arch"], rec["shape"], _mesh(rec.get("mesh")),
                         "-", "-", "-", "-", "-", "-", "ERROR", "-", "-"])
            continue
        t = roofline_terms(rec)
        uf = t["useful_flops_ratio"]
        rf = t["roofline_fraction"]
        rows.append([
            rec["arch"], rec["shape"], _mesh(rec["mesh"]),
            rec.get("mode", "-"), str(rec.get("microbatches") or "-"),
            f"{rec['memory']['peak_estimate_gb']:.1f}",
            f"{t['compute_s']:.2e}", f"{t['memory_s']:.2e}",
            f"{t['collective_s']:.2e}", t["dominant"],
            "-" if uf is None else f"{100 * uf:.0f}",
            "-" if rf is None else f"{100 * rf:.1f}",
        ])
    if md:
        lines = ["| " + " | ".join(headers) + " |",
                 "|" + "|".join("---" for _ in headers) + "|"]
        lines += ["| " + " | ".join(map(str, r)) + " |" for r in rows]
        return "\n".join(lines)
    from benchmarks.util import table
    return table(rows, headers, title="Roofline per (arch x shape x mesh)")


def _mesh(name):
    return {"single_pod_16x16": "1pod", "multi_pod_2x16x16": "2pod"}.get(
        name, name or "-")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="results/dryrun_final*.json")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    recs = load_records(args.pattern)
    if args.mesh:
        recs = [r for r in recs if r.get("mesh") == args.mesh]
    if not recs:
        print(f"no dry-run records match {args.pattern} — run "
              "`python -m repro.launch.dryrun --all --mesh both --out "
              "results/dryrun_final` first")
        return
    out = fmt_table(recs, md=args.md)
    if args.md:
        print(out)


if __name__ == "__main__":
    main()
