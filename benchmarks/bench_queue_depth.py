"""Table 7 axis: lease batching (the paper's slave `max_queue_size`).

The paper sweeps the slaves' local queue depth and finds throughput rises
until the queue is deep enough to hide master round-trips, then flattens.
Our twin knob is `lease_items`: work ids granted per `WorkQueue.lease`
round-trip. This bench sweeps lease_items x shards over the SAME seeded
synthetic stream and records, per config:

  wall_s        end-to-end wall clock
  round_trips   lease calls against the master (the cost deeper batches
                amortize; Table 7's independent variable, inverted)
  leased        work ids granted (== stream length + redeliveries)
  redeliveries  lease-expiry / fail_worker re-sends (the exposure deeper
                batches add: a dead worker strands more leases)
  idle_s        per-worker idle seconds (proc transport: worker-reported
                time blocked on the master; inproc: 0 by construction)

Runs in-process by default (deterministic, no spawn cost — the round-trip
count is transport-invariant because the lease protocol is the same
object); `--transport proc` measures real processes, where round-trips
are genuine socket RTTs and idle_s is real blocked time.

  PYTHONPATH=src python -m benchmarks.bench_queue_depth [--minutes 8]
      [--transport proc] [--shards 2,4] [--lease-items 1,2,4,8]

Writes machine-readable `results/BENCH_queue_depth.json`.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.loader import audio_batch_maker, make_shard_pool

OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                   "BENCH_queue_depth.json")


def run_config(n_batches, shards, lease_items, transport="inproc", seed=0,
               batch_long_chunks=1):
    make = audio_batch_maker(seed=seed,
                             batch_long_chunks=batch_long_chunks)
    pool = make_shard_pool(make, n_batches, shards,
                           lease_items=lease_items, lease_timeout_s=300.0)
    pre = Preprocessor(cfg, plan="sharded", shards=shards, pad_multiple=1,
                       lease_items=lease_items, transport=transport)
    t0 = time.perf_counter()
    results = list(pre.run(pool))
    wall = time.perf_counter() - t0
    wids = sorted(r.wid for r in results)
    assert wids == list(range(n_batches)), f"lost/dup chunks: {wids}"
    stats = pre.plan.worker_stats or []
    keep = np.concatenate(
        [np.asarray(r.det.keep)
         for r in sorted(results, key=lambda r: r.wid)])
    return {
        "shards": shards, "lease_items": lease_items,
        "transport": transport, "n_batches": n_batches,
        "wall_s": round(wall, 3),
        "round_trips": int(sum(s.lease_calls for s in stats)),
        "leased": int(sum(s.leased_total for s in stats)),
        "redeliveries": int(pre.plan.redeliveries),
        "idle_s": {s.worker: round(s.idle_s, 3) for s in stats},
        "busy_s": {s.worker: round(s.busy_s, 3) for s in stats},
        "keep_crc": int(np.packbits(keep).sum()),   # cheap parity stamp
    }


def run(minutes=8.0, shards=(2, 4), lease_items=(1, 2, 4, 8),
        transport="inproc", seed=0):
    n_batches = max(8, int(round(minutes)))
    rows = []
    for k in shards:
        for li in lease_items:
            row = run_config(n_batches, k, li, transport=transport,
                             seed=seed)
            rows.append(row)
            idle = sum(row["idle_s"].values())
            print(f"shards={k} lease_items={li}: {row['wall_s']:.2f}s, "
                  f"{row['round_trips']} round-trips for {row['leased']} "
                  f"ids, {row['redeliveries']} redeliveries, "
                  f"idle {idle:.2f}s")
    # every config must see the same survivors — the knob moves work,
    # never values
    crcs = {r["keep_crc"] for r in rows}
    assert len(crcs) == 1, f"configs disagree on survivors: {crcs}"
    findings = {}
    for k in shards:
        mine = {r["lease_items"]: r for r in rows if r["shards"] == k}
        base, deep = mine[min(lease_items)], mine[max(lease_items)]
        findings[f"shards{k}"] = {
            f"round_trips_{min(lease_items)}": base["round_trips"],
            f"round_trips_{max(lease_items)}": deep["round_trips"],
            "round_trip_drop": round(
                1.0 - deep["round_trips"] / max(base["round_trips"], 1), 3),
            "wall_ratio": round(deep["wall_s"] / base["wall_s"], 3),
        }
        assert deep["round_trips"] < base["round_trips"], (
            f"lease batching did not reduce round-trips at shards={k}: "
            f"{base['round_trips']} -> {deep['round_trips']}")
        print(f"shards={k}: lease_items {min(lease_items)}->"
              f"{max(lease_items)} cuts round-trips "
              f"{base['round_trips']} -> {deep['round_trips']} "
              f"({findings[f'shards{k}']['round_trip_drop']:.0%}), "
              f"wall x{findings[f'shards{k}']['wall_ratio']:.2f}")
    out = {"bench": "queue_depth", "transport": transport,
           "n_batches": n_batches, "rows": rows, "findings": findings}
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {os.path.normpath(OUT)}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=8.0,
                    help="stream length (1 batch ~= 1 minute of audio)")
    ap.add_argument("--transport", choices=("inproc", "proc"),
                    default="inproc")
    ap.add_argument("--shards", default="2,4")
    ap.add_argument("--lease-items", default="1,2,4,8")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(minutes=args.minutes,
        shards=tuple(int(s) for s in args.shards.split(",")),
        lease_items=tuple(int(s) for s in args.lease_items.split(",")),
        transport=args.transport, seed=args.seed)


if __name__ == "__main__":
    main()
