"""The REAL-process scaling curve (paper Figs 11-12, measured, not DES).

`bench_scaling.py` answers "what does the paper's cost model predict";
this bench runs the actual master/worker runtime: one seeded stream
through REAL worker processes on the TCP transport with the STORE data
plane (chunk bytes via a shared ChunkStore, the master's socket carrying
only leases and content keys), sharded {1, 2, 4, 8, 16}, lease batching
on. Reported per shard count: wall time, speedup vs the single-process
two_phase serial baseline, parallel efficiency, and the per-worker
idle/busy split from the workers' own `bye` reports. A socket-plane
reference run grades the data-plane byte cut (must be >= 90%), and every
sharded run is verified bit-identical to the serial baseline.

On a 1-core container the curve is honest about what it measures:
contention + per-process jit compiles, not the paper's 4-core-VM fleet —
the point is the MEASURED curve from the real runtime, with per-worker
idle/busy making the queueing behavior visible.

  PYTHONPATH=src python -m benchmarks.bench_scaling_real
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.util import save_json, table

SHARDS = (1, 2, 4, 8, 16)
N_BATCHES = 16
SEED = 13


def _plane_bytes(plane):
    from repro.obs import metrics as obs_metrics
    reg = obs_metrics.get_registry()
    return sum(
        reg.counter(name, labels=("plane",)).labels(plane=plane).value
        for name in ("dist_fetch_bytes_total", "dist_push_bytes_total"))


def _check_identical(results, ref_out):
    for r in results:
        want = ref_out[r.wid]
        np.testing.assert_array_equal(np.asarray(r.det.keep),
                                      np.asarray(want.det.keep))
        np.testing.assert_array_equal(r.cleaned, want.cleaned)
        assert r.n_kept == want.n_kept


def run(shards=SHARDS, n_batches=N_BATCHES):
    from repro.configs import SERF_AUDIO as cfg
    from repro.core.plans import Preprocessor
    from repro.data.loader import audio_batch_maker

    make = audio_batch_maker(seed=SEED, batch_long_chunks=1)
    stream = [(w, (make(w)[0], None)) for w in range(n_batches)]

    # serial baseline: the single-process two_phase plan, one pass over
    # the same stream (includes its one-time compile, as every sharded
    # wall below includes its workers' compiles)
    ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    t0 = time.perf_counter()
    ref_out = {w: ref(chunks) for w, (chunks, _) in stream}
    serial_wall = time.perf_counter() - t0
    print(f"serial two_phase: {n_batches} batches in {serial_wall:.1f}s",
          flush=True)

    # socket-plane reference (2 real workers over tcp, no store): the
    # data-plane bytes the master's socket carries without the store
    before = _plane_bytes("socket")
    pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1,
                       transport="tcp", lease_items=2,
                       lease_timeout_s=600.0, stall_timeout_s=900.0)
    t0 = time.perf_counter()
    sock_results = list(pre.run(list(stream)))
    sock_wall = time.perf_counter() - t0
    socket_bytes = _plane_bytes("socket") - before
    assert sorted(r.wid for r in sock_results) == list(range(n_batches))
    _check_identical(sock_results, ref_out)
    print(f"socket-plane reference (2 shards): {sock_wall:.1f}s, "
          f"{socket_bytes / 1e6:.1f} MB over the master socket", flush=True)

    rows, sweep = [], []
    store_bytes = None
    for s in shards:
        dp_dir = tempfile.mkdtemp(prefix=f"bench_dplane_{s}_")
        try:
            before = _plane_bytes("store")
            pre = Preprocessor(cfg, plan="sharded", shards=s,
                               pad_multiple=1, transport="tcp",
                               data_plane=dp_dir, lease_items=2,
                               lease_timeout_s=600.0, stall_timeout_s=900.0)
            t0 = time.perf_counter()
            results = list(pre.run(list(stream)))
            wall = time.perf_counter() - t0
            store_bytes = _plane_bytes("store") - before
        finally:
            shutil.rmtree(dp_dir, ignore_errors=True)
        assert sorted(r.wid for r in results) == list(range(n_batches)), \
            f"{s}-shard run lost/duplicated chunks"
        _check_identical(results, ref_out)
        workers = [{"worker": st.worker, "shard": st.shard,
                    "chunks_done": st.chunks_done,
                    "lease_calls": st.lease_calls,
                    "idle_s": st.idle_s, "busy_s": st.busy_s}
                   for st in pre.plan.worker_stats]
        idle = sum(w["idle_s"] for w in workers)
        busy = sum(w["busy_s"] for w in workers)
        speedup = serial_wall / wall
        row = {"shards": s, "wall_s": wall, "speedup": speedup,
               "efficiency": speedup / s,
               "redeliveries": pre.plan.redeliveries,
               "store_key_bytes": store_bytes,
               "idle_s_total": idle, "busy_s_total": busy,
               "workers": workers}
        sweep.append(row)
        rows.append((s, wall, speedup, speedup / s, idle, busy,
                     pre.plan.redeliveries))
        print(f"  {s:2d} shards: wall {wall:7.1f}s  speedup "
              f"{speedup:5.2f}x  eff {speedup / s:5.1%}  "
              f"idle {idle:7.1f}s  busy {busy:7.1f}s", flush=True)

    byte_cut = 1.0 - store_bytes / socket_bytes
    assert byte_cut >= 0.9, \
        f"store plane cut only {byte_cut:.1%} of socket data-plane bytes"
    table(rows, ["shards", "wall_s", "speedup", "efficiency",
                 "idle_s", "busy_s", "redeliv"],
          title="Real-process scaling (tcp transport, store data plane)")
    print(f"data-plane byte cut: {byte_cut:.2%} "
          f"({store_bytes / 1e3:.1f} kB of keys vs "
          f"{socket_bytes / 1e6:.1f} MB of payloads)", flush=True)
    out = {
        "config": {"n_batches": n_batches, "seed": SEED,
                   "lease_items": 2, "transport": "tcp",
                   "data_plane": "store", "host_cores": 1},
        "serial_wall_s": serial_wall,
        "socket_plane_ref": {"shards": 2, "wall_s": sock_wall,
                             "socket_bytes": socket_bytes},
        "store_key_bytes": store_bytes,
        "data_plane_byte_cut": byte_cut,
        "bit_identical_to_two_phase": True,
        "sweep": sweep,
    }
    save_json("BENCH_scaling_real", out)
    print("saved results/BENCH_scaling_real.json", flush=True)
    return out


if __name__ == "__main__":
    run()
