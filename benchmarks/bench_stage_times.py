"""Paper Table 1 / Fig 1: per-stage execution time vs split length.

Each stage is measured INDEPENDENTLY on the same audio (as in the paper),
for split lengths 5..30 s. Also writes the calibration file the DES
simulator (Figs 11-18) consumes: seconds of compute per second of audio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core import stages as S
from repro.core import detect as D
from repro.core import indices as I
from repro.data.synthetic import generate_labelled
from repro.kernels.fir_hpf.ops import highpass
from benchmarks.util import time_fn, table, save_json

SPLITS = (5, 10, 15, 20, 30)


def _audio_minutes(minutes, seed=0):
    n_seg = int(minutes * 60 / 5)
    audio, labels = generate_labelled(seed, n_seg, segment_s=5.0)
    return audio, labels


def run(minutes=2.0, seed=0):
    audio, _ = _audio_minutes(minutes, seed)
    n_seg, _, S5src = audio.shape
    total_src_s = n_seg * 5.0
    mono = np.asarray(S.to_mono(jnp.asarray(audio)))        # 44.1 kHz
    x22 = np.asarray(jax.jit(lambda a: S.compress(a, cfg))(jnp.asarray(mono)))

    def chunks_of(arr, split_s, rate):
        n = int(split_s * rate)
        total = arr.shape[0] * arr.shape[1]
        flat = arr.reshape(-1)[: (total // n) * n]
        return jnp.asarray(flat.reshape(-1, n))

    rows = []
    calib = {}
    for split_s in SPLITS:
        c_src = chunks_of(mono, split_s, cfg.source_rate_hz)
        c22 = chunks_of(x22, split_s, cfg.target_rate_hz)

        t_split, _ = time_fn(
            jax.jit(lambda a: a.reshape(-1, c_src.shape[1])), mono)
        t_down, _ = time_fn(jax.jit(lambda a: S.compress(a, cfg)), c_src)
        t_hpf, _ = time_fn(jax.jit(highpass), c22)
        stft_fn = jax.jit(lambda a: S.stft_chunks(a, cfg)[1])
        t_fft, _ = time_fn(stft_fn, c22)
        power = stft_fn(c22)
        t_rain, _ = time_fn(jax.jit(
            lambda p: D.detect_rain(I.all_indices(p, cfg), cfg)), power)
        t_cic, _ = time_fn(jax.jit(
            lambda p: D.detect_cicada(I.all_indices(p, cfg), cfg)), power)

        def cic_filter(a):
            spec, p = S.stft_chunks(a, cfg)
            idx = I.all_indices(p, cfg)
            mask = D.detect_cicada(idx, cfg)
            spec = S.remove_cicada_band(spec, idx["cicada_peak_bin"], mask,
                                        cfg)
            return S.istft_chunks(spec, a.shape[1], cfg)
        t_cicf, _ = time_fn(jax.jit(cic_filter), c22)
        t_sil, _ = time_fn(jax.jit(lambda p: I.snr_est(p)), power)
        t_mmse, _ = time_fn(jax.jit(lambda a: S.mmse_denoise(a, cfg)), c22)

        rows.append([split_s, t_split, t_down, t_hpf, t_fft, t_rain,
                     t_cic, t_cicf, t_sil, t_mmse])
        calib[split_s] = {
            "master_prep": (t_split + t_down) / total_src_s,
            "detect": (t_fft + t_rain + t_cic) / total_src_s,
            "cicada_filter": t_cicf / total_src_s,
            "silence": t_sil / total_src_s,
            "mmse": t_mmse / total_src_s,
        }

    headers = ["split_s", "Splitting", "Down+AA", "HPF", "FFT(DFT)",
               "RainDet", "CicadaDet", "CicadaFilt", "Silence", "MMSE-STSA"]
    out = table(rows, headers,
                title=f"Table-1 equivalent: stage seconds for "
                      f"{minutes:.1f} min of audio, per split length")
    # The paper's two key findings, checked programmatically:
    mmse_col = [r[-1] for r in rows]
    others = [sum(r[1:-1]) for r in rows]
    finding_mmse_dominates = all(m > o for m, o in zip(mmse_col, others))
    save_json("stage_times", {"rows": rows, "headers": headers,
                              "minutes": minutes, "calibration": calib,
                              "mmse_dominates": finding_mmse_dominates})
    print(f"\nMMSE-STSA dominates all other stages combined: "
          f"{finding_mmse_dominates} (paper Table 1 finding)")
    return calib


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=2.0)
    run(minutes=ap.parse_args().minutes)


if __name__ == "__main__":
    main()
