"""Paper Figs 19-20: CPU utilisation (~90% through the run) and RAM usage.

CPU: DES busy-core fraction over time. RAM: analytic footprint of the
pipeline's buffers (queue depth x chunk bytes + batch working set) —
mirroring the paper's observation that RAM is under-utilised because the
workload streams.
"""
from __future__ import annotations

import numpy as np

from benchmarks.des import simulate
from benchmarks.bench_scaling import paper_costs
from benchmarks.util import table, save_json


def run(hours=2.0):
    costs = paper_costs()
    sim = simulate(hours * 3600, costs, [4, 4, 4, 4], chunk_s=15.0,
                   trace_dt=2.0)
    trace = sim["utilization_trace"]
    ts = np.array([t for t, _ in trace])
    us = np.array([u for _, u in trace])
    mid = us[(ts > ts.max() * 0.1) & (ts < ts.max() * 0.9)]
    rows = [[f"{int(t)}s", f"{100 * u:.0f}%"] for t, u in
            trace[:: max(1, len(trace) // 12)]]
    table(rows, ["t", "CPU util"],
          title="Fig-19 equivalent: utilisation over the run (DES)")
    print(f"steady-state mean utilisation: {100 * mid.mean():.1f}% "
          f"(paper: ~90%)")

    # Fig 20: RAM model per 16 GB slave
    chunk_mb = 15 * 44_100 * 2 * 4 / 2**20
    queue_mb = 5 * chunk_mb
    working_mb = 4 * chunk_mb * 3          # per-core working set (stft+spec)
    total_mb = queue_mb + working_mb + 400  # + runtime baseline
    print(f"RAM model per slave: queue {queue_mb:.0f} MB + working "
          f"{working_mb:.0f} MB + runtime ~400 MB = {total_mb:.0f} MB "
          f"of 16 GB ({100 * total_mb / 16384:.1f}% — paper: ~11%)")
    save_json("utilization", {
        "steady_state_util": float(mid.mean()),
        "ram_frac": float(total_mb / 16384),
        "finding_cpu_bound": bool(mid.mean() > 0.8),
    })


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=2.0)
    run(hours=ap.parse_args().hours)


if __name__ == "__main__":
    main()
