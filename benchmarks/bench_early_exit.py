"""The paper's HEADLINE economy, measured on-device: early exit (two-phase,
MMSE on survivors only) vs no early exit (fused, masked MMSE on everything),
plus the streaming plan's dispatch-ahead over a batch stream.

The paper saves most of the dominant MMSE cost by deleting rain/silence
chunks first; here the same stage graph runs under all three execution plans
on the same audio and reports wall-clock + the survivor fraction (CPU wall
time; the TPU-side equivalent is the flops/bytes delta in EXPERIMENTS.md
§Perf cell 3).
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.synthetic import generate_labelled
from benchmarks.util import table, save_json


def run(minutes=4.0, seed=1, rainy=True):
    n_long = max(4, int(minutes))
    probs = (0.35, 0.25, 0.1, 0.3) if rainy else (0.6, 0.1, 0.1, 0.2)
    audio, _ = generate_labelled(seed, n_long * 12, segment_s=5.0,
                                 label_probs=probs, persistence=0.7)
    S5 = audio.shape[-1]
    chunks = jnp.asarray(audio.reshape(n_long, 12, 2, S5)
                         .transpose(0, 2, 1, 3).reshape(n_long, 2, 12 * S5))

    fused = Preprocessor(cfg, plan="fused")
    _ = fused(chunks)                                   # compile + warm
    t0 = time.perf_counter()
    _ = fused(chunks)
    t_fused = time.perf_counter() - t0

    two = Preprocessor(cfg, plan="two_phase")
    _ = two(chunks)                                     # warm both phases
    t0 = time.perf_counter()
    res = two(chunks)
    t_two = time.perf_counter() - t0

    # streaming: per-batch wall time with detection dispatch-ahead over a
    # 2-batch stream of the same work (shared compile cache, already warm)
    streaming = Preprocessor(cfg, plan="streaming")
    stream = [chunks, chunks]
    _ = list(streaming.run(stream))
    t0 = time.perf_counter()
    _ = list(streaming.run(stream))
    t_stream = (time.perf_counter() - t0) / len(stream)

    frac = res.n_kept / int(res.det.stats["n_chunks5"])
    rows = [["fused (no early exit)", t_fused, 1.0],
            ["two-phase (paper)", t_two, t_fused / t_two],
            ["streaming (dispatch-ahead)", t_stream, t_fused / t_stream]]
    table(rows, ["plan", "wall s/batch", "speedup"],
          title=f"Early-exit economy: {minutes:.0f} min of audio, "
                f"survivors {frac:.0%}")
    save_json("early_exit", {
        "t_fused": t_fused, "t_two_phase": t_two, "t_streaming": t_stream,
        "survivor_frac": frac,
        "finding_early_exit_saves": bool(t_two < t_fused),
    })
    print(f"\npaper's claim: skipping removed audio before the expensive "
          f"stage saves wall time -> {t_fused:.2f}s vs {t_two:.2f}s "
          f"({'confirmed' if t_two < t_fused else 'NOT confirmed'} at "
          f"{frac:.0%} survivorship)")


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=4.0)
    run(minutes=ap.parse_args().minutes)


if __name__ == "__main__":
    main()
