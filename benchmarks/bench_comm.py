"""Paper Fig 10: file sending times vs split length.

The paper bounced 30 min of audio between two VMs. The TPU-native analogue
of master<->slave file transfer is host<->device transfer (feeding chunks to
the mesh) — measured here per split length — plus the on-mesh redistribution
cost, which the dry-run's collective term covers (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SERF_AUDIO as cfg
from benchmarks.util import table, save_json

SPLITS = (5, 10, 15, 20, 30)


def run(minutes=8.0):
    rate = cfg.target_rate_hz
    total = int(minutes * 60 * rate)
    rng = np.random.RandomState(0)
    flat = rng.randn(total).astype(np.float32)
    rows = []
    for split_s in SPLITS:
        n = int(split_s * rate)
        chunks = flat[: (total // n) * n].reshape(-1, n)
        # round-trip each chunk individually (the paper sent file-by-file)
        t0 = time.perf_counter()
        for i in range(chunks.shape[0]):
            dev = jax.device_put(chunks[i])
            _ = np.asarray(dev)
        per_chunk = time.perf_counter() - t0
        # batched transfer (production mode)
        t0 = time.perf_counter()
        dev = jax.device_put(chunks)
        _ = np.asarray(dev)
        batched = time.perf_counter() - t0
        rows.append([split_s, chunks.shape[0],
                     per_chunk, batched,
                     chunks.nbytes / 2**20 / max(per_chunk, 1e-9)])
    table(rows, ["split_s", "n_chunks", "per-chunk RT (s)",
                 "batched RT (s)", "per-chunk MB/s"],
          title=f"Fig-10 equivalent: host<->device transfer, "
                f"{minutes:.0f} min of audio")
    save_json("comm_times", {"rows": rows})
    print("\npaper finding: 5 s chunks transfer slower per-byte than >=10 s "
          "(per-message overhead); transfer is small vs MMSE compute")


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=8.0)
    run(minutes=ap.parse_args().minutes)


if __name__ == "__main__":
    main()
