"""Paper Tables 2-3 + Fig 3: does the MMSE-STSA filter help detection?
And: silence-detection ROC/AUC for PSD vs SNR thresholds, raw vs filtered.

The paper found: (T2) MMSE does NOT improve rain/cicada detection (rain gets
worse); (T3) SNR-threshold silence detection works equally well without
MMSE, so silence detection goes BEFORE the expensive filter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core import stages as S
from repro.core import detect as D
from repro.core import indices as I
from repro.data.synthetic import generate_labelled, LABELS
from benchmarks.util import table, save_json


def _auc(scores, positives):
    order = np.argsort(-scores)
    y = positives[order]
    P, N = y.sum(), (~y).sum()
    if P == 0 or N == 0:
        return float("nan")
    tps = np.cumsum(y)
    fps = np.cumsum(~y)
    tpr = np.concatenate([[0], tps / P])
    fpr = np.concatenate([[0], fps / N])
    return float(np.trapezoid(tpr, fpr))


def run(minutes=4.0, seed=0):
    n_seg = int(minutes * 60 / 15)
    audio, labels = generate_labelled(seed, n_seg, segment_s=15.0)
    names = np.array(LABELS)[labels]
    x = jax.jit(lambda a: S.compress(S.to_mono(a), cfg))(jnp.asarray(audio))

    def detector_acc(power):
        idx = I.all_indices(power, cfg)
        rain = np.asarray(D.detect_rain(idx, cfg))
        cic = np.asarray(D.detect_cicada(idx, cfg))
        rain_acc = ((rain == (names == "rain")).mean())
        cic_acc = ((cic == (names == "cicada")).mean())
        return rain_acc, cic_acc, idx

    _, power_raw = jax.jit(lambda a: S.stft_chunks(a, cfg))(x)
    filt = jax.jit(lambda a: S.mmse_denoise(a, cfg))(x)
    _, power_f = jax.jit(lambda a: S.stft_chunks(a, cfg))(filt)

    r_raw, c_raw, idx_raw = detector_acc(power_raw)
    r_f, c_f, idx_f = detector_acc(power_f)
    rows = [["Raw", c_raw, r_raw], ["MMSE STSA", c_f, r_f]]
    table(rows, ["Filter", "Cicada Acc", "Rain Acc"],
          title="Table-2 equivalent: detection accuracy raw vs MMSE-filtered")

    # Table 3 / Fig 3: silence AUC, PSD vs SNR scores, raw vs filtered
    sil = names == "silence"
    rows3 = []
    for src, idx in [("Raw", idx_raw), ("Filtered", idx_f)]:
        auc_psd = _auc(-np.asarray(idx["psd"]), sil)
        auc_snr = _auc(-np.asarray(idx["snr"]), sil)
        rows3.append([src, "PSD", auc_psd])
        rows3.append([src, "SNR", auc_snr])
    table(rows3, ["Audio Source", "Index", "AUC"],
          title="Table-3 equivalent: silence-removal AUC")
    save_json("detector_accuracy", {
        "table2": rows, "table3": rows3,
        "finding_mmse_no_help": bool(r_f <= r_raw + 0.02),
        "finding_snr_robust": bool(
            rows3[1][2] > 0.85 and rows3[3][2] > 0.85),
    })
    print(f"\npaper findings: MMSE does not improve rain detection "
          f"({r_raw:.3f} -> {r_f:.3f}); SNR-based silence AUC is "
          f"MMSE-insensitive ({rows3[1][2]:.3f} raw vs {rows3[3][2]:.3f} "
          f"filtered) -> silence detection placed BEFORE MMSE")


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=4.0)
    run(minutes=ap.parse_args().minutes)


if __name__ == "__main__":
    main()
