"""Elastic-fleet economics: what membership costs, what speculation buys.

Two experiments over REAL proc workers (the transport the paper's
master/slave deployment maps to):

  overhead   the same stream with the elastic machinery off (fixed fleet,
             no straggler detector) vs on (membership registry active,
             speculative re-lease armed). The contract is that elasticity
             is control-plane only — a handful of registry dict writes
             and an idle-path straggler probe — so the wall-clock delta
             should be noise.

  straggler  the paper's throughput-is-the-slowest-node problem (Stowell
             et al., PAPERS.md): the worker granted the LAST chunk is
             SIGSTOPped at grant for `stall_s`, turning it into a genuine
             end-of-stream straggler. With speculation OFF the stream
             waits out the stall; with speculation ON the idle survivor
             receives a duplicate lease and finishes while the straggler
             sleeps — the end-of-stream tail (gap between the last two
             acceptance timestamps in the durable telemetry) collapses
             from ~stall_s to the survivor's recompute time.

Writes `results/BENCH_chaos.json`.
"""
from __future__ import annotations

import shutil
import tempfile
import threading
import time

import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.loader import audio_batch_maker, make_shard_pool
from repro.obs import telemetry as obs_telemetry
from benchmarks.util import table, save_json


def _run_proc(pre, pool, on_grant=None, timeout_s=900.0):
    """Run a proc-transport sharded plan to completion on a thread,
    installing `on_grant` on the service as soon as the fleet handle is
    published. Returns (wall_s, results)."""
    plan = pre.plan
    results, err = [], []

    def consume():
        try:
            results.extend(plan.run(pool))
        except BaseException as e:      # noqa: BLE001 — reraised below
            err.append(e)

    t0 = time.perf_counter()
    t = threading.Thread(target=consume, daemon=True, name="bench-chaos")
    t.start()
    if on_grant is not None:
        while plan.fleet is None and t.is_alive():
            time.sleep(0.01)
        if plan.fleet is not None:
            plan.fleet.service.on_grant = on_grant
    t.join(timeout_s)
    wall = time.perf_counter() - t0
    if t.is_alive():
        raise RuntimeError("bench_chaos run hung")
    if err:
        raise err[0]
    return wall, results


def _tail_s(telem_dir):
    """End-of-stream tail: the gap between the last two master-side
    acceptance timestamps — how long the stream sat waiting on its final
    chunk after the rest were done."""
    recs = obs_telemetry.read_records(telem_dir)
    ts = sorted(r["accept_ts"] for r in recs
                if r.get("status") == "done" and r.get("accept_ts"))
    return float(ts[-1] - ts[-2]) if len(ts) >= 2 else 0.0


def _straggler_pass(make, n_batches, stall_s, speculate):
    """One injected-straggler run; returns (wall, tail, plan)."""
    pool = make_shard_pool(make, n_batches, 2, lease_timeout_s=600.0)
    tdir = tempfile.mkdtemp(prefix="bench_chaos_")
    telem = obs_telemetry.TelemetryWriter(tdir)
    kwargs = dict(speculate=speculate)
    if speculate:
        # factor 0: any in-flight chunk is speculatable the moment a
        # worker idles — the deterministic arm (organic p95 thresholds
        # are compile-skewed on a 2-worker CPU run this small)
        kwargs.update(straggler_factor=0.0, straggler_min_history=1)
    pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1,
                       transport="proc", telemetry=telem, **kwargs)
    stalled = []

    def on_grant(worker, wid):
        if wid == n_batches - 1 and not stalled:
            stalled.append(worker)
            fleet = pre.plan.fleet
            fleet.stall(fleet.service.workers[worker].shard, stall_s)

    try:
        wall, results = _run_proc(pre, pool, on_grant=on_grant)
        assert sorted(r.wid for r in results) == list(range(n_batches))
        assert stalled, "the last chunk was never granted"
        telem.close()
        return wall, _tail_s(tdir), pre.plan
    finally:
        telem.close()
        shutil.rmtree(tdir, ignore_errors=True)


def run(n_batches=6, stall_s=15.0, seed=17):
    make = audio_batch_maker(seed=seed, batch_long_chunks=1)

    # -- experiment 1: elasticity machinery off vs on, no chaos ------------
    walls = {}
    for mode, kwargs in (("off", dict(speculate=False, elastic=False)),
                         ("on", dict(speculate=True, elastic=True))):
        pool = make_shard_pool(make, n_batches, 2, lease_timeout_s=600.0)
        pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1,
                           transport="proc", **kwargs)
        walls[mode], results = _run_proc(pre, pool)
        assert sorted(r.wid for r in results) == list(range(n_batches))
    overhead = walls["on"] / walls["off"] - 1.0

    # -- experiment 2: injected end-of-stream straggler, spec off vs on ----
    wall_off, tail_off, _ = _straggler_pass(make, n_batches, stall_s,
                                            speculate=False)
    wall_on, tail_on, plan = _straggler_pass(make, n_batches, stall_s,
                                             speculate=True)

    rows = [["elastic off", walls["off"], "-", "-"],
            ["elastic on", walls["on"], f"{overhead:+.2%}", "-"],
            ["straggler, spec off", wall_off, "-", tail_off],
            ["straggler, spec on", wall_on, "-", tail_on]]
    table(rows, ["mode", "wall s", "overhead", "tail s"],
          title=f"Elastic fleet ({n_batches} batches, 2 proc workers, "
                f"{stall_s:.0f}s injected stall)")

    findings = {
        "elasticity_overhead_pct": overhead,
        "stall_s": stall_s,
        "tail_off_s": tail_off,
        "tail_on_s": tail_on,
        "tail_cut_s": tail_off - tail_on,
        "wall_cut_s": wall_off - wall_on,
        "speculations": plan.speculations,
        "speculations_lost": plan.speculations_lost,
        "speculation_cuts_tail": bool(tail_on < tail_off),
    }
    out = {
        "elasticity_overhead": {"off_wall_s": walls["off"],
                                "on_wall_s": walls["on"],
                                "overhead_pct": overhead},
        "straggler_speculation": {
            "stall_s": stall_s,
            "off": {"wall_s": wall_off, "tail_s": tail_off},
            "on": {"wall_s": wall_on, "tail_s": tail_on,
                   "speculations": plan.speculations,
                   "speculations_lost": plan.speculations_lost},
        },
        "findings": findings,
    }
    path = save_json("BENCH_chaos", out)
    print(f"\nspeculative re-lease cut the end-of-stream tail from "
          f"{tail_off:.1f}s to {tail_on:.1f}s "
          f"({findings['tail_cut_s']:+.1f}s; wall "
          f"{findings['wall_cut_s']:+.1f}s) under a {stall_s:.0f}s "
          f"injected stall; elastic machinery overhead {overhead:+.2%}")
    print(f"record -> {path}")
    assert findings["speculation_cuts_tail"], \
        "speculation failed to cut the injected-straggler tail"
    return findings


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--stall-s", type=float, default=15.0)
    ap.add_argument("--seed", type=int, default=17)
    args = ap.parse_args()
    run(n_batches=args.batches, stall_s=args.stall_s, seed=args.seed)


if __name__ == "__main__":
    main()
