"""Paper Figs 11-13 + the comparison section: scalability of the
master-slave system, via the DES calibrated with measured stage costs.

Fig 11: execution time vs cores (4-core VMs, master co-runs a slave).
Fig 12: speedup over 1-core serial.
Fig 13: few large machines vs many small machines.
Footer: comparison against Dugan (6.57x @8), Truskinger (24x @160),
Thudumu (7.5x @13), and the paper itself (21.76x @32).
"""
from __future__ import annotations

from benchmarks.des import StageCosts, simulate, serial_time
from benchmarks.util import table, save_json, load_json


# the paper's Table 1, seconds per 2 h (=7200 s) of audio, per split length
_PAPER_T1 = {
    #        split:     5        10       15       20       30
    "split":        (7.85,    7.95,    8.13,    9.24,    8.87),
    "down":         (10.18,   9.59,    9.30,    9.29,    9.57),
    "hpf":          (86.63,   47.79,   34.8,    28.2,    21.67),
    "fft":          (2.39,    47.79,   71.90,   73.15,   73.21),
    "rain":         (41.11,   40.46,   39.86,   39.94,   42.67),
    "cicada_det":   (30.47,   31.58,   32.04,   32.32,   31.36),
    "cicada_filt":  (103.48,  64.30,   51.94,   45.27,   37.46),
    "mmse":         (1020.57, 1002.65, 993.10,  986.92,  923.21),
}
_T1_SPLITS = (5, 10, 15, 20, 30)


def paper_costs(split_s=15):
    """The paper's own Table-1 cost profile (seconds per second of audio at
    the given split length; their Java/SoX stack): MMSE dominates."""
    i = _T1_SPLITS.index(split_s)
    c = {k: v[i] for k, v in _PAPER_T1.items()}
    return StageCosts(
        master_prep=(c["split"] + c["down"] + c["hpf"]) / 7200,
        detect=(c["fft"] + c["rain"] + c["cicada_det"]) / 7200,
        cicada_filter=c["cicada_filt"] / 7200,
        silence=10.0 / 7200,               # paper: ~10 s, split-insensitive
        mmse=c["mmse"] / 7200,
        comm_per_mb=4.0 / 302.0,           # paper Fig 10: <4 s per 302 MB
    )


def costs_from_calibration(split_s=15):
    try:
        calib = load_json("stage_times")["calibration"][str(split_s)]
    except Exception:
        return paper_costs(split_s)
    try:
        comm = load_json("comm_times")["rows"][2][2]
        comm_per_mb = comm / (8 * 60 * 22_050 * 4 / 2**20)
    except Exception:
        comm_per_mb = 4.0 / 302.0
    return StageCosts(comm_per_mb=comm_per_mb, **calib)


def _curve(costs, total_s, label):
    t1 = serial_time(total_s, costs)
    rows = []
    speedups = {}
    for cores in (1, 2, 4, 8, 12, 16, 20, 24, 28, 32):
        if cores == 1:
            t = t1
        else:
            n_slaves = max(1, cores // 4)
            slaves = [4] * n_slaves if cores % 4 == 0 else \
                [4] * (cores // 4) + [cores % 4]
            sim = simulate(total_s, costs, slaves, chunk_s=15.0,
                           queue_size=5, send_interval_s=2.0, master_cores=4)
            t = sim["makespan_s"]
        speedups[cores] = t1 / t
        rows.append([cores, t, t1 / t, t1 / t / cores])
    table(rows, ["cores", "exec time (s)", "speedup", "efficiency"],
          title=label)
    return t1, speedups


def run(hours=2.0):
    total_s = hours * 3600
    # (a) the paper's cost profile — validates the paper's scaling claim
    _, paper_speedups = _curve(
        paper_costs(), total_s,
        f"Figs 11-12, PAPER cost profile (Table 1, Java/SoX): {hours:.1f} h")
    # (b) our measured JAX/XLA profile — the bottleneck has MOVED
    costs = costs_from_calibration()
    t1, speedups = _curve(
        costs, total_s,
        "Figs 11-12, OUR measured cost profile (XLA kernels)")
    print(
        "\nNOTE (reproduction finding): with the paper's Java cost profile\n"
        "(MMSE ~10x everything) the master-slave design scales near-\n"
        f"linearly ({paper_speedups[32]:.1f}x @32); with OUR XLA kernel\n"
        "profile (MMSE ~100x faster) the serial master prep becomes the\n"
        f"Amdahl bottleneck ({speedups[32]:.1f}x @32). Our TPU-native\n"
        "pipeline therefore data-parallelizes the master stages too (they\n"
        "live in the same sharded jit) — no serial master exists.\n")

    # Fig 13 + comparison run in the PAPER's cost environment
    pc = paper_costs()
    t1p = serial_time(total_s, pc)
    het_rows = []
    for label, slaves in [
        ("1x4-core slave (+master slave)", [4, 4]),
        ("2x2-core slaves (+master slave)", [4, 2, 2]),
        ("4x1-core slaves (+master slave)", [4, 1, 1, 1, 1]),
        ("master only", [4]),
    ]:
        sim = simulate(total_s, pc, slaves, chunk_s=15.0, master_cores=4)
        het_rows.append([label, sum(slaves), sim["makespan_s"],
                         t1p / sim["makespan_s"]])
    table(het_rows, ["config", "cores", "exec time (s)", "speedup"],
          title="Fig-13 equivalent: small vs large machines (paper costs)")

    s32 = paper_speedups[32]
    s13 = t1p / simulate(total_s, pc, [4, 4, 4, 1], chunk_s=15.0,
                         master_cores=4)["makespan_s"]
    comp_rows = [
        ["THIS WORK (paper)", 32, 21.76],
        ["THIS REPRODUCTION (DES, paper costs)", 32, round(s32, 2)],
        ["Dugan et al. [16] best", 8, 6.57],
        ["Truskinger et al. [15]", 160, 24.0],
        ["Thudumu et al. [17]", 13, 7.5],
        ["paper @ Thudumu's 13 cores", 13, 9.98],
        ["THIS REPRODUCTION @ 13 cores", 13, round(s13, 2)],
    ]
    table(comp_rows, ["system", "cores", "speedup over serial"],
          title="Comparison section (paper reports / our DES)")
    save_json("scaling", {"paper_speedups": paper_speedups,
                          "our_speedups": speedups, "hetero": het_rows,
                          "comparison": comp_rows,
                          "near_linear_at_32": bool(s32 > 18.0)})
    print(f"paper headline: 21.76x @32 cores; reproduction (paper costs): "
          f"{s32:.2f}x @32 "
          f"({'near-linear reproduced' if s32 > 18 else 'BELOW paper'})")
    return paper_speedups


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=2.0)
    run(hours=ap.parse_args().hours)


if __name__ == "__main__":
    main()
