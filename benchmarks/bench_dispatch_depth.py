"""Pipeline depth x padding sweep: what the depth-K async executor and
bucketed survivor shapes buy over the one-ahead streaming baseline.

Two axes, crossed:
  * dispatch depth 1/2/4/8 — detect batches in flight ahead of the tail
  * tail padding 'linear' (historic: next pad_multiple, retraces the tail
    jit per distinct survivor count) vs 'pow2' (O(log B) bucket shapes)

Timing protocol: every config warms on ONE batch (service warm-up: the
detect compile plus its first tail shape), then times TWO streams of
fresh seeds and reports the faster (min-of-2 absorbs shared-machine load
spikes; each pass still pays its structural compile costs, because its
survivor counts are new — linear padding retraces per count exactly as
on a real unbounded stream, while pow2 lands in already-compiled
buckets). Per-stage overlap, host-boundary bytes, and tail compile
counts come from the plans' own BatchResult.timings records plus the
shared CompileCache.

Writes the machine-readable `results/BENCH_pipeline.json` regression
record; `benchmarks/run.py --smoke` gates on the async executor
separately (ordering + overlap on a tiny stream).
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import JIT_CACHE, Preprocessor
from repro.data.loader import audio_batch_maker
from repro.launch.preprocess import pipeline_report
from benchmarks.util import table, save_json


def _stream(seed, n_batches, batch_long_chunks):
    make = audio_batch_maker(seed=seed, batch_long_chunks=batch_long_chunks)
    return [(w, (make(w)[0], None)) for w in range(n_batches)]


def _run_one(plan, stream, **kw):
    pre = Preprocessor(cfg, plan=plan, pad_multiple=1, **kw)
    t0 = time.perf_counter()
    results = list(pre.run(stream))
    wall = time.perf_counter() - t0
    assert [r.wid for r in results] == [w for w, _ in stream], \
        f"plan {plan} broke stream order"
    timings = [r.timings for r in results if r.timings is not None]
    n_chunks = sum(int(r.det.stats["n_chunks5"]) for r in results)
    src = sum(r.src_bytes for r in results)
    keep = np.concatenate([np.asarray(r.det.keep) for r in results])
    cleaned = np.concatenate([r.cleaned for r in results])
    return wall, timings, n_chunks, src, (keep, cleaned)


def run(minutes=16.0, batch_long_chunks=2, depths=(1, 2, 4, 8), seed=11):
    n_batches = max(4, int(round(minutes / batch_long_chunks)))
    warm = _stream(seed, 1, batch_long_chunks)
    timed = [_stream(seed + 1 + i, n_batches, batch_long_chunks)
             for i in range(2)]

    def tail_compiles():
        return sum(1 for k in JIT_CACHE.keys()
                   if k[0] in ("tail", "tail_idx", "tail_idx_fused"))

    rows, recs = [], []
    refs = [None, None]
    configs = [("two_phase", {}), ("streaming", {})]
    configs += [("async", {"depth": d, "bucket": b})
                for b in ("linear", "pow2") for d in depths]
    for plan, kw in configs:
        JIT_CACHE.clear()
        _run_one(plan, warm, **kw)          # warm: compiles for stream A
        passes = []
        for i, stream in enumerate(timed):
            before = tail_compiles()
            wall, timings, n_chunks, src, out = _run_one(plan, stream,
                                                         **kw)
            retraces = tail_compiles() - before  # fresh counts force these
            if refs[i] is None:
                refs[i] = out
            else:                            # every config, bit-identical
                np.testing.assert_array_equal(out[0], refs[i][0])
                np.testing.assert_array_equal(out[1], refs[i][1])
            passes.append((wall, timings, n_chunks, src, retraces))
        wall, timings, n_chunks, src, retraces = min(passes,
                                                     key=lambda p: p[0])
        rep = pipeline_report(timings) if timings else {}
        label = plan + (f" d={kw['depth']} {kw['bucket']}" if kw else "")
        rec = {
            "plan": plan, **kw, "wall_s": wall,
            "chunks_per_s": n_chunks / wall, "mb_per_s": src / 2**20 / wall,
            "tail_retraces": retraces, **rep,
        }
        recs.append(rec)
        rows.append([label, wall, n_chunks / wall, retraces,
                     rep.get("overlapped", 0),
                     rep.get("d2h_bytes_per_batch", 0) / 2**20,
                     rep.get("old_boundary_bytes_per_batch", 0) / 2**20])
    table(rows, ["config", "wall s", "chunks/s", "tail retraces",
                 "overlapped", "D2H MB/batch", "old boundary MB/batch"],
          title=f"Dispatch depth x padding ({n_batches} batches, "
                f"{batch_long_chunks} long chunks each)")

    by = {(r["plan"], r.get("depth"), r.get("bucket")): r for r in recs}
    stream_wall = by[("streaming", None, None)]["wall_s"]
    d_head = 4 if 4 in depths else depths[-1]     # headline depth
    a4 = by[("async", d_head, "pow2")]
    findings = {
        "headline_depth": d_head,
        "async_d4_pow2_beats_streaming": bool(a4["wall_s"] < stream_wall),
        "speedup_vs_streaming": stream_wall / a4["wall_s"],
        "pow2_caps_retraces": all(
            r["tail_retraces"] <= np.ceil(np.log2(
                batch_long_chunks * 12)) + 1
            for r in recs if r.get("bucket") == "pow2"),
        # host-boundary economy: mask + idx + padded cleaned vs the old
        # round-trip MEASURED on this stream (full wave5 + mask down,
        # survivors up, cleaned down) — not a flat 2x-full-batch model
        "boundary_per_batch": a4["d2h_bytes_per_batch"]
        + a4["h2d_bytes_per_batch"],
        "old_boundary_per_batch": a4["old_boundary_bytes_per_batch"],
        "full_batch_bytes": a4["full_batch_bytes"],
        "transfer_drop": 1 - (a4["d2h_bytes_per_batch"]
                              + a4["h2d_bytes_per_batch"])
        / a4["old_boundary_bytes_per_batch"],
    }
    path = save_json("BENCH_pipeline", {"rows": recs, "findings": findings})
    print(f"\nasync d={d_head} pow2 vs streaming: {stream_wall:.2f}s -> "
          f"{a4['wall_s']:.2f}s "
          f"({findings['speedup_vs_streaming']:.2f}x, "
          f"{'beats' if findings['async_d4_pow2_beats_streaming'] else 'does NOT beat'}"
          f" the one-ahead baseline); host boundary "
          f"{findings['boundary_per_batch'] / 2**20:.2f} MB/batch vs the "
          f"old round-trip's measured "
          f"{findings['old_boundary_per_batch'] / 2**20:.2f} MB/batch "
          f"({findings['transfer_drop']:.0%} less)")
    print(f"record -> {path}")
    return findings


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=16.0)
    ap.add_argument("--batch-long-chunks", type=int, default=2)
    args = ap.parse_args()
    run(minutes=args.minutes, batch_long_chunks=args.batch_long_chunks)


if __name__ == "__main__":
    main()
