"""Paper Tables 4-6 / Figs 4-7: detection accuracy vs split length.

Ground truth is labelled at 5 s resolution (as the paper's manual labels);
each detector runs at split lengths 5/10/15/20/30 s and is scored at 5 s
resolution — a chunk-level decision fans out to its 5 s cells, so longer
splits pay for within-chunk mixtures exactly as in the paper's protocol.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core import stages as S
from repro.core import detect as D
from repro.core import indices as I
from repro.data.synthetic import generate_labelled, LABELS
from benchmarks.util import table, save_json

SPLITS = (5, 10, 15, 20, 30)


def run(minutes=8.0, seed=1):
    n_seg = int(minutes * 60 / 5)
    n_seg -= n_seg % 6                      # 30 s divisibility
    audio, labels = generate_labelled(seed, n_seg, segment_s=5.0)
    names = np.array(LABELS)[labels]
    x = np.asarray(jax.jit(lambda a: S.compress(S.to_mono(a), cfg))(
        jnp.asarray(audio)))
    n5 = x.shape[1]
    flat = x.reshape(-1)

    results = {}
    all_rows = {}
    for det_name, detect_fn, positive in [
        ("cicada", lambda idx: D.detect_cicada(idx, cfg), "cicada"),
        ("rain", lambda idx: D.detect_rain(idx, cfg), "rain"),
        ("silence", lambda idx: D.detect_silence(idx, cfg), "silence"),
    ]:
        rows = []
        for split_s in SPLITS:
            k = split_s // 5
            n = k * n5
            chunks = jnp.asarray(flat[: (flat.size // n) * n].reshape(-1, n))
            _, power = jax.jit(lambda a: S.stft_chunks(a, cfg))(chunks)
            idx = I.all_indices(power, cfg)
            pred_chunk = np.asarray(detect_fn(idx))
            pred5 = np.repeat(pred_chunk, k)[: len(names)]
            if det_name == "silence":
                # paper: rain samples excluded from the silence scoring
                sel = names != "rain"
            else:
                sel = np.ones(len(names), bool)
            y = (names == positive)[sel]
            p = pred5[: len(names)][sel]
            tp = float((p & y).mean())
            fp = float((p & ~y).mean())
            fn = float((~p & y).mean())
            tn = float((~p & ~y).mean())
            acc = tp + tn
            rows.append([split_s, 100 * tp, 100 * fp, 100 * fn, 100 * tn,
                         100 * acc])
        all_rows[det_name] = rows
        table(rows, ["split_s", "TP%", "FP%", "FN%", "TN%", "Acc%"],
              title=f"Table 4-6 equivalent: {det_name} detection vs split "
                    "length (5 s scoring resolution)")
        results[det_name] = rows

    # paper findings: rain/cicada are split-length-insensitive;
    # silence detection degrades at long splits (silence is short-lived)
    sil_acc = [r[-1] for r in results["silence"]]
    cic_acc = [r[-1] for r in results["cicada"]]
    save_json("split_accuracy", {
        "tables": all_rows,
        "finding_cicada_insensitive": bool(max(cic_acc) - min(cic_acc) < 8),
        "finding_silence_degrades": bool(sil_acc[0] >= max(sil_acc[2:]) - 1),
    })


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=8.0)
    run(minutes=ap.parse_args().minutes)


if __name__ == "__main__":
    main()
