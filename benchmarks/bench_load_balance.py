"""Paper Figs 14-18: load balance across slaves (DES) + the on-device
survivor balance from the real pipeline (scheduler.balance_stats)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.des import simulate
from benchmarks.bench_scaling import paper_costs
from benchmarks.util import table, save_json


def run(hours=2.0, trials=4):
    costs = paper_costs()
    total_s = hours * 3600
    out = {}
    # Figs 14-16: equal 4-core slaves
    for n_slaves in (2, 3, 4):
        rows = []
        for t in range(trials):
            sim = simulate(total_s * (1 + 0.01 * t), costs, [4] * n_slaves,
                           chunk_s=15.0)
            rows.append([t + 1] + sim["per_slave_chunks"])
        table(rows, ["trial"] + [f"slave{j}" for j in range(n_slaves)],
              title=f"Figs 14-16 equivalent: chunks per slave, "
                    f"{n_slaves} slaves")
        counts = np.array([r[1:] for r in rows], float)
        imb = counts.max(1) / counts.mean(1)
        out[f"equal_{n_slaves}"] = {"rows": rows,
                                    "max_imbalance": float(imb.max())}
    # Figs 17-18: heterogeneous
    for label, slaves in [("2x2core vs 4core(master)", [4, 2, 2]),
                          ("4x1core vs 4core(master)", [4, 1, 1, 1, 1])]:
        sim = simulate(total_s, costs, slaves, chunk_s=15.0)
        counts = np.array(sim["per_slave_chunks"], float)
        expect = np.array(slaves, float)
        ratio = counts / counts.sum()
        want = expect / expect.sum()
        rows = [[f"slave{j}({c}c)", int(counts[j]), ratio[j], want[j]]
                for j, c in enumerate(slaves)]
        table(rows, ["slave", "chunks", "share", "core share"],
              title=f"Figs 17-18 equivalent: {label}")
        out[label] = {"proportional": bool(
            np.abs(ratio - want).max() < 0.08)}

    # on-device: survivor balance before/after compaction
    from repro.core.plans import Preprocessor
    from repro.core.scheduler import balance_stats
    from repro.configs import SERF_AUDIO as cfg
    from repro.data.synthetic import generate_labelled
    audio, _ = generate_labelled(5, 8 * 12, segment_s=5.0)
    S5 = audio.shape[-1]
    chunks = (audio.reshape(8, 12, 2, S5).transpose(0, 2, 1, 3)
              .reshape(8, 2, 12 * S5))
    det = Preprocessor(cfg).detect(jnp.asarray(chunks))
    bs = jax.jit(lambda k: balance_stats(k, 8))(det.keep)
    print(f"\non-device survivor imbalance over 8 shards: "
          f"{float(bs['imbalance']):.3f} -> "
          f"{float(bs['imbalance_after_compact']):.3f} after compaction "
          f"(loads: {np.asarray(bs['loads']).tolist()})")
    out["device_compaction"] = {
        "before": float(bs["imbalance"]),
        "after": float(bs["imbalance_after_compact"]),
    }

    # cross-shard survivor re-balancing (ShardedPlan's detection -> MMSE
    # handoff): a skewed stream — one shard's chunks mostly survive, the
    # other's mostly die — must come out near-even after the re-shard
    from repro.core.scheduler import Rebalancer
    keep_np = np.asarray(det.keep)
    order = np.argsort(~keep_np, kind="stable")   # survivors first = skew
    skewed = keep_np[order].reshape(4, -1)
    asg = Rebalancer(4).assign(list(skewed))
    st = asg.stats()
    print(f"cross-shard re-balance on a skewed stream: "
          f"{st['loads_before'].tolist()} -> {st['loads_after'].tolist()} "
          f"(max/min {st['max_min_before']:.2f} -> "
          f"{st['max_min_after']:.2f}, moved {st['moved']})")
    out["rebalance"] = {
        "before": st["loads_before"].tolist(),
        "after": st["loads_after"].tolist(),
        "max_min_after": st["max_min_after"],
    }
    save_json("load_balance", out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=2.0)
    run(hours=ap.parse_args().hours)


if __name__ == "__main__":
    main()
