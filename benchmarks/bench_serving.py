"""Serving load test: persistent worker pool + continuous batching under
N concurrent synthetic clients.

What it measures, on a pool of long-lived proc workers (spawned ONCE for
the whole bench — the acceptance story is warm jits and stable pids):

  * wave protocol — >= 3 consecutive pump waves through the same pool:
    same worker pids every wave, wave 2+ wall a fraction of wave 1's
    (the compile paid once, never again)
  * load levels — >= 3 concurrency levels (clients x per-client arrival
    rate), each level timed with the min-of-2 protocol
    `bench_dispatch_depth` uses (two passes of fresh request seeds, the
    faster pass reported — absorbs shared-machine load spikes): client-
    observed p50/p99 latency, completed-request throughput, and the
    batch-occupancy histogram from the batcher's dispatch log
  * parity — EVERY batch the batcher dispatched is rebuilt bit-exactly
    from its logged request ids and re-run through the in-process
    two_phase plan; every served record must match bit-for-bit

Findings: saturation throughput + the level where throughput stopped
growing (the saturation point), p99-vs-occupancy pairs per level, pid
stability, and the wave walls. Machine-readable record:
`results/BENCH_serving.json`.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.loader import audio_batch_maker
from repro.serve import ContinuousBatcher, WorkerPool
from benchmarks.util import table, save_json


def _occupancy_hist(entries):
    hist = {}
    for e in entries:
        key = f"{e['n_real']}/{e['rows']}"
        hist[key] = hist.get(key, 0) + 1
    return hist


def _verify_batches(chunks_by_rid, records, log_entries, ref):
    """Rebuild every dispatched batch from its logged rids (+ zero pads)
    and check each served record bit-for-bit against two_phase."""
    checked = 0
    for e in log_entries:
        rows = [chunks_by_rid[r] for r in e["rids"]]
        batch = np.stack(rows)
        if e["rows"] > e["n_real"]:
            pad = np.zeros((e["rows"] - e["n_real"],) + batch.shape[1:],
                           np.float32)
            batch = np.concatenate([batch, pad])
        want = ref(batch)
        keep = np.asarray(want.det.keep)
        per = keep.size // e["rows"]
        offs = np.concatenate([[0], np.cumsum(keep)]).astype(int)
        for j, rid in enumerate(e["rids"]):
            rec = records.get(rid)
            if rec is None or not rec["ok"]:
                continue
            lo, hi = j * per, (j + 1) * per
            np.testing.assert_array_equal(rec["keep"], keep[lo:hi])
            np.testing.assert_array_equal(
                rec["cleaned"], want.cleaned[offs[lo]:offs[hi]])
            checked += 1
    return checked


def _load_pass(pool, make, seed, clients, per_client, rate_hz, max_batch,
               linger_s):
    """One timed pass: `clients` threads, exponential inter-arrival at
    `rate_hz` per client. Returns (wall, latencies, records,
    chunks_by_rid, log_entries, n_expired)."""
    batcher = ContinuousBatcher(pool=pool, max_batch=max_batch,
                                max_queue=max(64, clients * per_client),
                                linger_s=linger_s)
    records, chunks_by_rid = {}, {}
    lat, lock = [], threading.Lock()

    def client(cid):
        rng = np.random.RandomState(seed * 7919 + cid)
        for i in range(per_client):
            chunk = make(seed * 100 + cid * per_client + i)[0][0]
            t0 = time.monotonic()
            rid = batcher.submit(chunk)
            with lock:
                chunks_by_rid[rid] = chunk
            rec = batcher.wait(rid, timeout_s=600.0)
            dt = time.monotonic() - t0
            with lock:
                records[rid] = rec
                lat.append(dt)
            time.sleep(float(rng.exponential(1.0 / rate_hz)))

    t0 = time.perf_counter()
    with batcher:
        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    wall = time.perf_counter() - t0
    return (wall, lat, records, chunks_by_rid, list(batcher.batch_log),
            batcher.expired)


def run(minutes=6.0, workers=2, transport="proc", levels=None,
        max_batch=4, linger_s=0.02, seed=13):
    make = audio_batch_maker(seed=seed, batch_long_chunks=1)
    ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    # (clients, per-client rate): offered load grows ~4x per level so the
    # top level saturates the pool whatever the machine
    levels = levels or [(2, 0.5), (4, 1.0), (8, 2.0)]
    per_client = max(2, int(round(minutes / 2)))

    pool = WorkerPool(cfg, workers=workers, transport=transport,
                      poll_s=0.005).start()
    try:
        # -- wave protocol: 3 pump waves, same pids, warm after wave 1 --
        pids0 = dict(pool.pids)
        wave_walls = []
        for wave in range(3):
            t0 = time.perf_counter()
            wids = [pool.submit(make(1000 + wave * workers + k)[0])
                    for k in range(workers)]
            pool.wait(wids, timeout_s=600.0)
            wave_walls.append(time.perf_counter() - t0)
            assert pool.pids == pids0, \
                f"worker pids changed across waves: {pids0} -> {pool.pids}"
        assert pool.respawns == 0
        warm = (not wave_walls
                or wave_walls[1] < wave_walls[0] * 0.8
                or transport == "inproc")
        print(f"wave walls: {['%.2fs' % w for w in wave_walls]} on pids "
              f"{sorted(pids0.values())} (no respawns)")

        # -- load levels, min-of-2 per level ---------------------------
        rows, recs = [], []
        bit_checked = 0
        for clients, rate in levels:
            passes = []
            for p in range(2):               # min-of-2: fresh seeds each
                out = _load_pass(pool, make, seed + 17 * p + clients,
                                 clients, per_client, rate, max_batch,
                                 linger_s)
                bit_checked += _verify_batches(out[3], out[2], out[4],
                                               ref)
                passes.append(out)
            wall, lat, records, _, log, expired = min(
                passes, key=lambda o: o[0])
            ok = [r for r in records.values() if r["ok"]]
            rec = {
                "clients": clients, "rate_hz_per_client": rate,
                "offered_rps": clients * rate,
                "completed": len(ok), "expired": expired,
                "wall_s": wall, "throughput_rps": len(ok) / wall,
                "p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3),
                "mean_occupancy": float(np.mean(
                    [e["occupancy"] for e in log])) if log else None,
                "occupancy_hist": _occupancy_hist(log),
            }
            recs.append(rec)
            rows.append([f"{clients}x{rate:g}/s", rec["offered_rps"],
                         rec["throughput_rps"], rec["p50_ms"],
                         rec["p99_ms"], rec["mean_occupancy"] or 0.0])
        table(rows, ["clients x rate", "offered rps", "served rps",
                     "p50 ms", "p99 ms", "occupancy"],
              title=f"Serving load test ({workers} {transport} workers, "
                    f"max_batch={max_batch}, {per_client} req/client, "
                    f"min of 2 passes)")

        # -- findings --------------------------------------------------
        tps = [r["throughput_rps"] for r in recs]
        sat_i = len(tps) - 1
        for i in range(1, len(tps)):
            if tps[i] < tps[i - 1] * 1.05:   # stopped growing: saturated
                sat_i = i
                break
        findings = {
            "workers": workers, "transport": transport,
            "saturation_rps": max(tps),
            "saturation_level": {
                "clients": recs[sat_i]["clients"],
                "rate_hz_per_client": recs[sat_i]["rate_hz_per_client"]},
            "p99_vs_occupancy": [
                {"occupancy": r["mean_occupancy"], "p99_ms": r["p99_ms"]}
                for r in recs],
            "pids_stable_across_waves": True,   # asserted above
            "wave_walls_s": wave_walls,
            "warm_after_wave1": bool(warm),
            "bit_identical_to_two_phase": True,  # asserted per batch
            "results_verified": bit_checked,
        }
        path = save_json("BENCH_serving", {"rows": recs,
                                           "findings": findings})
        print(f"\nsaturation {findings['saturation_rps']:.2f} req/s at "
              f"{recs[sat_i]['clients']} clients; {bit_checked} served "
              f"results verified bit-identical to two_phase")
        print(f"record -> {path}")
        return findings
    finally:
        pool.shutdown(drain=True)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=6.0)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--transport", default="proc",
                    choices=("proc", "inproc"))
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()
    run(minutes=args.minutes, workers=args.workers,
        transport=args.transport, max_batch=args.max_batch)


if __name__ == "__main__":
    main()
