"""Benchmark aggregator: one bench per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full]
  PYTHONPATH=src python -m benchmarks.run --smoke   # executor regression gate

Order matters: stage-time calibration feeds the DES benches; comm feeds the
DES transfer model. The roofline table prints from the dry-run records.
"""
from __future__ import annotations

import argparse
import time
import traceback


def smoke(chaos_seed=None):
    """One tiny batch stream through EVERY registered execution plan:
    survivor sets must match bit-for-bit and cleaned audio to rtol=1e-4, so
    executor regressions fail fast (scripts/verify.sh runs this). Then the
    sharded fault-tolerance gate: 2 simulated shards with a forced lease
    expiry AND a mid-stream worker crash must finish with redeliveries >= 1
    and zero lost or duplicated chunks. Then the PROCESS-mode FT gate: the
    same recovery story on 2 REAL worker processes over the proc
    transport, one SIGKILLed mid-stream — zero lost/duplicate chunks,
    output bit-identical to two_phase. Then the STORE-DATA-PLANE gate: the
    same stream over 2 real workers on the TCP transport twice, socket
    plane vs store plane — the store run must cut the socket's data-plane
    bytes (dist_fetch_bytes_total + dist_push_bytes_total, per plane) by
    >= 90% while staying bit-identical. Then the cache gate: the same tiny
    stream twice through CachedPlan over a fresh store — the second pass
    must be >= 90% hits with survivor masks bit-identical to the uncached
    reference. Then the async-pipeline gate: `--plan async --depth 4` on a
    tiny stream must emit every chunk id exactly once IN INPUT ORDER,
    bit-identical to two_phase, with >= 1 overlapped dispatch visible in
    the per-batch timing records. Finally the SERVING gate: a pool of 2
    persistent proc workers behind the continuous batcher takes 12
    concurrent requests including one deadline miss and one worker
    SIGKILL mid-request — every surviving request must resolve exactly
    once, bit-identical to two_phase, with the killed worker's lease
    redelivered. Finally the FUSED-TAIL gate: two_phase with the fused
    single-pass survivor tail vs the staged per-stage tail, bit-identical
    masks + cleaned audio in ref AND interpret backends, pad rows zero.
    Then the OBSERVABILITY gate: the driver over 2 real proc workers
    with --trace + --telemetry must yield a schema-valid Chrome trace with
    worker events parented under the master's run span and exactly one
    durable telemetry record per chunk. Finally the CHAOS gate: seeded
    randomized schedules (SIGKILL, mid-run join, graceful drain, SIGSTOP
    stall — at least one of each) fired against 2+ REAL proc workers
    while the stream runs, every chunk exactly once and bit-identical to
    two_phase, plus an injected-straggler scenario where the last chunk's
    holder is SIGSTOPped: an idle survivor must win the speculative
    duplicate lease and the losing incarnation must be attributed in the
    durable telemetry under reason "speculated". Any failing schedule is
    reproducible via --chaos-seed (the seed is printed in the failure)."""
    import numpy as np
    from repro.configs import SERF_AUDIO as cfg
    from repro.core.plans import PLANS, Preprocessor
    from repro.data.synthetic import generate_labelled

    audio, _ = generate_labelled(0, 2 * 12, segment_s=5.0)
    S5 = audio.shape[-1]
    chunks = (audio.reshape(2, 12, 2, S5).transpose(0, 2, 1, 3)
              .reshape(2, 2, 12 * S5))
    stream = [(0, (chunks[:1], None)), (1, (chunks[1:], None))]
    ref_name = ref = None
    failures = []
    for name in sorted(PLANS):
        t0 = time.time()
        try:
            pre = Preprocessor(cfg, plan=name, pad_multiple=1)
            results = sorted(pre.run(stream), key=lambda r: r.wid)
            keep = np.concatenate([np.asarray(r.det.keep) for r in results])
            cleaned = np.concatenate([r.cleaned for r in results])
            assert np.isfinite(cleaned).all(), "non-finite output"
            assert cleaned.shape[0] == int(keep.sum())
            if ref is None:
                ref_name, ref = name, (keep, cleaned)
            else:
                np.testing.assert_array_equal(keep, ref[0])
                np.testing.assert_allclose(cleaned, ref[1],
                                           rtol=1e-4, atol=1e-5)
            print(f"plan {name:10s} OK: {cleaned.shape[0]}/{keep.size} "
                  f"survivors in {time.time() - t0:.1f}s"
                  + ("" if ref[1] is cleaned else f" (== {ref_name})"))
        except Exception:
            failures.append(name)
            traceback.print_exc()
    try:
        _ft_smoke(np, cfg, Preprocessor)
    except Exception:
        failures.append("sharded-ft")
        traceback.print_exc()
    try:
        _proc_ft_smoke(np, cfg, Preprocessor)
    except Exception:
        failures.append("proc-ft")
        traceback.print_exc()
    try:
        _store_plane_smoke(np, cfg, Preprocessor)
    except Exception:
        failures.append("store-plane")
        traceback.print_exc()
    try:
        _cache_smoke(np, cfg, Preprocessor, stream, ref)
    except Exception:
        failures.append("cache")
        traceback.print_exc()
    try:
        _async_smoke(np, cfg, Preprocessor)
    except Exception:
        failures.append("async-pipeline")
        traceback.print_exc()
    try:
        _serving_smoke(np, cfg, Preprocessor)
    except Exception:
        failures.append("serving")
        traceback.print_exc()
    try:
        _fused_smoke(np, cfg, Preprocessor)
    except Exception:
        failures.append("fused-tail")
        traceback.print_exc()
    try:
        _obs_smoke()
    except Exception:
        failures.append("obs")
        traceback.print_exc()
    try:
        _chaos_smoke(np, cfg, Preprocessor, chaos_seed=chaos_seed)
    except Exception:
        failures.append("chaos")
        traceback.print_exc()
    n_gates = len(PLANS) + 9
    print(f"\nsmoke: {n_gates - len(failures)}/{n_gates} "
          f"gates OK" + (f"; FAILED: {failures}" if failures else ""))
    raise SystemExit(1 if failures else 0)


def _ft_smoke(np, cfg, Preprocessor):
    """ShardedPlan recovery gate: a lease forced to expire before the run
    plus shard 1 crashing mid-stream; every chunk id must come out exactly
    once, with at least one queue redelivery."""
    from repro.data.loader import audio_batch_maker, make_shard_pool
    from repro.data.queue import SettableClock, WorkQueue
    from repro.ft.failure import CrashInjector

    t0 = time.time()
    n_batches = 5
    clock = SettableClock()
    queue = WorkQueue(n_batches, lease_timeout_s=30.0, clock=clock)
    ghost = queue.lease("ghost", 1)        # a worker that died pre-run
    clock.t = 31.0                         # ... and whose lease has expired
    injector = CrashInjector()
    injector.kill(1, after_items=1)        # shard 1 dies mid-stream
    make = audio_batch_maker(seed=3, batch_long_chunks=2)
    pool = make_shard_pool(make, n_batches, 2, queue=queue)
    pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1,
                       injector=injector)
    results = list(pre.run(pool))
    wids = sorted(r.wid for r in results)
    assert wids == list(range(n_batches)), \
        f"lost/duplicated chunks: emitted {wids}"
    assert pre.plan.redeliveries >= 1, "expected at least one redelivery"
    ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    for r in sorted(results, key=lambda r: r.wid):
        want = ref(make(r.wid)[0])
        np.testing.assert_array_equal(np.asarray(r.det.keep),
                                      np.asarray(want.det.keep))
        np.testing.assert_allclose(r.cleaned, want.cleaned,
                                   rtol=1e-4, atol=1e-5)
    print(f"plan sharded-ft OK: wid {ghost[0]} redelivered after forced "
          f"lease expiry, shard 1 crashed, {len(wids)}/{n_batches} chunk "
          f"ids exactly once, redeliveries={pre.plan.redeliveries} "
          f"in {time.time() - t0:.1f}s")


def _proc_ft_smoke(np, cfg, Preprocessor):
    """REAL-process fault-tolerance gate: 2 worker processes over the proc
    transport, one SIGKILLed mid-stream while holding a lease; every chunk
    id must come out exactly once, bit-identical to the in-process
    two_phase plan, with the lost lease redelivered to the survivor."""
    from repro.data.loader import audio_batch_maker, make_shard_pool
    from repro.ft.failure import CrashInjector

    t0 = time.time()
    n_batches = 5
    make = audio_batch_maker(seed=3, batch_long_chunks=2)
    pool = make_shard_pool(make, n_batches, 2, lease_timeout_s=120.0)
    injector = CrashInjector()
    # after_items=0: shard1 is SIGKILLed the moment its FIRST lease is
    # granted — deterministic under any compile-time skew (a later fuse
    # could never burn if the other worker drained the queue first)
    injector.kill(1, after_items=0)
    pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1,
                       transport="proc", injector=injector)
    results = list(pre.run(pool))
    wids = [r.wid for r in results]
    assert wids == list(range(n_batches)), \
        f"lost/duplicated/misordered chunks: emitted {wids}"
    assert pre.plan.redeliveries >= 1, "expected at least one redelivery"
    assert injector.crashed == frozenset({1}), "shard1 was not SIGKILLed"
    ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    for r in results:
        want = ref(make(r.wid)[0])
        np.testing.assert_array_equal(np.asarray(r.det.keep),
                                      np.asarray(want.det.keep))
        np.testing.assert_array_equal(r.cleaned, want.cleaned)
    done = {st.worker: st.chunks_done for st in pre.plan.worker_stats}
    print(f"plan proc-ft    OK: 2 real worker processes, shard1 SIGKILLed "
          f"holding a lease, {len(wids)}/{n_batches} chunk ids exactly "
          f"once (per-worker {done}), redeliveries="
          f"{pre.plan.redeliveries}, cleaned bit-identical to two_phase "
          f"in {time.time() - t0:.1f}s")


def _store_plane_smoke(np, cfg, Preprocessor):
    """Store-data-plane gate: the same seeded stream over 2 REAL worker
    processes on the TCP transport (loopback) twice — once on the socket
    data plane (chunk batches and result payloads cross the master's
    control socket) and once on the store data plane (bytes move through
    a shared ChunkStore; the socket carries content keys). The store run
    must cut the master's data-plane socket bytes by >= 90% — measured
    from dist_fetch_bytes_total{plane} + dist_push_bytes_total{plane} —
    with ZERO payload bytes on the socket plane, and both runs must be
    bit-identical to each other and to two_phase."""
    import shutil
    import tempfile

    from repro.data.loader import audio_batch_maker, make_shard_pool
    from repro.obs import metrics as obs_metrics

    t0 = time.time()
    n_batches = 4
    make = audio_batch_maker(seed=11, batch_long_chunks=1)
    reg = obs_metrics.get_registry()

    def plane_bytes(plane):
        return sum(
            reg.counter(name, labels=("plane",)).labels(plane=plane).value
            for name in ("dist_fetch_bytes_total", "dist_push_bytes_total"))

    tmp = tempfile.mkdtemp(prefix="smoke_dplane_")
    try:
        runs, wire = {}, {}
        for mode in ("socket", "store"):
            pool = make_shard_pool(make, n_batches, 2,
                                   lease_timeout_s=120.0)
            kw = {"data_plane": tmp} if mode == "store" else {}
            before = {p: plane_bytes(p) for p in ("socket", "store")}
            pre = Preprocessor(cfg, plan="sharded", shards=2,
                               pad_multiple=1, transport="tcp",
                               lease_items=2, **kw)
            runs[mode] = sorted(pre.run(pool), key=lambda r: r.wid)
            delta = {p: plane_bytes(p) - before[p]
                     for p in ("socket", "store")}
            wire[mode] = delta[mode]
            other = "store" if mode == "socket" else "socket"
            assert delta[other] == 0, \
                f"{mode} run leaked {delta[other]} bytes onto the " \
                f"{other} plane"
            wids = [r.wid for r in runs[mode]]
            assert wids == list(range(n_batches)), \
                f"{mode} run lost/duplicated chunks: {wids}"
        ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
        for a, b in zip(runs["socket"], runs["store"]):
            want = ref(make(a.wid)[0])
            for r in (a, b):
                np.testing.assert_array_equal(np.asarray(r.det.keep),
                                              np.asarray(want.det.keep))
                np.testing.assert_array_equal(r.cleaned, want.cleaned)
        cut = 1.0 - wire["store"] / wire["socket"]
        assert cut >= 0.9, \
            f"store plane cut only {cut:.1%} of data-plane socket bytes " \
            f"({wire['store']:.0f} vs {wire['socket']:.0f})"
        print(f"plan store-dp   OK: 2 real workers over tcp, store plane "
              f"carried {wire['store']:.0f} B of keys vs "
              f"{wire['socket']:.0f} B of payloads on the socket plane "
              f"({cut:.1%} cut), bit-identical to two_phase, "
              f"in {time.time() - t0:.1f}s")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _cache_smoke(np, cfg, Preprocessor, stream, ref):
    """CachedPlan gate: the same tiny stream twice over a fresh store —
    pass 2 must be >= 90% cache hits and its survivor masks / cleaned
    audio must match the uncached plan-equivalence reference."""
    import shutil
    import tempfile

    t0 = time.time()
    store_dir = tempfile.mkdtemp(prefix="smoke_cache_")
    try:
        for pass_no in (1, 2):
            pre = Preprocessor(cfg, plan="cached", inner="two_phase",
                               store=store_dir, pad_multiple=1)
            results = sorted(pre.run(stream), key=lambda r: r.wid)
            keep = np.concatenate([np.asarray(r.det.keep) for r in results])
            cleaned = np.concatenate([r.cleaned for r in results])
            np.testing.assert_array_equal(keep, ref[0])
            np.testing.assert_allclose(cleaned, ref[1],
                                       rtol=1e-4, atol=1e-5)
        st = pre.plan.stats
        assert st.hit_rate >= 0.9, \
            f"warm pass hit rate {st.hit_rate:.0%} < 90%"
        print(f"plan cache      OK: warm pass {st.hits}/{st.hits + st.misses}"
              f" hits, masks bit-identical to uncached, "
              f"in {time.time() - t0:.1f}s")
    finally:
        shutil.rmtree(store_dir, ignore_errors=True)


def _async_smoke(np, cfg, Preprocessor):
    """Depth-4 async executor gate: a 5-batch stream must come out in
    input order with zero lost/duplicated chunks, bit-identical to
    two_phase, and the timing records must show at least one dispatch that
    overlapped earlier in-flight work (the whole point of the window)."""
    from repro.data.loader import audio_batch_maker

    t0 = time.time()
    n_batches = 5
    make = audio_batch_maker(seed=5, batch_long_chunks=2)
    stream = [(w, (make(w)[0], None)) for w in range(n_batches)]
    pre = Preprocessor(cfg, plan="async", depth=4, pad_multiple=1)
    results = list(pre.run(stream))
    wids = [r.wid for r in results]
    assert wids == list(range(n_batches)), \
        f"async emitted out of order / lost chunks: {wids}"
    overlapped = sum(1 for t in pre.plan.last_timings
                     if t.get("in_flight", 1) >= 2)
    assert overlapped >= 1, "no overlapped dispatch in the timing record"
    ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    for r in results:
        want = ref(make(r.wid)[0])
        np.testing.assert_array_equal(np.asarray(r.det.keep),
                                      np.asarray(want.det.keep))
        np.testing.assert_array_equal(r.cleaned, want.cleaned)
    print(f"plan async-pipe OK: depth 4, {len(wids)}/{n_batches} chunk ids "
          f"in order, {overlapped} overlapped dispatches, cleaned "
          f"bit-identical to two_phase in {time.time() - t0:.1f}s")


def _serving_smoke(np, cfg, Preprocessor):
    """Serving-subsystem gate: a pool of 2 persistent PROC workers behind
    the continuous batcher takes 12 concurrent requests, one with an
    already-expired deadline (must fail, never reach a batch) and one
    worker SIGKILLed the moment it is granted its first lease (its work
    must be redelivered to the survivor). Every surviving request must
    resolve exactly once, bit-identical to the in-process two_phase plan
    on the same assembled batches."""
    from repro.data.loader import audio_batch_maker
    from repro.ft.failure import CrashInjector
    from repro.serve import ContinuousBatcher, WorkerPool

    t0 = time.time()
    n_req = 12
    make = audio_batch_maker(seed=7, batch_long_chunks=1)
    chunks = [make(w)[0][0] for w in range(n_req)]
    pool = WorkerPool(cfg, workers=2, transport="proc", respawn=False,
                      poll_s=0.01).start()
    try:
        injector = CrashInjector()
        injector.kill(0, after_items=0)   # shard0 dies on its 1st grant
        injector.attach(0, pool.pids[0])
        pool.service.on_grant = lambda worker, wid: injector.on_pull(
            pool.service.workers[worker].shard)

        batcher = ContinuousBatcher(pool=pool, max_batch=4, linger_s=0.05)
        rids, doomed = [], None
        for i, c in enumerate(chunks):
            if i == 5:                    # one deadline miss, mid-queue
                doomed = batcher.submit(c, timeout_s=0.0)
                rids.append(doomed)
            else:
                rids.append(batcher.submit(c))
        records = {}
        stall = time.time() + 420
        while len(records) < n_req:
            for rid in batcher.pump():
                records[rid] = batcher.result(rid)
            assert time.time() < stall, \
                f"serving smoke stalled ({len(records)}/{n_req} resolved)"
            time.sleep(0.005)

        # exactly-once: every record was popped exactly once
        assert all(batcher.result(r) is None for r in rids)
        assert records[doomed]["ok"] is False \
            and records[doomed]["error"] == "deadline"
        assert all(e["rids"].count(doomed) == 0
                   for e in batcher.batch_log), \
            "an expired request reached a dispatched batch"
        survivors = [r for r in rids if r != doomed]
        assert all(records[r]["ok"] for r in survivors)
        assert injector.crashed == frozenset({0}), "shard0 not SIGKILLed"
        assert pool.queue.redeliveries >= 1
        assert pool.queue.redelivered_from["shard0"] >= 1

        ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
        by_rid = dict(zip(rids, chunks))
        checked = 0
        for e in batcher.batch_log:
            batch = np.stack([by_rid[r] for r in e["rids"]])
            if e["rows"] > e["n_real"]:
                batch = np.concatenate([batch, np.zeros(
                    (e["rows"] - e["n_real"],) + batch.shape[1:],
                    np.float32)])
            want = ref(batch)
            keep = np.asarray(want.det.keep)
            per = keep.size // e["rows"]
            offs = np.concatenate([[0], np.cumsum(keep)]).astype(int)
            for j, rid in enumerate(e["rids"]):
                lo, hi = j * per, (j + 1) * per
                np.testing.assert_array_equal(records[rid]["keep"],
                                              keep[lo:hi])
                np.testing.assert_array_equal(
                    records[rid]["cleaned"], want.cleaned[offs[lo]:offs[hi]])
                checked += 1
        assert checked == len(survivors)
        print(f"plan serving    OK: 2 proc workers, {len(survivors)}/"
              f"{n_req} requests exactly-once + bit-identical "
              f"(1 deadline miss, shard0 SIGKILLed, redeliveries="
              f"{pool.queue.redeliveries}) in {time.time() - t0:.1f}s")
    finally:
        pool.shutdown(drain=False)


def _fused_smoke(np, cfg, Preprocessor):
    """Fused-survivor-tail gate: two_phase with the fused single-pass tail
    vs two_phase with the staged per-stage tail on the same tiny stream —
    survivor masks AND cleaned audio bit-identical in both the ref oracle
    and interpret-kernel backends, and pad-index slots must come through
    the fused kernel as exactly-zero rows (fill-gather semantics)."""
    import jax.numpy as jnp
    from repro.core.graph import PipelineGraph
    from repro.data.loader import audio_batch_maker
    from repro.kernels import backend

    t0 = time.time()
    make = audio_batch_maker(seed=9, batch_long_chunks=1)
    stream = [(w, (make(w)[0], None)) for w in range(2)]
    for mode in ("ref", "interpret"):
        with backend.use(mode):
            staged = Preprocessor(cfg, plan="two_phase", pad_multiple=1,
                                  fuse_tail=False)
            fused = Preprocessor(cfg, plan="two_phase", pad_multiple=1,
                                 fuse_tail=True)
            assert fused.plan.fuse_tail is True
            for a, b in zip(staged.run(stream), fused.run(stream)):
                np.testing.assert_array_equal(np.asarray(a.det.keep),
                                              np.asarray(b.det.keep))
                np.testing.assert_array_equal(a.cleaned, b.cleaned)
    # pad rows: out-of-range survivor slots -> exactly-zero output rows
    g = PipelineGraph(cfg)
    rng = np.random.RandomState(0)
    wave = jnp.asarray(rng.randn(4, cfg.final_split_samples)
                       .astype(np.float32))
    idx = jnp.asarray([2, 99, 0], jnp.int32)
    with backend.use("ref"):
        out = np.asarray(g.tail_indexed_fused(wave, idx))
    assert not out[1].any() and out[0].any() and out[2].any()
    print(f"plan fused-tail OK: fused == staged bit-identical (ref + "
          f"interpret), pad rows zero, in {time.time() - t0:.1f}s")


def _obs_smoke():
    """Observability gate: the real driver (`launch.preprocess`) over 2
    REAL proc workers with `--trace` + `--telemetry` must produce (a) a
    schema-valid Chrome trace (validate_chrome_trace: required keys, known
    phases, X events carry dur, B/E balance LIFO per pid/tid) in which
    worker-process events carry a different pid than the master AND are
    parented under the master's run span across the pickle boundary, and
    (b) exactly ONE durable telemetry 'done' record per chunk, written
    master-side at acceptance with an accept timestamp."""
    import json
    import os
    import shutil
    import tempfile

    from repro.launch import preprocess as launch_pre
    from repro.obs import telemetry as obs_telemetry
    from repro.obs import tracing as obs_tracing

    t0 = time.time()
    n_batches = 2          # --minutes 4 / --batch-long-chunks 2
    tmp = tempfile.mkdtemp(prefix="smoke_obs_")
    trace_path = os.path.join(tmp, "trace.json")
    tdir = os.path.join(tmp, "telemetry")
    prev_tracer = obs_tracing.get_tracer()
    try:
        launch_pre.main([
            "--minutes", "4", "--batch-long-chunks", "2",
            "--plan", "sharded", "--transport", "proc", "--shards", "2",
            "--trace", trace_path, "--telemetry", tdir])
        with open(trace_path) as f:
            data = json.load(f)
        counts = obs_tracing.validate_chrome_trace(data)
        events = data["traceEvents"]
        trace_id = data["otherData"]["trace_id"]
        run_span = trace_id + ":0"
        master_pid = os.getpid()
        roots = [e for e in events
                 if e["name"] == "preprocess_run" and e["ph"] == "B"]
        assert len(roots) == 1 and roots[0]["pid"] == master_pid \
            and roots[0]["args"]["span"] == run_span
        worker_evs = [e for e in events if e["pid"] != master_pid]
        assert worker_evs, "no worker-process events reached the trace"
        assert all(e["args"].get("trace") == trace_id for e in worker_evs)
        # 'E' closers carry no parent by design; every opener/complete must
        assert all(e["args"].get("parent") == run_span
                   for e in worker_evs if e["ph"] != "E"), \
            "worker events not parented under the master run span"
        assert any(e["name"] == "compute" for e in worker_evs)

        recs = obs_telemetry.read_records(tdir)
        done = [r for r in recs if r.get("status") == "done"]
        wids = sorted(r["wid"] for r in done)
        assert wids == list(range(n_batches)), \
            f"telemetry done records not exactly-once per chunk: {wids}"
        assert all(r.get("accept_ts") and r.get("worker") for r in done)
        print(f"plan obs        OK: proc run traced ({len(events)} events, "
              f"phases {counts}), {len(worker_evs)} worker events parented "
              f"under the run span, {len(done)}/{n_batches} telemetry "
              f"records exactly once, in {time.time() - t0:.1f}s")
    finally:
        obs_tracing.set_tracer(prev_tracer)
        shutil.rmtree(tmp, ignore_errors=True)


def _chaos_smoke(np, cfg, Preprocessor, chaos_seed=None):
    """Elastic-fleet chaos gate. N distinct seeded schedules — each mixing
    at least one SIGKILL, one mid-run join, one graceful drain and one
    SIGSTOP stall — fire against REAL proc workers while the stream runs:
    every chunk must come out exactly once, masks AND cleaned audio
    bit-identical to two_phase, every scheduled event must fire, and at
    least one lease redelivery and one registered late joiner must be
    observed across the schedules. Then the injected-straggler speculation
    scenario: the holder of the LAST chunk is SIGSTOPped at grant; an idle
    survivor must win a speculative duplicate lease, with the losing
    incarnation attributed in durable telemetry under reason
    "speculated". Every failure message carries the seed that reproduces
    the schedule (`--chaos-seed`)."""
    from repro.data.loader import audio_batch_maker, make_shard_pool
    from repro.ft.chaos import ACTIONS, ChaosRunner, make_schedule

    t0 = time.time()
    seeds = [int(chaos_seed)] if chaos_seed is not None else [11, 23, 37]
    n_batches = 6
    ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    total_redeliveries = total_specs = 0
    joined_names = []
    for seed in seeds:
        t1 = time.time()
        make = audio_batch_maker(seed=seed, batch_long_chunks=2)
        pool = make_shard_pool(make, n_batches, 2, lease_timeout_s=300.0)
        pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1,
                           transport="proc", elastic=True)
        schedule = make_schedule(seed, n_batches)
        runner = ChaosRunner(pre.plan, pool, schedule, seed=seed)
        tag = (f"[chaos seed {seed}] reproduce with: PYTHONPATH=src "
               f"python -m benchmarks.run --smoke --chaos-seed {seed}")
        try:
            results, fired = runner.run()
            wids = sorted(r.wid for r in results)
            assert wids == list(range(n_batches)), \
                f"lost/duplicated chunks: emitted {wids}"
            unfired = [e.action for e in schedule if not e.fired]
            assert not unfired, f"events never fired: {unfired}"
            by_action = {a: sum(1 for e in fired if e.action == a)
                         for a in ACTIONS}
            assert all(by_action[a] >= 1 for a in ACTIONS), \
                f"schedule incomplete: {by_action}"
            for r in sorted(results, key=lambda r: r.wid):
                want = ref(make(r.wid)[0])
                np.testing.assert_array_equal(np.asarray(r.det.keep),
                                              np.asarray(want.det.keep))
                np.testing.assert_array_equal(r.cleaned, want.cleaned)
        except Exception as e:
            raise AssertionError(f"{tag}: {e}") from e
        names = {st.worker for st in pre.plan.worker_stats}
        joined_names += [f"shard{e.target}" for e in fired
                         if e.action == "join"
                         and f"shard{e.target}" in names]
        total_redeliveries += pre.plan.redeliveries
        total_specs += pre.plan.speculations
        print(f"  chaos seed {seed}: {len(wids)}/{n_batches} exactly once "
              f"+ bit-identical under {by_action}, redeliveries="
              f"{pre.plan.redeliveries} in {time.time() - t1:.1f}s")
    assert total_redeliveries >= 1, \
        "no schedule produced a lease redelivery"
    assert joined_names, \
        "no late joiner ever registered with the membership registry"
    spec_worker, spec_plan = _chaos_speculation_smoke(np, cfg, Preprocessor,
                                                      ref)
    total_specs += spec_plan.speculations
    print(f"plan chaos      OK: {len(seeds)} seeded schedules "
          f"(seeds {seeds}) exactly once + bit-identical, "
          f"redeliveries={total_redeliveries}, late joiners registered "
          f"{sorted(set(joined_names))}, speculations={total_specs} "
          f"({spec_worker} lost the duplicate-lease race, attributed "
          f"in telemetry) in {time.time() - t0:.1f}s")


def _chaos_speculation_smoke(np, cfg, Preprocessor, ref):
    """Injected-straggler speculation scenario (the deterministic arm of
    the chaos gate): factor-0 detector = every in-flight chunk counts as
    a straggler once any history exists, so the moment the pending queue
    empties, the idle worker receives a speculative duplicate of the
    SIGSTOPped holder's chunk and wins the race."""
    import shutil
    import tempfile
    import threading

    from repro.data.loader import audio_batch_maker, make_shard_pool
    from repro.obs.telemetry import (TelemetryWriter, read_records,
                                     worker_ledger)

    n_batches = 6
    make = audio_batch_maker(seed=7, batch_long_chunks=1)
    pool = make_shard_pool(make, n_batches, 2, lease_timeout_s=300.0)
    tdir = tempfile.mkdtemp(prefix="smoke_chaos_spec_")
    telem = TelemetryWriter(tdir)
    pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1,
                       transport="proc", speculate=True,
                       straggler_factor=0.0, straggler_min_history=1,
                       telemetry=telem)
    plan = pre.plan
    results, err, stalled = [], [], []

    def consume():
        try:
            results.extend(plan.run(pool))
        except BaseException as e:      # noqa: BLE001 — reraised below
            err.append(e)

    def on_grant(worker, wid):
        # the LAST chunk's holder becomes a genuine straggler: stopped
        # long enough that the idle survivor computes the duplicate first
        if wid == n_batches - 1 and not stalled:
            stalled.append(worker)
            plan.fleet.stall(plan.fleet.service.workers[worker].shard,
                             20.0)

    t = threading.Thread(target=consume, daemon=True,
                         name="chaos-spec-consumer")
    t.start()
    try:
        while plan.fleet is None and t.is_alive():
            time.sleep(0.01)
        if plan.fleet is not None:
            plan.fleet.service.on_grant = on_grant
        t.join(600.0)
        assert not t.is_alive(), "speculation scenario hung"
        if err:
            raise err[0]
        telem.close()
        wids = sorted(r.wid for r in results)
        assert wids == list(range(n_batches)), \
            f"lost/duplicated chunks: emitted {wids}"
        for r in results:
            want = ref(make(r.wid)[0])
            np.testing.assert_array_equal(np.asarray(r.det.keep),
                                          np.asarray(want.det.keep))
            np.testing.assert_array_equal(r.cleaned, want.cleaned)
        assert stalled, "the last chunk was never granted?"
        assert plan.speculations >= 1, \
            "no speculative duplicate lease was granted"
        assert plan.speculations_lost >= 1, \
            "both incarnations of the speculated chunk vanished"
        recs = read_records(tdir)
        lost = [r for r in recs if r.get("status") == "redelivered"
                and r.get("reason") == "speculated"]
        assert lost, "losing incarnation not attributed in telemetry"
        led = worker_ledger(recs)
        losers = [w for w, e in led.items() if e["speculation_lost"]]
        assert losers, "worker ledger shows no speculation_lost breakout"
        done = sorted(r["wid"] for r in recs if r.get("status") == "done")
        assert done == list(range(n_batches)), \
            f"telemetry done records not exactly-once: {done}"
        return losers[0], plan
    finally:
        telem.close()
        shutil.rmtree(tdir, ignore_errors=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny batch through every execution plan, then exit")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="run the chaos gate with this single schedule "
                         "seed (reproduce a failing schedule; default: "
                         "the gate's own seed set)")
    args = ap.parse_args()
    if args.smoke:
        smoke(chaos_seed=args.chaos_seed)
    minutes = 16.0 if args.full else 2.0
    hours = 2.0

    from benchmarks import (bench_stage_times, bench_two_split,
                            bench_detector_accuracy, bench_split_accuracy,
                            bench_comm, bench_config_search, bench_scaling,
                            bench_load_balance, bench_utilization,
                            bench_early_exit, bench_cache,
                            bench_dispatch_depth, bench_queue_depth,
                            bench_serving, bench_fused_tail,
                            bench_obs_overhead, bench_chaos,
                            bench_scaling_real)
    steps = [
        ("Table 1 / Fig 1: stage times",
         lambda: bench_stage_times.run(minutes=minutes)),
        ("Fig 2: two-split HPF",
         lambda: bench_two_split.run(minutes=min(minutes, 4.0))),
        ("Fig 10: communication",
         lambda: bench_comm.run(minutes=4.0 if not args.full else 30.0)),
        ("Tables 2-3 / Fig 3: detector accuracy vs MMSE",
         lambda: bench_detector_accuracy.run(minutes=max(4.0, minutes))),
        ("Tables 4-6 / Figs 4-7: split-length accuracy",
         lambda: bench_split_accuracy.run(minutes=max(6.0, minutes))),
        ("Table 7: config search",
         lambda: bench_config_search.run(hours=hours)),
        ("Table 7: queue depth (lease batching)",
         lambda: bench_queue_depth.run(
             minutes=8.0 if not args.full else 16.0)),
        ("Figs 11-13: scaling", lambda: bench_scaling.run(hours=hours)),
        ("Figs 11-12 measured: real-process scaling (tcp + store plane)",
         lambda: bench_scaling_real.run(
             shards=(1, 2, 4, 8, 16) if args.full else (1, 2, 4))),
        ("Figs 14-18: load balance",
         lambda: bench_load_balance.run(hours=hours)),
        ("Figs 19-20: utilisation",
         lambda: bench_utilization.run(hours=hours)),
        ("Headline: early-exit economy (on-device)",
         lambda: bench_early_exit.run(minutes=4.0)),
        ("Store: cold/warm/partial-overlap cache economics",
         lambda: bench_cache.run(minutes=8.0 if not args.full else 32.0)),
        ("Pipeline: dispatch depth x survivor buckets",
         lambda: bench_dispatch_depth.run(
             minutes=16.0 if not args.full else 32.0)),
        ("Serving: worker pool + continuous batching p50/p99",
         lambda: bench_serving.run(
             minutes=6.0 if not args.full else 16.0)),
        ("Kernel: fused survivor tail vs staged",
         lambda: bench_fused_tail.run(reps=2 if not args.full else 4)),
        ("Observability: off/metrics/full overhead",
         lambda: bench_obs_overhead.run(reps=2 if not args.full else 4)),
        ("Elasticity: membership overhead + speculative tail cut",
         lambda: bench_chaos.run()),
    ]
    failures = []
    for name, fn in steps:
        print(f"\n{'=' * 72}\n>> {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"[{name}] done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()

    print(f"\n{'=' * 72}\n>> Roofline (from dry-run records, if present)\n"
          f"{'=' * 72}", flush=True)
    try:
        from benchmarks import roofline
        recs = roofline.load_records("results/dryrun_final.json")
        recs += roofline.load_records("results/dryrun_audio_final.json")
        if recs:
            roofline.fmt_table(recs)
        else:
            print("no dry-run records yet (run repro.launch.dryrun --all)")
    except Exception:
        failures.append("roofline")
        traceback.print_exc()

    print(f"\n{len(steps) - len(failures)}/{len(steps)} benches OK"
          + (f"; FAILED: {failures}" if failures else ""))
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
