"""Benchmark aggregator: one bench per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full]
  PYTHONPATH=src python -m benchmarks.run --smoke   # executor regression gate

Order matters: stage-time calibration feeds the DES benches; comm feeds the
DES transfer model. The roofline table prints from the dry-run records.
"""
from __future__ import annotations

import argparse
import time
import traceback


def smoke():
    """One tiny batch stream through EVERY registered execution plan:
    survivor sets must match bit-for-bit and cleaned audio to rtol=1e-4, so
    executor regressions fail fast (scripts/verify.sh runs this)."""
    import numpy as np
    from repro.configs import SERF_AUDIO as cfg
    from repro.core.plans import PLANS, Preprocessor
    from repro.data.synthetic import generate_labelled

    audio, _ = generate_labelled(0, 2 * 12, segment_s=5.0)
    S5 = audio.shape[-1]
    chunks = (audio.reshape(2, 12, 2, S5).transpose(0, 2, 1, 3)
              .reshape(2, 2, 12 * S5))
    stream = [(0, (chunks[:1], None)), (1, (chunks[1:], None))]
    ref_name = ref = None
    failures = []
    for name in sorted(PLANS):
        t0 = time.time()
        try:
            pre = Preprocessor(cfg, plan=name, pad_multiple=1)
            results = list(pre.run(stream))
            keep = np.concatenate([np.asarray(r.det.keep) for r in results])
            cleaned = np.concatenate([r.cleaned for r in results])
            assert np.isfinite(cleaned).all(), "non-finite output"
            assert cleaned.shape[0] == int(keep.sum())
            if ref is None:
                ref_name, ref = name, (keep, cleaned)
            else:
                np.testing.assert_array_equal(keep, ref[0])
                np.testing.assert_allclose(cleaned, ref[1],
                                           rtol=1e-4, atol=1e-5)
            print(f"plan {name:10s} OK: {cleaned.shape[0]}/{keep.size} "
                  f"survivors in {time.time() - t0:.1f}s"
                  + ("" if ref[1] is cleaned else f" (== {ref_name})"))
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"\nsmoke: {len(PLANS) - len(failures)}/{len(PLANS)} plans OK"
          + (f"; FAILED: {failures}" if failures else ""))
    raise SystemExit(1 if failures else 0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny batch through every execution plan, then exit")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    minutes = 16.0 if args.full else 2.0
    hours = 2.0

    from benchmarks import (bench_stage_times, bench_two_split,
                            bench_detector_accuracy, bench_split_accuracy,
                            bench_comm, bench_config_search, bench_scaling,
                            bench_load_balance, bench_utilization,
                            bench_early_exit)
    steps = [
        ("Table 1 / Fig 1: stage times",
         lambda: bench_stage_times.run(minutes=minutes)),
        ("Fig 2: two-split HPF",
         lambda: bench_two_split.run(minutes=min(minutes, 4.0))),
        ("Fig 10: communication",
         lambda: bench_comm.run(minutes=4.0 if not args.full else 30.0)),
        ("Tables 2-3 / Fig 3: detector accuracy vs MMSE",
         lambda: bench_detector_accuracy.run(minutes=max(4.0, minutes))),
        ("Tables 4-6 / Figs 4-7: split-length accuracy",
         lambda: bench_split_accuracy.run(minutes=max(6.0, minutes))),
        ("Table 7: config search",
         lambda: bench_config_search.run(hours=hours)),
        ("Figs 11-13: scaling", lambda: bench_scaling.run(hours=hours)),
        ("Figs 14-18: load balance",
         lambda: bench_load_balance.run(hours=hours)),
        ("Figs 19-20: utilisation",
         lambda: bench_utilization.run(hours=hours)),
        ("Headline: early-exit economy (on-device)",
         lambda: bench_early_exit.run(minutes=4.0)),
    ]
    failures = []
    for name, fn in steps:
        print(f"\n{'=' * 72}\n>> {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"[{name}] done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()

    print(f"\n{'=' * 72}\n>> Roofline (from dry-run records, if present)\n"
          f"{'=' * 72}", flush=True)
    try:
        from benchmarks import roofline
        recs = roofline.load_records("results/dryrun_final.json")
        recs += roofline.load_records("results/dryrun_audio_final.json")
        if recs:
            roofline.fmt_table(recs)
        else:
            print("no dry-run records yet (run repro.launch.dryrun --all)")
    except Exception:
        failures.append("roofline")
        traceback.print_exc()

    print(f"\n{len(steps) - len(failures)}/{len(steps)} benches OK"
          + (f"; FAILED: {failures}" if failures else ""))
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
