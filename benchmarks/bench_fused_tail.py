"""Fused survivor tail vs the staged per-stage tail: wall clock and
HBM-boundary bytes across survivor buckets.

The fused pass (kernels/fused_tail) replaces the staged gather -> [hpf ->]
stft -> mmse -> istft dispatch chain with ONE kernel whose only HBM
crossing is the packed gain-filtered spectrum; the staged chain
materialises every intermediate (gathered batch, padded batch, raw
spectrum, filtered spectrum) between dispatches. Two measurements per
pow2 survivor bucket {2, 8, 32, full}:

  wall clock      jit(tail_indexed) vs jit(tail_indexed_fused), one warm
                  pass (compile) then min-of-`reps` timed passes. On CPU
                  both resolve to XLA-compiled jnp (backend auto), so this
                  measures the fusion's dispatch/materialisation economy,
                  not kernel quality — the compiled-TPU sweep is the open
                  ROADMAP item.
  boundary bytes  the analytic per-dispatch HBM traffic model: bytes every
                  staged intermediate materialises vs the fused kernel's
                  packed-spectrum handoff. Exact array sizes, f32/c64.

A roofline sketch per bucket (benchmarks/roofline.py `fused_tail_record`)
classifies the fused pass compute- vs memory-bound at TPU v5e constants.

Writes `results/BENCH_fused.json`.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core.graph import PipelineGraph
from repro.data.loader import audio_batch_maker
from repro.kernels.fused_tail import kernel as FTK
from repro.kernels.stft_dft.kernel import PAD_OUT
from benchmarks import roofline
from benchmarks.util import table, save_json


def boundary_bytes(R, S, window, hop, hpf=False):
    """(staged, fused) inter-dispatch HBM bytes for an R-row tail.

    Staged: every stage output materialises — the gathered (R,S) f32
    batch, the optional hpf (R,S) f32, the (R,S_pad) f32 pad, the raw
    (R,Fv,bins) c64 spectrum, the filtered (R,Fv,bins) c64 spectrum, and
    the (R,S) f32 resynthesis. Fused: the kernel's packed (R,F,PAD_OUT)
    f32 spectrum plus the same (R,S) f32 resynthesis out of `finish`."""
    _, S_pad, F, Fv = FTK.tail_geometry(S, window, hop)
    bins = window // 2 + 1
    staged = R * S * 4                 # gather
    if hpf:
        staged += R * S * 4            # hpf output
    staged += R * S_pad * 4            # pad_for_stft
    staged += R * Fv * bins * 8        # raw spectrum (complex64)
    staged += R * Fv * bins * 8        # gain-filtered spectrum
    staged += R * S * 4                # istft output
    fused = R * F * PAD_OUT * 4        # packed filtered spectrum
    fused += R * S * 4                 # istft output (finish)
    return staged, fused


def _min_wall(fn, wave, idx, reps):
    jax.block_until_ready(fn(wave, idx))          # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(wave, idx))
        best = min(best, time.perf_counter() - t0)
    return best


def run(buckets=(2, 8, 32, None), reps=2, seed=13, batch_long_chunks=3):
    make = audio_batch_maker(seed=seed,
                             batch_long_chunks=batch_long_chunks)
    g = PipelineGraph(cfg)
    det = g.detection(jnp.asarray(make(0)[0]))
    wave5 = det.wave5
    B, S = wave5.shape
    window, hop = cfg.stft_window, cfg.stft_hop
    staged_fn = jax.jit(lambda w, i: g.tail_indexed(w, i))
    fused_fn = jax.jit(lambda w, i: g.tail_indexed_fused(w, i))

    rows, recs = [], []
    for b in buckets:
        R = B if b is None else min(b, B)
        idx = jnp.arange(R, dtype=jnp.int32)
        t_staged = _min_wall(staged_fn, wave5, idx, reps)
        t_fused = _min_wall(fused_fn, wave5, idx, reps)
        by_s, by_f = boundary_bytes(R, S, window, hop)
        roof = roofline.roofline_terms(
            roofline.fused_tail_record(R, S, window, hop))
        rec = {
            "bucket": "full" if b is None else b, "rows": R,
            "staged_wall_s": t_staged, "fused_wall_s": t_fused,
            "speedup": t_staged / t_fused,
            "staged_boundary_bytes": by_s, "fused_boundary_bytes": by_f,
            "boundary_reduction": 1 - by_f / by_s,
            "roofline_dominant": roof["dominant"],
            "roofline_compute_s": roof["compute_s"],
            "roofline_memory_s": roof["memory_s"],
        }
        recs.append(rec)
        rows.append(["full" if b is None else b, R, t_staged, t_fused,
                     t_staged / t_fused, by_s / 2**20, by_f / 2**20,
                     f"{rec['boundary_reduction']:.0%}", roof["dominant"]])
    table(rows, ["bucket", "rows", "staged s", "fused s", "speedup",
                 "staged MB", "fused MB", "boundary cut", "v5e bound"],
          title=f"Fused vs staged survivor tail (B={B}, S={S}, "
                f"min-of-{reps})")

    tot_s = sum(r["staged_wall_s"] for r in recs)
    tot_f = sum(r["fused_wall_s"] for r in recs)
    findings = {
        "fused_no_slower_than_staged": bool(tot_f <= tot_s * 1.05),
        "total_speedup": tot_s / tot_f,
        "boundary_cut_every_bucket": all(
            r["boundary_reduction"] > 0 for r in recs),
        "min_boundary_reduction": min(
            r["boundary_reduction"] for r in recs),
    }
    path = save_json("BENCH_fused", {"rows": recs, "findings": findings})
    print(f"\nfused tail vs staged over buckets "
          f"{[r['bucket'] for r in recs]}: total {tot_s:.2f}s -> "
          f"{tot_f:.2f}s ({findings['total_speedup']:.2f}x); boundary "
          f"bytes cut {findings['min_boundary_reduction']:.0%}+ per bucket")
    print(f"record -> {path}")
    return findings


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--batch-long-chunks", type=int, default=3)
    args = ap.parse_args()
    run(reps=args.reps, batch_long_chunks=args.batch_long_chunks)


if __name__ == "__main__":
    main()
