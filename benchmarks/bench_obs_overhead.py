"""Observability overhead: the same stream with telemetry off vs on.

The `repro.obs` contract is zero-cost-when-off and cheap-when-on: the
metrics registry, the tracer, and the telemetry writer may not tax the
pipeline they watch. Three modes over one fixed two_phase stream:

  off       registry disabled, null tracer, no telemetry writer — the
            baseline a pipeline without repro.obs would run
  metrics   registry enabled (the default production posture): every
            per-batch counter/histogram update is live
  full      metrics + a Chrome-trace tracer installed globally + a
            durable per-chunk JSONL telemetry record per emission

Each mode runs one warm pass (jit compile excluded from the measurement)
then min-of-`reps` timed passes. Findings assert the FULL mode stays
within 5% of off-mode wall clock and that survivor masks and cleaned
audio are bit-identical across all three modes — instrumentation must
never touch values. Obs global state is restored afterwards regardless.

Writes `results/BENCH_obs.json`.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.loader import audio_batch_maker
from repro.obs import metrics as obs_metrics
from repro.obs import telemetry as obs_telemetry
from repro.obs import tracing as obs_tracing
from benchmarks.util import table, save_json


def _run_stream(pre, stream, telem=None):
    results = sorted(pre.run(stream), key=lambda r: r.wid)
    if telem is not None:
        for r in results:
            obs_telemetry.record_result(telem, r.wid, r)
    keep = np.concatenate([np.asarray(r.det.keep) for r in results])
    cleaned = np.concatenate([r.cleaned for r in results])
    return keep, cleaned


def _measure(stream, reps, telem=None):
    pre = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    out = _run_stream(pre, stream, telem)          # warm: compile pass
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = _run_stream(pre, stream, telem)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(n_batches=4, batch_long_chunks=2, reps=2, seed=11):
    make = audio_batch_maker(seed=seed, batch_long_chunks=batch_long_chunks)
    stream = [(w, (make(w)[0], None)) for w in range(n_batches)]
    src_mb = sum(np.asarray(make(w)[0]).nbytes
                 for w in range(n_batches)) / 2**20

    reg = obs_metrics.get_registry()
    was_enabled = reg.enabled
    prev_tracer = obs_tracing.get_tracer()
    telem_dir = tempfile.mkdtemp(prefix="bench_obs_")
    rows, recs, outs = [], {}, {}
    try:
        # off: the no-repro.obs baseline
        reg.enabled = False
        obs_tracing.set_tracer(obs_tracing.NULL_TRACER)
        t_off, outs["off"] = _measure(stream, reps)

        # metrics: registry live (the default posture)
        reg.enabled = True
        t_metrics, outs["metrics"] = _measure(stream, reps)

        # full: + tracer + durable telemetry records
        tracer = obs_tracing.Tracer()
        obs_tracing.set_tracer(tracer)
        tracer.start_run("bench_obs_full")
        with obs_telemetry.TelemetryWriter(telem_dir) as telem:
            t_full, outs["full"] = _measure(stream, reps, telem)
        tracer.finish_run()
        n_events = len(tracer.events)
        n_records = telem.records_written

        for mode, t in (("off", t_off), ("metrics", t_metrics),
                        ("full", t_full)):
            recs[mode] = {"wall_s": t, "overhead": t / t_off - 1.0,
                          "mb_per_s": src_mb / t}
            rows.append([mode, t, f"{recs[mode]['overhead']:+.2%}",
                         src_mb / t])
    finally:
        reg.enabled = was_enabled
        obs_tracing.set_tracer(prev_tracer)
        shutil.rmtree(telem_dir, ignore_errors=True)

    table(rows, ["mode", "wall s", "overhead", "MB/s"],
          title=f"Observability overhead ({n_batches} batches, "
                f"{src_mb:.0f} MB source, min-of-{reps})")

    identical = all(
        np.array_equal(outs[m][0], outs["off"][0])
        and np.array_equal(outs[m][1], outs["off"][1])
        for m in ("metrics", "full"))
    findings = {
        "full_overhead": recs["full"]["overhead"],
        "metrics_overhead": recs["metrics"]["overhead"],
        "full_overhead_under_5pct": bool(recs["full"]["overhead"] < 0.05),
        "output_bit_identical_all_modes": bool(identical),
        "trace_events": n_events,
        "telemetry_records": n_records,
    }
    path = save_json("BENCH_obs", {"rows": recs, "findings": findings})
    print(f"\nfull observability (metrics + trace + telemetry) cost "
          f"{findings['full_overhead']:+.2%} wall clock vs off "
          f"({n_events} trace events, {n_records} telemetry records); "
          f"output bit-identical: {identical}")
    print(f"record -> {path}")
    assert identical, "instrumentation changed output values"
    return findings


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-long-chunks", type=int, default=2)
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args()
    run(n_batches=args.batches, batch_long_chunks=args.batch_long_chunks,
        reps=args.reps)


if __name__ == "__main__":
    main()
