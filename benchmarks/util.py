"""Benchmark helpers: timing, tables, result persistence."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def time_fn(fn, *args, warmup=1, iters=3):
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), float(np.std(ts))


def table(rows, headers, title=None, floatfmt="{:.3f}"):
    def fmt(v):
        if isinstance(v, float):
            return floatfmt.format(v)
        return str(v)
    widths = [max(len(h), *(len(fmt(r[i])) for r in rows))
              for i, h in enumerate(headers)] if rows else [len(h) for h in headers]
    lines = []
    if title:
        lines.append(f"== {title} ==")
    lines.append("  ".join(h.ljust(w) for h, w in zip(headers, widths)))
    lines.append("  ".join("-" * w for w in widths))
    for r in rows:
        lines.append("  ".join(fmt(v).ljust(w) for v, w in zip(r, widths)))
    out = "\n".join(lines)
    print(out, flush=True)
    return out


def save_json(name, obj):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name if name.endswith(".json")
                        else name + ".json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path


def load_json(name):
    path = os.path.join(RESULTS_DIR, name if name.endswith(".json")
                        else name + ".json")
    with open(path) as f:
        return json.load(f)
