"""Paper Fig 2: the "two-split" HPF trick.

The paper: filtering many short files costs more than filtering 1-minute
chunks first and re-splitting (SoX per-call overhead). The TPU/XLA analogue
of per-file overhead is per-DISPATCH overhead: one jit call per chunk vs one
batched call over long chunks. We measure three regimes:
  (a) per-chunk dispatch at the target split length   (paper: one split)
  (b) per-chunk dispatch at 60 s, then re-split       (paper: two splits)
  (c) fully batched single dispatch                   (our production mode)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.kernels.fir_hpf.ops import highpass
from repro.data.synthetic import generate_labelled
from repro.core import stages as S
from benchmarks.util import time_fn, table, save_json

SPLITS = (5, 10, 15, 20, 30)


def run(minutes=2.0, seed=0):
    n_seg = int(minutes * 60 / 5)
    audio, _ = generate_labelled(seed, n_seg, segment_s=5.0, stereo=False)
    x22 = np.asarray(jax.jit(lambda a: S.compress(a, cfg))(
        jnp.asarray(audio)))
    flat = x22.reshape(-1)
    hp = jax.jit(highpass)

    rows = []
    n60 = int(60 * cfg.target_rate_hz)
    longs = flat[: (flat.size // n60) * n60].reshape(-1, n60)

    def per_chunk(chunks):
        for i in range(chunks.shape[0]):
            jax.block_until_ready(hp(chunks[i:i + 1]))

    t_long, _ = time_fn(per_chunk, longs, warmup=1, iters=2)
    for split_s in SPLITS:
        n = int(split_s * cfg.target_rate_hz)
        chunks = flat[: (flat.size // n) * n].reshape(-1, n)
        t_short, _ = time_fn(per_chunk, chunks, warmup=1, iters=2)
        t_batched, _ = time_fn(hp, jnp.asarray(chunks))
        rows.append([split_s, chunks.shape[0], t_short, t_long, t_batched])

    out = table(rows, ["split_s", "n_chunks", "per-chunk@split",
                       "per-chunk@60s(two-split)", "batched(one dispatch)"],
                title="Fig-2 equivalent: HPF dispatch-overhead regimes (s)")
    save_json("two_split", {"rows": rows})
    short5 = rows[0][2]
    assert rows[0][3] <= short5 * 1.2, "two-split should not be slower at 5s"
    print("\npaper finding reproduced: long-chunk filtering amortizes "
          f"per-call overhead ({short5:.2f}s -> {rows[0][3]:.2f}s at 5 s "
          f"splits; fully-batched: {rows[0][4]:.3f}s)")


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=2.0)
    run(minutes=ap.parse_args().minutes)


if __name__ == "__main__":
    main()
