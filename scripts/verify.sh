#!/usr/bin/env bash
# Tier-1 verification gate (see ROADMAP.md "Tier-1 verify"):
#   1. the repo's own test suite
#   2. the executor smoke: one tiny batch through every registered
#      execution plan (survivor sets must agree bit-for-bit), PLUS the
#      sharded fault-tolerance gate — ShardedPlan over 2 simulated shards
#      with a forced lease expiry and a mid-stream worker crash must
#      finish with redeliveries >= 1 and zero lost/duplicated chunks —
#      PLUS the process-mode FT gate — the same recovery on 2 REAL worker
#      processes over the repro.dist proc transport, one SIGKILLed
#      mid-stream while holding a lease: zero lost/duplicate chunks,
#      output bit-identical to two_phase —
#      PLUS the store-data-plane gate — the same stream over 2 REAL
#      worker processes on the TCP transport twice, socket data plane vs
#      store data plane (chunk batches and result payloads through a
#      shared ChunkStore, the socket carrying only content keys): the
#      store run must cut the master's data-plane socket bytes by >= 90%
#      (measured from dist_fetch_bytes_total{plane} +
#      dist_push_bytes_total{plane}) while staying bit-identical —
#      PLUS the cache gate — the same tiny stream twice through
#      CachedPlan over a fresh store: the second pass must be >= 90%
#      cache hits with survivor masks bit-identical to the uncached plan —
#      PLUS the async-pipeline gate — `--plan async --depth 4` on a tiny
#      stream must emit every chunk id exactly once in input order,
#      bit-identical to two_phase, with >= 1 overlapped dispatch observed
#      in the per-batch timing records —
#      PLUS the serving gate — a persistent pool of 2 proc workers behind
#      the continuous batcher serving 12 concurrent requests, one with an
#      already-expired deadline (must fail, never dispatch) and one
#      worker SIGKILLed at its first lease grant (work redelivered): all
#      surviving requests answered exactly once, bit-identical to
#      two_phase —
#      PLUS the fused-tail gate — two_phase with the fused single-pass
#      survivor tail (gather+hpf+stft+mmse in one kernel) vs the staged
#      per-stage tail: masks + cleaned audio bit-identical in both the
#      ref and interpret backends, pad-index rows exactly zero —
#      PLUS the observability gate — the launch driver over 2 REAL proc
#      workers with --trace + --telemetry: the Chrome trace must pass
#      the repro.obs schema check (required keys, known phases, X events
#      carry dur, B/E balance LIFO per pid/tid) with worker-process
#      events parented under the master's run span across the pickle
#      boundary, and the durable telemetry JSONL must hold exactly ONE
#      master-side 'done' record per chunk —
#      PLUS the chaos gate — seeded randomized schedules (>= 1 SIGKILL,
#      >= 1 mid-run join, >= 1 graceful drain, >= 1 SIGSTOP stall each)
#      fired against 2+ REAL proc workers while the stream runs: every
#      chunk exactly once, masks AND cleaned audio bit-identical to
#      two_phase, redeliveries and registered late joiners observed;
#      then the injected-straggler scenario — the last chunk's holder is
#      SIGSTOPped at grant, an idle survivor must win the speculative
#      duplicate lease, and the losing incarnation must be attributed in
#      durable telemetry under reason "speculated". A failing schedule
#      prints its seed; reproduce with
#        bash scripts/verify.sh --chaos-seed <seed>
#      (forwarded to `benchmarks.run --smoke`, which then runs ONLY that
#      schedule plus the speculation scenario)
#
#   bash scripts/verify.sh [--chaos-seed N] [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

CHAOS_ARGS=()
PYTEST_ARGS=()
while [[ $# -gt 0 ]]; do
  case "$1" in
    --chaos-seed)
      CHAOS_ARGS=(--chaos-seed "$2"); shift 2 ;;
    --chaos-seed=*)
      CHAOS_ARGS=(--chaos-seed "${1#*=}"); shift ;;
    *)
      PYTEST_ARGS+=("$1"); shift ;;
  esac
done

python -m pytest -x -q "${PYTEST_ARGS[@]+"${PYTEST_ARGS[@]}"}"
python -m benchmarks.run --smoke "${CHAOS_ARGS[@]+"${CHAOS_ARGS[@]}"}"
