"""End-to-end driver (deliverable (b)): the paper's preprocessing pipeline
feeding a whisper-family audio model — preprocess, featurize, train.

The pipeline's cleaned 5 s chunks become STFT-frame embeddings (the stubbed
conv frontend per the brief), and the whisper-small-family encoder-decoder
trains to predict per-chunk pseudo-transcripts (synthetic token streams keyed
to the chunk's acoustic label — enough structure for the loss to fall).

  PYTHONPATH=src python examples/preprocess_and_train.py --steps 60
(reduced model; a full-size run uses --no-reduced on real hardware)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SERF_AUDIO, ARCHS, reduced
from repro.core.plans import Preprocessor
from repro.core import stages as S
from repro.data.synthetic import generate_labelled
from repro.distributed.sharding import NULL_RULES
from repro.models.zoo import build_model
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_train_step, init_train_state


def featurize(cfg_audio, model_cfg, chunks, n_frames=64):
    """Cleaned 5 s chunks -> frame embeddings (B, n_frames, d_model): the
    'conv frontend stub' = pooled log-power STFT frames projected by a fixed
    random matrix."""
    _, power = S.stft_chunks(jnp.asarray(chunks), cfg_audio)
    feats = jnp.log1p(power)                          # (B, F, bins)
    F = feats.shape[1] - feats.shape[1] % n_frames
    feats = feats[:, :F].reshape(feats.shape[0], n_frames, -1,
                                 feats.shape[-1]).mean(axis=2)
    proj = jax.random.normal(jax.random.key(7),
                             (feats.shape[-1], model_cfg.d_model)) * 0.05
    return feats @ proj


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--dec-len", type=int, default=24)
    args = ap.parse_args()

    model_cfg = reduced(ARCHS["whisper-small"])
    model = build_model(model_cfg)
    opt = OptConfig(lr=3e-3, warmup_steps=10, decay_steps=args.steps)
    params, opt_state = init_train_state(model, opt, jax.random.key(0))
    step_fn = jax.jit(make_train_step(model, NULL_RULES, opt),
                      donate_argnums=(0, 1))

    pre = Preprocessor(SERF_AUDIO, plan="two_phase")
    rng = np.random.RandomState(0)
    t0, losses = time.time(), []
    for step in range(1, args.steps + 1):
        # 1) preprocess a fresh minute of audio (early-exit pipeline)
        audio, labels = generate_labelled(step, 12, segment_s=5.0)
        S5 = audio.shape[-1]
        lc = audio.reshape(1, 12, 2, S5).transpose(0, 2, 1, 3).reshape(
            1, 2, 12 * S5)
        res = pre(jnp.asarray(lc))
        if res.n_kept == 0:
            continue
        kept_labels = labels[np.asarray(res.det.keep)]
        # 2) featurize survivors; batch up
        idx = rng.choice(res.n_kept, size=args.batch)
        frames = featurize(SERF_AUDIO, model_cfg, res.cleaned[idx])
        # pseudo-transcripts keyed to the acoustic label
        base = (kept_labels[idx][:, None] * 31 + 5).astype(np.int32)
        toks = (base + np.arange(args.dec_len)[None, :] * 7) % \
            model_cfg.vocab_size
        batch = {"enc_frames": frames,
                 "tokens": jnp.asarray(toks),
                 "targets": jnp.asarray(toks)}
        # 3) train
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            print(f"step {step:4d} kept {res.n_kept:2d}/12 chunks  "
                  f"loss {losses[-1]:.3f}  "
                  f"({step / (time.time() - t0):.2f} steps/s)", flush=True)
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'LEARNED' if losses[-1] < losses[0] * 0.8 else 'check setup'})")


if __name__ == "__main__":
    main()
