"""Batched serving example: a request queue pumping fixed-size batches
through prefill + KV-cache decode (greedy), on a reduced gemma-7b.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models.zoo import build_model
from repro.serve.engine import ServeEngine, RequestQueue


def main():
    cfg = reduced(ARCHS["gemma-7b"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_seq=96)
    queue = RequestQueue(engine, batch_size=4, prompt_len=16, n_tokens=32)

    rng = np.random.RandomState(0)
    rids = [queue.submit(rng.randint(0, cfg.vocab_size, size=16))
            for _ in range(10)]
    t0 = time.time()
    served = []
    while len(served) < len(rids):
        served.extend(queue.pump())
    dt = time.time() - t0
    print(f"served {len(rids)} requests x 32 tokens in {dt:.2f}s "
          f"({len(rids) * 32 / dt:.1f} tok/s, batch=4)")
    print("first response:", queue.result(rids[0])[:12].tolist())


if __name__ == "__main__":
    main()
