"""Quickstart: preprocess synthetic bird-acoustic audio through the paper's
unified early-exit pipeline — now a config-declared stage graph run by an
execution plan — and print what each stage did.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.synthetic import generate_labelled, LABELS


def main():
    # 4 minutes of 44.1 kHz stereo audio with ground-truth labels
    n_long = 4
    audio, labels = generate_labelled(0, n_long * 12, segment_s=5.0)
    S5 = audio.shape[-1]
    long_chunks = (audio.reshape(n_long, 12, 2, S5).transpose(0, 2, 1, 3)
                   .reshape(n_long, 2, 12 * S5))
    print(f"input: {long_chunks.shape[0]} x 60 s stereo long chunks "
          f"({long_chunks.nbytes / 2**20:.0f} MB)")
    print("ground truth:",
          {l: int((labels == i).sum()) for i, l in enumerate(LABELS)})

    # The stage order is DATA on the config; the plan decides execution
    # (fused / two_phase / streaming / async / sharded / cached —
    # see repro.core.plans.PLANS).
    pre = Preprocessor(cfg, plan="two_phase",
                       pad_multiple=len(jax.devices()))
    res = pre(jnp.asarray(long_chunks))

    s = {k: float(v) for k, v in res.det.stats.items()}
    print(f"\nstage graph: {' -> '.join(cfg.stages)}")
    print(f"  detect_rain      removed {s['frac_rain']:.1%}")
    print(f"  cicada_bandstop  band-stopped {s['frac_cicada15']:.1%} "
          f"of 15 s chunks")
    print(f"  detect_silence   removed {s['frac_silence']:.1%}")
    print(f"  mmse             ran on the {res.n_kept} survivors only "
          f"({s['frac_kept']:.1%}) — the paper's early-exit economy")
    print(f"\noutput: {res.cleaned.shape[0]} cleaned 5 s chunks @ "
          f"{cfg.target_rate_hz / 1000:.2f} kHz, "
          f"finite={np.isfinite(res.cleaned).all()}")


if __name__ == "__main__":
    main()
