"""Fault-tolerance walkthrough: train, 'lose' capacity, restore the
checkpoint onto a smaller mesh (restore-time resharding), keep training with
the exact data cursor — no sample loss or duplication. Phase 4 shows the
same exactly-once story for the PREPROCESSING stream: a `--store`d cached
run is killed mid-stream and relaunched with `resume=True` — the
`repro.store.RunJournal` skips exactly what was already emitted, and the
`ChunkStore` turns the dead run's unemitted-but-computed work into hits.

  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models.zoo import build_model
from repro.distributed.sharding import ShardingRules, tree_shardings
from repro.ft.failure import plan_mesh, HeartbeatMonitor
from repro.launch.mesh import mesh_from_plan
from repro.ckpt import checkpoint as ckpt
from repro.data.loader import TokenLoader
from repro.train.optimizer import OptConfig
from repro.train.train_step import (make_train_step, init_train_state,
                                    train_state_specs)


def train_some(params, opt_state, step_fn, loader, n):
    it = iter(loader)
    last = None
    for _ in range(n):
        wid, batch = next(it)
        params, opt_state, metrics = step_fn(params, opt_state,
                                             jax.tree.map(jnp.asarray, batch))
        last = float(metrics["loss"])
    return params, opt_state, last


def main():
    cfg = reduced(ARCHS["llama3.2-3b"])
    model = build_model(cfg)
    opt = OptConfig(lr=1e-3)
    ckdir = tempfile.mkdtemp(prefix="elastic_")

    # phase 1: full fleet
    n_dev = len(jax.devices())
    plan = plan_mesh(n_dev)
    print(f"phase 1: {n_dev} device(s) -> mesh {plan.shape} ({plan.reason})")
    mesh = mesh_from_plan(plan)
    rules = ShardingRules(mesh, cfg.sharding_mode)
    pspecs, ospecs = train_state_specs(model, opt)
    p_sh, o_sh = tree_shardings(rules, pspecs), tree_shardings(rules, ospecs)
    params, opt_state = init_train_state(model, opt, jax.random.key(0))
    step_fn = jax.jit(make_train_step(model, rules, opt),
                      in_shardings=(p_sh, o_sh, None),
                      out_shardings=(p_sh, o_sh, None))
    loader = TokenLoader(cfg.vocab_size, 4, 32, n_batches=100)
    params, opt_state, loss1 = train_some(params, opt_state, step_fn,
                                          loader, 5)
    cursor = len(loader.cursor()["done"])
    ckpt.save(ckdir, 5, (params, opt_state),
              meta={"step": 5, "cursor_done": cursor})
    print(f"  trained 5 steps (loss {loss1:.3f}), checkpointed at "
          f"cursor={cursor}")

    # phase 2: heartbeat declares a worker dead -> re-plan on less capacity
    hb = HeartbeatMonitor(timeout_s=0.0)
    hb.beat("worker-1")
    print(f"phase 2: heartbeat lost for {hb.dead() or {'worker-1'}} -> "
          "re-planning mesh")
    plan2 = plan_mesh(max(1, n_dev // 2))
    mesh2 = mesh_from_plan(plan2)
    print(f"  new mesh {plan2.shape} ({plan2.reason})")
    rules2 = ShardingRules(mesh2, cfg.sharding_mode)
    p_sh2 = tree_shardings(rules2, pspecs)
    o_sh2 = tree_shardings(rules2, ospecs)

    # phase 3: restore WITH resharding onto the new mesh + exact data resume
    like = jax.tree.map(lambda x: x, (params, opt_state))
    (params2, opt2), meta = ckpt.restore(ckdir, 5, like=like,
                                         shardings=(p_sh2, o_sh2))
    loader2 = TokenLoader(cfg.vocab_size, 4, 32, n_batches=100,
                          start_at=meta["cursor_done"])
    step_fn2 = jax.jit(make_train_step(model, rules2, opt),
                       in_shardings=(p_sh2, o_sh2, None),
                       out_shardings=(p_sh2, o_sh2, None))
    params2, opt2, loss2 = train_some(params2, opt2, step_fn2, loader2, 5)
    print(f"phase 3: restored at step {meta['step']}, resumed batches from "
          f"work-id {meta['cursor_done']}, trained 5 more steps "
          f"(loss {loss2:.3f})")
    print("elastic restart complete: no sample was lost or duplicated.")

    # phase 4: kill-and-resume for the preprocessing stream
    from repro.configs import SERF_AUDIO
    from repro.core.plans import Preprocessor
    from repro.data.loader import audio_batch_maker

    store = tempfile.mkdtemp(prefix="elastic_store_")
    make = audio_batch_maker(seed=0, batch_long_chunks=1)
    stream = [(w, make(w)) for w in range(4)]
    pre = Preprocessor(SERF_AUDIO, plan="cached", store=store, journal=True)
    gen = pre.run(stream)
    emitted = [next(gen).wid, next(gen).wid]
    gen.close()                        # the preprocessing run 'dies' here
    print(f"phase 4: cached preprocess run killed after emitting "
          f"chunks {emitted}")
    pre2 = Preprocessor(SERF_AUDIO, plan="cached", store=store,
                        journal=True, resume=True)
    rest = [r.wid for r in pre2.run(stream)]
    assert sorted(emitted + rest) == list(range(4))
    print(f"  --resume emitted {rest} (store: {pre2.plan.stats}): "
          f"each chunk exactly once across the kill.")


if __name__ == "__main__":
    main()
