"""Validation of the trip-count-aware HLO walker: scan-free graphs must
match an analytic count, and scanned graphs must match their unrolled
equivalents (which XLA's own cost_analysis undercounts)."""
import subprocess
import sys
import textwrap


def _run(body):
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=4'\n"
            "import jax, jax.numpy as jnp\n"
            "from repro.launch.hlo_analysis import analyze_hlo\n"
            + textwrap.dedent(body) + "\nprint('SUBPROC_OK')\n")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"}, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SUBPROC_OK" in out.stdout
    return out.stdout


def test_walker_counts_scan_trip_counts():
    _run("""
    L, E, B = 6, 128, 4
    w = jax.ShapeDtypeStruct((L, E, E), jnp.float32)
    x = jax.ShapeDtypeStruct((B, E), jnp.float32)

    def body(h, wl):
        return jnp.tanh(h @ wl), None

    def scanned(ws, h):
        h, _ = jax.lax.scan(body, h, ws)
        return h.sum()

    def unrolled(ws, h):
        for i in range(L):
            h, _ = body(h, ws[i])
        return h.sum()

    fs = analyze_hlo(jax.jit(scanned).lower(w, x).compile().as_text())
    fu = analyze_hlo(jax.jit(unrolled).lower(w, x).compile().as_text())
    expected = 2.0 * B * E * E * L
    assert abs(fs["dot_flops"] - expected) / expected < 0.05, fs
    assert abs(fu["dot_flops"] - expected) / expected < 0.05, fu
    # XLA's own counter misses the trip count on the scanned version
    ca = jax.jit(scanned).lower(w, x).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one dict per program
        ca = ca[0]
    assert ca["flops"] < 0.5 * expected
    """)


def test_walker_counts_collectives_inside_scan():
    _run("""
    from jax.sharding import PartitionSpec as P, NamedSharding
    try:                                # AxisType is newer-jax only
        from jax.sharding import AxisType
        mesh = jax.make_mesh((4,), ("model",), axis_types=(AxisType.Auto,))
    except ImportError:
        mesh = jax.make_mesh((4,), ("model",))
    L, E, B = 5, 64, 8
    w = jax.ShapeDtypeStruct((L, E, E), jnp.float32)
    x = jax.ShapeDtypeStruct((B, E), jnp.float32)

    def body(h, wl):
        h = h @ wl                      # wl col-sharded -> psum per layer
        h = jax.lax.with_sharding_constraint(
            h, NamedSharding(mesh, P(None, None)))
        return jnp.tanh(h), None

    def f(ws, h):
        h, _ = jax.lax.scan(body, h, ws)
        return h.sum()

    sh_w = NamedSharding(mesh, P(None, None, "model"))
    c = jax.jit(f, in_shardings=(sh_w, None)).lower(w, x).compile()
    agg = analyze_hlo(c.as_text())
    # at least L reduce/all-gather rounds of the (B,E) activation
    assert agg["coll_bytes"] >= L * B * E * 4 * 0.5, agg
    """)
