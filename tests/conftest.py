"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device flag belongs ONLY to launch/dryrun.py, per the brief).
Collective tests that need multiple devices spawn subprocesses."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
