"""repro.obs: metrics registry units, tracer + Chrome-trace schema,
durable telemetry (crash-safe reader, exactly-once acceptance records),
InProc vs Proc telemetry parity on the sharded plan, SIGKILL redelivery
attribution across worker incarnations, the ring caps that replaced the
unbounded in-memory ledgers, StoreStats mirroring, and the `metrics` RPC.
"""
import json
import os

import numpy as np
import pytest

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import TIMINGS_CAP, Preprocessor
from repro.obs import metrics as obs_metrics
from repro.obs import telemetry as obs_telemetry
from repro.obs import tracing as obs_tracing
from repro.obs.metrics import MetricsRegistry, NullRegistry, NULL_INSTRUMENT
from repro.obs.tracing import NULL_TRACER, Tracer, validate_chrome_trace
from repro.serve.batcher import BATCH_LOG_CAP, ContinuousBatcher
from repro.store.chunk_store import StoreStats


@pytest.fixture
def fresh_registry():
    """Swap in an isolated registry; restore the global one afterwards."""
    prev = obs_metrics.get_registry()
    reg = MetricsRegistry()
    obs_metrics.set_registry(reg)
    yield reg
    obs_metrics.set_registry(prev)


# ----------------------------------------------------------- metrics

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)                      # counters are monotonic
    g = reg.gauge("g")
    g.set(5)
    g.dec(2)
    assert g.value == 3
    h = reg.histogram("h_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 7.0):
        h.observe(v)
    snap = reg.snapshot()
    (series,) = snap["h_seconds"]["series"]
    assert series["count"] == 3 and series["sum"] == pytest.approx(7.55)
    assert series["buckets"]["0.1"] == 1        # cumulative
    assert series["buckets"]["1.0"] == 2
    assert series["buckets"]["+Inf"] == 3


def test_labeled_series_and_kind_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("rpc_total", labels=("method",))
    c.labels(method="lease").inc(2)
    c.labels(method="fetch").inc()
    snap = reg.snapshot()["rpc_total"]
    got = {tuple(s["labels"].items()): s["value"] for s in snap["series"]}
    assert got == {(("method", "lease"),): 2, (("method", "fetch"),): 1}
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("rpc_total")


def test_render_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("x_total", "things", ("kind",)).labels(kind="a").inc(2)
    reg.histogram("d_seconds", buckets=(1.0,)).observe(0.5)
    text = reg.render()
    assert '# TYPE x_total counter' in text
    assert 'x_total{kind="a"} 2' in text
    assert 'd_seconds_bucket{le="1.0"} 1' in text
    assert 'd_seconds_count 1' in text


def test_disabled_registry_is_null_and_mutation_gated():
    null = NullRegistry()
    assert null.counter("a") is NULL_INSTRUMENT
    assert null.snapshot() == {}
    reg = MetricsRegistry()
    c = reg.counter("a_total")
    reg.enabled = False                # toggled mid-run: live instruments
    c.inc(100)                         # must stop mutating too
    reg.enabled = True
    assert c.value == 0


def test_module_level_instruments_respect_enabled(fresh_registry):
    obs_metrics.counter("m_total").inc()
    assert obs_metrics.snapshot()["m_total"]["series"][0]["value"] == 1
    fresh_registry.enabled = False
    assert obs_metrics.counter("m_total") is NULL_INSTRUMENT


# ----------------------------------------------------------- tracing

def test_tracer_spans_nest_and_validate():
    t = Tracer()
    t.start_run("run")
    with t.span("outer", wid=1):
        with t.span("inner"):
            t.instant("mark", x=2)
    t.complete("work", start_s=1.0, end_s=2.0)
    t.async_begin("request", 7)
    t.async_end("request", 7)
    t.finish_run()
    data = t.chrome()
    counts = validate_chrome_trace(data)
    assert counts == {"B": 3, "E": 3, "i": 1, "X": 1, "b": 1, "e": 1}
    # every opener after start_run is parented under the run span
    run_span = t.trace_id + ":0"
    for ev in data["traceEvents"]:
        if ev["ph"] in ("B", "X", "i") and ev["name"] != "run":
            assert ev["args"]["parent"] == run_span


def test_trace_propagation_parents_child_events():
    parent = Tracer()
    parent.start_run("run")
    spec = parent.propagate()
    child = Tracer(**spec)             # the worker-process twin
    child.complete("compute", start_s=1.0, end_s=2.0, wid=0)
    parent.add_events(child.drain())
    parent.finish_run()
    evs = parent.chrome()["traceEvents"]
    (compute,) = [e for e in evs if e["name"] == "compute"]
    assert compute["args"]["parent"] == parent.trace_id + ":0"
    assert compute["args"]["trace"] == parent.trace_id
    validate_chrome_trace(evs)
    assert child.drain() == []         # drain pops


def test_validate_chrome_trace_rejects_bad_events():
    base = {"ts": 0, "pid": 1, "tid": 1}
    with pytest.raises(ValueError, match="missing"):
        validate_chrome_trace([{"ph": "B", **base}])        # no name
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome_trace([{"ph": "?", "name": "x", **base}])
    with pytest.raises(ValueError, match="without dur"):
        validate_chrome_trace([{"ph": "X", "name": "x", **base}])
    with pytest.raises(ValueError, match="closes"):
        validate_chrome_trace([{"ph": "B", "name": "a", **base},
                               {"ph": "E", "name": "b", **base}])
    with pytest.raises(ValueError, match="unclosed"):
        validate_chrome_trace([{"ph": "B", "name": "a", **base}])


def test_tracer_caps_events():
    t = Tracer(max_events=3)
    for i in range(5):
        t.instant(f"e{i}")
    assert len(t.events) == 3 and t.dropped == 2


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    with NULL_TRACER.span("x"):
        NULL_TRACER.instant("y")
    assert NULL_TRACER.propagate() is None
    assert NULL_TRACER.start_run() is None


# --------------------------------------------------------- telemetry

def test_telemetry_write_read_and_torn_tail(tmp_path):
    d = tmp_path / "t"
    with obs_telemetry.TelemetryWriter(d) as w:
        w.record(event="chunk", status="done", wid=0, worker="a",
                 survivors=3, accept_ts=1.0)
        w.record(event="chunk", status="done", wid=1, worker="b",
                 survivors=2, accept_ts=2.0)
    assert w.records_written == 2
    # a writer SIGKILLed mid-write leaves a torn trailing line: skipped
    with open(w.path, "a") as f:
        f.write('{"event":"chunk","status":"do')
    recs = obs_telemetry.read_records(str(d))
    assert [r["wid"] for r in recs] == [0, 1]
    led = obs_telemetry.worker_ledger(recs)
    assert led["a"]["chunks_done"] == 1 and led["a"]["survivors"] == 3
    assert led["b"]["first_accept_ts"] == 2.0
    chunks = obs_telemetry.chunk_ledger(recs)
    assert chunks[0]["done"] and chunks[0]["survivors"] == 3


def test_telemetry_torn_mid_file_raises(tmp_path):
    p = tmp_path / "x.jsonl"
    p.write_text('{"event":"chunk","wid":0}\n{"torn\n{"event":"chunk"}\n')
    with pytest.raises(ValueError):
        obs_telemetry.read_records(str(p))


def _sharded_stream(n_batches):
    from repro.data.loader import audio_batch_maker
    make = audio_batch_maker(seed=21, batch_long_chunks=1)
    return make, [(w, (make(w)[0], None)) for w in range(n_batches)]


@pytest.mark.parametrize("transport", ["inproc", "proc"])
def test_sharded_telemetry_exactly_once(transport, tmp_path):
    """Both transports must leave exactly ONE master-side 'done' record
    per chunk, attributing a real worker, with acceptance timestamps.
    The (wid, status, survivors) view is transport-invariant — the
    records describe the work, not the wire (timestamps, pids and
    content keys legitimately differ and are excluded)."""
    _, stream = _sharded_stream(2)
    d = tmp_path / transport
    with obs_telemetry.TelemetryWriter(d) as w:
        pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1,
                           transport=transport, telemetry=w)
        results = list(pre.run(stream))
    assert sorted(r.wid for r in results) == [0, 1]
    recs = obs_telemetry.read_records(str(d))
    done = [r for r in recs if r["status"] == "done"]
    assert sorted(r["wid"] for r in done) == [0, 1]
    by_wid = {r["wid"]: r for r in done}
    for r in results:
        rec = by_wid[r.wid]
        assert rec["survivors"] == int(r.n_kept)
        assert rec["worker"].startswith("shard")
        assert rec["accept_ts"] is not None
        assert rec["redelivered"] == 0


def test_proc_sigkill_leaves_redelivery_attribution(tmp_path):
    """A worker SIGKILLed while holding a lease must leave a durable
    'redelivered' record attributing the LOSING incarnation, and the
    eventual 'done' record must carry the redelivery count and the
    surviving worker — both attempts visible in one ledger."""
    from repro.data.loader import audio_batch_maker, make_shard_pool
    from repro.ft.failure import CrashInjector

    n_batches = 3
    make = audio_batch_maker(seed=3, batch_long_chunks=2)
    pool = make_shard_pool(make, n_batches, 2, lease_timeout_s=120.0)
    injector = CrashInjector()
    injector.kill(1, after_items=0)    # shard1 dies at its first grant
    d = tmp_path / "t"
    with obs_telemetry.TelemetryWriter(d) as w:
        pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1,
                           transport="proc", injector=injector,
                           telemetry=w)
        results = list(pre.run(pool))
    assert sorted(r.wid for r in results) == list(range(n_batches))
    assert pre.plan.redeliveries >= 1

    recs = obs_telemetry.read_records(str(d))
    done = {r["wid"]: r for r in recs if r["status"] == "done"}
    assert sorted(done) == list(range(n_batches))   # exactly once each
    redel = [r for r in recs if r["status"] == "redelivered"]
    assert redel, "no durable redelivery attribution"
    assert all(r["worker"] == "shard1" for r in redel)
    for r in redel:
        final = done[r["wid"]]
        assert final["redelivered"] >= 1
        assert final["worker"] == "shard0"          # the survivor won it
    led = obs_telemetry.worker_ledger(recs)
    assert led["shard1"]["redelivered_from"] >= 1
    assert led["shard0"]["chunks_done"] == n_batches


# ---------------------------------------------------------- ring caps

def test_batch_log_is_ring_capped():
    b = ContinuousBatcher(plan=lambda x: x, max_batch=1)
    assert b.batch_log.maxlen == BATCH_LOG_CAP
    for i in range(BATCH_LOG_CAP + 10):
        b.batch_log.append({"rids": [i]})
    assert len(b.batch_log) == BATCH_LOG_CAP
    assert b.batch_log[0]["rids"] == [10]           # oldest evicted


def test_async_plan_timings_ring_capped():
    pre = Preprocessor(cfg, plan="async", pad_multiple=1)
    assert pre.plan.last_timings.maxlen == TIMINGS_CAP


# ----------------------------------------------------- store mirroring

def test_store_stats_mirror_into_registry(fresh_registry):
    st = StoreStats(label="lake")
    st.hits += 2
    st.bytes_saved += 1000
    st.misses += 1
    assert (st.hits, st.misses, st.bytes_saved) == (2, 1, 1000)
    assert st.hit_rate == pytest.approx(2 / 3)
    snap = obs_metrics.snapshot()
    assert snap["store_hits_total"]["series"][0] == {
        "labels": {"store": "lake"}, "value": 2}
    assert snap["store_bytes_saved_total"]["series"][0]["value"] == 1000
    # disabled registry: plain attributes still work, nothing mirrored
    fresh_registry.enabled = False
    st.hits += 5
    assert st.hits == 7


def test_chunk_store_labels_stats_by_directory(tmp_path, fresh_registry):
    from repro.store import ChunkStore
    store = ChunkStore(tmp_path / "mystore")
    store.put("k1", {"a": np.zeros(4, np.float32)})
    assert store.get("k1", src_bytes=64) is not None
    snap = obs_metrics.snapshot()
    assert snap["store_hits_total"]["series"][0]["labels"] == {
        "store": "mystore"}
    assert snap["store_writes_total"]["series"][0]["value"] == 1


# ------------------------------------------------------- metrics RPC

def test_metrics_rpc_over_transport(fresh_registry):
    from repro.data.queue import WorkQueue
    from repro.dist.service import QueueService, RPC_METHODS
    from repro.dist.transport import InProcTransport

    assert "metrics" in RPC_METHODS
    q = WorkQueue(2, lease_timeout_s=60.0)
    svc = QueueService(q)
    proxy = InProcTransport().connect(svc)
    proxy.call("lease", "shard0", 1)
    snap = proxy.call("metrics")
    assert snap["dist_lease_calls_total"]["series"][0] == {
        "labels": {"worker": "shard0"}, "value": 1}
    json.dumps(snap)                   # the RPC payload is JSON-safe
    text = proxy.call("metrics", render=True)
    assert 'dist_lease_calls_total{worker="shard0"} 1' in text


def test_redelivery_counter_fires_without_telemetry(fresh_registry):
    from repro.data.queue import SettableClock, WorkQueue
    from repro.dist.service import QueueService

    clock = SettableClock()
    q = WorkQueue(2, lease_timeout_s=10.0, clock=clock)
    QueueService(q)                    # attaches on_redeliver, no writer
    q.lease("w0", 2)
    clock.t = 11.0
    q.lease("w1", 1)                   # reaps w0's expired leases first
    snap = obs_metrics.snapshot()
    series = snap["dist_redeliveries_total"]["series"]
    (s,) = [s for s in series if s["labels"]["worker"] == "w0"]
    assert s["labels"]["reason"] == "expired" and s["value"] == 2
