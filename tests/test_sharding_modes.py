"""The hillclimbed sharding modes (zero3, sp_ep) must produce the same math
as unsharded execution — verified on a 4-device CPU mesh in a subprocess."""
import subprocess
import sys
import textwrap

from repro.distributed.sharding import _TABLES


def test_mode_tables_well_formed():
    for mode in ("tp", "fsdp_tp", "zero3", "sp_ep"):
        t = _TABLES[mode]
        for k, v in t.items():
            assert isinstance(v, tuple), (mode, k)
        # zero3/sp_ep must not double-map the model axis in one spec
        if mode == "zero3":
            assert t["act_ff"] == () and t["batch"][-1] == "model"
        if mode == "sp_ep":
            assert t["seq"] == ("model",) and t["act_ff"] == ()


def _run(body):
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=4'\n"
            + textwrap.dedent(body) + "\nprint('SUBPROC_OK')\n")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=500,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"}, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SUBPROC_OK" in out.stdout


def test_zero3_and_sp_ep_match_unsharded_loss():
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ARCHS, reduced
    from repro.launch.mesh import _make_mesh
    from repro.models.zoo import build_model
    from repro.distributed.sharding import (ShardingRules, tree_shardings,
                                            NULL_RULES)
    mesh = _make_mesh((2, 2), ("data", "model"))
    for arch, mode in [("llama3.2-3b", "zero3"),
                       ("granite-moe-3b-a800m", "sp_ep")]:
        cfg = dataclasses.replace(reduced(ARCHS[arch]), dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(1), (4, 32), 0,
                                    cfg.vocab_size)
        batch = {"tokens": tokens, "targets": tokens}
        ref_loss, _ = jax.jit(
            lambda p, b: model.loss_fn(p, b, NULL_RULES))(params, batch)
        rules = ShardingRules(mesh, mode)
        p_sh = tree_shardings(rules, model.param_specs())
        with mesh:
            loss, _ = jax.jit(
                lambda p, b: model.loss_fn(p, b, rules),
                in_shardings=(p_sh, {"tokens": rules.sharding("batch", None),
                                     "targets": rules.sharding("batch",
                                                               None)}))(
                params, batch)
        assert abs(float(loss) - float(ref_loss)) < 2e-3, (
            arch, mode, float(loss), float(ref_loss))
    """)
