"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + no NaNs; prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.distributed.sharding import NULL_RULES as R
from repro.models.zoo import build_model

B, S = 2, 32


def _batch(cfg, key=1):
    tokens = jax.random.randint(jax.random.key(key), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.num_prefix_tokens:
        batch["prefix"] = jax.random.normal(
            jax.random.key(2), (B, cfg.num_prefix_tokens, cfg.d_model))
    if cfg.is_enc_dec:
        batch["enc_frames"] = jax.random.normal(
            jax.random.key(3), (B, 16, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)

    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        lambda p: model.loss_fn(p, batch, R), has_aux=True))(params)
    assert np.isfinite(float(loss))
    assert 2.0 < float(loss) < 12.0            # ~ln(vocab) at init
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_prefill_decode(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, caches = jax.jit(lambda p, b: model.prefill(p, b, R))(
        params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        kw = {"enc_len": 16} if cfg.is_enc_dec else {}
        cache = model.init_cache(B, S, **kw)
    else:
        cache = model.init_cache(B)
    tok = batch["tokens"][:, 0]
    dlogits, cache2 = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, 3, R))(params, cache, tok)
    assert dlogits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(dlogits)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma-7b",
                                  "granite-moe-3b-a800m", "zamba2-1.2b",
                                  "xlstm-125m", "whisper-small",
                                  "paligemma-3b"])
def test_decode_matches_prefill(arch):
    """serve_step correctness: decoding token t against the prefill cache of
    tokens[:t] reproduces prefill(tokens[:t+1])'s next-token logits."""
    cfg = dataclasses.replace(reduced(ARCHS[arch]), dtype="float32",
                              moe_capacity_factor=16.0)   # dropless: decode
    # has no capacity drops, so prefill must not drop either to compare
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    full = _batch(cfg)
    k = S - 1
    prefix_batch = dict(full)
    prefix_batch["tokens"] = full["tokens"][:, :k]
    logits_ref, _ = jax.jit(lambda p, b: model.prefill(p, b, R))(params, full)

    _, pf_caches = jax.jit(lambda p, b: model.prefill(p, b, R))(
        params, prefix_batch)
    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        kw = {"enc_len": 16} if cfg.is_enc_dec else {}
        caches = model.init_cache(B, S + (cfg.num_prefix_tokens or 0), **kw)
        for key in pf_caches:
            if key in ("k", "v", "xk", "xv"):
                pad = [(0, 0)] * pf_caches[key].ndim
                pad[2] = (0, caches[key].shape[2] - pf_caches[key].shape[2])
                caches[key] = jnp.pad(pf_caches[key], pad).astype(
                    caches[key].dtype)
            else:
                caches[key] = pf_caches[key]
    else:
        caches = pf_caches
    pos = (cfg.num_prefix_tokens or 0) + k
    tok = full["tokens"][:, k]
    logits_dec, _ = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, pos, R))(
            params, caches, tok)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_ref), rtol=5e-3, atol=5e-3)


def test_moe_balance_metrics_exposed():
    cfg = reduced(ARCHS["granite-moe-3b-a800m"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    loss, metrics = jax.jit(
        lambda p, b: model.loss_fn(p, b, R))(params, _batch(cfg))
    assert "lb_loss" in metrics and "dropped_frac" in metrics
    assert float(metrics["dropped_frac"]) < 0.5


def test_vocab_padding_masked_in_loss():
    """Padded vocab rows must never receive probability mass."""
    cfg = reduced(ARCHS["whisper-small"])          # vocab 512 stays unpadded
    assert cfg.padded_vocab == cfg.vocab_size
    full = ARCHS["granite-moe-3b-a800m"]
    assert full.padded_vocab % 256 == 0
    assert full.padded_vocab >= full.vocab_size
