"""The serving subsystem: StandingWorkQueue semantics, the persistent
WorkerPool (bit-identity vs two_phase, warm waves, gauges, SIGKILL
redelivery on real processes), the ContinuousBatcher (linger-bounded
partial batches, deadlines, admission control, pow2 occupancy), and the
service-level satellites (zero-padded pumps, result() popping, cached
warm hits short-circuiting the pool)."""
import threading
import time

import numpy as np
import pytest

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.loader import audio_batch_maker
from repro.data.queue import SettableClock, StandingWorkQueue
from repro.serve import (AdmissionError, ContinuousBatcher,
                        PreprocessService, WorkerPool)

make = audio_batch_maker(seed=23, batch_long_chunks=1)
CHUNKS = [make(w)[0][0] for w in range(8)]      # (2, S_long) requests
REF = Preprocessor(cfg, plan="two_phase", pad_multiple=1)


def ref_sliced(chunks, rows):
    """Reference per-request records: the zero-padded batch through
    two_phase, sliced exactly as the serving layers slice."""
    batch = np.stack(chunks)
    if rows > len(chunks):
        batch = np.concatenate([batch, np.zeros(
            (rows - len(chunks),) + batch.shape[1:], np.float32)])
    res = REF(batch)
    keep = np.asarray(res.det.keep)
    per = keep.size // rows
    offs = np.concatenate([[0], np.cumsum(keep)]).astype(int)
    out = []
    for j in range(len(chunks)):
        lo, hi = j * per, (j + 1) * per
        out.append({"keep": keep[lo:hi],
                    "cleaned": res.cleaned[offs[lo]:offs[hi]]})
    return out


# ------------------------------------------------- standing queue

def test_standing_queue_open_ended_fifo_and_close():
    q = StandingWorkQueue(lease_timeout_s=60.0)
    assert not q.finished                 # empty but OPEN: workers poll
    a, b = q.add(), q.add()
    assert q.lease("w", 1) == [a], "standing queue must lease FIFO"
    assert q.lease("w", 2) == [b]
    assert q.depth() == (0, 2)
    q.complete([a, b])
    assert not q.finished                 # drained but still open
    c = q.add()
    q.close()
    with pytest.raises(RuntimeError):
        q.add()                           # closed to new work
    assert not q.finished                 # c outstanding
    q.lease("w", 1)
    q.complete([c])
    assert q.finished

def test_standing_queue_redelivery_beats_new_traffic():
    clock = SettableClock()
    q = StandingWorkQueue(lease_timeout_s=5.0, clock=clock)
    old = q.add()
    assert q.lease("dead", 1) == [old]
    clock.t = 6.0                         # the lease expires
    new = q.add()
    assert q.lease("live", 1) == [old], \
        "a redelivered request must go to the front of the line"
    assert q.lease("live", 1) == [new]

def test_standing_queue_abort_unblocks_workers():
    q = StandingWorkQueue()
    q.add()
    q.abort()
    assert q.finished                     # workers exit without draining


# ------------------------------------------------- worker pool (inproc)

def test_pool_waves_bit_identical_and_exactly_once():
    """Three consecutive submit waves through ONE pool: every result
    bit-identical to a direct two_phase call on the same batch, each wid
    resolved exactly once, ledger/gauges consistent."""
    with WorkerPool(cfg, workers=2, transport="inproc",
                    poll_s=0.002) as pool:
        seen = set()
        for wave in range(3):
            batches = {pool.submit(np.stack(CHUNKS[2 * k:2 * k + 2])):
                       CHUNKS[2 * k:2 * k + 2] for k in range(2)}
            got = pool.wait(list(batches), timeout_s=300.0)
            assert sorted(got) == sorted(batches)
            assert not seen & got.keys(), "a wid resolved twice"
            seen |= got.keys()
            for wid, res in got.items():
                want = REF(np.stack(batches[wid]))
                np.testing.assert_array_equal(np.asarray(res.det.keep),
                                              np.asarray(want.det.keep))
                np.testing.assert_array_equal(res.cleaned, want.cleaned)
                assert res.n_kept == want.n_kept
        g = pool.gauges()
        assert g["completed"] == g["submitted"] == 6
        assert g["queue_depth"] == 0 and g["oldest_age_s"] is None
        assert sum(s.chunks_done for s in pool.worker_stats) == 6

def test_pool_gauges_show_backlog():
    pool = WorkerPool(cfg, workers=1, transport="inproc", poll_s=0.002)
    # not started: submissions queue up and age
    pool.submit(np.stack(CHUNKS[:1]))
    pool.submit(np.stack(CHUNKS[1:2]))
    g = pool.gauges()
    assert g["queue_depth"] + g["in_flight"] == 2
    assert g["oldest_age_s"] >= 0.0 and g["completed"] == 0
    pool.start()
    pool.drain(timeout_s=300.0)
    assert pool.gauges()["queue_depth"] == 0
    pool.shutdown()


# ------------------------------------------------- continuous batcher

def _sync_batcher(**kw):
    """Batcher over the in-process plan (no pool): deterministic
    single-threaded dispatch for policy tests."""
    return ContinuousBatcher(plan=REF, **kw)

def test_batcher_full_batch_dispatches_immediately():
    clock = SettableClock()
    b = _sync_batcher(max_batch=2, linger_s=10.0, clock=clock)
    r0, r1 = b.submit(CHUNKS[0]), b.submit(CHUNKS[1])
    done = b.pump()                       # full batch: no linger wait
    assert sorted(done) == [r0, r1]
    want = ref_sliced(CHUNKS[:2], 2)
    for j, rid in enumerate((r0, r1)):
        rec = b.result(rid)
        assert rec["ok"]
        np.testing.assert_array_equal(rec["keep"], want[j]["keep"])
        np.testing.assert_array_equal(rec["cleaned"], want[j]["cleaned"])
        assert b.result(rid) is None      # popped: exactly once

def test_batcher_partial_batch_after_linger_zero_padded():
    clock = SettableClock()
    b = _sync_batcher(max_batch=4, linger_s=0.5, clock=clock)
    rids = [b.submit(c) for c in CHUNKS[:3]]
    assert b.pump() == []                 # partial + linger not elapsed
    clock.t = 0.6
    done = b.pump()                       # linger elapsed: serve partial
    assert sorted(done) == sorted(rids)
    (entry,) = b.batch_log
    assert entry["n_real"] == 3 and entry["rows"] == 4  # pow2 bucket,
    want = ref_sliced(CHUNKS[:3], 4)                    # zero-padded
    for j, rid in enumerate(rids):
        rec = b.result(rid)
        assert rec["ok"]
        np.testing.assert_array_equal(rec["keep"], want[j]["keep"])
        np.testing.assert_array_equal(rec["cleaned"], want[j]["cleaned"])

def test_batcher_pow2_occupancy_buckets():
    clock = SettableClock()
    b = _sync_batcher(max_batch=8, linger_s=0.0, clock=clock)
    for n, rows in ((3, 4), (5, 8), (8, 8)):
        for c in CHUNKS[:n]:
            b.submit(c)
        b.pump()
        assert b.batch_log[-1]["rows"] == rows

def test_batcher_deadline_expired_fails_and_never_dispatches():
    clock = SettableClock()
    b = _sync_batcher(max_batch=4, linger_s=0.2, clock=clock)
    doomed = b.submit(CHUNKS[0], timeout_s=0.1)
    live = b.submit(CHUNKS[1])
    clock.t = 0.3                         # doomed expired, linger passed
    done = b.pump()
    assert sorted(done) == [doomed, live]
    rec = b.result(doomed)
    assert rec == {"ok": False, "error": "deadline",
                   "waited_s": pytest.approx(0.3)}
    assert b.result(doomed) is None
    assert all(doomed not in e["rids"] for e in b.batch_log), \
        "an expired request reached a dispatched batch"
    assert b.result(live)["ok"]
    assert b.expired == 1

def test_batcher_late_result_not_served_stale():
    """A request whose deadline passes while its batch computes is
    failed at delivery: stale results are dropped, not served."""
    clock = SettableClock()

    class SlowPlan:
        def __call__(self, batch):
            clock.t += 10.0               # the batch "takes" 10 s
            return REF(batch)

    b = ContinuousBatcher(plan=SlowPlan(), max_batch=2, linger_s=0.0,
                          clock=clock)
    rid = b.submit(CHUNKS[0], timeout_s=5.0)
    ok_rid = b.submit(CHUNKS[1])          # no deadline: still served
    b.pump()
    assert b.result(rid)["ok"] is False
    assert b.result(ok_rid)["ok"] is True

def test_batcher_admission_control_backpressure():
    b = _sync_batcher(max_batch=4, max_queue=2, linger_s=10.0,
                      clock=SettableClock())
    b.submit(CHUNKS[0])
    b.submit(CHUNKS[1])
    with pytest.raises(AdmissionError):
        b.submit(CHUNKS[2])
    assert b.rejected == 1


# ------------------------------------------------- pool + batcher + service

def test_batcher_over_pool_concurrent_clients():
    """4 client threads against a 2-worker inproc pool with the pump on
    a background thread: every request resolves exactly once,
    bit-identical to the reference slicing of its logged batch."""
    with WorkerPool(cfg, workers=2, transport="inproc",
                    poll_s=0.002) as pool:
        b = ContinuousBatcher(pool=pool, max_batch=4, linger_s=0.01)
        chunks_by_rid, records, lock = {}, {}, threading.Lock()

        def client(cid):
            for i in range(2):
                c = CHUNKS[(cid * 2 + i) % len(CHUNKS)]
                rid = b.submit(c)
                with lock:
                    chunks_by_rid[rid] = c
                rec = b.wait(rid, timeout_s=300.0)
                with lock:
                    records[rid] = rec

        with b:
            ts = [threading.Thread(target=client, args=(c,))
                  for c in range(4)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        assert len(records) == 8 and all(r["ok"] for r in records.values())
        for e in b.batch_log:
            want = ref_sliced([chunks_by_rid[r] for r in e["rids"]],
                              e["rows"])
            for j, rid in enumerate(e["rids"]):
                np.testing.assert_array_equal(records[rid]["keep"],
                                              want[j]["keep"])
                np.testing.assert_array_equal(records[rid]["cleaned"],
                                              want[j]["cleaned"])

def test_service_zero_pads_and_pops_results():
    svc = PreprocessService(cfg, batch_long_chunks=4)
    rids = [svc.submit(c) for c in CHUNKS[:3]]
    assert sorted(svc.pump()) == sorted(rids)
    want = ref_sliced(CHUNKS[:3], 4)      # zero-padded to the batch size
    for j, rid in enumerate(rids):
        rec = svc.result(rid)
        np.testing.assert_array_equal(rec["keep"], want[j]["keep"])
        np.testing.assert_array_equal(rec["cleaned"], want[j]["cleaned"])
        assert svc.result(rid) is None    # popped: bounded result map

def test_service_pool_path_and_cached_short_circuit(tmp_path):
    """PreprocessService(pool=...): pumps go to the pool's persistent
    workers; with a cached plan, a repeated batch is served from the
    store WITHOUT touching a worker."""
    with WorkerPool(cfg, workers=1, transport="inproc",
                    poll_s=0.002) as pool:
        svc = PreprocessService(cfg, plan="cached", store=str(tmp_path),
                                batch_long_chunks=2, pool=pool)
        rids = [svc.submit(c) for c in CHUNKS[:2]]
        svc.pump()
        miss = {rid: svc.result(rid) for rid in rids}
        n_after_miss = pool.queue.n_items
        assert n_after_miss == 1          # the miss went to the pool
        rids2 = [svc.submit(c) for c in CHUNKS[:2]]
        svc.pump()
        assert pool.queue.n_items == n_after_miss, \
            "a cached warm hit touched a worker"
        assert svc.cache_stats.hits == 1
        want = ref_sliced(CHUNKS[:2], 2)
        for j, (rid, rid2) in enumerate(zip(rids, rids2)):
            hit = svc.result(rid2)
            np.testing.assert_array_equal(miss[rid]["keep"],
                                          want[j]["keep"])
            np.testing.assert_array_equal(hit["keep"], want[j]["keep"])
            np.testing.assert_array_equal(hit["cleaned"],
                                          want[j]["cleaned"])
        assert sum(s.chunks_done for s in svc.worker_stats) == 1


# ------------------------------------------------- proc-mode chaos

@pytest.mark.slow
def test_pool_proc_sigkill_redelivered_exactly_once():
    """A 2-proc-worker pool with shard0 SIGKILLed the moment its first
    lease is granted: the in-flight request is redelivered to the
    survivor exactly once, results stay bit-identical, and the pool
    reports the dead worker's reclaimed lease."""
    from repro.ft.failure import CrashInjector

    pool = WorkerPool(cfg, workers=2, transport="proc", respawn=False,
                      poll_s=0.01).start()
    try:
        injector = CrashInjector()
        injector.kill(0, after_items=0)
        injector.attach(0, pool.pids[0])
        pool.service.on_grant = lambda worker, wid: injector.on_pull(
            pool.service.workers[worker].shard)
        batches = {pool.submit(np.stack(CHUNKS[2 * k:2 * k + 2])):
                   CHUNKS[2 * k:2 * k + 2] for k in range(3)}
        got = pool.wait(list(batches), timeout_s=420.0)
        assert sorted(got) == sorted(batches)
        assert injector.crashed == frozenset({0})
        assert pool.queue.redeliveries >= 1
        assert pool.queue.redelivered_from["shard0"] >= 1
        assert list(pool.pids) == [1], "only shard1 should survive"
        for wid, res in got.items():
            want = REF(np.stack(batches[wid]))
            np.testing.assert_array_equal(np.asarray(res.det.keep),
                                          np.asarray(want.det.keep))
            np.testing.assert_array_equal(res.cleaned, want.cleaned)
    finally:
        pool.shutdown(drain=False)
