"""End-to-end behaviour tests for the paper's system: serving engine, data
loaders, and the early-exit economics that are the paper's headline claim."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SERF_AUDIO, reduced
from repro.data.loader import AudioChunkLoader, TokenLoader
from repro.models.zoo import build_model
from repro.serve.engine import ServeEngine, RequestQueue


def test_serve_engine_greedy_deterministic():
    cfg = reduced(ARCHS["llama3.2-3b"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_seq=48)
    prompts = np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 8))
    a = eng.generate(prompts, 6)
    b = eng.generate(prompts, 6)
    np.testing.assert_array_equal(a, b)
    assert (a < cfg.vocab_size).all()


def test_request_queue_serves_all():
    cfg = reduced(ARCHS["xlstm-125m"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_seq=32)
    q = RequestQueue(eng, batch_size=3, prompt_len=8, n_tokens=4)
    rng = np.random.RandomState(1)
    rids = [q.submit(rng.randint(0, cfg.vocab_size, 8)) for _ in range(5)]
    done = {}
    while len(done) < len(rids):
        for r in q.pump():
            done[r] = q.result(r)
    for r in rids:
        assert done[r].shape == (4,)
        assert q.result(r) is None   # popped: handed over exactly once


def test_token_loader_deterministic_resume():
    mk = lambda start: TokenLoader(512, 2, 16, n_batches=5, seed=3,  # noqa
                                   start_at=start)
    full = {wid: b["tokens"].copy() for wid, b in mk(0)}
    resumed = {wid: b["tokens"].copy() for wid, b in mk(3)}
    assert sorted(resumed) == [3, 4]
    for wid in resumed:
        np.testing.assert_array_equal(full[wid], resumed[wid])


def test_audio_loader_shapes():
    loader = AudioChunkLoader(seed=0, n_batches=2, batch_long_chunks=2)
    items = list(loader)
    assert len(items) == 2
    chunks, labels = items[0][1]
    assert chunks.shape[0] == 2 and chunks.shape[1] == 2
    assert chunks.shape[2] == 12 * int(5.0 * 44_100)
    assert labels.shape == (2 * 12,)


def test_early_exit_saves_mmse_work():
    """The paper's headline economy: MMSE runs on survivors only. Verify the
    survivor fraction is materially < 1 on a rainy/silent stream."""
    from repro.core.plans import Preprocessor
    from repro.data.synthetic import generate_labelled
    audio, labels = generate_labelled(
        11, 4 * 12, segment_s=5.0, label_probs=(0.2, 0.4, 0.05, 0.35))
    S5 = audio.shape[-1]
    chunks = (audio.reshape(4, 12, 2, S5).transpose(0, 2, 1, 3)
              .reshape(4, 2, 12 * S5))
    det = Preprocessor(SERF_AUDIO).detect(jnp.asarray(chunks))
    frac_kept = float(det.stats["frac_kept"])
    assert frac_kept < 0.7          # the early exit is doing real work
    assert frac_kept > 0.05         # ... without deleting everything
