"""The master/worker runtime: WorkQueue thread-safety under a served-queue
load (8 threads, forced expiries), the QueueService RPC surface + per-worker
ledger, the worker runtime driven in-process over InProcTransport, and the
acceptance parity — the same seeded stream through InProcTransport vs
ProcTransport at shards {1, 2, 4} must yield bit-identical masks and
cleaned audio in identical emission order."""
import collections
import random
import threading
import time

import numpy as np
import pytest

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.loader import audio_batch_maker, make_shard_pool
from repro.data.queue import WorkQueue
from repro.dist.service import QueueService, RPC_METHODS
from repro.dist.transport import InProcTransport, RemoteError
from repro.dist.worker import run_worker


# ------------------------------------------------- queue thread-safety

def test_workqueue_thread_hammer_no_lost_or_dup():
    """8 threads lease/complete/fail against ONE queue with a 20 ms lease
    timeout and scripted over-deadline sleeps, so expiry reaps race live
    completes. Exactly-once accounting must survive: every id retired
    once, none lost, none retired twice (the newly-retired return value is
    the dedup gate)."""
    n = 400
    q = WorkQueue(n, lease_timeout_s=0.02)
    retired = collections.Counter()
    lock = threading.Lock()
    errors = []

    def worker(tid):
        rng = random.Random(1000 + tid)
        name = f"w{tid}"
        try:
            while not q.finished:
                ids = q.lease(name, rng.randint(1, 4))
                if not ids:
                    time.sleep(0.001)
                    continue
                if rng.random() < 0.2:
                    time.sleep(0.03)      # blow the deadline: forced expiry
                if rng.random() < 0.05:
                    q.fail_worker(name)   # chaos: drop own live leases
                newly = q.complete(ids)
                with lock:
                    retired.update(newly)
        except Exception as e:            # pragma: no cover - must not fire
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors
    assert q.finished
    assert sorted(retired) == list(range(n)), "lost work ids"
    assert max(retired.values()) == 1, "a work id was retired twice"
    assert q.redeliveries >= 1, "the hammer never forced a redelivery"
    assert sum(q.redelivered_from.values()) == q.redeliveries


# --------------------------------------------------- service + transport

def test_queue_service_ledger_and_grant_hook():
    q = WorkQueue(4, lease_timeout_s=60.0)
    svc = QueueService(q)
    granted = []
    svc.on_grant = lambda worker, wid: granted.append((worker, wid))
    assert svc.hello("shard0", pid=123, shard=0) == {}
    assert svc.lease("shard0", 3) == [0, 1, 2]
    assert granted == [("shard0", 0), ("shard0", 1), ("shard0", 2)]
    assert svc.complete([0]) == [0]
    assert svc.complete([0]) == []          # the exactly-once gate
    svc.push_result("shard0", 1, {"x": 1})
    assert svc.pop_results() == [("shard0", 1, {"x": 1})]
    assert svc.pop_results() == []
    assert not svc.finished
    assert svc.progress() == (1, 4)
    (st,) = svc.worker_report()
    assert (st.pid, st.shard) == (123, 0)
    assert st.lease_calls == 1 and st.leased_total == 3
    assert st.chunks_done == 0     # a push is not credit — acceptance is
    svc.note_done("shard0")        # (the master's completion gate calls it)
    assert svc.worker_report()[0].chunks_done == 1
    assert st.leases_held == 2              # ids 1, 2 still registered
    assert st.last_beat_age_s is not None


def test_inproc_transport_serves_only_the_rpc_surface():
    q = WorkQueue(2)
    svc = QueueService(q)
    proxy = InProcTransport().connect(svc)
    assert proxy.call("lease", "w", 1) == [0]
    assert proxy.call("finished") is False  # property, dispatched plainly
    assert proxy.call("complete", [0]) == [0]
    for method in ("pop_results", "worker_report", "queue", "on_grant"):
        assert method not in RPC_METHODS
        with pytest.raises(RemoteError):
            proxy.call(method)


def test_sharded_plan_rejects_unknown_transport():
    with pytest.raises(ValueError, match="transport"):
        Preprocessor(cfg, plan="sharded", shards=2, transport="carrier-pigeon")


# ----------------------------------------------------- worker runtime

def test_worker_runtime_inproc_round_trip():
    """Drive the REAL worker loop (lease -> fetch -> detect+tail -> push)
    in-process over InProcTransport; the master completes what came back.
    Results must match the two_phase reference bit-for-bit — the worker
    runtime is the same computation, reached over the wire protocol."""
    n = 2
    make = audio_batch_maker(seed=9, batch_long_chunks=1)
    setup = {"cfg": cfg, "stages": None, "source_channels": 2,
             "pad_multiple": 1, "bucket": "linear", "backend_mode": "auto"}
    q = WorkQueue(n, lease_timeout_s=60.0)
    svc = QueueService(q, fetch_item=lambda wid: make(wid)[0], setup=setup)
    stats = run_worker(svc, shard=0, lease_items=2,
                       transport=InProcTransport(), max_items=n)
    assert stats["chunks"] == n
    got = {wid: payload for _, wid, payload in svc.pop_results()}
    assert sorted(got) == list(range(n))
    assert q.complete(sorted(got)) == list(range(n))
    svc.note_done("shard0", n)     # master-side acceptance credit
    assert q.finished
    ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    for wid, payload in got.items():
        want = ref(make(wid)[0])
        np.testing.assert_array_equal(payload["keep"],
                                      np.asarray(want.det.keep))
        np.testing.assert_array_equal(payload["cleaned"], want.cleaned)
        assert payload["n_kept"] == want.n_kept
    (st,) = svc.worker_report()
    assert st.chunks_done == n and st.lease_calls == 1  # one round-trip


def test_worker_skips_stale_fetch():
    """A fetch that answers None (the id completed — possibly emitted and
    released — while this redelivered lease was in flight) is skipped:
    no compute, no push, no crash. This is the recovery path for a lease
    that expired mid-compile and lost the redelivery race."""
    make = audio_batch_maker(seed=9, batch_long_chunks=1)
    q = WorkQueue(2, lease_timeout_s=60.0)
    setup = {"cfg": cfg, "stages": None, "source_channels": 2,
             "pad_multiple": 1, "bucket": "linear", "backend_mode": "auto"}
    svc = QueueService(
        q, setup=setup,
        fetch_item=lambda wid: None if wid == 0 else make(wid)[0])
    stats = run_worker(svc, shard=0, lease_items=2,
                       transport=InProcTransport(), max_items=1)
    assert stats["chunks"] == 1            # wid 0 skipped, wid 1 computed
    results = svc.pop_results()
    assert [wid for _, wid, _ in results] == [1]


# --------------------------------------------------- transport parity

def _stream(n_batches):
    make = audio_batch_maker(seed=21, batch_long_chunks=1)
    return [(w, (make(w)[0], None)) for w in range(n_batches)]


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_transport_parity_bit_identical(shards, tmp_path):
    """Acceptance: the same seeded stream through the in-proc simulated
    transport, through REAL worker processes over loopback, and through
    the TCP transport with the STORE data plane (chunk bytes via a shared
    ChunkStore, the socket carrying only keys) yields bit-identical keep
    masks, bit-identical cleaned audio, and identical emission order."""
    stream = _stream(3)
    runs = {}
    for transport in ("inproc", "proc", "tcp"):
        kw = ({"data_plane": str(tmp_path / "dp")}
              if transport == "tcp" else {})
        pre = Preprocessor(cfg, plan="sharded", shards=shards,
                           pad_multiple=1, transport=transport, **kw)
        results = list(pre.run(list(stream)))
        runs[transport] = results
        assert sorted(r.wid for r in results) == [0, 1, 2]
    orders = [[r.wid for r in rs] for rs in runs.values()]
    assert all(o == orders[0] for o in orders), \
        f"emission order diverged: {orders}"
    for other in ("proc", "tcp"):
        for a, b in zip(runs["inproc"], runs[other]):
            assert a.wid == b.wid
            np.testing.assert_array_equal(np.asarray(a.det.keep),
                                          np.asarray(b.det.keep))
            np.testing.assert_array_equal(a.cleaned, b.cleaned)
            assert a.n_kept == b.n_kept
            assert a.src_bytes == b.src_bytes
