"""End-to-end pipeline behaviour on the synthetic labelled stream: detector
quality (the paper's Tables 4-6 axes), early-exit bookkeeping, and fused vs
two-phase equivalence — all through the Preprocessor facade."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.synthetic import generate_labelled, LABELS


@pytest.fixture(scope="module")
def stream():
    n_long = 10
    audio, labels = generate_labelled(7, n_long * 12, segment_s=5.0)
    S5 = audio.shape[-1]
    chunks = (audio.reshape(n_long, 12, 2, S5).transpose(0, 2, 1, 3)
              .reshape(n_long, 2, 12 * S5))
    det = Preprocessor(cfg).detect(jnp.asarray(chunks))
    return chunks, labels, det


def _frac(mask, names, label):
    sel = names == label
    return mask[sel].mean() if sel.any() else np.nan


def test_detector_quality(stream):
    _, labels, det = stream
    names = np.array(LABELS)[labels]
    rain = np.asarray(det.rain)
    sil = np.asarray(det.silence)
    keep = np.asarray(det.keep)
    # rain mostly removed by the rain filter (paper Table 5 ballpark);
    # residual rain may be caught by the silence filter (paper notes this)
    assert _frac(rain, names, "rain") > 0.6
    assert _frac(rain | sil, names, "rain") > 0.85
    # no bird audio falsely removed (paper: "never removed very clear calls")
    assert _frac(keep, names, "bird") > 0.95
    assert _frac(keep, names, "cicada") > 0.95
    # silence mostly removed
    assert _frac(sil, names, "silence") > 0.6
    # keep = ~rain & ~silence exactly
    np.testing.assert_array_equal(keep, ~(rain | sil))


def test_cicada_band_removal_reduces_band_energy(stream):
    chunks, labels, det = stream
    cic = np.asarray(det.cicada15)
    if not cic.any():
        pytest.skip("no cicada chunk in sample")
    # energy in the cicada band after filtering should drop vs raw chunks
    from repro.core import stages as S
    x = S.to_mono(jnp.asarray(chunks))
    x = S.compress(x, cfg)
    c15 = S.split(x, 4)
    _, praw = S.stft_chunks(c15, cfg)
    wave5 = np.asarray(det.wave5)
    w15 = wave5.reshape(-1, 3 * wave5.shape[-1])
    _, pflt = S.stft_chunks(jnp.asarray(w15), cfg)
    from repro.core.indices import band_energy_ratio
    raw_ratio = np.asarray(band_energy_ratio(praw, *cfg.cicada_band_hz))
    flt_ratio = np.asarray(band_energy_ratio(pflt, *cfg.cicada_band_hz))
    assert (flt_ratio[cic] < raw_ratio[cic] - 0.1).all()


def test_two_phase_matches_fused_on_survivors(stream):
    chunks, _, det = stream
    x = jnp.asarray(chunks[:4])
    fused = Preprocessor(cfg, plan="fused")(x)
    two = Preprocessor(cfg, plan="two_phase", pad_multiple=1)(x)
    keep = np.asarray(two.det.keep)
    np.testing.assert_array_equal(keep, np.asarray(fused.det.keep))
    want = np.asarray(fused.det.wave5)[keep]
    np.testing.assert_allclose(two.cleaned, want, rtol=1e-4, atol=1e-5)
    assert two.n_kept == keep.sum()


def test_seed_shims_are_gone():
    """The deprecated seed entry points were deleted once nothing imported
    them (ROADMAP); only the graph re-exports remain."""
    import repro.core.pipeline as pipeline
    for name in ("detection_phase", "mmse_phase", "preprocess_fused",
                 "preprocess_two_phase"):
        assert not hasattr(pipeline, name)
    assert pipeline.PipelineGraph is not None
    assert pipeline.PipelineOutput is not None


def test_mmse_reduces_background_noise_keeps_signal():
    """The Ephraim-Malah filter's purpose: stationary noise down, calls kept."""
    from repro.core.stages import mmse_denoise
    rng = np.random.RandomState(0)
    n = cfg.final_split_samples
    noise_level = 0.05
    t = np.arange(n) / cfg.target_rate_hz
    call = np.zeros(n, np.float32)
    call[n // 2:n // 2 + 4000] = np.sin(
        2 * np.pi * 4000 * t[:4000]).astype(np.float32)
    x = call + noise_level * rng.randn(n).astype(np.float32)
    out = np.asarray(mmse_denoise(jnp.asarray(x)[None], cfg))[0]
    noise_seg = slice(4000, n // 2 - 4000)
    sig_seg = slice(n // 2, n // 2 + 4000)
    in_noise = np.sqrt((x[noise_seg] ** 2).mean())
    out_noise = np.sqrt((out[noise_seg] ** 2).mean())
    out_sig = np.sqrt((out[sig_seg] ** 2).mean())
    assert out_noise < 0.5 * in_noise          # noise attenuated >6 dB
    assert out_sig > 0.5                       # call substantially kept
