"""The fused survivor tail (kernels/fused_tail + the plan wiring):
staged-vs-fused bit-identity across keep rates and backend modes, the
zero-pad-row invariant, bucket-keyed fused compiles, donation value
identity, the non-canonical-stage-list fallback, and the autotuner's
VMEM feasibility across pow2 buckets.

Bitwise comparisons always pit JITTED against JITTED: XLA contracts
mul+add chains to FMA under jit but not in eager op-by-op dispatch, so a
jitted path and its eager twin legitimately differ in the last bit —
plans always run jitted, and so do these assertions.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SERF_AUDIO as cfg
from repro.core import scheduler as SCHED
from repro.core.graph import GraphValidationError, PipelineGraph
from repro.core.plans import JIT_CACHE, Preprocessor
from repro.data.loader import audio_batch_maker
from repro.kernels import backend
from repro.kernels.fused_tail import kernel as FTK
from repro.kernels.fused_tail import ops as FTO

_HPF_TAIL_STAGES = cfg.stages[:-1] + ("hpf", "mmse")
_ALL_KEPT_STAGES = ("to_mono", "compress", "split_detect", "stft",
                    "cicada_bandstop", "istft", "split_final",
                    "removal_point", "mmse")


def _stream(seed, n_batches, batch_long_chunks=1):
    make = audio_batch_maker(seed=seed,
                             batch_long_chunks=batch_long_chunks)
    return [(w, (make(w)[0], None)) for w in range(n_batches)]


def _small_wave(B=6, n_tiles=1, seed=0):
    """A (B, S) f32 batch with S one STFT tile — small enough that
    interpret-mode grid steps stay cheap."""
    S = n_tiles * 128 * cfg.stft_hop + cfg.stft_window
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(B, S).astype(np.float32) * 0.3)


# ------------------------------------------------- plan-level equivalence

@pytest.mark.parametrize("rate, mk", [
    ("0%", lambda: (dataclasses.replace(cfg, silence_snr_threshold=2.0),
                    None)),
    ("~37%", lambda: (cfg, None)),
    ("100%", lambda: (cfg, _ALL_KEPT_STAGES)),
])
def test_fused_plan_bit_identical_to_staged(rate, mk):
    """two_phase with the fused tail vs two_phase with the staged tail on
    the seed-25 stream: masks AND cleaned bit-identical at every keep-rate
    regime (auto backend = the ref path on CPU)."""
    c, stages = mk()
    stream = _stream(25 if rate == "~37%" else 21, 3)
    staged = Preprocessor(c, plan="two_phase", stages=stages,
                          pad_multiple=1, fuse_tail=False)
    fused = Preprocessor(c, plan="two_phase", stages=stages,
                         pad_multiple=1, fuse_tail=True)
    assert staged.plan.fuse_tail is False and fused.plan.fuse_tail is True
    for a, b in zip(staged.run(stream), fused.run(stream)):
        np.testing.assert_array_equal(np.asarray(a.det.keep),
                                      np.asarray(b.det.keep))
        np.testing.assert_array_equal(a.cleaned, b.cleaned)
        assert a.n_kept == b.n_kept


def test_fused_auto_engages_on_canonical_tail():
    assert Preprocessor(cfg, plan="two_phase").plan.fuse_tail is True
    assert Preprocessor(cfg, plan="async").plan.fuse_tail is True
    g = PipelineGraph(cfg)
    assert g.fused_tail_spec == {"hpf": False}
    assert PipelineGraph(cfg, _HPF_TAIL_STAGES).fused_tail_spec \
        == {"hpf": True}


# ------------------------------------------- tail-level mode equivalence

@pytest.mark.parametrize("mode", ["ref", "interpret"])
@pytest.mark.parametrize("hpf", [False, True])
def test_fused_tail_bit_identical_per_mode(mode, hpf):
    """jit(staged tail_indexed) vs jit(fused tail_indexed_fused), same
    backend mode, bitwise — on a small batch so interpret stays cheap."""
    stages = _HPF_TAIL_STAGES if hpf else None
    g = PipelineGraph(cfg, stages)
    wave = _small_wave(B=6)
    idx = jnp.asarray([4, 1, 3, 9, 9], jnp.int32)   # 2 pad slots
    staged = jax.jit(lambda w, i: g.tail_indexed(w, i))
    fused = jax.jit(lambda w, i: g.tail_indexed_fused(w, i))
    with backend.use(mode):
        a, b = staged(wave, idx), fused(wave, idx)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_matmul_mode_matches_staged():
    g = PipelineGraph(cfg)
    wave = _small_wave(B=4, seed=2)
    idx = jnp.asarray([2, 0, 7], jnp.int32)
    staged = jax.jit(lambda w, i: g.tail_indexed(w, i))
    fused = jax.jit(lambda w, i: g.tail_indexed_fused(w, i))
    with backend.use("matmul"):
        a, b = staged(wave, idx), fused(wave, idx)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------- pad-row invariant

@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_fused_pad_rows_all_zero(mode):
    """Out-of-range survivor-index slots (the scheduler's pad convention)
    must come out as exactly-zero cleaned rows through the fused pass —
    fill-gather semantics preserved inside the kernel."""
    g = PipelineGraph(cfg)
    wave = _small_wave(B=6, seed=3)
    idx = jnp.asarray([3, 0, 99, 5, 1_000_000], jnp.int32)
    with backend.use(mode):
        out = jax.jit(lambda w, i: g.tail_indexed_fused(w, i))(wave, idx)
    out = np.asarray(out)
    assert not out[2].any() and not out[4].any()
    assert out[0].any() and out[1].any() and out[3].any()


# ------------------------------------------------ bucket-keyed compiles

def test_fused_tail_bucketed_compile_count():
    """With fusion auto-engaged, the async plan's tail compiles land under
    the 'tail_idx_fused' kind, one CompileCache entry per pow2 bucket —
    and NO staged 'tail_idx' entries exist."""
    stream = _stream(24, 4, batch_long_chunks=2)
    JIT_CACHE.clear()
    pre = Preprocessor(cfg, plan="async", depth=2, bucket="pow2",
                       pad_multiple=1)
    res = list(pre.run(stream))
    counts = [r.n_kept for r in res]
    cap = int(np.asarray(res[0].det.keep).size)
    expect = {SCHED.quantize_survivors(n, cap, 1, "pow2")
              for n in counts if n}
    kinds = {k[0] for k in JIT_CACHE.keys()}
    assert "tail_idx" not in kinds
    got = {k[-1] for k in JIT_CACHE.keys() if k[0] == "tail_idx_fused"}
    assert got == expect, counts


# -------------------------------------------------------- donation

def test_fused_donation_value_identity():
    """Forcing wave5 donation through the fused tail must not change a
    bit (CPU ignores donation with a warning; the VALUES contract is what
    this pins for real accelerators)."""
    stream = _stream(25, 2)
    plain = Preprocessor(cfg, plan="two_phase", pad_multiple=1,
                         donate=False)
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message=".*donated buffers.*")
        donated = Preprocessor(cfg, plan="two_phase", pad_multiple=1,
                               donate=True)
        assert donated.plan.fuse_tail is True
        for a, b in zip(plain.run(stream), donated.run(stream)):
            np.testing.assert_array_equal(a.cleaned, b.cleaned)


# ----------------------------------------------- non-canonical fallback

def test_non_canonical_tail_falls_back_to_staged():
    """A survivor chain that is not [hpf ->] mmse must keep the staged
    path: auto fuse_tail resolves False, fuse_tail=True raises, and the
    fused graph entry point refuses."""
    odd = cfg.stages[:-1] + ("hpf", "hpf", "mmse")
    g = PipelineGraph(cfg, odd)
    assert g.fused_tail_spec is None
    pre = Preprocessor(cfg, plan="two_phase", stages=odd, pad_multiple=1)
    assert pre.plan.fuse_tail is False
    with pytest.raises(GraphValidationError):
        Preprocessor(cfg, plan="two_phase", stages=odd, pad_multiple=1,
                     fuse_tail=True)
    with pytest.raises(GraphValidationError):
        g.tail_indexed_fused(_small_wave(B=2),
                             jnp.asarray([0, 1], jnp.int32))
    # ... and the odd graph still RUNS correctly through the staged path
    res = list(pre.run(_stream(25, 2)))
    assert sum(r.n_kept for r in res) > 0


# ------------------------------------------------------------ autotuner

def test_autotuner_feasible_for_every_pow2_bucket():
    """best_config returns a VMEM-feasible candidate for every pow2
    survivor bucket at the production chunk size, and a timed autotune
    pass caches a winner that best_config then returns."""
    S5 = cfg.final_split_samples
    cap = 36
    buckets = sorted({SCHED.quantize_survivors(n, cap, 1, "pow2")
                      for n in range(1, cap + 1)})
    for rows in buckets:
        tc = FTO.best_config(rows, S5, cfg)
        assert tc in FTO.CANDIDATES
        assert FTO.vmem_bytes(tc, S5, cfg.stft_window, cfg.stft_hop) \
            <= FTO.VMEM_BUDGET
    # timed probe on a small shape (ref backend: one probe, cached)
    FTO.clear_tuning()
    wave = _small_wave(B=8, seed=5)
    idx = jnp.asarray([0, 3, 5, 9], jnp.int32)
    with backend.use("ref"):
        tc = FTO.autotune(wave, idx, cfg, reps=1)
        assert tc in FTO.CANDIDATES
        assert FTO.best_config(4, wave.shape[1], cfg) == tc
    FTO.clear_tuning()


def test_vmem_model_monotone_in_frame_block():
    S5 = cfg.final_split_samples
    sizes = [FTO.vmem_bytes(FTO.TailConfig(fb, 128), S5)
             for fb in (1, 2, 4, 8)]
    assert sizes == sorted(sizes)
    assert FTO.vmem_bytes(FTO.TailConfig(1, 128), S5, hpf=True) \
        > FTO.vmem_bytes(FTO.TailConfig(1, 128), S5, hpf=False)


def test_tail_geometry_matches_staged_padding():
    from repro.kernels.stft_dft import ops as SO
    for S in (cfg.final_split_samples, 16_640, 33_000):
        x = jnp.zeros((1, S), jnp.float32)
        n_tiles, S_pad, F, Fv = FTK.tail_geometry(S, cfg.stft_window,
                                                  cfg.stft_hop)
        assert SO.pad_for_stft(x, cfg.stft_window, cfg.stft_hop).shape[1] \
            == S_pad
        assert F == n_tiles * 128
        assert Fv == (S - cfg.stft_window) // cfg.stft_hop + 1
