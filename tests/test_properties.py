"""Hypothesis property-based tests on system invariants (per the brief)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st   # noqa: E402
import hypothesis.extra.numpy as hnp                       # noqa: E402

from repro.core import indices as I
from repro.core import scheduler as SCHED
from repro.kernels.fir_hpf import ref as FR
from repro.kernels.mmse_stsa import ref as MR
from repro.train import compression as C

_settings = settings(max_examples=25, deadline=None)

power_arrays = hnp.arrays(
    np.float32, hnp.array_shapes(min_dims=3, max_dims=3, min_side=2,
                                 max_side=24),
    elements=st.floats(2.0**-20, 2.0**13, width=32))


@_settings
@given(power_arrays)
def test_indices_ranges(power):
    p = jnp.asarray(power)
    snr = np.asarray(I.snr_est(p))
    flat = np.asarray(I.spectral_flatness(p))
    assert ((snr >= 0) & (snr < 1 + 1e-6)).all()
    assert ((flat > 0) & (flat <= 1 + 1e-5)).all()


@_settings
@given(power_arrays, st.floats(0.1, 100.0))
def test_indices_scale_invariance(power, scale):
    """snr/flatness are ratios — invariant to loudness scaling (what makes
    the thresholds transferable across recording gains)."""
    p = jnp.asarray(power)
    # atol 1e-3 on a [0,1] index: float cancellation near snr=0 (constant
    # envelopes) is three orders below the decision thresholds (0.45)
    np.testing.assert_allclose(np.asarray(I.snr_est(p * scale)),
                               np.asarray(I.snr_est(p)), rtol=2e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(I.spectral_flatness(p * scale)),
                               np.asarray(I.spectral_flatness(p)),
                               rtol=2e-3, atol=2e-4)


@_settings
@given(power_arrays, st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_silence_threshold_monotonicity(power, t1, t2):
    lo, hi = min(t1, t2), max(t1, t2)
    p = jnp.asarray(power)
    snr = I.snr_est(p)
    assert (np.asarray(snr < lo) <= np.asarray(snr < hi)).all()


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
def test_compaction_preserves_survivors(seed, n):
    rng = np.random.RandomState(seed)
    keep = jnp.asarray(rng.rand(n) < 0.5)
    chunks = jnp.asarray(rng.randn(n, 7).astype(np.float32))
    packed, pkeep, count = SCHED.compact(chunks, keep)
    count = int(count)
    assert count == int(keep.sum())
    assert bool(np.asarray(pkeep[:count]).all())
    assert not np.asarray(pkeep[count:]).any()
    want = set(map(tuple, np.asarray(chunks)[np.asarray(keep)]))
    got = set(map(tuple, np.asarray(packed[:count])))
    assert want == got


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(33, 400), st.integers(1, 3))
def test_fir_linearity(seed, S, stride):
    rng = np.random.RandomState(seed)
    h = FR.highpass_taps(1000.0, 22_050, 33)
    x = jnp.asarray(rng.randn(1, S).astype(np.float32))
    y = jnp.asarray(rng.randn(1, S).astype(np.float32))
    a = float(rng.uniform(-2, 2))
    left = FR.fir_ref(a * x + y, h, stride)
    right = a * FR.fir_ref(x, h, stride) + FR.fir_ref(y, h, stride)
    np.testing.assert_allclose(np.asarray(left), np.asarray(right),
                               rtol=1e-3, atol=1e-4)


@_settings
@given(hnp.arrays(np.float32,
                  hnp.array_shapes(min_dims=1, max_dims=3, min_side=1,
                                   max_side=64),
                  elements=st.floats(-(2.0**13), 2.0**13, width=32)))
def test_rowwise_quant_error_bound(x):
    codes, scale = C.quantize_rowwise_int8(jnp.asarray(x))
    deq = np.asarray(C.dequantize_rowwise_int8(codes, scale))
    bound = np.asarray(scale)[..., None] * 0.5 + 1e-6
    assert (np.abs(deq - x) <= bound + 1e-4 * np.abs(x)).all()


@_settings
@given(st.integers(0, 2**31 - 1))
def test_ef_quantization_residual_identity(seed):
    """dequant(codes) + new_residual == grad + residual (error feedback
    loses nothing)."""
    rng = np.random.RandomState(seed)
    g = jnp.asarray(rng.randn(40, 17).astype(np.float32) * 10)
    r = jnp.asarray(rng.randn(40, 17).astype(np.float32))
    codes, scale, new_r = C.quantize_ef(g, r)
    deq = C.dequantize_block_int8(codes, scale, g.shape)
    np.testing.assert_allclose(np.asarray(deq + new_r), np.asarray(g + r),
                               rtol=1e-5, atol=1e-5)


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(2, 100))
def test_mmse_gain_bounded(seed, F):
    rng = np.random.RandomState(seed)
    power = jnp.asarray(rng.exponential(1.0, (1, F, 33)).astype(np.float32))
    noise = MR.estimate_noise_psd(power, min(8, F))
    g = np.asarray(MR.mmse_stsa_gain_ref(power, noise, gain_floor=0.05))
    assert (g >= 0.05 - 1e-6).all() and (g <= 10.0 + 1e-6).all()
    assert np.isfinite(g).all()
