"""The multi-shard execution layer: Rebalancer/balance-stat coverage
(skewed masks, all-removed shards, survivor counts that don't divide), the
ShardedPlan's bit-identical-survivor equivalence with TwoPhasePlan, and
crash/lease-expiry recovery with exactly-once emission."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SERF_AUDIO as cfg
from repro.core import scheduler as SCHED
from repro.core.plans import JIT_CACHE, Preprocessor
from repro.data.loader import (ShardedLoader, audio_batch_maker,
                               make_shard_pool)
from repro.data.queue import SettableClock as FakeClock
from repro.data.queue import WorkQueue
from repro.distributed.sharding import pool_rules
from repro.ft.failure import CrashInjector


# ------------------------------------------------------ scheduler coverage

def test_shard_load_and_balance_stats_skewed():
    """One shard holds every survivor: loads are per-shard exact and the
    'before' imbalance is the shard count (max = n, mean = n/k)."""
    keep = jnp.asarray([True] * 6 + [False] * 18)
    loads = np.asarray(SCHED.shard_load(keep, 4))
    assert loads.tolist() == [6, 0, 0, 0]
    bs = jax.jit(lambda k: SCHED.balance_stats(k, 4))(keep)
    assert float(bs["imbalance"]) == pytest.approx(4.0)
    assert float(bs["imbalance_after_compact"]) == pytest.approx(
        np.ceil(6 / 4) / (6 / 4))


def test_shard_load_pads_non_divisible():
    """N not divisible by n_shards: trailing shard sees the short tail."""
    keep = jnp.asarray([True] * 10)          # 10 chunks over 4 shards
    loads = np.asarray(SCHED.shard_load(keep, 4))
    assert loads.tolist() == [3, 3, 3, 1]
    assert int(loads.sum()) == 10            # padding adds no survivors


def test_balance_stats_all_removed():
    keep = jnp.zeros((12,), bool)
    bs = SCHED.balance_stats(keep, 3)
    assert np.asarray(bs["loads"]).tolist() == [0, 0, 0]
    assert np.isfinite(float(bs["imbalance"]))


def test_rebalancer_skewed_and_all_removed_shard():
    """Skewed masks — one shard all-survivor, one all-removed — come out
    within the +-1 of integer division (max/min <= 1.5 for n >= 2k)."""
    keeps = [np.ones(12, bool), np.zeros(12, bool),
             np.array([True, False] * 6)]
    asg = SCHED.Rebalancer(3).assign(keeps)
    st = asg.stats()
    assert st["loads_before"].tolist() == [12, 0, 6]
    assert st["max_min_before"] == 12.0
    assert st["loads_after"].tolist() == [6, 6, 6]
    assert st["max_min_after"] <= 1.5
    assert st["moved"] == 6                  # shard0's overflow -> shard1
    assert asg.bounds.tolist() == [0, 6, 12, 18]


def test_rebalancer_non_divisible_and_fewer_live_shards():
    keeps = [np.ones(7, bool), np.ones(4, bool), np.zeros(5, bool)]
    asg = SCHED.Rebalancer(3).assign(keeps, out_shards=2)   # one shard died
    assert asg.counts_after.tolist() == [6, 5]              # 11 over 2
    assert int(asg.counts_after.sum()) == 11
    assert asg.stats()["max_min_after"] <= 1.5


def test_rebalancer_split_pads_batches():
    reb = SCHED.Rebalancer(2, pad_multiple=4)
    surv = np.arange(10, dtype=np.float32).reshape(5, 2)
    asg = reb.assign([np.ones(3, bool), np.ones(2, bool)])
    parts = list(reb.split(surv, asg))
    assert [(j, b.shape[0], n) for j, b, n in parts] == [(0, 4, 3), (1, 4, 2)]
    np.testing.assert_array_equal(parts[0][1][:3], surv[:3])
    np.testing.assert_array_equal(parts[0][1][3], 0.0)  # pad = zero rows,
    # never repeated audio (PR 4: repeated-row padding wasted MMSE flops)


def test_rebalancer_empty():
    asg = SCHED.Rebalancer(2).assign([np.zeros(4, bool), np.zeros(4, bool)])
    assert asg.counts_after.tolist() == [0, 0]
    assert list(SCHED.Rebalancer(2).split(np.zeros((0, 8)), asg)) == []
    assert asg.stats()["max_min_after"] == 1.0


# ------------------------------------------------- plan equivalence / FT

def _long_chunks(seed, n_long):
    from repro.data.synthetic import generate_labelled
    audio, _ = generate_labelled(seed, n_long * 12, segment_s=5.0)
    S5 = audio.shape[-1]
    return (audio.reshape(n_long, 12, 2, S5).transpose(0, 2, 1, 3)
            .reshape(n_long, 2, 12 * S5))


@pytest.fixture(scope="module")
def chunks():
    return _long_chunks(11, 4)


def test_sharded_matches_two_phase_bitwise_masks(chunks):
    """Acceptance: bit-identical survivor masks and matching cleaned audio
    vs TwoPhasePlan on the same stream, compared per work id."""
    stream = [(0, (chunks[:1], None)), (1, (chunks[1:3], None)),
              (2, (chunks[3:], None))]
    ref = {r.wid: r for r in
           Preprocessor(cfg, plan="two_phase", pad_multiple=2).run(stream)}
    pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=2)
    got = {r.wid: r for r in pre.run(stream)}
    assert sorted(got) == sorted(ref)
    for wid, r in got.items():
        np.testing.assert_array_equal(np.asarray(r.det.keep),
                                      np.asarray(ref[wid].det.keep))
        np.testing.assert_allclose(r.cleaned, ref[wid].cleaned,
                                   rtol=1e-4, atol=1e-5)
        assert r.n_kept == ref[wid].n_kept


def test_sharded_single_batch_call_matches_fused(chunks):
    """The serve path (__call__): rows split across shards, survivors
    rebalanced, output identical to the fused reference."""
    x = jnp.asarray(chunks)
    ref = Preprocessor(cfg, plan="fused")(x)
    sh = Preprocessor(cfg, plan="sharded", shards=3, pad_multiple=1)(x)
    keep = np.asarray(sh.det.keep)
    np.testing.assert_array_equal(keep, np.asarray(ref.det.keep))
    np.testing.assert_allclose(sh.cleaned, np.asarray(ref.cleaned),
                               rtol=1e-4, atol=1e-5)
    assert sh.det.stats["n_chunks5"] == keep.size


def test_sharded_service_round_trip(chunks):
    from repro.serve.preprocess_service import PreprocessService
    svc = PreprocessService(cfg, batch_long_chunks=2, plan="sharded",
                            shards=2)
    rids = [svc.submit(chunks[i]) for i in range(3)]
    served = []
    while len(served) < len(rids):
        served.extend(svc.pump())
    det = Preprocessor(cfg).detect(jnp.asarray(chunks[:3]))
    keep = np.asarray(det.keep)
    for j, rid in enumerate(rids):
        r = svc.result(rid)
        np.testing.assert_array_equal(r["keep"], keep[j * 12:(j + 1) * 12])
        assert r["cleaned"].shape[0] == int(r["keep"].sum())


def test_sharded_rebalance_ratio_on_skewed_stream():
    """Acceptance: post-rebalance max/min shard load <= 1.5 when the
    per-shard survivor counts are heavily skewed (silence-heavy batches on
    one shard, bird-heavy on the other)."""
    base = _long_chunks(5, 2)
    quiet = np.zeros_like(base) + 1e-4 * np.random.RandomState(0).randn(
        *base.shape).astype(np.float32)     # all-silence batches
    stream = [(0, (base, None)), (1, (quiet, None))]
    pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1)
    results = list(pre.run(stream))
    assert sorted(r.wid for r in results) == [0, 1]
    st = pre.plan.last_assignment.stats()
    assert int(st["loads_before"].min()) == 0          # the skew is real
    assert st["max_min_after"] <= 1.5
    assert int(st["loads_after"].sum()) == sum(r.n_kept for r in results)


def test_sharded_crash_recovery_exactly_once():
    """Acceptance: a killed worker mid-stream finishes the run with
    redeliveries >= 1 and no missing or duplicate chunk ids."""
    n_batches = 6
    make = audio_batch_maker(seed=2, batch_long_chunks=1)
    pool = make_shard_pool(make, n_batches, 3)
    inj = CrashInjector()
    inj.kill(1, after_items=1)
    pre = Preprocessor(cfg, plan="sharded", shards=3, pad_multiple=1,
                       injector=inj)
    results = list(pre.run(pool))
    wids = sorted(r.wid for r in results)
    assert wids == list(range(n_batches))              # exactly once
    assert pre.plan.redeliveries >= 1
    assert not inj.alive(1)
    ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    for r in results:
        want = ref(make(r.wid)[0])
        np.testing.assert_array_equal(np.asarray(r.det.keep),
                                      np.asarray(want.det.keep))


def test_sharded_forced_lease_expiry_redelivers():
    """A lease orphaned by a pre-run crash (deadline already past) is
    reaped on the first pull and the work completes on a live shard."""
    clock = FakeClock()
    n_batches = 3
    queue = WorkQueue(n_batches, lease_timeout_s=5.0, clock=clock)
    orphan = queue.lease("ghost", 1)
    assert orphan == [0]
    clock.t = 6.0
    make = audio_batch_maker(seed=4, batch_long_chunks=1)
    pool = make_shard_pool(make, n_batches, 2, queue=queue)
    pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1)
    results = list(pre.run(pool))
    assert sorted(r.wid for r in results) == list(range(n_batches))
    assert pre.plan.redeliveries >= 1


def test_sharded_all_shards_dead_raises():
    make = audio_batch_maker(seed=1, batch_long_chunks=1)
    pool = make_shard_pool(make, 4, 2)
    inj = CrashInjector()
    inj.kill(0, after_items=0)
    inj.kill(1, after_items=0)
    pre = Preprocessor(cfg, plan="sharded", shards=2, injector=inj)
    with pytest.raises(RuntimeError, match="stalled"):
        list(pre.run(pool))


def test_sharded_per_shard_rules_share_compile_cache(chunks):
    """pool_rules: same-mesh (here: unmeshed) shards dedup to ONE compiled
    phase in the shared CompileCache — N shards never mean N compiles."""
    JIT_CACHE.clear()
    rules = pool_rules(3)
    assert len({r.fingerprint for r in rules}) == 1
    pre = Preprocessor(cfg, rules, plan="sharded", shards=3, pad_multiple=1)
    pre(jnp.asarray(chunks))
    assert len(JIT_CACHE) == 2            # one detect + one tail, shared
    with pytest.raises(ValueError, match="per-shard rules"):
        Preprocessor(cfg, pool_rules(2), plan="sharded", shards=3)
    with pytest.raises(ValueError, match="only valid with the sharded"):
        Preprocessor(cfg, pool_rules(2), plan="two_phase")


def test_sharded_loader_pool_shares_queue():
    make = audio_batch_maker(seed=0, batch_long_chunks=1)
    pool = make_shard_pool(make, 4, 2)
    assert all(isinstance(ld, ShardedLoader) for ld in pool)
    assert pool[0].queue is pool[1].queue
    got = pool[0].pull()
    assert len(got) == 1
    wid, (batch, labels) = got[0]
    assert batch.shape[0] == 1
    assert pool[0].complete(wid) and not pool[0].complete(wid)
