"""Elastic-fleet primitives: straggler detection, speculative duplicate
leases, the membership registry, seeded chaos schedules, graceful drain +
late join through the real worker runtime, and pool autoscaling."""
import threading
import time

import numpy as np

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.loader import audio_batch_maker
from repro.data.queue import SettableClock, WorkQueue
from repro.dist.service import QueueService
from repro.dist.transport import InProcTransport
from repro.dist.worker import run_worker
from repro.ft.chaos import ACTIONS, make_schedule
from repro.ft.failure import StragglerDetector


# ---------------------------------------------------- straggler detector

def test_straggler_detector_min_history_gates():
    """No speculation before the detector has seen enough completions:
    with an empty latency history every in-flight time looks infinite."""
    clock = SettableClock()
    sd = StragglerDetector(factor=2.0, min_history=5, clock=clock)
    sd.start("t")
    clock.t += 100.0
    assert sd.stragglers() == []          # ancient, but history too thin
    for i in range(5):
        sd.start(i)
        clock.t += 1.0
        sd.complete(i)
    assert sd.stragglers() == ["t"]       # history filled: now it fires


def test_straggler_detector_p95_window():
    clock = SettableClock()
    sd = StragglerDetector(factor=2.0, min_history=10, clock=clock)
    for i in range(100):
        sd.start(i)
        clock.t += 1.0
        sd.complete(i)
    assert sd.p95() == 1.0
    sd.start("x")
    clock.t += 1.5
    assert sd.stragglers() == []          # 1.5 <= 2 x p95
    clock.t += 1.0
    assert sd.stragglers() == ["x"]       # 2.5 > 2 x p95


def test_straggler_detector_latency_truncation():
    """The rolling history stays bounded: past 1000 samples it is cut back
    to the newest 500 (long streams must not grow the list forever)."""
    clock = SettableClock()
    sd = StragglerDetector(clock=clock)
    for i in range(1001):
        sd.start(i)
        sd.complete(i)
    assert len(sd._latencies) == 500


def test_straggler_detector_orders_longest_running_first():
    """Speculation re-leases from the front of the list, so the slowest
    item must come first."""
    clock = SettableClock()
    sd = StragglerDetector(factor=1.0, min_history=1, clock=clock)
    sd.start("old")
    clock.t = 5.0
    sd.start("new")
    clock.t = 6.0
    sd.start("quick")
    sd.complete("quick")
    clock.t = 20.0
    assert sd.stragglers() == ["old", "new"]


# ------------------------------------------------ speculative duplicate leases

def test_work_queue_speculate_refusals_and_grant():
    clock = SettableClock()
    q = WorkQueue(3, lease_timeout_s=10.0, clock=clock)
    assert not q.speculate("w2", 0)       # not leased yet
    assert q.lease("w1", 2) == [0, 1]
    assert not q.speculate("w1", 0)       # self-speculation refused
    assert q.speculate("w2", 0)
    assert not q.speculate("w3", 0)       # at most one backup per id
    assert q.speculated() == [0]
    assert q.leases_held("w2") == [0]     # a spec copy counts as held work
    q.complete([1])
    assert not q.speculate("w2", 1)       # done ids are never duplicated
    assert q.speculations == 1


def test_work_queue_speculation_first_completion_wins():
    losses = []
    clock = SettableClock()
    q = WorkQueue(2, lease_timeout_s=10.0, clock=clock)
    q.on_redeliver = lambda wid, w, reason: losses.append((wid, w, reason))
    q.lease("w1", 2)
    assert q.speculate("w2", 0) and q.speculate("w2", 1)
    assert q.complete([0], worker="w1") == [0]      # primary wins wid 0
    assert losses == [(0, "w2", "speculated")]
    assert q.complete([1], worker="w2") == [1]      # backup wins wid 1
    assert losses[-1] == (1, "w1", "speculated")
    assert q.speculations_lost == 2
    assert q.redeliveries == 0            # a lost race is not a lost lease
    assert q.complete([0], worker="w2") == []       # exactly-once holds
    assert q.finished


def test_work_queue_speculation_promoted_on_primary_expiry():
    """When the primary lease expires while a live backup exists, the
    backup is PROMOTED instead of re-queueing the id — the backup is
    already computing it; a third copy would only add load."""
    clock = SettableClock()
    q = WorkQueue(2, lease_timeout_s=10.0, clock=clock)
    q.lease("w1", 2)
    assert q.speculate("w2", 0)
    clock.t = 5.0
    q.heartbeat_extend("w2")              # backup stays fresh (-> 15)
    clock.t = 12.0                        # w1's primaries (10) expire
    assert q.lease("w3", 5) == [1]        # only the spec-less id re-pends
    assert q.leases_held("w2") == [0]     # the backup is primary now
    assert q.speculated() == []
    assert q.redeliveries == 2


def test_work_queue_speculation_promoted_on_fail_worker():
    clock = SettableClock()
    q = WorkQueue(2, lease_timeout_s=10.0, clock=clock)
    q.lease("w1", 2)
    assert q.speculate("w2", 0)
    assert sorted(q.fail_worker("w1")) == [0, 1]
    assert q.lease("w3", 5) == [1]        # wid 0 went to the backup, not pending
    assert q.leases_held("w2") == [0]
    # and a dead worker's own spec copies just evaporate
    q2 = WorkQueue(1, clock=SettableClock())
    q2.lease("w1", 1)
    assert q2.speculate("w2", 0)
    assert q2.fail_worker("w2") == []
    assert q2.speculated() == [] and q2.leases_held("w1") == [0]
    assert q2.redeliveries == 0


def test_work_queue_spec_expiry_evaporates_silently():
    """An expired backup costs nothing: the primary still owns the id,
    nothing re-pends, no redelivery is counted."""
    clock = SettableClock()
    q = WorkQueue(1, lease_timeout_s=10.0, clock=clock)
    q.lease("w1", 1)
    assert q.speculate("w2", 0)
    clock.t = 5.0
    q.heartbeat_extend("w1")              # primary -> 15; backup stays 10
    clock.t = 12.0
    assert q.lease("w3", 1) == []
    assert q.speculated() == []
    assert q.leases_held("w1") == [0]
    assert q.redeliveries == 0 and q.speculations_lost == 0


# ------------------------------------------------------ membership registry

def test_queue_service_membership_registry():
    q = WorkQueue(4, lease_timeout_s=60.0, clock=SettableClock())
    svc = QueueService(q)
    svc.hello("shard0", pid=1, shard=0)
    svc.hello("shard1", pid=2, shard=1)
    e0 = svc.epoch
    assert e0 >= 2                        # each join bumped the epoch
    assert svc.active_workers() == ["shard0", "shard1"]
    svc.hello("shard0", pid=1, shard=0)   # re-hello while active: no churn
    assert svc.epoch == e0
    assert svc.drain("shard1") is True
    assert svc.draining("shard1")
    assert svc.epoch == e0 + 1
    assert svc.lease("shard1", 4) == []   # draining workers take no work
    assert svc.lease("shard0", 1) == [0]
    svc.bye("shard1")
    assert svc.workers["shard1"].state == "departed"
    assert svc.draining("shard1")         # departed still reads as leaving
    svc.hello("shard1", pid=3, shard=1)   # rejoin: a fresh incarnation
    assert svc.workers["shard1"].state == "active"
    assert svc.lease("shard1", 1) == [1]
    svc.fail_worker("shard0")
    assert svc.workers["shard0"].state == "dead"
    assert svc.active_workers() == ["shard1"]
    assert svc.epoch > e0 + 1


def test_queue_service_grants_speculative_lease_to_idle_worker():
    """The wiring end to end: an ACTIVE worker whose normal lease comes
    back empty receives a duplicate of the slowest flagged in-flight id."""
    clock = SettableClock()
    q = WorkQueue(3, lease_timeout_s=60.0, clock=clock)
    sd = StragglerDetector(factor=2.0, min_history=2, clock=clock)
    svc = QueueService(q, straggler=sd)
    for wid in (0, 1):
        assert svc.lease("w1", 1) == [wid]
        clock.t += 1.0
        assert svc.complete([wid], worker="w1") == [wid]
    assert svc.lease("w1", 1) == [2]      # in flight on w1
    clock.t += 10.0                       # way past 2 x p95(=1.0)
    assert svc.lease("w2", 1) == [2]      # pending empty -> speculated
    assert q.speculated() == [2]
    svc.drain("w2")
    assert svc.lease("w2", 1) == []       # but never to a draining worker
    assert svc.complete([2], worker="w2") == [2]
    assert q.speculations == 1 and q.speculations_lost == 1
    assert q.finished


def test_speculation_telemetry_attributes_loser_and_keeps_done_record(
        tmp_path):
    """Regression: a lost speculation race must write a 'redelivered'
    record with reason 'speculated' attributing the LOSER without
    clobbering the winner's timeline — the 'done' record written at
    acceptance must still appear, exactly once."""
    from repro.obs.telemetry import (TelemetryWriter, read_records,
                                     worker_ledger)
    clock = SettableClock()
    q = WorkQueue(1, lease_timeout_s=60.0, clock=clock)
    tw = TelemetryWriter(str(tmp_path))
    svc = QueueService(q, telemetry=tw)
    svc.lease("w1", 1)
    assert q.speculate("w2", 0)
    assert svc.complete([0], worker="w2") == [0]    # w1 lost the race
    svc.note_done("w2", wid=0, survivors=3, bytes_out=12)
    tw.close()
    recs = read_records(str(tmp_path))
    lost = [r for r in recs if r.get("status") == "redelivered"]
    assert len(lost) == 1
    assert lost[0]["reason"] == "speculated" and lost[0]["worker"] == "w1"
    done = [r for r in recs if r.get("status") == "done"]
    assert len(done) == 1 and done[0]["wid"] == 0
    assert done[0]["worker"] == "w2" and done[0]["accept_ts"]
    led = worker_ledger(recs)
    assert led["w1"]["speculation_lost"] == 1
    assert led["w1"]["redelivered_from"] == 1
    assert led["w2"]["chunks_done"] == 1


# ------------------------------------------------------- chaos schedules

def test_make_schedule_deterministic_and_complete():
    for seed in (0, 11, 23, 37, 99):
        a = make_schedule(seed, 8)
        b = make_schedule(seed, 8)
        assert [(e.after_done, e.action, e.stall_s) for e in a] == \
               [(e.after_done, e.action, e.stall_s) for e in b]
        assert {e.action for e in a} == set(ACTIONS)    # >= 1 of each
        assert all(1 <= e.after_done <= 6 for e in a)   # never past n-2
        join = next(e for e in a if e.action == "join")
        assert join.after_done <= 2     # early: must hello before the drain
        stall = next(e for e in a if e.action == "stall")
        assert stall.after_done >= 5    # late: the speculation shape
        assert [e.after_done for e in a] == sorted(e.after_done for e in a)
    assert [(e.after_done, e.action) for e in make_schedule(23, 8)] != \
           [(e.after_done, e.action) for e in make_schedule(37, 8)]
    assert len(make_schedule(3, 8, extra_events=4)) == len(ACTIONS) + 4


# ------------------------------- drain + late join via the worker runtime

def test_worker_drain_and_late_join_inproc():
    """A drained worker finishes what it holds, takes no more, and exits
    through bye; a late joiner hellos into the run in progress and
    finishes the stream. Every id is accepted exactly once."""
    n = 4
    make = audio_batch_maker(seed=9, batch_long_chunks=1)
    setup = {"cfg": cfg, "stages": None, "source_channels": 2,
             "pad_multiple": 1, "bucket": "linear", "backend_mode": "auto"}
    hold = threading.Event()

    def fetch(wid):
        if wid >= 2:
            # the tail of the stream is held back until the drain below
            # has been issued, so shard0 cannot race through everything
            hold.wait(120.0)
        return make(wid)[0]

    q = WorkQueue(n, lease_timeout_s=120.0)
    svc = QueueService(q, fetch_item=fetch, setup=setup)

    accepted = []

    def accept_all():
        while not q.finished:
            for worker, wid, payload in svc.pop_results():
                if svc.complete([wid], worker=worker):
                    svc.note_done(worker, wid=wid)
                    accepted.append(wid)
            time.sleep(0.002)

    acceptor = threading.Thread(target=accept_all, daemon=True)
    acceptor.start()
    stats0 = {}
    t0 = threading.Thread(
        target=lambda: stats0.update(
            run_worker(svc, shard=0, lease_items=1, poll_s=0.005,
                       transport=InProcTransport())),
        daemon=True)
    t0.start()
    deadline = time.monotonic() + 300.0
    while not accepted and time.monotonic() < deadline:
        time.sleep(0.005)
    assert accepted, "shard0 made no progress"
    svc.drain("shard0")
    hold.set()
    t0.join(120.0)
    assert not t0.is_alive(), "a drained worker must exit"
    assert svc.workers["shard0"].state == "departed"    # left through bye
    assert not q.finished                  # it left work behind
    stats1 = run_worker(svc, shard=1, lease_items=1, poll_s=0.005,
                        transport=InProcTransport())
    acceptor.join(60.0)
    assert q.finished
    assert sorted(accepted) == list(range(n))
    assert 0 < stats0["chunks"] < n        # drained out mid-run
    assert stats1["chunks"] >= 1           # the joiner carried the rest
    assert svc.workers["shard1"].state == "departed"
    assert q.redeliveries == 0             # graceful exits reap nothing


# ---------------------------------------------------- pool autoscaling

def test_worker_pool_autoscale_inproc():
    """Sustained backlog scales the pool up toward max_workers; a
    sustained fully-idle pool drains back toward min_workers. Results
    stay exactly-once and bit-identical to two_phase throughout."""
    from repro.serve import WorkerPool

    make = audio_batch_maker(seed=13, batch_long_chunks=1)
    batches = [make(w)[0] for w in range(6)]
    pool = WorkerPool(cfg, workers=1, transport="inproc", poll_s=0.005,
                      min_workers=1, max_workers=3,
                      autoscale_backlog_s=0.05, autoscale_idle_s=0.1).start()
    try:
        wids = [pool.submit(b) for b in batches]
        got = pool.wait(wids, timeout_s=300.0)
        assert sorted(got) == sorted(wids)
        assert pool.scale_ups >= 1, "sustained backlog never scaled up"
        assert len(pool._live_active()) <= 3
        deadline = time.monotonic() + 120.0
        while len(pool._live_active()) > 1 and time.monotonic() < deadline:
            pool.poll()                    # each pump runs the autoscaler
            time.sleep(0.01)
        assert pool.scale_downs >= 1, "idle pool never drained down"
        assert len(pool._live_active()) == 1
        g = pool.gauges()
        assert g["epoch"] >= 1 and g["scale_ups"] == pool.scale_ups
        ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
        for wid, b in zip(wids, batches):
            want = ref(b)
            np.testing.assert_array_equal(np.asarray(got[wid].det.keep),
                                          np.asarray(want.det.keep))
            np.testing.assert_array_equal(got[wid].cleaned, want.cleaned)
    finally:
        pool.shutdown(drain=False)
