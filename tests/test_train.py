"""Optimizer / train-step / compression behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.distributed.sharding import NULL_RULES as R
from repro.models.zoo import build_model
from repro.train import compression as C
from repro.train import optimizer as O
from repro.train.train_step import make_train_step, init_train_state


def _quadratic_run(opt_cfg, steps=150, compress=False):
    """Minimize ||Wx - y||^2 over W with the full train machinery stubbed to
    a quadratic: checks optimization plumbing end to end."""
    rng = np.random.RandomState(0)
    Wtrue = jnp.asarray(rng.randn(8, 8).astype(np.float32))
    X = jnp.asarray(rng.randn(64, 8).astype(np.float32))
    Y = X @ Wtrue.T
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    state = O.init_opt_state(opt_cfg, params)
    residual = C.init_residuals(params) if compress else None

    @jax.jit
    def step(params, state, residual):
        def loss_fn(p):
            return jnp.mean((X @ p["w"].T - Y) ** 2)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        if residual is not None:
            grads, residual = C.compress_grads_ef(grads, residual)
        params, state, _ = O.apply_updates(opt_cfg, params, state, grads)
        return params, state, residual, loss

    losses = []
    for _ in range(steps):
        params, state, residual, loss = step(params, state, residual)
        losses.append(float(loss))
    return losses


def test_adamw_converges_on_quadratic():
    cfg = O.OptConfig(lr=0.1, warmup_steps=5, decay_steps=150,
                      weight_decay=0.0)
    losses = _quadratic_run(cfg)
    assert losses[-1] < 0.02 * losses[0]


def test_quantized_state_tracks_f32():
    base = O.OptConfig(lr=0.1, warmup_steps=5, decay_steps=150,
                       weight_decay=0.0)
    l32 = _quadratic_run(base)
    l8 = _quadratic_run(dataclasses.replace(base, quantize_state=True))
    assert l8[-1] < 0.05 * l8[0]
    assert abs(l8[-1] - l32[-1]) < 0.1 * max(l32[0], 1e-9)


def test_compressed_grads_with_error_feedback_converge():
    cfg = O.OptConfig(lr=0.1, warmup_steps=5, decay_steps=150,
                      weight_decay=0.0)
    lc = _quadratic_run(cfg, compress=True)
    assert lc[-1] < 0.05 * lc[0]


def test_grad_clip():
    grads = {"a": jnp.full((4,), 100.0)}
    clipped, gn = O.clip_by_global_norm(grads, 1.0)
    assert float(gn) > 100
    np.testing.assert_allclose(float(O.global_norm(clipped)), 1.0, rtol=1e-5)


def test_schedule_warmup_and_decay():
    cfg = O.OptConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(O.schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100, 1000]]
    assert lrs[0] == 0.0 and lrs[1] == 0.5
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6 and abs(lrs[5] - 0.1) < 1e-6


def test_microbatch_equivalence():
    """Grad accumulation over microbatches == full-batch gradients."""
    cfg = dataclasses.replace(reduced(ARCHS["llama3.2-3b"]), dtype="float32")
    model = build_model(cfg)
    opt_cfg = O.OptConfig(lr=1e-3)
    params, state = init_train_state(model, opt_cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    outs = {}
    for mb in (1, 4):
        step = jax.jit(make_train_step(model, R, opt_cfg,
                                       num_microbatches=mb))
        p, s, metrics = step(params, state, batch)
        outs[mb] = (p, float(metrics["loss"]))
    assert abs(outs[1][1] - outs[4][1]) < 1e-4
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        outs[1][0], outs[4][0])
    assert max(jax.tree.leaves(d)) < 5e-3


def test_train_step_moe_runs():
    cfg = reduced(ARCHS["granite-moe-3b-a800m"])
    model = build_model(cfg)
    opt_cfg = O.OptConfig(lr=1e-3, quantize_state=True)
    params, state = init_train_state(model, opt_cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                cfg.vocab_size)
    step = jax.jit(make_train_step(model, R, opt_cfg))
    p, s, metrics = step(params, state,
                         {"tokens": tokens, "targets": tokens})
    assert np.isfinite(float(metrics["loss"]))
    assert int(s["step"]) == 1
