"""Sharding rules + multi-device collective tests.

Divisibility validation runs in-process (pure math over all 40 cells).
Actual multi-device lowerings (collective matmul, sharded pipeline) run in
SUBPROCESSES with --xla_force_host_platform_device_count, because tests in
this process must keep seeing 1 CPU device (per the brief)."""
import subprocess
import sys
import textwrap

import pytest

from repro.configs import ARCHS, SHAPES, cell_is_runnable
from repro.distributed.sharding import ShardingRules, _TABLES


def test_rules_resolution_no_mesh():
    r = ShardingRules(mesh=None)
    assert r.constrain(1.0, "batch") == 1.0
    assert r.sharding("batch") is None


def test_rules_tables_complete():
    for mode, table in _TABLES.items():
        for name, axes in table.items():
            assert isinstance(axes, tuple), (mode, name)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_all_cells_shard_evenly(arch):
    """Static divisibility audit for every (arch x shape) cell on the 16x16
    and 2x16x16 meshes — catches sharding mismatch before any compile."""
    cfg = ARCHS[arch]
    for tp in (16,):
        assert cfg.q_dim % tp == 0, "q_dim"
        assert cfg.kv_dim % tp == 0, "kv_dim"
        if cfg.d_ff:
            assert cfg.d_ff % tp == 0, "d_ff"
        assert cfg.padded_vocab % tp == 0, "vocab"
        assert cfg.d_model % 32 == 0, "fsdp d_model over pod*data"
    for shape in SHAPES.values():
        ok, _ = cell_is_runnable(cfg, shape)
        if not ok:
            continue
        if shape.kind in ("train", "prefill"):
            assert shape.global_batch % 32 == 0 or shape.global_batch % 16 == 0
        elif shape.global_batch > 1:
            assert shape.global_batch % 32 == 0
            assert shape.seq_len % 16 == 0      # kv_seq over model
        else:
            assert shape.seq_len % 256 == 0     # kv_seq over data x model


_SUBPROCESS_TEMPLATE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.mesh import _make_mesh
{body}
print("SUBPROC_OK")
"""


def _run_subprocess(body):
    code = _SUBPROCESS_TEMPLATE.format(body=textwrap.dedent(body))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SUBPROC_OK" in out.stdout


def test_collective_matmul_matches_einsum():
    _run_subprocess("""
    from repro.distributed.collective_matmul import ag_matmul
    mesh = _make_mesh((4,), ("model",))
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(16, 8).astype(np.float32))
    w = jnp.asarray(rng.randn(8, 12).astype(np.float32))
    got = jax.jit(lambda a, b: ag_matmul(a, b, mesh))(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)
    """)


def test_pipeline_sharded_matches_single_device():
    """The audio pipeline gives identical masks under 4-way data
    parallelism (the paper's distribution-invariance requirement)."""
    _run_subprocess("""
    from repro.configs import SERF_AUDIO as cfg
    from repro.core.plans import Preprocessor
    from repro.data.synthetic import generate_labelled
    from repro.distributed.sharding import ShardingRules
    audio, labels = generate_labelled(3, 4*12, segment_s=5.0)
    S5 = audio.shape[-1]
    chunks = (audio.reshape(4, 12, 2, S5).transpose(0, 2, 1, 3)
              .reshape(4, 2, 12*S5))
    mesh = _make_mesh((4, 1), ("data", "model"))
    rules = ShardingRules(mesh)
    x = jax.device_put(jnp.asarray(chunks),
                       NamedSharding(mesh, P("data", None, None)))
    with mesh:
        det_sh = Preprocessor(cfg, rules).detect(x)
    det_1 = Preprocessor(cfg).detect(jnp.asarray(chunks))
    np.testing.assert_array_equal(np.asarray(det_sh.keep),
                                  np.asarray(det_1.keep))
    np.testing.assert_allclose(np.asarray(det_sh.wave5),
                               np.asarray(det_1.wave5), atol=2e-4)
    """)


def test_train_step_sharded_matches_single_device():
    """One TP+DP train step == single-device step (tiny f32 model)."""
    _run_subprocess("""
    import dataclasses
    from repro.configs import ARCHS, reduced
    from repro.models.zoo import build_model
    from repro.distributed.sharding import ShardingRules, tree_shardings
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import (make_train_step, init_train_state,
                                        train_state_specs)
    cfg = dataclasses.replace(reduced(ARCHS["llama3.2-3b"]), dtype="float32")
    model = build_model(cfg)
    opt = OptConfig(lr=1e-2)
    params, state = init_train_state(model, opt, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    from repro.distributed.sharding import NULL_RULES
    p1, s1, m1 = jax.jit(make_train_step(model, NULL_RULES, opt))(
        params, state, batch)
    mesh = _make_mesh((2, 2), ("data", "model"))
    rules = ShardingRules(mesh)
    pspecs, ospecs = train_state_specs(model, opt)
    p_sh = tree_shardings(rules, pspecs)
    o_sh = tree_shardings(rules, ospecs)
    with mesh:
        step = jax.jit(make_train_step(model, rules, opt),
                       in_shardings=(p_sh, o_sh, None),
                       out_shardings=(p_sh, o_sh, None))
        p2, s2, m2 = step(params, state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, p2)))
    assert d < 1e-3, d
    """)
