"""The matmul-DFT twin (dry-run/TPU path) must match the FFT oracle, and the
pipeline must produce identical detector decisions under it."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels.stft_dft.ref as R
from repro.kernels import backend
from repro.kernels.stft_dft import ops as O


def test_stft_matmul_matches_fft():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 40_000).astype(np.float32))
    xp = O.pad_for_stft(x)
    prev = R.MATMUL_DTYPE
    try:
        R.MATMUL_DTYPE = jnp.float32
        zm = R.stft_matmul(xp)
    finally:
        R.MATMUL_DTYPE = prev
    zr = R.stft_ref(xp)
    err = float(jnp.max(jnp.abs(zm - zr))) / float(jnp.max(jnp.abs(zr)))
    assert err < 1e-4, err


def test_istft_matmul_roundtrip():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(1, 33_000).astype(np.float32))
    xp = O.pad_for_stft(x)
    z = R.stft_ref(xp)
    prev = R.MATMUL_DTYPE
    try:
        R.MATMUL_DTYPE = jnp.float32
        xr = R.istft_matmul(z, xp.shape[1])
    finally:
        R.MATMUL_DTYPE = prev
    cov = R.num_frames(xp.shape[1], 256, 128) * 128 + 128
    np.testing.assert_allclose(np.asarray(xr[:, :cov]),
                               np.asarray(xp[:, :cov]), atol=2e-4)


def test_pipeline_masks_identical_under_matmul_backend():
    """The dry-run path (matmul mode, bf16 streams) must reach the same
    keep/remove decisions as the CPU fft path. The compile cache keys on
    the backend mode, so the two runs really are separate traces."""
    from repro.configs import SERF_AUDIO as cfg
    from repro.core.plans import Preprocessor
    from repro.data.synthetic import generate_labelled
    audio, _ = generate_labelled(4, 4 * 12, segment_s=5.0)
    S5 = audio.shape[-1]
    chunks = jnp.asarray(audio.reshape(4, 12, 2, S5).transpose(0, 2, 1, 3)
                         .reshape(4, 2, 12 * S5))
    pre = Preprocessor(cfg)
    det_fft = pre.detect(chunks)
    with backend.use("matmul"):
        det_mm = pre.detect(chunks)
    np.testing.assert_array_equal(np.asarray(det_fft.keep),
                                  np.asarray(det_mm.keep))
    np.testing.assert_array_equal(np.asarray(det_fft.rain),
                                  np.asarray(det_mm.rain))
    np.testing.assert_array_equal(np.asarray(det_fft.cicada15),
                                  np.asarray(det_mm.cicada15))
