"""The persistence subsystem: content-addressed ChunkStore (atomic writes,
crc-verified reads, hit/miss stats), RunJournal queue snapshots, and
CachedPlan — including the acceptance criteria: masks bit-identical to an
uncached ShardedPlan over a 50%-prestored stream, and exactly-once emission
across a kill + resume."""
import glob
import os

import numpy as np
import pytest

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import CachedPlan, PLANS, Preprocessor
from repro.data.loader import audio_batch_maker, make_shard_pool
from repro.data.queue import SettableClock, WorkQueue
from repro.distributed.sharding import pool_rules
from repro.kernels import backend
from repro.store import ChunkStore, RunJournal, content_key


def _stream(seed, wids, batch_long_chunks=1):
    make = audio_batch_maker(seed=seed, batch_long_chunks=batch_long_chunks)
    return [(w, make(w)) for w in wids]


@pytest.fixture(scope="module")
def stream4():
    return _stream(21, range(4))


# -------------------------------------------------------------- content key

def test_content_key_sensitivity():
    x = np.ones((1, 2, 64), np.float32)
    fp = ("cfg", ("a", "b"), "geom")
    k = content_key(x, fp, "auto")
    assert k == content_key(x.copy(), fp, "auto")      # value identity
    assert k != content_key(x + 1e-6, fp, "auto")      # bytes matter
    assert k != content_key(x, ("cfg", ("a",), "geom"), "auto")  # graph
    assert k != content_key(x, fp, "ref")              # backend mode
    assert len(k) == 64                                # sha256 hex


# -------------------------------------------------------------- chunk store

def test_store_roundtrip_and_stats(tmp_path):
    store = ChunkStore(tmp_path)
    arrays = {"cleaned": np.arange(12, dtype=np.float32).reshape(3, 4),
              "keep": np.array([True, False, True])}
    assert store.put("k1", arrays, meta={"n_kept": 2}) is True
    assert "k1" in store and len(store) == 1 and store.keys() == ["k1"]
    got, meta = store.get("k1", src_bytes=100)
    assert meta["n_kept"] == 2
    np.testing.assert_array_equal(got["cleaned"], arrays["cleaned"])
    np.testing.assert_array_equal(got["keep"], arrays["keep"])
    assert got["keep"].dtype == np.bool_
    assert store.get("nope") is None
    st = store.stats
    assert (st.hits, st.misses, st.writes) == (1, 1, 1)
    assert st.bytes_saved == 100 and st.bytes_written > 0
    assert st.hit_rate == 0.5
    # entries are immutable: a second put of the same key writes nothing
    assert store.put("k1", arrays) is False
    assert st.dup_writes == 1


def test_store_writes_are_atomic_no_tmp_residue(tmp_path):
    store = ChunkStore(tmp_path)
    store.put("deadbeef", {"a": np.zeros(4)})
    assert glob.glob(os.path.join(str(tmp_path), "objects", "*.tmp-*")) == []
    # the entry mirrors the ckpt layout: manifest.json + one .npy per leaf
    entry = os.path.join(str(tmp_path), "objects", "deadbeef")
    assert sorted(os.listdir(entry)) == ["a.npy", "manifest.json"]
    # a crashed writer's tmp dir (manifest already written, rename never
    # happened) is not an entry
    ghost = os.path.join(str(tmp_path), "objects", "feedface.tmp-xyz")
    os.makedirs(ghost)
    open(os.path.join(ghost, "manifest.json"), "w").write("{}")
    assert store.keys() == ["deadbeef"] and len(store) == 1


def test_store_gc_evicts_least_recently_hit(tmp_path):
    """Retention sweep: gc(max_bytes) drops the coldest entries first —
    'cold' meaning least recently HIT (a read refreshes recency), with
    write order the tie-break — and the survivors stay readable."""
    store = ChunkStore(tmp_path)
    for i in range(4):
        store.put(f"k{i}", {"a": np.full(256, i, np.float32)})
        # deterministic write order (same-ms writes would tie on mtime)
        mpath = os.path.join(str(tmp_path), "objects", f"k{i}",
                             "manifest.json")
        os.utime(mpath, (1_000_000 + i, 1_000_000 + i))
    per = store.entry_bytes("k0")
    assert per > 256 * 4 // 2
    # k0 is the oldest write but gets HIT -> recency beats write order
    assert store.get("k0") is not None
    rep = store.gc(max_bytes=2 * per)
    assert rep["evicted"] == 2 and rep["bytes_freed"] == 2 * per
    assert rep["entries_after"] == 2 and rep["bytes_after"] <= 2 * per
    assert store.keys() == ["k0", "k3"]       # k1, k2 were coldest
    got, _ = store.get("k0")
    np.testing.assert_array_equal(got["a"], 0.0)
    assert store.stats.gc_evicted == 2
    assert store.stats.gc_bytes_freed == 2 * per
    assert "gc_evicted" in store.stats.as_dict()
    # a fitting store is untouched
    assert store.gc(max_bytes=10 * per)["evicted"] == 0


def test_store_crc_corruption_raises_then_evicts(tmp_path):
    arrays = {"x": np.arange(8, dtype=np.float32)}
    strict = ChunkStore(tmp_path)
    strict.put("kk", arrays)
    target = os.path.join(str(tmp_path), "objects", "kk", "x.npy")
    raw = bytearray(open(target, "rb").read())
    raw[-1] ^= 0xFF
    open(target, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="crc"):
        strict.get("kk")
    healing = ChunkStore(tmp_path, evict_corrupt=True)
    assert healing.get("kk") is None               # evicted + miss
    assert healing.stats.corrupt == 1
    assert "kk" not in healing                     # a re-put self-heals
    assert healing.put("kk", arrays) is True
    got, _ = healing.get("kk")
    np.testing.assert_array_equal(got["x"], arrays["x"])


# ------------------------------------------------------------------ journal

def test_run_journal_roundtrip(tmp_path):
    j = RunJournal(tmp_path)
    assert j.load() is None and j.resume_queue() is None
    clock = SettableClock()
    q = WorkQueue(5, lease_timeout_s=10.0, clock=clock)
    q.lease("w", 2)
    q.complete([0])
    j.record(q, meta={"note": "mid-run"})
    meta = j.load()
    assert meta["emitted"] == 1 and meta["note"] == "mid-run"
    assert meta["queue"]["done"] == [0] and meta["queue"]["leased"] == [1]
    q2 = j.resume_queue(n_items=5, clock=SettableClock())
    ids = q2.lease("w2", 10)
    assert sorted(ids) == [1, 2, 3, 4]             # 1 redelivered, 0 never
    with pytest.raises(ValueError, match="refusing to mix"):
        j.resume_queue(n_items=7)
    # a fresh handle on the same directory resumes the step counter
    j2 = RunJournal(tmp_path)
    assert j2.step == j.step
    j2.record(q2)
    assert j2.step == j.step + 1


# -------------------------------------------------------------- cached plan

def test_cached_plan_registered_and_passthrough(stream4):
    assert PLANS["cached"] is CachedPlan
    ref = {r.wid: r for r in
           Preprocessor(cfg, plan="two_phase").run(stream4)}
    pre = Preprocessor(cfg, plan="cached")         # no store: passthrough
    assert pre.plan.stats is None
    got = {r.wid: r for r in pre.run(stream4)}
    assert sorted(got) == sorted(ref)
    for w in ref:
        np.testing.assert_array_equal(np.asarray(got[w].det.keep),
                                      np.asarray(ref[w].det.keep))
        np.testing.assert_allclose(got[w].cleaned, ref[w].cleaned,
                                   rtol=1e-4, atol=1e-5)


def test_cached_sharded_50pct_prestored_bit_identical(tmp_path, stream4):
    """ACCEPTANCE: CachedPlan(inner='sharded') over a stream whose first
    half was previously stored produces survivor masks bit-identical to an
    uncached ShardedPlan run, with hit/miss stats reported."""
    ref = {r.wid: r for r in
           Preprocessor(cfg, plan="sharded", shards=2).run(stream4)}
    seed_pre = Preprocessor(cfg, plan="cached", inner="sharded", shards=2,
                            store=tmp_path)
    list(seed_pre.run(stream4[:2]))                # pre-store 50%
    assert seed_pre.plan.stats.writes == 2

    pre = Preprocessor(cfg, plan="cached", inner="sharded", shards=2,
                       store=tmp_path)
    got = {r.wid: r for r in pre.run(stream4)}
    st = pre.plan.stats
    assert (st.hits, st.misses) == (2, 2) and st.hit_rate == 0.5
    assert st.bytes_saved > 0
    assert sorted(got) == sorted(ref)
    for w in ref:
        np.testing.assert_array_equal(np.asarray(got[w].det.keep),
                                      np.asarray(ref[w].det.keep))
        np.testing.assert_allclose(got[w].cleaned, ref[w].cleaned,
                                   rtol=1e-4, atol=1e-5)
        assert got[w].n_kept == ref[w].n_kept
    # a third, fully-warm run never touches the inner plan
    warm = Preprocessor(cfg, plan="cached", inner="sharded", shards=2,
                        store=tmp_path)
    warm_res = {r.wid: r for r in warm.run(stream4)}
    assert warm.plan.stats.hit_rate == 1.0
    for w in ref:
        np.testing.assert_array_equal(np.asarray(warm_res[w].det.keep),
                                      np.asarray(ref[w].det.keep))


def test_cached_emits_in_stream_order_with_labels(tmp_path):
    stream = [(w, (chunks, f"label{w}"))
              for w, (_, (chunks, _)) in enumerate(_stream(9, range(3)))]
    pre = Preprocessor(cfg, plan="cached", store=tmp_path)
    list(pre.run(stream[:1]))                      # wid 0 pre-stored
    results = list(Preprocessor(cfg, plan="cached", store=tmp_path)
                   .run(stream))
    assert [r.wid for r in results] == [0, 1, 2]   # merged back in order
    assert [r.labels for r in results] == ["label0", "label1", "label2"]


def test_cached_kill_and_resume_exactly_once(tmp_path, stream4):
    """ACCEPTANCE: a journaled run killed mid-stream and relaunched with
    resume=True emits each chunk exactly once across the two processes."""
    store = os.path.join(str(tmp_path), "store")
    pre = Preprocessor(cfg, plan="cached", store=store, journal=True)
    gen = pre.run(stream4)
    first = [next(gen).wid, next(gen).wid]
    gen.close()                                    # 'kill' mid-stream
    assert first == [0, 1]
    # resume=False would re-emit from scratch; resume=True must not
    pre2 = Preprocessor(cfg, plan="cached", store=store, journal=True,
                        resume=True)
    rest = [r.wid for r in pre2.run(stream4)]
    assert sorted(first + rest) == [0, 1, 2, 3]    # exactly once
    # emission is incremental, so the killed run only computed (and stored)
    # what it emitted; the resume pays compute for the tail alone and the
    # store ends up holding the full stream
    assert pre2.plan.stats.misses == 2
    assert len(pre2.plan.store) == 4
    # resuming a FINISHED run emits nothing
    pre3 = Preprocessor(cfg, plan="cached", store=store, journal=True,
                        resume=True)
    assert list(pre3.run(stream4)) == []
    # and a mismatched stream is refused, not silently mixed
    with pytest.raises(ValueError, match="refusing to mix"):
        list(Preprocessor(cfg, plan="cached", store=store, journal=True,
                          resume=True).run(stream4[:3]))
    # ... including a SAME-LENGTH stream with different content: resuming
    # must never silently skip chunks the dead run never saw
    other = _stream(99, range(4))
    with pytest.raises(ValueError, match="different content"):
        list(Preprocessor(cfg, plan="cached", store=store, journal=True,
                          resume=True).run(other))


def test_cached_call_and_warm_cache_serving(tmp_path):
    from repro.serve.preprocess_service import PreprocessService
    make = audio_batch_maker(seed=6, batch_long_chunks=1)
    long_chunk = make(0)[0][0]                     # one (C, S) long chunk
    svc = PreprocessService(cfg, batch_long_chunks=2, plan="cached",
                            store=tmp_path)
    rid = svc.submit(long_chunk)
    svc.pump()
    cold = svc.result(rid)
    assert svc.cache_stats.misses == 1
    rid2 = svc.submit(long_chunk)                  # identical request group
    svc.pump()
    warm = svc.result(rid2)
    assert svc.cache_stats.hits == 1
    np.testing.assert_array_equal(warm["keep"], cold["keep"])
    np.testing.assert_allclose(warm["cleaned"], cold["cleaned"],
                               rtol=1e-6)
    # an uncached service reports no stats
    assert PreprocessService(cfg, plan="two_phase").cache_stats is None


def test_cached_plan_validation(tmp_path):
    with pytest.raises(ValueError, match="only valid with the sharded"):
        Preprocessor(cfg, pool_rules(2), plan="cached", inner="two_phase")
    # pool rules + sharded inner is the supported combination
    pre = Preprocessor(cfg, pool_rules(2), plan="cached", inner="sharded",
                       shards=2)
    assert pre.plan.inner.shards == 2
    with pytest.raises(ValueError, match="resume=True needs a journal"):
        Preprocessor(cfg, plan="cached", store=tmp_path, resume=True)
    with pytest.raises(ValueError, match="journal=True"):
        Preprocessor(cfg, plan="cached", journal=True)
    pool = make_shard_pool(audio_batch_maker(0), 2, 2)
    with pytest.raises(ValueError, match="plain batch stream"):
        list(Preprocessor(cfg, plan="cached",
                          store=tmp_path).run(pool))


def test_cached_plan_self_heals_corrupt_entry(tmp_path, stream4):
    """A bit-rotted store entry behind a path-constructed CachedPlan is
    evicted and recomputed, not fatal on every future run."""
    pre = Preprocessor(cfg, plan="cached", store=tmp_path)
    ref = list(pre.run(stream4[:1]))
    key = pre.plan.store.keys()[0]
    target = os.path.join(str(tmp_path), "objects", key, "cleaned.npy")
    raw = bytearray(open(target, "rb").read())
    raw[-1] ^= 0xFF
    open(target, "wb").write(bytes(raw))
    pre2 = Preprocessor(cfg, plan="cached", store=tmp_path)
    got = list(pre2.run(stream4[:1]))
    assert pre2.plan.stats.corrupt == 1 and pre2.plan.stats.writes == 1
    np.testing.assert_allclose(got[0].cleaned, ref[0].cleaned, rtol=1e-6)
    # the rewritten entry hits again
    pre3 = Preprocessor(cfg, plan="cached", store=tmp_path)
    list(pre3.run(stream4[:1]))
    assert pre3.plan.stats.hits == 1


def test_cached_key_isolation_across_graph_and_backend(tmp_path, stream4):
    """A store shared across configurations can never serve a stale entry:
    the key binds the graph fingerprint and kernel backend mode."""
    import dataclasses
    pre = Preprocessor(cfg, plan="cached", store=tmp_path)
    list(pre.run(stream4[:1]))
    assert pre.plan.stats.writes == 1
    # same bytes, different stage list -> different key -> miss
    cfg2 = dataclasses.replace(cfg, stages=cfg.stages[:-1])
    pre2 = Preprocessor(cfg2, plan="cached", store=tmp_path)
    list(pre2.run(stream4[:1]))
    assert pre2.plan.stats.misses == 1 and pre2.plan.stats.hits == 0
    # same bytes + graph, different backend mode -> miss
    with backend.use("ref"):
        pre3 = Preprocessor(cfg, plan="cached", store=tmp_path)
        list(pre3.run(stream4[:1]))
    assert pre3.plan.stats.misses == 1 and pre3.plan.stats.hits == 0
    # original configuration still hits
    pre4 = Preprocessor(cfg, plan="cached", store=tmp_path)
    list(pre4.run(stream4[:1]))
    assert pre4.plan.stats.hits == 1
