"""The stage-graph API: registry round-trip, build-time geometry
validation, plan equivalence across pad multiples (fused == two_phase ==
streaming on survivors), compile-cache keying, and the serving glue."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SERF_AUDIO as cfg
from repro.core.graph import (GraphValidationError, PipelineGraph, STAGES)
from repro.core.plans import (CompileCache, JIT_CACHE, PLANS, Preprocessor,
                              TwoPhasePlan)
from repro.data.synthetic import generate_labelled
from repro.distributed.sharding import ShardingRules


def _long_chunks(seed, n_long):
    audio, labels = generate_labelled(seed, n_long * 12, segment_s=5.0)
    S5 = audio.shape[-1]
    return (audio.reshape(n_long, 12, 2, S5).transpose(0, 2, 1, 3)
            .reshape(n_long, 2, 12 * S5)), labels


@pytest.fixture(scope="module")
def chunks():
    return _long_chunks(7, 4)[0]


# ----------------------------------------------------------- registry/graph

def test_stage_registry_round_trip():
    """The paper's order is config DATA: every declared stage resolves in
    the registry and the built graph reproduces the declared order."""
    graph = PipelineGraph(cfg)
    assert graph.names == cfg.stages
    assert all(n in STAGES for n in cfg.stages)
    assert [s.name for s in graph.stages] == list(cfg.stages)
    assert graph.has_removal_point
    # ablation by config edit, not driver fork: drop the final MMSE stage
    cfg2 = dataclasses.replace(cfg, stages=cfg.stages[:-1])
    assert PipelineGraph(cfg2).names == cfg.stages[:-1]
    # geometry propagated: 60 s stereo source -> 5 s mono @ 22.05 kHz
    assert graph.out_geom.split_s == cfg.final_split_s
    assert graph.out_geom.rate_hz == cfg.target_rate_hz
    assert graph.out_geom.channels == 1


@pytest.mark.parametrize("bad, match", [
    (("to_mono", "compress", "split_final", "split_detect"),
     "cannot split"),                       # 5 s chunks into 15 s chunks
    (("compress",), "mono"),                # stereo into the FIR
    (("to_mono", "compress", "cicada_bandstop"), "spec"),   # no STFT ran
    (("to_mono", "compress", "compress"), "Hz"),            # double compress
    (("to_mono", "nonexistent_stage"), "unknown stages"),
    (("to_mono", "compress", "split_detect", "stft", "detect_rain",
      "removal_point", "mmse", "detect_silence"), "power"),
    # ^ past a removal point only the waveform survives compaction
])
def test_graph_validation_rejects_bad_orders(bad, match):
    with pytest.raises(GraphValidationError, match=match):
        PipelineGraph(cfg, bad)


def test_detect_flux_drop_in_detector(chunks):
    """Registry extensibility: the spectral-flux energy detector swaps in
    for 'detect_silence' purely via the stage list — no executor changes —
    and keeps transient bird activity while removing silence and steady
    rain."""
    st = list(cfg.stages)
    st[st.index("detect_silence")] = "detect_flux"
    graph = PipelineGraph(cfg, tuple(st))
    assert graph.has_removal_point and "detect_flux" in graph.names
    chunks4, labels = _long_chunks(13, 4)
    pre = Preprocessor(cfg, plan="two_phase", stages=tuple(st))
    res = pre(jnp.asarray(chunks4))
    keep = np.asarray(res.det.keep)
    assert res.cleaned.shape[0] == keep.sum() == res.n_kept
    # flux keeps active chunks (bird=0, cicada=2), removes silence + rain
    active = np.isin(labels, (0, 2))
    assert (keep == active).mean() >= 0.9
    # flux also runs stacked WITH the SNR detector (masks OR together)
    both = tuple(cfg.stages[:-2] + ("detect_flux",) + cfg.stages[-2:])
    res2 = Preprocessor(cfg, plan="two_phase", stages=both)(
        jnp.asarray(chunks4))
    assert not (np.asarray(res2.det.keep) & ~keep).any()
    # and validation still guards it: flux needs power spectra upstream
    with pytest.raises(GraphValidationError, match="power"):
        PipelineGraph(cfg, ("to_mono", "compress", "detect_flux"))


def test_two_phase_requires_removal_point():
    graph = PipelineGraph(
        cfg, ("to_mono", "compress", "split_detect", "stft", "detect_rain",
              "cicada_bandstop", "istft", "split_final", "detect_silence",
              "mmse"))
    with pytest.raises(GraphValidationError, match="removal_point"):
        TwoPhasePlan(graph)


# ------------------------------------------------------- plan equivalence

@pytest.mark.parametrize("pad_multiple", [1, 2, 8])
def test_plan_equivalence(chunks, pad_multiple):
    """fused == two_phase == streaming on the survivor set, for every
    phase-B pad multiple (padding must never leak into results)."""
    x = jnp.asarray(chunks)
    ref = Preprocessor(cfg, plan="fused")(x)
    two = Preprocessor(cfg, plan="two_phase", pad_multiple=pad_multiple)(x)
    np.testing.assert_array_equal(np.asarray(two.det.keep),
                                  np.asarray(ref.det.keep))
    np.testing.assert_allclose(two.cleaned, ref.cleaned,
                               rtol=1e-4, atol=1e-5)
    # streaming: same work as a 2-batch stream through run()
    pre_s = Preprocessor(cfg, plan="streaming", pad_multiple=pad_multiple)
    results = list(pre_s.run([(0, (chunks[:2], None)),
                              (1, (chunks[2:], None))]))
    assert [r.wid for r in results] == [0, 1]
    cat = np.concatenate([r.cleaned for r in results])
    np.testing.assert_allclose(cat, ref.cleaned, rtol=1e-4, atol=1e-5)


def test_all_removed_batch():
    """Every plan handles a batch with zero survivors cleanly."""
    chunks, _ = _long_chunks(3, 1)
    all_silent = dataclasses.replace(cfg, silence_snr_threshold=2.0)
    for name in sorted(PLANS):
        pre = Preprocessor(all_silent, plan=name, pad_multiple=4)
        results = list(pre.run([chunks]))
        assert len(results) == 1
        res = results[0]
        assert res.n_kept == 0
        assert res.cleaned.shape == (0, all_silent.final_split_samples)
        assert not np.asarray(res.det.keep).any()


# ------------------------------------------------------------ compile cache

def test_sharding_rules_fingerprint_is_stable():
    """The old cache keyed on id(rules): two logically-equal rules objects
    got separate entries and a GC'd id could alias. Fingerprints compare by
    value."""
    a, b = ShardingRules(None), ShardingRules(None)
    assert a is not b and a.fingerprint == b.fingerprint
    c = ShardingRules(None, overrides={"chunks": ("data",)})
    assert c.fingerprint != a.fingerprint


def test_compile_cache_shared_across_equal_rules(chunks):
    JIT_CACHE.clear()
    x = jnp.asarray(chunks[:1])
    det1 = Preprocessor(cfg, ShardingRules(None)).detect(x)
    n_after_first = len(JIT_CACHE)
    det2 = Preprocessor(cfg, ShardingRules(None)).detect(x)
    assert len(JIT_CACHE) == n_after_first == 1    # one shared compile
    np.testing.assert_array_equal(np.asarray(det1.keep),
                                  np.asarray(det2.keep))


def test_compile_cache_evicts_at_cap():
    cache = CompileCache(maxsize=3)
    for i in range(10):
        cache.get(("k", i), lambda i=i: i)
    assert len(cache) == 3
    assert ("k", 9) in cache and ("k", 0) not in cache
    # LRU: touching an old-but-live key keeps it resident
    cache.get(("k", 7), lambda: "rebuilt")
    cache.get(("k", 99), lambda: 99)
    assert ("k", 7) in cache


# ------------------------------------------------------------- serve glue

def test_preprocess_service_round_trip(chunks):
    from repro.serve.preprocess_service import PreprocessService
    svc = PreprocessService(cfg, batch_long_chunks=2, plan="two_phase")
    rids = [svc.submit(chunks[i]) for i in range(3)]
    served = []
    while len(served) < len(rids):
        served.extend(svc.pump())
    # cross-check against a direct facade run on the same stacked batch
    det = Preprocessor(cfg).detect(jnp.asarray(chunks[:3]))
    keep = np.asarray(det.keep)
    for j, rid in enumerate(rids):
        r = svc.result(rid)
        assert r is not None
        np.testing.assert_array_equal(r["keep"], keep[j * 12:(j + 1) * 12])
        assert r["cleaned"].shape[0] == int(r["keep"].sum())
        assert np.isfinite(r["cleaned"]).all()
