"""Checkpoint roundtrip/corruption/async + fault-tolerance primitives."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.data.queue import SettableClock as FakeClock
from repro.data.queue import WorkQueue
from repro.ft.failure import (HeartbeatMonitor, StragglerDetector, plan_mesh)


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"w": jnp.ones((5,), jnp.bfloat16),
                  "codes": (jnp.arange(6, dtype=jnp.int8),)},
            "step": jnp.asarray(7, jnp.int32)}


def test_ckpt_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 3, tree, meta={"cursor": 42})
    restored, meta = ckpt.restore(tmp_path, 3, like=tree)
    assert meta["cursor"] == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_ckpt_async_and_latest_and_prune(tmp_path):
    tree = _tree()
    h = ckpt.save(tmp_path, 1, tree, async_save=True)
    h.wait()
    ckpt.save(tmp_path, 5, tree)
    ckpt.save(tmp_path, 9, tree)
    assert ckpt.latest_step(tmp_path) == 9
    ckpt.prune_old(tmp_path, keep=2)
    assert ckpt.latest_step(tmp_path) == 9
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tmp_path, 1, like=tree)


def test_ckpt_corruption_detected(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 2, tree)
    target = os.path.join(tmp_path, "step_2", "a.npy")
    raw = bytearray(open(target, "rb").read())
    raw[-1] ^= 0xFF
    open(target, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="crc"):
        ckpt.restore(tmp_path, 2, like=tree)


def test_ckpt_restore_structure_mismatch(tmp_path):
    ckpt.save(tmp_path, 1, {"x": jnp.ones(3)})
    with pytest.raises(KeyError):
        ckpt.restore(tmp_path, 1, like={"y": jnp.ones(3)})


# ------------------------------------------------------------------ queue/ft

def test_work_queue_lease_complete_expire():
    clock = FakeClock()
    q = WorkQueue(10, lease_timeout_s=5.0, clock=clock)
    ids = q.lease("w1", max_items=3)
    assert ids == [0, 1, 2]
    q.complete([0, 1])
    clock.t = 10.0                     # lease on 2 expires -> redelivered
    ids2 = q.lease("w2", max_items=10)
    assert 2 in ids2
    assert q.redeliveries == 1
    q.complete(ids2)
    assert q.finished


def test_work_queue_fail_worker_and_resume():
    clock = FakeClock()
    q = WorkQueue(6, clock=clock)
    q.lease("w1", 2)
    q.lease("w2", 2)
    q.complete([2, 3])
    back = q.fail_worker("w1")
    assert sorted(back) == [0, 1]
    state = q.state()
    q2 = WorkQueue.from_state(state, clock=clock)
    remaining = []
    while True:
        got = q2.lease("w3", 2)
        if not got:
            break
        remaining.extend(got)
    assert sorted(remaining) == [0, 1, 4, 5]   # done items never re-issued


def test_work_queue_late_complete_not_redelivered():
    """Regression: a lease that expires and is reaped (back into pending)
    and is THEN completed late by its original worker must never be
    re-delivered by a later lease() — the stale pending copy is dropped."""
    clock = FakeClock()
    q = WorkQueue(2, lease_timeout_s=5.0, clock=clock)
    assert q.lease("w1", 1) == [0]
    clock.t = 10.0                     # w1's lease expires
    q.state()                          # a checkpoint tick reaps it: 0 is
    assert q.redeliveries == 1         # back in pending...
    assert q.complete([0]) == [0]      # ...then w1 finishes late
    assert q.lease("w2", 2) == [1]     # 0 must NOT come back
    assert q.complete([1]) == [1]
    assert q.complete([1]) == []       # completion is exactly-once
    assert q.finished


def test_work_queue_state_roundtrip_with_outstanding_leases():
    """Serialize/restore under a SettableClock with leases still live at
    snapshot time: leased ids are recorded in the snapshot and re-enter
    pending on restore (their holder died with the process) — never lost,
    and done ids never re-issued."""
    clock = FakeClock()
    q = WorkQueue(8, lease_timeout_s=30.0, clock=clock)
    assert q.lease("w1", 3) == [0, 1, 2]
    q.complete([0])
    assert q.lease("w2", 2) == [3, 4]
    q.complete([3])
    state = q.state()                       # leases on 1, 2, 4 still live
    assert state["done"] == [0, 3]
    assert state["leased"] == [1, 2, 4]
    q2 = WorkQueue.from_state(state, lease_timeout_s=30.0,
                              clock=FakeClock())
    got = []
    while True:
        ids = q2.lease("w3", 3)
        if not ids:
            break
        got.extend(ids)
    assert sorted(got) == [1, 2, 4, 5, 6, 7]   # leased ids redelivered once
    q2.complete(got)
    assert q2.finished


def test_work_queue_state_reaps_expired_before_snapshot():
    """A lease already past its deadline at snapshot time is reaped INTO
    pending, not recorded as leased — the snapshot never resurrects a
    lease the queue itself considers dead."""
    clock = FakeClock()
    q = WorkQueue(3, lease_timeout_s=5.0, clock=clock)
    q.lease("w1", 1)
    clock.t = 6.0                          # w1's lease expired
    q.lease("w2", 1)                       # reaps 0, leases it to w2... or 1
    state = q.state()
    assert state["done"] == []
    assert len(state["leased"]) == 1
    assert q.redeliveries == 1
    q2 = WorkQueue.from_state(state, clock=FakeClock())
    remaining = q2.lease("w3", 10)
    assert sorted(remaining) == [0, 1, 2]


def test_crash_injector_fuse_and_revive():
    from repro.ft.failure import CrashInjector
    inj = CrashInjector()
    inj.kill(0, after_items=2)
    assert inj.on_pull(0) and inj.on_pull(0)     # two items pass
    assert not inj.on_pull(0)                    # dies holding the third
    assert not inj.alive(0) and inj.crashed == frozenset({0})
    assert inj.alive(1) and inj.on_pull(1)       # other shards unaffected
    inj.revive(0)
    assert inj.alive(0) and inj.on_pull(0)


def test_heartbeat_monitor():
    clock = FakeClock()
    hb = HeartbeatMonitor(timeout_s=3.0, clock=clock)
    hb.beat("a")
    hb.beat("b")
    clock.t = 2.0
    hb.beat("a")
    clock.t = 4.0
    assert hb.dead() == {"b"}
    assert hb.alive() == {"a"}


def test_heartbeat_monitor_forget():
    """A drained/departed worker stops heartbeating BY DESIGN: forget()
    must drop it from tracking so it does not sit in dead() forever (and
    trigger repeated fail_worker calls from every idle master tick)."""
    clock = FakeClock()
    hb = HeartbeatMonitor(timeout_s=3.0, clock=clock)
    hb.beat("a")
    hb.beat("b")
    clock.t = 10.0
    assert hb.dead() == {"a", "b"}
    hb.forget("b")
    assert hb.dead() == {"a"}
    assert hb.alive() == set()
    hb.forget("ghost")                  # unknown worker: a quiet no-op
    assert hb.dead() == {"a"}
    hb.beat("b")                        # a rejoin starts tracking afresh
    assert hb.alive() == {"b"}


def test_work_queue_fail_worker_without_leases_keeps_ledger_clean():
    """Regression: failing a worker that holds NOTHING used to plant a
    phantom zero-count entry in `redelivered_from` (Counter += 0), so
    per-worker reports charged redeliveries to workers that never lost
    a lease. Only workers whose leases actually came back may appear."""
    q = WorkQueue(4, clock=FakeClock())
    assert q.fail_worker("idle") == []
    assert "idle" not in q.redelivered_from
    q.lease("w1", 2)
    assert sorted(q.fail_worker("w1")) == [0, 1]
    assert q.redelivered_from == {"w1": 2}
    assert q.fail_worker("w1") == []     # second fail: nothing held now
    assert q.redelivered_from == {"w1": 2}
    assert q.redeliveries == 2


def test_straggler_detector():
    clock = FakeClock()
    sd = StragglerDetector(factor=2.0, min_history=5, clock=clock)
    for i in range(10):
        sd.start(i)
        clock.t += 1.0
        sd.complete(i)
    sd.start("slow")
    clock.t += 5.0                      # > 2 x p95(=1.0)
    assert sd.stragglers() == ["slow"]


def test_plan_mesh_elastic():
    assert plan_mesh(512).shape == (2, 16, 16)
    assert plan_mesh(256).shape == (16, 16)
    p = plan_mesh(100)
    assert p.shape == (6, 16) and "spare" in p.reason
    assert plan_mesh(8).shape == (1, 8)


def test_train_driver_checkpoint_restart(tmp_path):
    """Integration: kill/restart resumes step count and data cursor."""
    from repro.launch.train import main as train_main
    d = str(tmp_path / "ck")
    train_main(["--arch", "xlstm-125m", "--reduced", "--steps", "6",
                "--batch", "2", "--seq", "32", "--ckpt-dir", d,
                "--ckpt-every", "3", "--log-every", "100"])
    assert ckpt.latest_step(d) == 6
    final = train_main(["--arch", "xlstm-125m", "--reduced", "--steps", "9",
                        "--batch", "2", "--seq", "32", "--ckpt-dir", d,
                        "--resume", "--log-every", "100"])
    assert final == 9
