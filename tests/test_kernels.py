"""Per-kernel allclose sweeps: interpret-mode pallas_call vs the pure-jnp
ref.py oracle, across shapes and parameter settings (per the brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import backend
from repro.kernels.stft_dft import kernel as SK, ref as SR, ops as SO
from repro.kernels.mmse_stsa import kernel as MK, ref as MR, ops as MO
from repro.kernels.fir_hpf import kernel as FK, ref as FR, ops as FO


# ------------------------------------------------------------------- STFT
@pytest.mark.parametrize("B,n_tiles", [(1, 1), (2, 2), (3, 1)])
def test_stft_kernel_vs_fft_oracle(B, n_tiles):
    rng = np.random.RandomState(B * 7 + n_tiles)
    S = n_tiles * SK.FRAME_TILE * 128 + 128
    x = jnp.asarray(rng.randn(B, S).astype(np.float32))
    got = SK.stft_pallas(x, interpret=True)
    bins = 129
    z = jax.lax.complex(got[..., :bins], got[..., bins:2 * bins])
    want = SR.stft_ref(x)
    np.testing.assert_allclose(np.asarray(z), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_stft_pad_and_slice_matches_ref():
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(2, 50_000).astype(np.float32))
    xp = SO.pad_for_stft(x)
    with backend.use("interpret"):
        z = SO.stft(xp)
    np.testing.assert_allclose(np.asarray(z), np.asarray(SR.stft_ref(xp)),
                               rtol=2e-4, atol=2e-4)


def test_istft_roundtrip():
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(2, 33_000).astype(np.float32))
    xp = SO.pad_for_stft(x)
    z = SR.stft_ref(xp)
    xr = SO.istft(z, xp.shape[1])
    cov = SR.num_frames(xp.shape[1], 256, 128) * 128 + 128
    np.testing.assert_allclose(np.asarray(xr[:, :cov]),
                               np.asarray(xp[:, :cov]), atol=1e-4)


# ------------------------------------------------------------------- MMSE
@pytest.mark.parametrize("B,F,K", [(1, 32, 128), (2, 64, 129), (1, 16, 256)])
def test_mmse_kernel_vs_bessel_oracle(B, F, K):
    rng = np.random.RandomState(B + F + K)
    power = jnp.asarray(rng.exponential(1.0, (B, F, K)).astype(np.float32))
    power = power.at[:, F // 4:F // 2, : K // 3].add(40.0)
    noise = MR.estimate_noise_psd(power, 8)
    with backend.use("interpret"):
        got = MO.mmse_gain(power, noise)
    want = MR.mmse_stsa_gain_ref(power, noise)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=2e-5)


def test_mmse_gain_bounds_and_signal_behaviour():
    rng = np.random.RandomState(11)
    power = jnp.asarray(rng.exponential(1.0, (1, 64, 129)).astype(np.float32))
    power = power.at[:, 32:, 40:50].set(500.0)       # strong tonal signal
    noise = MR.estimate_noise_psd(power, 8)
    g = MR.mmse_stsa_gain_ref(power, noise, gain_floor=0.1)
    g = np.asarray(g)
    assert (g >= 0.1 - 1e-6).all() and (g <= 10.0).all()
    assert g[:, 40:, 40:50].mean() > 0.9      # signal region passed through
    assert g[:, 10:30, 60:].mean() < 0.45     # noise-only region attenuated


def test_bessel_polynomials_match_scipy_jax():
    x = jnp.asarray(np.linspace(0.0, 60.0, 500, dtype=np.float32))
    np.testing.assert_allclose(np.asarray(MK.i0e_poly(x)),
                               np.asarray(jax.scipy.special.i0e(x)),
                               rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(np.asarray(MK.i1e_poly(x)),
                               np.asarray(jax.scipy.special.i1e(x)),
                               rtol=3e-5, atol=6e-6)


# -------------------------------------------------------------------- FIR
@pytest.mark.parametrize("stride,S,taps", [(1, 5000, 129), (2, 10_000, 129),
                                           (2, 8193, 65), (3, 9001, 33)])
def test_fir_kernel_vs_conv_oracle(stride, S, taps):
    rng = np.random.RandomState(stride * S % 97)
    x = jnp.asarray(rng.randn(2, S).astype(np.float32))
    h = FR.bandpass_decimate_taps(1000.0, 11_025.0, 44_100, taps)
    got = FK.fir_pallas(x, h, stride=stride, interpret=True)
    want = FR.fir_ref(x, h, stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_fir_frequency_response():
    t = np.arange(44_100).astype(np.float32) / 44_100
    for f0, passband in [(400.0, False), (4000.0, True), (13_000.0, False)]:
        tone = jnp.asarray(np.sin(2 * np.pi * f0 * t))[None]
        out = np.asarray(FO.bandpass_decimate(tone))
        ratio = np.sqrt((out[:, 1000:] ** 2).mean()) / np.sqrt(0.5)
        assert (ratio > 0.9) == passband, (f0, ratio)


# ------------------------------------------------------------- fused tail
@pytest.mark.parametrize("hpf", [False, True])
@pytest.mark.parametrize("n_tiles", [1, 2])
def test_fused_tail_kernel_vs_composed_oracle(hpf, n_tiles):
    """Interpret-mode fused pass vs the composed per-stage ref oracle —
    the same allclose contract every per-kernel sweep above uses (bitwise
    staged-vs-fused identity per mode lives in test_fused_tail.py)."""
    from repro.configs import SERF_AUDIO as cfg
    from repro.kernels.fused_tail import kernel as FTK, ref as FTR
    rng = np.random.RandomState(10 * n_tiles + hpf)
    S = n_tiles * FK.OUT_TILE // 16 * 128 + 256
    wave = jnp.asarray(rng.randn(5, S).astype(np.float32) * 0.3)
    idx = jnp.asarray([3, 0, 4, 7], jnp.int32)      # one pad slot
    packed = FTK.fused_tail_pallas(wave, idx, cfg, hpf=hpf,
                                   interpret=True)
    got = FTK.finish(packed, S, cfg)
    want = FTR.fused_tail_ref(wave, idx, cfg, hpf=hpf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert not np.asarray(got[3]).any()             # pad row exactly zero
