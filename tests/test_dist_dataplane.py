"""The off-master data plane and the worker registry: shard assignment by
reservation/announce (never argv), the store-backed chunk fetch + result
push path (lease_chunks grants content keys, the socket carries ~70-byte
refs instead of megabyte batches), authkey hygiene (env-only, never argv,
never error text, wrong keys rejected without leaking a handler thread),
and the crash-consistency story: a result pushed to the store but never
acked redelivers exactly once, with first-write-wins dedup."""
import multiprocessing
import threading
import time

import numpy as np
import pytest

from repro.configs import SERF_AUDIO as cfg
from repro.core.plans import Preprocessor
from repro.data.loader import audio_batch_maker, make_shard_pool
from repro.data.queue import WorkQueue
from repro.dist.data_plane import StoreDataPlane, result_key
from repro.dist.service import QueueService
from repro.dist.transport import (InProcTransport, RemoteError,
                                  TcpTransport)
from repro.dist.worker import run_worker
from repro.obs import metrics as obs_metrics


def _plane_bytes(name, plane):
    reg = obs_metrics.get_registry()
    return reg.counter(name, labels=("plane",)).labels(plane=plane).value


# ------------------------------------------------------ worker registry

def test_registry_assigns_reserved_then_sequential():
    """`hello(None, pid, -1)` is an ANNOUNCE: the registry hands back the
    shard reserved for that pid at spawn, or the next free id for a
    walk-up joiner — and explicit legacy identities keep the counter
    ahead so later announces never collide."""
    q = WorkQueue(8, lease_timeout_s=60.0)
    svc = QueueService(q, setup={"pad_multiple": 2})
    svc.reserve(111, 3)
    spec = svc.hello(None, pid=111, shard=-1)
    assert spec["assigned"] == {"worker": "shard3", "shard": 3}
    assert spec["pad_multiple"] == 2          # setup blob rides along
    a = svc.hello(None, pid=222, shard=-1)["assigned"]
    b = svc.hello(None, pid=333, shard=-1)["assigned"]
    assert (a["shard"], b["shard"]) == (4, 5)  # next free, past the pin
    shards = {st.worker: st.shard for st in svc.worker_report()}
    assert shards == {"shard3": 3, "shard4": 4, "shard5": 5}
    svc.hello("shard9", pid=444, shard=9)      # legacy self-asserted name
    c = svc.hello(None, pid=555, shard=-1)["assigned"]
    assert c["shard"] == 10


# ------------------------------------------------- store data plane unit

def test_lease_chunks_grants_keys_and_reoffers_cached(tmp_path):
    """The store-plane lease returns (wid, content key) pairs in ONE
    round-trip; a redelivered lease re-offers the SAME key without
    re-hashing or re-writing the raw entry."""
    chunks = {w: np.full((1, 2, 16), w, np.float32) for w in range(2)}
    q = WorkQueue(2, lease_timeout_s=60.0)
    plane = StoreDataPlane(tmp_path / "dp")
    svc = QueueService(q, fetch_item=lambda wid: chunks[wid],
                       data_plane=plane)
    pairs = svc.lease_chunks("a", 2)
    keys = dict(pairs)
    assert sorted(keys) == [0, 1]
    assert all(k.startswith("raw-") for k in keys.values())
    assert plane.store.stats.writes == 2
    svc.fail_worker("a")                       # both leases reclaim
    pairs2 = svc.lease_chunks("b", 2)
    assert dict(pairs2) == keys                # cached offer, same keys
    assert plane.store.stats.writes == 2       # no re-publish
    assert plane.store.stats.dup_writes == 0   # not even a dup attempt


def test_lease_chunks_retired_item_yields_none_key(tmp_path):
    """A work id whose bytes are gone by offer time (retired mid-race)
    grants a None key the worker skips — never a crash."""
    q = WorkQueue(2, lease_timeout_s=60.0)
    plane = StoreDataPlane(tmp_path / "dp")
    svc = QueueService(
        q, data_plane=plane,
        fetch_item=lambda wid: None if wid == 0
        else np.ones((1, 2, 8), np.float32))
    pairs = svc.lease_chunks("w", 2)
    assert pairs[0] == [0, None]
    assert pairs[1][0] == 1 and pairs[1][1].startswith("raw-")


def test_lease_chunks_requires_data_plane():
    svc = QueueService(WorkQueue(1), fetch_item=lambda wid: None)
    with pytest.raises(RuntimeError, match="store data plane"):
        svc.lease_chunks("w", 1)


def test_fetch_many_is_one_pass_one_heartbeat():
    """The batched socket fetch materializes and accounts every item but
    heartbeats exactly ONCE per round-trip (the per-item loop it replaced
    extended the lease N times and hammered the monitor)."""
    q = WorkQueue(3, lease_timeout_s=60.0)
    svc = QueueService(q, fetch_item=lambda wid: np.full((1, 2, 4), wid,
                                                         np.float32))
    ids = svc.lease("w", 3)
    before = _plane_bytes("dist_fetch_bytes_total", "socket")
    beats = []
    orig = svc.heartbeat
    svc.heartbeat = lambda w: beats.append(w) or orig(w)
    items = svc.fetch_many("w", ids)
    assert beats == ["w"]
    for wid, item in zip(ids, items):
        np.testing.assert_array_equal(
            item, np.full((1, 2, 4), wid, np.float32))
    # every batch's bytes charged to the socket plane
    assert _plane_bytes("dist_fetch_bytes_total", "socket") - before \
        == 3 * items[0].nbytes


def test_store_plane_pushed_but_unacked_redelivers_exactly_once(tmp_path):
    """The crash the store plane must absorb: a worker writes its result
    to the store, then dies BEFORE the push_result ack. The id redelivers
    (same content key, from the offer cache), the second incarnation's
    store write loses first-write-wins, and the master accepts exactly
    once — resolving the FIRST incarnation's bytes."""
    q = WorkQueue(1, lease_timeout_s=60.0)
    plane = StoreDataPlane(tmp_path / "dp")
    svc = QueueService(q, fetch_item=lambda wid: np.ones((1, 2, 8),
                                                         np.float32),
                       data_plane=plane)
    ((wid, key),) = svc.lease_chunks("a", 1)
    plane.push(key, {"ans": np.arange(4, dtype=np.float32), "mark": 1})
    svc.fail_worker("a")                       # died pre-ack: no push_result
    assert q.redeliveries == 1
    pairs2 = svc.lease_chunks("b", 1)
    assert pairs2 == [[wid, key]]              # exactly one redelivery
    ref = plane.push(key, {"ans": np.arange(4, dtype=np.float32),
                           "mark": 2})         # recompute dedups
    assert ref == {"store_key": result_key(key)}
    assert plane.store.stats.dup_writes >= 1
    svc.push_result("b", wid, ref)
    ((_, got_wid, got_ref),) = svc.pop_results()
    assert svc.complete([got_wid]) == [wid]    # accepted exactly once
    assert svc.complete([got_wid]) == []
    full = svc.resolve_result(got_ref)
    assert full["mark"] == 1                   # first write won
    np.testing.assert_array_equal(full["ans"],
                                  np.arange(4, dtype=np.float32))


# ------------------------------------------- worker runtime, store plane

def test_store_plane_inproc_worker_round_trip(tmp_path):
    """The REAL worker loop over the store plane: lease_chunks grants
    keys, chunk bytes and result payloads move through the shared
    ChunkStore, the socket planes carry ZERO payload bytes, and the
    resolved results match two_phase bit-for-bit."""
    n = 2
    make = audio_batch_maker(seed=9, batch_long_chunks=1)
    setup = {"cfg": cfg, "stages": None, "source_channels": 2,
             "pad_multiple": 1, "bucket": "linear", "backend_mode": "auto"}
    q = WorkQueue(n, lease_timeout_s=60.0)
    plane = StoreDataPlane(tmp_path / "dp")
    svc = QueueService(q, fetch_item=lambda wid: make(wid)[0], setup=setup,
                       data_plane=plane)
    names = ("dist_fetch_bytes_total", "dist_push_bytes_total")
    before = {(nm, p): _plane_bytes(nm, p)
              for nm in names for p in ("socket", "store")}
    stats = run_worker(svc, shard=None, lease_items=2,
                       transport=InProcTransport(), max_items=n)
    assert stats["chunks"] == n
    got = {}
    for _, wid, payload in svc.pop_results():
        assert set(payload) == {"store_key"}   # a ref, never the bytes
        assert payload["store_key"].startswith("res-")
        got[wid] = svc.resolve_result(payload)
    assert sorted(got) == list(range(n))
    assert q.complete(sorted(got)) == list(range(n))
    ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    for wid, payload in got.items():
        want = ref(make(wid)[0])
        np.testing.assert_array_equal(payload["keep"],
                                      np.asarray(want.det.keep))
        np.testing.assert_array_equal(payload["cleaned"], want.cleaned)
        assert payload["n_kept"] == want.n_kept
    assert len(plane.store) == 2 * n           # n raw + n result entries
    delta = {k: _plane_bytes(*k) - v for k, v in before.items()}
    raw_bytes = sum(np.ascontiguousarray(make(w)[0]).nbytes
                    for w in range(n))
    assert delta[("dist_fetch_bytes_total", "socket")] == 0
    assert delta[("dist_push_bytes_total", "socket")] == 0
    assert 0 < delta[("dist_fetch_bytes_total", "store")] < raw_bytes * 0.1
    assert 0 < delta[("dist_push_bytes_total", "store")] < raw_bytes * 0.1


# ------------------------------------------------------- authkey hygiene

def test_authkey_env_only_never_argv_never_error_text():
    """Regression: the authkey reaches workers via REPRO_DIST_AUTHKEY
    only — never argv (visible in `ps`), and never the text of a
    RemoteError shipped back over the wire. Spawned argv also carries no
    --shard: identity comes from the registry."""
    tp = TcpTransport()
    svc = QueueService(WorkQueue(1, lease_timeout_s=60.0), setup={})
    addr = tp.serve(svc)
    try:
        key = tp._authkey
        assert key and key not in addr
        h = tp.spawn_worker(shard=0)
        argv = " ".join(map(str, h.proc.args))
        h.kill()                               # argv is all we needed
        assert key not in argv
        assert "--shard" not in argv
        proxy = tp.connect(addr, authkey=key)
        with pytest.raises(RemoteError) as not_served:
            proxy.call("pop_results")          # master-side only
        assert key not in str(not_served.value)
        with pytest.raises(RemoteError) as raised:
            proxy.call("lease_chunks", "w", 1)  # raises: no data plane
        assert "RuntimeError" in str(raised.value)
        assert key not in str(raised.value)
        proxy.close()
        h.proc.wait(10)
    finally:
        tp.close()


def test_wrong_authkey_rejected_no_handler_thread_leak():
    """A wrong-key connect fails the handshake inside Listener.accept():
    the client sees AuthenticationError, the master spawns NO handler
    thread for it, and the listener keeps serving correct-key peers."""
    tp = TcpTransport()
    svc = QueueService(WorkQueue(1, lease_timeout_s=60.0))
    addr = tp.serve(svc)
    try:
        host, _, port = addr.rpartition(":")
        n_before = sum(t.name == "repro-dist-conn"
                       for t in threading.enumerate())
        from multiprocessing.connection import Client
        with pytest.raises(multiprocessing.AuthenticationError):
            Client((host, int(port)), authkey=b"not-the-key")
        time.sleep(0.2)
        n_after = sum(t.name == "repro-dist-conn"
                      for t in threading.enumerate())
        assert n_after <= n_before             # no thread for the intruder
        proxy = tp.connect(addr)               # listener survived
        assert tuple(proxy.call("progress")) == (0, 1)
        proxy.close()
    finally:
        tp.close()


# ------------------------------------- crash recovery over the store plane

def test_store_plane_sigkill_redelivered_exactly_once(tmp_path):
    """Acceptance: a worker SIGKILLed at its first grant, on the TCP
    transport with the store data plane, still yields every chunk exactly
    once, bit-identical to two_phase, with redeliveries >= 1."""
    from repro.ft.failure import CrashInjector

    n_batches = 3
    make = audio_batch_maker(seed=5, batch_long_chunks=1)
    pool = make_shard_pool(make, n_batches, 2, lease_timeout_s=120.0)
    inj = CrashInjector()
    inj.kill(1, after_items=0)                 # shard1 dies at first grant
    pre = Preprocessor(cfg, plan="sharded", shards=2, pad_multiple=1,
                       transport="tcp", injector=inj,
                       data_plane=str(tmp_path / "dp"))
    results = list(pre.run(pool))
    assert sorted(r.wid for r in results) == list(range(n_batches))
    assert pre.plan.redeliveries >= 1
    assert not inj.alive(1)
    ref = Preprocessor(cfg, plan="two_phase", pad_multiple=1)
    for r in results:
        want = ref(make(r.wid)[0])
        np.testing.assert_array_equal(np.asarray(r.det.keep),
                                      np.asarray(want.det.keep))
        np.testing.assert_array_equal(r.cleaned, want.cleaned)
